"""Low-rank decomposition and weight surgery (paper §3.2) — python reference.

The production converter lives in Rust (rust/src/convert/, using the
in-repo Jacobi SVD); this module is the *mathematical reference* used by
pytest to validate the architecture end-to-end, including the exactness
invariant: a full-rank J-LRD conversion of an MHA checkpoint must
reproduce the RoPElite model's forward pass bit-for-nearly-bit.

Weight surgery layout (shared contract with rust/src/convert/elitekv.rs):

* ``wq`` columns are permuted per head: the r elite chunks (in greedy
  selection order) move to the front, non-elite chunks follow in
  ascending index order. Chunk c occupies column pair (2c, 2c+1).
* ``wk_e``  = elite column pairs of ``wk``   [d, nh*2r]
* ``wk_ne`` = non-elite column pairs         [d, nh*(dh-2r)]
* J-LRD:  SVD([wk_ne | wv]) -> A_kv = U[:, :c], B = S[:c, :c] @ Vt[:c, :]
          b_k = B[:, :nh*(dh-2r)], b_v = B[:, nh*(dh-2r):]
* S-LRD:  independent SVDs of wk_ne (rank d_ck) and wv (rank d_cv).
* ``theta_e[l, h, i] = rope_base ** (-e_i / nc)`` for elite chunk e_i.
"""

from typing import Dict, Tuple

import numpy as np

from .configs import ModelConfig, Variant


def head_permutation(elite: np.ndarray, d_head: int) -> np.ndarray:
    """Column permutation for one head: elite chunk dims first (selection
    order), then remaining chunks ascending. elite: [r] chunk ids."""
    nc = d_head // 2
    rest = [c for c in range(nc) if c not in set(elite.tolist())]
    order = list(elite.tolist()) + rest
    cols = []
    for c in order:
        cols += [2 * c, 2 * c + 1]
    return np.asarray(cols, dtype=np.int64)


def permute_heads(w: np.ndarray, elite_l: np.ndarray, n_heads: int,
                  d_head: int) -> np.ndarray:
    """Apply per-head column permutation to a [d, nh*dh] projection."""
    d = w.shape[0]
    out = w.reshape(d, n_heads, d_head).copy()
    for h in range(n_heads):
        out[:, h, :] = out[:, h, head_permutation(elite_l[h], d_head)]
    return out.reshape(d, n_heads * d_head)


def elite_thetas(cfg: ModelConfig, elite: np.ndarray) -> np.ndarray:
    """theta_e [L, nh, r] from elite chunk indices [L, nh, r]."""
    nc = cfg.n_chunks
    return (cfg.rope_base ** (-elite.astype(np.float64) / nc)).astype(
        np.float32)


def elite_mask(cfg: ModelConfig, elite: np.ndarray) -> np.ndarray:
    """{0,1} mask [L, nh, nc] from elite chunk indices [L, nh, r]."""
    m = np.zeros((cfg.n_layers, cfg.n_heads, cfg.n_chunks), np.float32)
    for l in range(cfg.n_layers):
        for h in range(cfg.n_heads):
            m[l, h, elite[l, h]] = 1.0
    return m


def svd_truncate(w: np.ndarray, rank: int) -> Tuple[np.ndarray, np.ndarray]:
    """Optimal rank-r approximation (paper §2.3): A = U, B = S Vt."""
    u, s, vt = np.linalg.svd(w, full_matrices=False)
    a = u[:, :rank]
    b = s[:rank, None] * vt[:rank, :]
    return a.astype(np.float32), b.astype(np.float32)


def convert_elitekv(cfg: ModelConfig, params: Dict[str, np.ndarray],
                    elite: np.ndarray, d_ckv: int) -> Dict[str, np.ndarray]:
    """MHA checkpoint -> elitekv (J-LRD) checkpoint. elite: [L, nh, r]."""
    nh, dh = cfg.n_heads, cfg.d_head
    r = elite.shape[-1]
    r2 = 2 * r
    out: Dict[str, np.ndarray] = {"embed": params["embed"],
                                  "final_norm": params["final_norm"]}
    for l in range(cfg.n_layers):
        p = f"l{l}."
        wq_p = permute_heads(params[p + "wq"], elite[l], nh, dh)
        wk_p = permute_heads(params[p + "wk"], elite[l], nh, dh)
        wk_p = wk_p.reshape(-1, nh, dh)
        wk_e = wk_p[:, :, :r2].reshape(-1, nh * r2)
        wk_ne = wk_p[:, :, r2:].reshape(-1, nh * (dh - r2))
        w_kv = np.concatenate([wk_ne, params[p + "wv"]], axis=1)
        a_kv, b = svd_truncate(w_kv, d_ckv)
        split = nh * (dh - r2)
        out[p + "wq"] = wq_p
        out[p + "wk_e"] = wk_e
        out[p + "a_kv"] = a_kv
        out[p + "b_k"] = b[:, :split]
        out[p + "b_v"] = b[:, split:]
        for suffix in ("attn_norm", "wo", "ffn_norm", "w1", "w2", "w3"):
            out[p + suffix] = params[p + suffix]
    return out


def convert_slrd(cfg: ModelConfig, params: Dict[str, np.ndarray],
                 elite: np.ndarray, d_ck: int,
                 d_cv: int) -> Dict[str, np.ndarray]:
    """MHA checkpoint -> slrd (S-LRD ablation) checkpoint."""
    nh, dh = cfg.n_heads, cfg.d_head
    r = elite.shape[-1]
    r2 = 2 * r
    out: Dict[str, np.ndarray] = {"embed": params["embed"],
                                  "final_norm": params["final_norm"]}
    for l in range(cfg.n_layers):
        p = f"l{l}."
        wq_p = permute_heads(params[p + "wq"], elite[l], nh, dh)
        wk_p = permute_heads(params[p + "wk"], elite[l], nh, dh)
        wk_p = wk_p.reshape(-1, nh, dh)
        wk_e = wk_p[:, :, :r2].reshape(-1, nh * r2)
        wk_ne = wk_p[:, :, r2:].reshape(-1, nh * (dh - r2))
        a_k, b_k = svd_truncate(wk_ne, d_ck)
        a_v, b_v = svd_truncate(params[p + "wv"], d_cv)
        out[p + "wq"] = wq_p
        out[p + "wk_e"] = wk_e
        out[p + "a_k"] = a_k
        out[p + "b_k"] = b_k
        out[p + "a_v"] = a_v
        out[p + "b_v"] = b_v
        for suffix in ("attn_norm", "wo", "ffn_norm", "w1", "w2", "w3"):
            out[p + suffix] = params[p + suffix]
    return out


def convert_gqa(cfg: ModelConfig, params: Dict[str, np.ndarray],
                n_kv_heads: int) -> Dict[str, np.ndarray]:
    """MHA -> GQA by mean-pooling KV head groups (Ainslie et al. 2023)."""
    nh, dh = cfg.n_heads, cfg.d_head
    g = n_kv_heads
    rep = nh // g
    out = dict(params)
    for l in range(cfg.n_layers):
        p = f"l{l}."
        for w in ("wk", "wv"):
            m = params[p + w].reshape(-1, g, rep, dh)
            out[p + w] = m.mean(axis=2).reshape(-1, g * dh)
    return out


def storage_cost(cfg: ModelConfig, var: Variant) -> int:
    """KV-projection parameter count per layer (paper §3.2 formulas)."""
    d, nh, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    if var.kind in ("mha", "ropelite"):
        return 2 * d * nh * dh
    if var.kind == "gqa":
        return 2 * d * var.n_kv_heads * dh
    if var.kind == "elitekv":
        r = var.r
        return 2 * r * nh * d + var.d_ckv * (d + 2 * dh * nh - 2 * r * nh)
    if var.kind == "slrd":
        r = var.r
        return (2 * r * nh * d + var.d_ck * (d + dh * nh - 2 * r * nh)
                + var.d_cv * (d + dh * nh))
    raise ValueError(var.kind)
