"""L1 Pallas kernels: the EliteKV decode hot spot.

``elite_attention_decode`` is the paper's serving-time attention over the
*compressed* cache: per (batch, head) the score row is

    s[n] = q_rot . k_rot[n]^T  +  q_lat . c_kv[n]^T          (absorbed form)

where ``q_rot [2r]`` is the elite-rotated query slice, ``k_rot`` the cached
rotated elite keys, ``q_lat = q_nope @ B_k[h]^T  [d_ckv]`` the absorbed
no-RoPE query, and ``c_kv [S, d_ckv]`` the shared latent cache. The output
is returned *in latent space* (``o_lat = softmax(s) @ c_kv``); the caller
applies ``B_v`` (which in a production deployment is absorbed into W_o).

TPU mapping (DESIGN.md §8): the kernel streams the latent cache HBM→VMEM in
``BLOCK_S``-row tiles with an online (flash) softmax, so the full score row
never materializes and VMEM holds only one tile of ``c_kv``/``k_rot`` plus
the running (m, l, acc) carries. On this CPU image it must run under
``interpret=True`` (real-TPU lowering emits Mosaic custom-calls the CPU
PJRT plugin cannot execute).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_S = 64  # cache-length tile (TPU: 128; 64 keeps interpret tests fast)

_NEG = -1e30


def _decode_kernel(qr_ref, ql_ref, kr_ref, ckv_ref, len_ref, o_ref, *,
                   block_s: int, scale: float):
    """One (batch, head) program: online-softmax attention over the cache.

    qr_ref: [2r], ql_ref: [d_ckv], kr_ref: [S, 2r], ckv_ref: [S, d_ckv],
    len_ref: [1] valid cache length, o_ref: [d_ckv].
    """
    s_total = kr_ref.shape[0]
    d_ckv = ckv_ref.shape[1]
    n_blocks = s_total // block_s

    qr = qr_ref[...].astype(jnp.float32)
    ql = ql_ref[...].astype(jnp.float32)
    length = len_ref[...]  # scalar (BlockSpec squeezed the batch axis)

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        kr = kr_ref[pl.dslice(i * block_s, block_s), :].astype(jnp.float32)
        ckv = ckv_ref[pl.dslice(i * block_s, block_s), :].astype(jnp.float32)
        # Two MXU contractions: rotated-elite + absorbed-latent scores.
        s = (jnp.dot(kr, qr) + jnp.dot(ckv, ql)) * scale  # [block_s]
        idx = i * block_s + jax.lax.iota(jnp.int32, block_s)
        s = jnp.where(idx < length, s, _NEG)
        # Online softmax update (VPU).
        m_new = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # [block_s]
        l_new = l_prev * alpha + jnp.sum(p)
        acc_new = acc_prev * alpha + jnp.dot(p, ckv)  # [d_ckv]
        return m_new, l_new, acc_new

    init = (jnp.float32(_NEG), jnp.float32(0.0),
            jnp.zeros((d_ckv,), jnp.float32))
    _, l_fin, acc = jax.lax.fori_loop(0, n_blocks, body, init)
    o_ref[...] = (acc / l_fin).astype(o_ref.dtype)


def elite_attention_decode(q_rot, q_lat, k_rot, c_kv, lengths, *,
                           scale: float, block_s: int = BLOCK_S,
                           interpret: bool = True):
    """Fused decode attention over the compressed EliteKV cache.

    q_rot:  [B, H, 2r]     elite-rotated query
    q_lat:  [B, H, d_ckv]  absorbed no-RoPE query (q_nope @ B_k[h]^T)
    k_rot:  [B, S, H, 2r]  cached rotated elite keys
    c_kv:   [B, S, d_ckv]  shared latent KV cache
    lengths:[B] int32      valid cache length per sequence
    returns o_lat [B, H, d_ckv] = softmax(s) @ c_kv
    """
    b, h, dr = q_rot.shape
    s_total = k_rot.shape[1]
    d_ckv = c_kv.shape[-1]
    assert s_total % block_s == 0, (s_total, block_s)

    kernel = functools.partial(_decode_kernel, block_s=block_s, scale=scale)
    grid = (b, h)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, dr), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, None, d_ckv), lambda i, j: (i, j, 0)),
            # Full cache rows for this (batch, head); the kernel itself
            # tiles over S with pl.dslice (flash-style streaming).
            pl.BlockSpec((None, s_total, None, dr), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((None, s_total, d_ckv), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((None, None, d_ckv), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d_ckv), q_lat.dtype),
        interpret=interpret,
    )(q_rot, q_lat, k_rot, c_kv, lengths)


def _rope_kernel(x_ref, cos_ref, sin_ref, o_ref):
    """Per-(batch, head) partial-RoPE rotation: x [r, 2] chunk layout."""
    x = x_ref[...].astype(jnp.float32)  # [r, 2]
    cos = cos_ref[...].astype(jnp.float32)  # [r]
    sin = sin_ref[...].astype(jnp.float32)
    x0, x1 = x[:, 0], x[:, 1]
    o = jnp.stack((x0 * cos - x1 * sin, x0 * sin + x1 * cos), axis=-1)
    o_ref[...] = o.astype(o_ref.dtype)


def rope_rotate_elite(x, cos, sin, *, interpret: bool = True):
    """Pallas partial-RoPE for decode-time elite chunks.

    x: [B, H, 2r]; cos/sin: [B, H, r] (angle = pos * theta_e per head).
    """
    b, h, dr = x.shape
    r = dr // 2
    xc = x.reshape(b, h, r, 2)
    out = pl.pallas_call(
        _rope_kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((None, None, r, 2), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, r), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, None, r), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, r, 2), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, r, 2), x.dtype),
        interpret=interpret,
    )(xc, cos, sin)
    return out.reshape(b, h, dr)
