"""Rotary position embedding utilities (chunked, per-head-frequency aware).

RoPE convention: head dim ``d_h`` is split into ``nc = d_h/2`` adjacent 2-D
chunks; chunk ``i`` covers dims ``(2i, 2i+1)`` and carries frequency
``theta_i = base ** (-i / nc)`` (paper §2.2, Su et al. 2024).

Two layouts are needed:

* full / masked RoPE over all chunks with the shared frequency ladder
  (``mha`` and ``ropelite`` variants); the elite mask blends rotated and
  unrotated chunks so the mask can be a *runtime* input.
* per-head *elite* frequencies ``theta_e [n_heads, r]`` for the ``elitekv``
  variant, where conversion permuted each head's elite chunks to the front.
"""

import jax.numpy as jnp


def chunk_thetas(n_chunks: int, base: float) -> jnp.ndarray:
    """Frequency ladder theta_i = base^(-i/nc), shape [nc]."""
    i = jnp.arange(n_chunks, dtype=jnp.float32)
    return base ** (-i / n_chunks)


def rope_cos_sin(positions: jnp.ndarray, thetas: jnp.ndarray):
    """Angles for every (position, frequency) pair.

    positions: [...P] int32/float; thetas: [...F] -> cos/sin [..., P, F]
    broadcasting positions against a trailing frequency axis.
    """
    ang = positions.astype(jnp.float32)[..., None] * thetas[None, ...]
    return jnp.cos(ang), jnp.sin(ang)


def rotate_chunks(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """Rotate chunked input. x: [..., nc, 2]; cos/sin broadcastable [..., nc]."""
    x0, x1 = x[..., 0], x[..., 1]
    return jnp.stack((x0 * cos - x1 * sin, x0 * sin + x1 * cos), axis=-1)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, base: float):
    """Full RoPE. x: [B, T, H, D]; positions: [T] or [B, T] -> same shape."""
    b, t, h, d = x.shape
    nc = d // 2
    thetas = chunk_thetas(nc, base)
    cos, sin = rope_cos_sin(positions, thetas)  # [T, nc] or [B, T, nc]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xc = x.reshape(b, t, h, nc, 2)
    out = rotate_chunks(xc, cos, sin)
    return out.reshape(b, t, h, d)


def apply_rope_masked(x: jnp.ndarray, positions: jnp.ndarray, base: float,
                      mask: jnp.ndarray):
    """RoPElite partial RoPE: rotate only masked chunks (paper §3.1).

    x: [B, T, H, D]; mask: [H, nc] in {0,1} (1 = elite, keep rotation).
    Unmasked chunks are passed through linearly.
    """
    b, t, h, d = x.shape
    nc = d // 2
    rot = apply_rope(x, positions, base).reshape(b, t, h, nc, 2)
    xc = x.reshape(b, t, h, nc, 2)
    m = mask[None, None, :, :, None]
    return (m * rot + (1.0 - m) * xc).reshape(b, t, h, d)


def apply_rope_elite(x: jnp.ndarray, positions: jnp.ndarray,
                     theta_e: jnp.ndarray):
    """Per-head elite-frequency RoPE for the elitekv/slrd layout.

    x: [B, T, H, 2r] — each head's elite chunks, already permuted to the
    front by weight surgery; theta_e: [H, r] per-head chunk frequencies;
    positions: [T] or [B, T].
    """
    b, t, h, dr = x.shape
    r = dr // 2
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        ang = pos[None, :, None, None] * theta_e[None, None, :, :]
    else:
        ang = pos[:, :, None, None] * theta_e[None, None, :, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)  # [B?, T, H, r]
    xc = x.reshape(b, t, h, r, 2)
    return rotate_chunks(xc, cos, sin).reshape(b, t, h, dr)
