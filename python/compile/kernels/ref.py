"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package has a reference twin here implementing the
same contract with plain jax.numpy; pytest asserts allclose between the
two across shape/dtype sweeps (python/tests/test_kernel.py), and the Rust
integration suite re-checks parity through PJRT on the lowered HLO.
"""

import jax.numpy as jnp


def ref_elite_attention_decode(q_rot, q_lat, k_rot, c_kv, lengths, *,
                               scale: float):
    """Reference for elite_attention_decode.

    q_rot: [B, H, 2r]; q_lat: [B, H, C]; k_rot: [B, S, H, 2r];
    c_kv: [B, S, C]; lengths: [B] -> o_lat [B, H, C].
    """
    s = (jnp.einsum("bhd,bshd->bhs", q_rot, k_rot)
         + jnp.einsum("bhc,bsc->bhs", q_lat, c_kv)) * scale
    mask = jnp.arange(k_rot.shape[1])[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhs,bsc->bhc", p, c_kv)


def ref_rope_rotate_elite(x, cos, sin):
    """Reference for rope_rotate_elite. x: [B, H, 2r]; cos/sin: [B, H, r]."""
    b, h, dr = x.shape
    r = dr // 2
    xc = x.reshape(b, h, r, 2)
    x0, x1 = xc[..., 0], xc[..., 1]
    out = jnp.stack((x0 * cos - x1 * sin, x0 * sin + x1 * cos), axis=-1)
    return out.reshape(b, h, dr)
