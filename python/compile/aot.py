"""AOT lowering: JAX/Pallas -> HLO text + JSON manifests for the Rust L3.

Interchange is HLO *text* (not serialized HloModuleProto): jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (behind the
published `xla` 0.1.6 crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Every (config, variant) pair produces
    artifacts/<cfg>_<tag>_<fn>.hlo.txt     one module per entry point
    artifacts/<cfg>_<tag>.json             manifest: exact input/output
                                           order, names, shapes, dtypes
Parameters, optimizer state, extras (elite mask / elite frequencies), and
caches are all runtime inputs — nothing is baked, so one artifact covers
every checkpoint and every searched chunk set of that shape.

Usage: cd python && python -m compile.aot --out ../artifacts [--sets core]
"""

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import (CONFIGS, ModelConfig, Variant, parse_variant,
                      table1_grid)

F32, I32 = jnp.float32, jnp.int32


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


class IoSpec:
    """Ordered, named input/output layout of one lowered function."""

    def __init__(self):
        self.inputs: List[Dict] = []
        self.outputs: List[Dict] = []

    def inp(self, name, shape, dtype="f32"):
        self.inputs.append({"name": name, "shape": list(shape), "dtype": dtype})
        return sds(shape, I32 if dtype == "i32" else F32)

    def out(self, name, shape, dtype="f32"):
        self.outputs.append({"name": name, "shape": list(shape),
                             "dtype": dtype})


# --------------------------------------------------------------------------
# Per-function builders: (flat-arg wrapper, input specs, io manifest)
# --------------------------------------------------------------------------

def _unflatten(names, flat, start):
    return dict(zip(names, flat[start:start + len(names)])), start + len(names)


def build_init(cfg: ModelConfig, var: Variant):
    pspecs = M.param_specs(cfg, var)
    io = IoSpec()
    in_sds = [io.inp("seed", (), "i32")]
    for n, s in pspecs:
        io.out(f"param:{n}", s)

    def fn(seed):
        p = M.init_params(cfg, var, seed)
        return tuple(p[n] for n, _ in pspecs)

    return fn, in_sds, io


def _param_inputs(io: IoSpec, pspecs, prefix: str):
    return [io.inp(f"{prefix}:{n}", s) for n, s in pspecs]


def build_train_step(cfg, var, batch, seq):
    pspecs = M.param_specs(cfg, var)
    especs = M.extras_specs(cfg, var)
    pnames = [n for n, _ in pspecs]
    enames = [n for n, _ in especs]
    io = IoSpec()
    in_sds = []
    in_sds += _param_inputs(io, pspecs, "param")
    in_sds += _param_inputs(io, pspecs, "m")
    in_sds += _param_inputs(io, pspecs, "v")
    in_sds.append(io.inp("step", (), "i32"))
    in_sds.append(io.inp("lr", ()))
    in_sds += [io.inp(f"extra:{n}", s) for n, s in especs]
    in_sds.append(io.inp("tokens", (batch, seq), "i32"))
    in_sds.append(io.inp("targets", (batch, seq), "i32"))
    in_sds.append(io.inp("mask", (batch, seq)))
    for pre in ("param", "m", "v"):
        for n, s in pspecs:
            io.out(f"{pre}:{n}", s)
    io.out("step", (), "i32")
    io.out("loss", ())
    io.out("gnorm", ())

    np_ = len(pspecs)

    def fn(*flat):
        p, i = _unflatten(pnames, flat, 0)
        m, i = _unflatten(pnames, flat, i)
        v, i = _unflatten(pnames, flat, i)
        step, lr = flat[i], flat[i + 1]
        extras, i = _unflatten(enames, flat, i + 2)
        tokens, targets, mask = flat[i], flat[i + 1], flat[i + 2]
        new_p, new_m, new_v, new_step, loss, gnorm = M.train_step(
            cfg, var, p, m, v, step, lr, extras, tokens, targets, mask)
        outs = tuple(new_p[n] for n in pnames) + \
            tuple(new_m[n] for n in pnames) + \
            tuple(new_v[n] for n in pnames) + (new_step, loss, gnorm)
        return outs

    return fn, in_sds, io


def build_eval_loss(cfg, var, batch, seq):
    pspecs = M.param_specs(cfg, var)
    especs = M.extras_specs(cfg, var)
    pnames = [n for n, _ in pspecs]
    enames = [n for n, _ in especs]
    io = IoSpec()
    in_sds = _param_inputs(io, pspecs, "param")
    in_sds += [io.inp(f"extra:{n}", s) for n, s in especs]
    in_sds.append(io.inp("tokens", (batch, seq), "i32"))
    in_sds.append(io.inp("targets", (batch, seq), "i32"))
    in_sds.append(io.inp("mask", (batch, seq)))
    io.out("sum_nll", ())
    io.out("count", ())

    def fn(*flat):
        p, i = _unflatten(pnames, flat, 0)
        extras, i = _unflatten(enames, flat, i)
        tokens, targets, mask = flat[i], flat[i + 1], flat[i + 2]
        return M.eval_loss(cfg, var, p, extras, tokens, targets, mask)

    return fn, in_sds, io


def build_prefill(cfg, var, batch, s):
    pspecs = M.param_specs(cfg, var)
    especs = M.extras_specs(cfg, var)
    cspecs = M.cache_specs(cfg, var, batch, s)
    pnames = [n for n, _ in pspecs]
    enames = [n for n, _ in especs]
    io = IoSpec()
    in_sds = _param_inputs(io, pspecs, "param")
    in_sds += [io.inp(f"extra:{n}", s_) for n, s_ in especs]
    in_sds.append(io.inp("tokens", (batch, s), "i32"))
    in_sds.append(io.inp("true_len", (batch,), "i32"))
    io.out("logits", (batch, cfg.vocab))
    for n, s_ in cspecs:
        io.out(f"cache:{n}", s_)

    def fn(*flat):
        p, i = _unflatten(pnames, flat, 0)
        extras, i = _unflatten(enames, flat, i)
        tokens, true_len = flat[i], flat[i + 1]
        return M.prefill(cfg, var, p, extras, tokens, true_len)

    return fn, in_sds, io


def build_decode(cfg, var, batch, s, use_pallas=False):
    pspecs = M.param_specs(cfg, var)
    especs = M.extras_specs(cfg, var)
    cspecs = M.cache_specs(cfg, var, batch, s)
    pnames = [n for n, _ in pspecs]
    enames = [n for n, _ in especs]
    cnames = [n for n, _ in cspecs]
    io = IoSpec()
    in_sds = _param_inputs(io, pspecs, "param")
    in_sds += [io.inp(f"extra:{n}", s_) for n, s_ in especs]
    in_sds.append(io.inp("token", (batch,), "i32"))
    in_sds.append(io.inp("pos", (batch,), "i32"))
    in_sds += [io.inp(f"cache:{n}", s_) for n, s_ in cspecs]
    io.out("logits", (batch, cfg.vocab))
    for n, s_ in cspecs:
        io.out(f"cache:{n}", s_)

    def fn(*flat):
        p, i = _unflatten(pnames, flat, 0)
        extras, i = _unflatten(enames, flat, i)
        token, pos = flat[i], flat[i + 1]
        caches = list(flat[i + 2:i + 2 + len(cnames)])
        return M.decode_step(cfg, var, p, extras, token, pos, caches,
                             use_pallas=use_pallas)

    return fn, in_sds, io


def build_capture_qk(cfg, batch, seq):
    var = Variant("mha")
    pspecs = M.param_specs(cfg, var)
    pnames = [n for n, _ in pspecs]
    io = IoSpec()
    in_sds = _param_inputs(io, pspecs, "param")
    in_sds.append(io.inp("tokens", (batch, seq), "i32"))
    shp = (cfg.n_layers, batch, seq, cfg.n_heads, cfg.d_head)
    io.out("q_pre", shp)
    io.out("k_pre", shp)

    def fn(*flat):
        p, i = _unflatten(pnames, flat, 0)
        return M.capture_qk(cfg, p, flat[i])

    return fn, in_sds, io


def build_ropelite_delta(cfg, batch, seq):
    io = IoSpec()
    shp = (batch, seq, cfg.n_heads, cfg.d_head)
    in_sds = [io.inp("q_pre", shp), io.inp("k_pre", shp),
              io.inp("elite_mask", (cfg.n_heads, cfg.n_chunks))]
    io.out("distance", (cfg.n_heads, cfg.n_chunks))

    def fn(q, k, mask):
        return (M.ropelite_delta(cfg, q, k, mask),)

    return fn, in_sds, io


def build_contribution(cfg, batch, seq):
    io = IoSpec()
    shp = (cfg.n_layers, batch, seq, cfg.n_heads, cfg.d_head)
    in_sds = [io.inp("q_pre", shp), io.inp("k_pre", shp)]
    io.out("scores", (cfg.n_layers, cfg.n_heads, cfg.n_chunks))

    def fn(q, k):
        return (M.contribution_scores(cfg, q, k),)

    return fn, in_sds, io


# --------------------------------------------------------------------------
# Lowering driver
# --------------------------------------------------------------------------

# Baked batch/seq per config (documented in the manifest).
SHAPES = {
    "tiny": {"train": (8, 128), "eval": (8, 128), "serve": (4, 256),
             "capture": (2, 128)},
    "small": {"train": (8, 128), "eval": (8, 128), "serve": (4, 256),
              "capture": (2, 128)},
    "100m": {"train": (4, 128), "eval": (4, 128), "serve": (2, 256),
             "capture": (1, 128)},
}


def functions_for(cfg: ModelConfig, var: Variant, shapes) -> Dict[str, tuple]:
    bt, st = shapes["train"]
    be, se = shapes["eval"]
    bs, ss = shapes["serve"]
    bc, sc = shapes["capture"]
    fns = {
        "init": build_init(cfg, var),
        "train_step": build_train_step(cfg, var, bt, st),
        "eval_loss": build_eval_loss(cfg, var, be, se),
        "prefill": build_prefill(cfg, var, bs, ss),
        "decode": build_decode(cfg, var, bs, ss, use_pallas=False),
    }
    if var.kind == "elitekv":
        fns["decode_pallas"] = build_decode(cfg, var, bs, ss, use_pallas=True)
    if var.kind == "mha":
        fns["capture_qk"] = build_capture_qk(cfg, bc, sc)
        fns["ropelite_delta"] = build_ropelite_delta(cfg, bc, sc)
        fns["contribution"] = build_contribution(cfg, bc, sc)
    return fns


def lower_pair(cfg: ModelConfig, var: Variant, out_dir: str,
               only_fns=None) -> None:
    tag = var.tag()
    shapes = SHAPES[cfg.name]
    manifest = {
        "config": {
            "name": cfg.name, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_head": cfg.d_head, "d_ffn": cfg.d_ffn, "vocab": cfg.vocab,
            "max_seq": cfg.max_seq, "rope_base": cfg.rope_base,
        },
        "variant": {
            "kind": var.kind, "tag": tag, "r": var.r, "d_ckv": var.d_ckv,
            "d_ck": var.d_ck, "d_cv": var.d_cv, "n_kv_heads": var.n_kv_heads,
        },
        "cache_per_token": var.cache_per_token(cfg),
        "cache_ratio": var.cache_ratio(cfg),
        "params": [{"name": n, "shape": list(s)}
                   for n, s in M.param_specs(cfg, var)],
        "extras": [{"name": n, "shape": list(s)}
                   for n, s in M.extras_specs(cfg, var)],
        "shapes": shapes,
        "functions": {},
    }
    for fname, (fn, in_sds, io) in functions_for(cfg, var, shapes).items():
        if only_fns and fname not in only_fns:
            continue
        t0 = time.time()
        hlo_file = f"{cfg.name}_{tag}_{fname}.hlo.txt"
        path = os.path.join(out_dir, hlo_file)
        lowered = jax.jit(fn, keep_unused=True).lower(*in_sds)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest["functions"][fname] = {
            "file": hlo_file, "inputs": io.inputs, "outputs": io.outputs,
        }
        print(f"  {hlo_file}: {len(text) / 1e6:.2f} MB "
              f"({time.time() - t0:.1f}s)", flush=True)
    mpath = os.path.join(out_dir, f"{cfg.name}_{tag}.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)


def core_pairs() -> List[Tuple[str, str]]:
    """The default artifact set: everything tests + experiments need."""
    pairs: List[Tuple[str, str]] = []
    for cname in ("tiny", "small"):
        cfg = CONFIGS[cname]
        pairs.append((cname, "mha"))
        pairs.append((cname, "ropelite"))
        seen = set()
        for _, var in table1_grid(cfg):
            if var.kind == "mha" or var.tag() in seen:
                continue
            seen.add(var.tag())
            pairs.append((cname, var.tag()))
    # S-LRD ablation grid (fig 5) on tiny: three cache budgets x three splits.
    tiny = CONFIGS["tiny"]
    nc = tiny.n_chunks
    for r, budget in ((nc // 4, 192), (nc // 4, 128), (nc // 8, 96)):
        for frac in (0.25, 0.5, 0.75):
            ck = max(16, int(round(budget * frac / 16)) * 16)
            cv = budget - ck
            if cv < 16:
                continue
            pairs.append((cname_t := "tiny",
                          f"slrd_r{r}_ck{ck}_cv{cv}"))
    # Matching J-LRD points for fig5 (same total cache budget).
    for r, budget in ((nc // 4, 192), (nc // 4, 128), (nc // 8, 96)):
        tag = f"elitekv_r{r}_c{budget}"
        if ("tiny", tag) not in pairs:
            pairs.append(("tiny", tag))
    return pairs


def e2e_pairs() -> List[Tuple[str, str]]:
    cfg = CONFIGS["100m"]
    nc = cfg.n_chunks
    return [("100m", "mha"),
            ("100m", f"elitekv_r{nc // 4}_c{cfg.d_model // 4}")]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sets", default="core", choices=["core", "e2e", "all"])
    ap.add_argument("--pairs", default="",
                    help="comma list of cfg:variant overrides")
    ap.add_argument("--fns", default="", help="comma list filter")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.pairs:
        pairs = [tuple(p.split(":")) for p in args.pairs.split(",")]
    elif args.sets == "core":
        pairs = core_pairs()
    elif args.sets == "e2e":
        pairs = e2e_pairs()
    else:
        pairs = core_pairs() + e2e_pairs()

    only_fns = set(args.fns.split(",")) if args.fns else None
    t0 = time.time()
    for cname, tag in dict.fromkeys(pairs):
        cfg = CONFIGS[cname]
        var = parse_variant(tag)
        print(f"[aot] {cname} / {tag} "
              f"(cache {100 * var.cache_ratio(cfg):.1f}%)", flush=True)
        lower_pair(cfg, var, args.out, only_fns)
    print(f"[aot] done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
