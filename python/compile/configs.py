"""Model family and architecture-variant configuration.

The model family scales down LLaMA2 (the paper's subject) to sizes that
pretrain from scratch on a single CPU core:

    elite-tiny   d=256  L=4   8 heads  d_h=32  (~2 M params)  — sweeps/tests
    elite-small  d=512  L=8   8 heads  d_h=64  (~13 M params) — main tables
    elite-100m   d=768  L=12 12 heads  d_h=64  (~97 M params) — e2e example

Architecture variants mirror the paper:

    mha        — baseline multi-head attention with full RoPE
    ropelite   — RoPElite only (§3.1): elite-mask blended partial RoPE;
                 the mask is a *runtime input*, so a single artifact covers
                 every r and every search method (RoPElite/Uniform/Contribution)
    gqa<g>     — grouped-query attention baseline with g KV heads
    elitekv    — RoPElite + J-LRD (§3.2): per-head elite chunks rotated and
                 cached; everything else lives in a shared d_ckv latent
    slrd       — RoPElite + S-LRD ablation: separate d_ck / d_cv latents
"""

from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Static shape of one model in the family."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_head: int
    d_ffn: int
    vocab: int
    max_seq: int = 256
    rope_base: float = 10000.0

    @property
    def n_chunks(self) -> int:
        """Number of 2-D RoPE chunks per head (|I| in the paper)."""
        return self.d_head // 2

    @property
    def kv_elems_per_token(self) -> int:
        """Vanilla KV cache elements per token per layer (2 * n_h * d_h)."""
        return 2 * self.n_heads * self.d_head


@dataclass(frozen=True)
class Variant:
    """One architecture variant (paper §3).

    kind in {"mha", "ropelite", "gqa", "elitekv", "slrd"}.
    """

    kind: str
    # gqa:
    n_kv_heads: int = 0
    # ropelite / elitekv / slrd:
    r: int = 0  # elite chunks per head
    # elitekv (J-LRD):
    d_ckv: int = 0
    # slrd (S-LRD):
    d_ck: int = 0
    d_cv: int = 0

    def tag(self) -> str:
        if self.kind == "mha":
            return "mha"
        if self.kind == "ropelite":
            return "ropelite"
        if self.kind == "gqa":
            return f"gqa{self.n_kv_heads}"
        if self.kind == "elitekv":
            return f"elitekv_r{self.r}_c{self.d_ckv}"
        if self.kind == "slrd":
            return f"slrd_r{self.r}_ck{self.d_ck}_cv{self.d_cv}"
        raise ValueError(self.kind)

    def cache_per_token(self, cfg: ModelConfig) -> int:
        """KV cache elements per token per layer (paper §3.2 formulas)."""
        if self.kind == "mha" or self.kind == "ropelite":
            return cfg.kv_elems_per_token
        if self.kind == "gqa":
            return 2 * self.n_kv_heads * cfg.d_head
        if self.kind == "elitekv":
            return 2 * self.r * cfg.n_heads + self.d_ckv
        if self.kind == "slrd":
            return 2 * self.r * cfg.n_heads + self.d_ck + self.d_cv
        raise ValueError(self.kind)

    def cache_ratio(self, cfg: ModelConfig) -> float:
        return self.cache_per_token(cfg) / cfg.kv_elems_per_token


TINY = ModelConfig(
    name="tiny", d_model=256, n_layers=4, n_heads=8, d_head=32,
    d_ffn=704, vocab=512, max_seq=256,
)
SMALL = ModelConfig(
    name="small", d_model=512, n_layers=8, n_heads=8, d_head=64,
    d_ffn=1408, vocab=512, max_seq=256,
)
M100 = ModelConfig(
    name="100m", d_model=768, n_layers=12, n_heads=12, d_head=64,
    d_ffn=2048, vocab=2048, max_seq=256,
)

CONFIGS = {c.name: c for c in (TINY, SMALL, M100)}


def parse_variant(tag: str) -> Variant:
    """Inverse of Variant.tag()."""
    if tag == "mha":
        return Variant("mha")
    if tag == "ropelite":
        return Variant("ropelite")
    if tag.startswith("gqa"):
        return Variant("gqa", n_kv_heads=int(tag[3:]))
    if tag.startswith("elitekv_"):
        parts = tag.split("_")  # elitekv_r8_c128
        return Variant("elitekv", r=int(parts[1][1:]), d_ckv=int(parts[2][1:]))
    if tag.startswith("slrd_"):
        parts = tag.split("_")  # slrd_r8_ck96_cv160
        return Variant(
            "slrd", r=int(parts[1][1:]), d_ck=int(parts[2][2:]),
            d_cv=int(parts[3][2:]),
        )
    raise ValueError(f"unknown variant tag: {tag}")


# The cache-ratio grid used in the paper's Table 1, realized for the
# `small` config (d_h = 64, so paper r at d_h=128 maps to r/2 here).
def table1_grid(cfg: ModelConfig) -> List[Tuple[str, Variant]]:
    nc = cfg.n_chunks
    grid: List[Tuple[str, Variant]] = [
        ("100.0", Variant("mha")),
        ("50.0", Variant("elitekv", r=nc // 2, d_ckv=cfg.d_model // 2)),
        ("50.0", Variant("gqa", n_kv_heads=cfg.n_heads // 2)),
        ("34.4", Variant("elitekv", r=nc // 4, d_ckv=_r32(0.344, cfg, nc // 4))),
        ("28.1", Variant("elitekv", r=nc // 4, d_ckv=_r32(0.281, cfg, nc // 4))),
        ("25.0", Variant("elitekv", r=nc // 4, d_ckv=_r32(0.25, cfg, nc // 4))),
        ("25.0", Variant("gqa", n_kv_heads=cfg.n_heads // 4)),
        ("21.9", Variant("elitekv", r=nc // 8, d_ckv=_r32(0.219, cfg, nc // 8))),
        ("12.5", Variant("elitekv", r=nc // 8, d_ckv=_r32(0.125, cfg, nc // 8))),
        ("12.5", Variant("gqa", n_kv_heads=1)),
    ]
    return grid


def _r32(ratio: float, cfg: ModelConfig, r: int) -> int:
    """d_ckv hitting `ratio` of the vanilla cache, rounded to the
    hardware-friendly alignment (the paper's multiple-of-128 constraint,
    scaled to our model widths: 32 for d>=512, 16 for the tiny config)."""
    align = 32 if cfg.d_model >= 512 else 16
    target = ratio * cfg.kv_elems_per_token - 2 * r * cfg.n_heads
    return max(align, int(round(target / align)) * align)
