"""L2: LLaMA-style transformer with EliteKV architecture variants.

Build-time only — every entry point here is lowered by ``aot.py`` to HLO
text and executed from Rust through PJRT. Parameters and the variant's
static side-inputs ("extras": the RoPElite mask or the per-head elite
frequency table) are *runtime inputs*, so one HLO artifact per architecture
shape serves every checkpoint and every searched chunk set.

Variants (configs.Variant):
  mha       — baseline full-RoPE multi-head attention
  gqa       — grouped-query attention (mean-pooled conversion happens in Rust)
  ropelite  — paper §3.1: elite-mask blended partial RoPE (mask is runtime)
  elitekv   — paper §3.2 J-LRD: elite-rotated keys + shared latent cache
  slrd      — paper §4.3.2 S-LRD ablation: separate K / V latents

Entry points (see aot.py for the lowering matrix):
  init_params, forward/loss, train_step (AdamW in-graph), eval_loss,
  prefill, decode_step (jnp and Pallas flavours), capture_qk,
  ropelite_delta (the Algorithm-1 inner step, vectorized over heads+chunks).
"""

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, Variant
from .kernels import rope as rk
from .kernels.elite_attention import elite_attention_decode

EPS = 1e-5
ADAM_B1, ADAM_B2, ADAM_EPS, WEIGHT_DECAY, CLIP_NORM = 0.9, 0.95, 1e-8, 0.1, 1.0

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# Parameter / extras specs (single source of truth for argument order)
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, var: Variant) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat argument layout."""
    d, nh, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    specs: List[Tuple[str, Tuple[int, ...]]] = [("embed", (cfg.vocab, d))]
    for i in range(cfg.n_layers):
        p = f"l{i}."
        specs.append((p + "attn_norm", (d,)))
        specs.append((p + "wq", (d, nh * dh)))
        if var.kind in ("mha", "ropelite"):
            specs.append((p + "wk", (d, nh * dh)))
            specs.append((p + "wv", (d, nh * dh)))
        elif var.kind == "gqa":
            g = var.n_kv_heads
            specs.append((p + "wk", (d, g * dh)))
            specs.append((p + "wv", (d, g * dh)))
        elif var.kind == "elitekv":
            r2 = 2 * var.r
            specs.append((p + "wk_e", (d, nh * r2)))
            specs.append((p + "a_kv", (d, var.d_ckv)))
            specs.append((p + "b_k", (var.d_ckv, nh * (dh - r2))))
            specs.append((p + "b_v", (var.d_ckv, nh * dh)))
        elif var.kind == "slrd":
            r2 = 2 * var.r
            specs.append((p + "wk_e", (d, nh * r2)))
            specs.append((p + "a_k", (d, var.d_ck)))
            specs.append((p + "b_k", (var.d_ck, nh * (dh - r2))))
            specs.append((p + "a_v", (d, var.d_cv)))
            specs.append((p + "b_v", (var.d_cv, nh * dh)))
        else:
            raise ValueError(var.kind)
        specs.append((p + "wo", (nh * dh, d)))
        specs.append((p + "ffn_norm", (d,)))
        specs.append((p + "w1", (d, cfg.d_ffn)))
        specs.append((p + "w2", (cfg.d_ffn, d)))
        specs.append((p + "w3", (d, cfg.d_ffn)))
    specs.append(("final_norm", (d,)))
    return specs


def extras_specs(cfg: ModelConfig, var: Variant) -> List[Tuple[str, Tuple[int, ...]]]:
    """Variant side-inputs, runtime-fed so artifacts stay search-agnostic."""
    if var.kind == "ropelite":
        return [("elite_mask", (cfg.n_layers, cfg.n_heads, cfg.n_chunks))]
    if var.kind in ("elitekv", "slrd"):
        return [("theta_e", (cfg.n_layers, cfg.n_heads, var.r))]
    return []


def cache_specs(cfg: ModelConfig, var: Variant, batch: int, s: int):
    """Decode-cache tensors, stacked over layers: ordered (name, shape)."""
    L, nh, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    if var.kind in ("mha", "ropelite"):
        return [("cache_k", (L, batch, s, nh, dh)),
                ("cache_v", (L, batch, s, nh, dh))]
    if var.kind == "gqa":
        g = var.n_kv_heads
        return [("cache_k", (L, batch, s, g, dh)),
                ("cache_v", (L, batch, s, g, dh))]
    if var.kind == "elitekv":
        return [("cache_ke", (L, batch, s, nh, 2 * var.r)),
                ("cache_c", (L, batch, s, var.d_ckv))]
    if var.kind == "slrd":
        return [("cache_ke", (L, batch, s, nh, 2 * var.r)),
                ("cache_ck", (L, batch, s, var.d_ck)),
                ("cache_cv", (L, batch, s, var.d_cv))]
    raise ValueError(var.kind)


def init_params(cfg: ModelConfig, var: Variant, seed) -> Params:
    """Normal(0, 0.02) init, wo/w2 scaled by 1/sqrt(2L) (GPT-2 style)."""
    key = jax.random.PRNGKey(seed)
    out: Params = {}
    resid_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    for name, shape in param_specs(cfg, var):
        if name.endswith("norm"):
            out[name] = jnp.ones(shape, jnp.float32)
        else:
            key, sub = jax.random.split(key)
            w = 0.02 * jax.random.normal(sub, shape, jnp.float32)
            if name.endswith(("wo", "w2")):
                w = w * resid_scale
            out[name] = w
    return out


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS) * g


def swiglu(x, w1, w2, w3):
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def _heads(x, n, dh):
    return x.reshape(x.shape[0], x.shape[1], n, dh)


def _kv_states(cfg: ModelConfig, var: Variant, p: Params, i: int, xn,
               positions, extras):
    """Per-layer key/value states for the full-sequence (training) path.

    Returns (k [B,T,nh,dh], v [B,T,nh,dh]) with the variant's cache
    semantics already applied (rotation baked in where it would be cached).
    """
    nh, dh = cfg.n_heads, cfg.d_head
    pre = f"l{i}."
    if var.kind == "mha":
        k = _heads(xn @ p[pre + "wk"], nh, dh)
        v = _heads(xn @ p[pre + "wv"], nh, dh)
        k = rk.apply_rope(k, positions, cfg.rope_base)
        return k, v
    if var.kind == "gqa":
        g = var.n_kv_heads
        rep = nh // g
        k = _heads(xn @ p[pre + "wk"], g, dh)
        v = _heads(xn @ p[pre + "wv"], g, dh)
        k = rk.apply_rope(k, positions, cfg.rope_base)
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        return k, v
    if var.kind == "ropelite":
        mask = extras["elite_mask"][i]  # [nh, nc]
        k = _heads(xn @ p[pre + "wk"], nh, dh)
        v = _heads(xn @ p[pre + "wv"], nh, dh)
        k = rk.apply_rope_masked(k, positions, cfg.rope_base, mask)
        return k, v
    if var.kind in ("elitekv", "slrd"):
        r2 = 2 * var.r
        theta = extras["theta_e"][i]  # [nh, r]
        ke = _heads(xn @ p[pre + "wk_e"], nh, r2)
        ke = rk.apply_rope_elite(ke, positions, theta)
        if var.kind == "elitekv":
            c = xn @ p[pre + "a_kv"]  # [B,T,ckv]
            kn = _heads(c @ p[pre + "b_k"], nh, dh - r2)
            v = _heads(c @ p[pre + "b_v"], nh, dh)
        else:
            ck = xn @ p[pre + "a_k"]
            cv = xn @ p[pre + "a_v"]
            kn = _heads(ck @ p[pre + "b_k"], nh, dh - r2)
            v = _heads(cv @ p[pre + "b_v"], nh, dh)
        k = jnp.concatenate([ke, kn], axis=-1)  # elite chunks live up front
        return k, v
    raise ValueError(var.kind)


def _query(cfg: ModelConfig, var: Variant, p: Params, i: int, xn,
           positions, extras):
    """Query states matching the variant's key rotation layout."""
    nh, dh = cfg.n_heads, cfg.d_head
    q = _heads(xn @ p[f"l{i}.wq"], nh, dh)
    if var.kind in ("mha", "gqa"):
        return rk.apply_rope(q, positions, cfg.rope_base)
    if var.kind == "ropelite":
        mask = extras["elite_mask"][i]
        return rk.apply_rope_masked(q, positions, cfg.rope_base, mask)
    # elitekv / slrd: first 2r dims are the (permuted) elite chunks.
    r2 = 2 * var.r
    theta = extras["theta_e"][i]
    q_rot = rk.apply_rope_elite(q[..., :r2], positions, theta)
    return jnp.concatenate([q_rot, q[..., r2:]], axis=-1)


def _attend(q, k, v, causal_mask, scale):
    s = jnp.einsum("bmhd,bnhd->bhmn", q, k) * scale
    s = jnp.where(causal_mask[None, None, :, :], s, -1e30)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    pexp = jnp.exp(s - pmax)
    pr = pexp / jnp.sum(pexp, axis=-1, keepdims=True)
    return jnp.einsum("bhmn,bnhd->bmhd", pr, v)


def forward(cfg: ModelConfig, var: Variant, p: Params, extras,
            tokens) -> jnp.ndarray:
    """Full-sequence forward -> logits [B, T, vocab] (training path)."""
    b, t = tokens.shape
    positions = jnp.arange(t, dtype=jnp.int32)
    causal = jnp.tril(jnp.ones((t, t), bool))
    scale = 1.0 / float(cfg.d_head) ** 0.5
    x = p["embed"][tokens]
    for i in range(cfg.n_layers):
        pre = f"l{i}."
        xn = rmsnorm(x, p[pre + "attn_norm"])
        q = _query(cfg, var, p, i, xn, positions, extras)
        k, v = _kv_states(cfg, var, p, i, xn, positions, extras)
        o = _attend(q, k, v, causal, scale)
        x = x + o.reshape(b, t, -1) @ p[pre + "wo"]
        xn = rmsnorm(x, p[pre + "ffn_norm"])
        x = x + swiglu(xn, p[pre + "w1"], p[pre + "w2"], p[pre + "w3"])
    x = rmsnorm(x, p["final_norm"])
    return x @ p["embed"].T


def loss_fn(cfg, var, p, extras, tokens, targets, mask):
    """Masked mean cross-entropy next-token loss."""
    logits = forward(cfg, var, p, extras, tokens)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------------------------------------------------------
# Training (AdamW in-graph, constant LR per paper §4.1)
# --------------------------------------------------------------------------

def train_step(cfg, var, p, m, v, step, lr, extras, tokens, targets, mask):
    """One AdamW step. Returns (new_p, new_m, new_v, new_step, loss, gnorm)."""
    loss, grads = jax.value_and_grad(
        lambda pp: loss_fn(cfg, var, pp, extras, tokens, targets, mask))(p)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    clip = jnp.minimum(1.0, CLIP_NORM / (gnorm + 1e-12))
    step = step + 1
    stepf = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1 ** stepf
    bc2 = 1.0 - ADAM_B2 ** stepf
    new_p, new_m, new_v = {}, {}, {}
    for name in p:
        g = grads[name] * clip
        mn = ADAM_B1 * m[name] + (1 - ADAM_B1) * g
        vn = ADAM_B2 * v[name] + (1 - ADAM_B2) * g * g
        upd = (mn / bc1) / (jnp.sqrt(vn / bc2) + ADAM_EPS)
        wd = WEIGHT_DECAY if p[name].ndim >= 2 else 0.0
        new_p[name] = p[name] - lr * (upd + wd * p[name])
        new_m[name], new_v[name] = mn, vn
    return new_p, new_m, new_v, step, loss, gnorm


def eval_loss(cfg, var, p, extras, tokens, targets, mask):
    """Sum NLL + token count (Rust accumulates exact corpus perplexity)."""
    logits = forward(cfg, var, p, extras, tokens)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll), jnp.sum(mask)


# --------------------------------------------------------------------------
# Serving: prefill + decode over explicit caches
# --------------------------------------------------------------------------

def prefill(cfg, var, p, extras, tokens, true_len):
    """Process a padded prompt batch, build decode caches.

    tokens: [B, S]; true_len: [B] — returns (last_logits [B, vocab],
    *cache tensors [L, B, S, ...]) with positions >= true_len unmasked
    garbage (decode masks by length).
    """
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    causal = jnp.tril(jnp.ones((s, s), bool))
    scale = 1.0 / float(cfg.d_head) ** 0.5
    x = p["embed"][tokens]
    caches = [jnp.zeros(shape, jnp.float32)
              for _, shape in cache_specs(cfg, var, b, s)]
    for i in range(cfg.n_layers):
        pre = f"l{i}."
        xn = rmsnorm(x, p[pre + "attn_norm"])
        q = _query(cfg, var, p, i, xn, positions, extras)
        k, v, layer_cache = _kv_and_cache_full(cfg, var, p, i, xn,
                                               positions, extras)
        for ci, tensor in enumerate(layer_cache):
            caches[ci] = caches[ci].at[i].set(tensor)
        o = _attend(q, k, v, causal, scale)
        x = x + o.reshape(b, s, -1) @ p[pre + "wo"]
        xn = rmsnorm(x, p[pre + "ffn_norm"])
        x = x + swiglu(xn, p[pre + "w1"], p[pre + "w2"], p[pre + "w3"])
    x = rmsnorm(x, p["final_norm"])
    idx = jnp.clip(true_len - 1, 0, s - 1)
    last = x[jnp.arange(b), idx]  # [B, d]
    logits = last @ p["embed"].T
    return (logits, *caches)


def _kv_and_cache_full(cfg, var, p, i, xn, positions, extras):
    """Full-seq KV plus what the decode cache stores for this layer."""
    nh, dh = cfg.n_heads, cfg.d_head
    pre = f"l{i}."
    if var.kind in ("mha", "ropelite", "gqa"):
        k, v = _kv_states(cfg, var, p, i, xn, positions, extras)
        if var.kind == "gqa":
            # cache stores the *grouped* heads; recompute them for storage
            g = var.n_kv_heads
            kg = _heads(xn @ p[pre + "wk"], g, dh)
            vg = _heads(xn @ p[pre + "wv"], g, dh)
            kg = rk.apply_rope(kg, positions, cfg.rope_base)
            return k, v, [kg, vg]
        return k, v, [k, v]
    r2 = 2 * var.r
    theta = extras["theta_e"][i]
    ke = _heads(xn @ p[pre + "wk_e"], nh, r2)
    ke = rk.apply_rope_elite(ke, positions, theta)
    if var.kind == "elitekv":
        c = xn @ p[pre + "a_kv"]
        kn = _heads(c @ p[pre + "b_k"], nh, dh - r2)
        v = _heads(c @ p[pre + "b_v"], nh, dh)
        k = jnp.concatenate([ke, kn], axis=-1)
        return k, v, [ke, c]
    ck = xn @ p[pre + "a_k"]
    cv = xn @ p[pre + "a_v"]
    kn = _heads(ck @ p[pre + "b_k"], nh, dh - r2)
    v = _heads(cv @ p[pre + "b_v"], nh, dh)
    k = jnp.concatenate([ke, kn], axis=-1)
    return k, v, [ke, ck, cv]


def decode_step(cfg, var, p, extras, token, pos, caches, *,
                use_pallas: bool = False):
    """One decode step over explicit caches.

    token: [B] int32; pos: [B] int32 (write position = current length);
    caches: list of [L, B, S, ...]; returns (logits [B, vocab], *new caches).
    """
    b = token.shape[0]
    s = caches[0].shape[2]
    nh, dh = cfg.n_heads, cfg.d_head
    scale = 1.0 / float(cfg.d_head) ** 0.5
    bi = jnp.arange(b)
    length = pos + 1  # after writing the new token
    valid = jnp.arange(s)[None, :] < length[:, None]  # [B, S]
    x = p["embed"][token][:, None, :]  # [B, 1, d]
    posb = pos[:, None]  # [B, 1] per-sequence positions
    new_caches = list(caches)
    for i in range(cfg.n_layers):
        pre = f"l{i}."
        xn = rmsnorm(x, p[pre + "attn_norm"])
        q = _query(cfg, var, p, i, xn, posb, extras)[:, 0]  # [B, nh, dh]
        if var.kind in ("mha", "ropelite", "gqa"):
            new_caches, k_all, v_all = _decode_kv_dense(
                cfg, var, p, i, xn, posb, extras, new_caches, bi, pos)
            o = _masked_attend_dense(q, k_all, v_all, valid, scale)
        elif var.kind == "elitekv":
            r2 = 2 * var.r
            theta = extras["theta_e"][i]
            ke = _heads(xn @ p[pre + "wk_e"], nh, r2)
            ke = rk.apply_rope_elite(ke, posb, theta)[:, 0]  # [B, nh, 2r]
            c = (xn @ p[pre + "a_kv"])[:, 0]  # [B, ckv]
            new_caches[0] = new_caches[0].at[i, bi, pos].set(ke)
            new_caches[1] = new_caches[1].at[i, bi, pos].set(c)
            o = _elitekv_decode_attend(
                cfg, var, p, i, q, new_caches[0][i], new_caches[1][i],
                length, scale, use_pallas)
        else:  # slrd
            r2 = 2 * var.r
            theta = extras["theta_e"][i]
            ke = _heads(xn @ p[pre + "wk_e"], nh, r2)
            ke = rk.apply_rope_elite(ke, posb, theta)[:, 0]
            ck = (xn @ p[pre + "a_k"])[:, 0]
            cv = (xn @ p[pre + "a_v"])[:, 0]
            new_caches[0] = new_caches[0].at[i, bi, pos].set(ke)
            new_caches[1] = new_caches[1].at[i, bi, pos].set(ck)
            new_caches[2] = new_caches[2].at[i, bi, pos].set(cv)
            o = _slrd_decode_attend(
                cfg, var, p, i, q, new_caches[0][i], new_caches[1][i],
                new_caches[2][i], valid, scale)
        x = x + (o.reshape(b, -1) @ p[pre + "wo"])[:, None, :]
        xn = rmsnorm(x, p[pre + "ffn_norm"])
        x = x + swiglu(xn, p[pre + "w1"], p[pre + "w2"], p[pre + "w3"])
    x = rmsnorm(x[:, 0], p["final_norm"])
    return (x @ p["embed"].T, *new_caches)


def _decode_kv_dense(cfg, var, p, i, xn, posb, extras, caches, bi, pos):
    """Write this token's dense K/V into the cache; return full K/V views."""
    nh, dh = cfg.n_heads, cfg.d_head
    pre = f"l{i}."
    if var.kind == "gqa":
        g = var.n_kv_heads
        k = _heads(xn @ p[pre + "wk"], g, dh)
        v = _heads(xn @ p[pre + "wv"], g, dh)
        k = rk.apply_rope(k, posb, cfg.rope_base)
    else:
        k = _heads(xn @ p[pre + "wk"], nh, dh)
        v = _heads(xn @ p[pre + "wv"], nh, dh)
        if var.kind == "mha":
            k = rk.apply_rope(k, posb, cfg.rope_base)
        else:
            k = rk.apply_rope_masked(k, posb, cfg.rope_base,
                                     extras["elite_mask"][i])
    caches[0] = caches[0].at[i, bi, pos].set(k[:, 0])
    caches[1] = caches[1].at[i, bi, pos].set(v[:, 0])
    k_all, v_all = caches[0][i], caches[1][i]  # [B, S, g|nh, dh]
    if var.kind == "gqa":
        rep = nh // var.n_kv_heads
        k_all = jnp.repeat(k_all, rep, axis=2)
        v_all = jnp.repeat(v_all, rep, axis=2)
    return caches, k_all, v_all


def _masked_attend_dense(q, k_all, v_all, valid, scale):
    s = jnp.einsum("bhd,bnhd->bhn", q, k_all) * scale
    s = jnp.where(valid[:, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhn,bnhd->bhd", pr, v_all)


def _elitekv_decode_attend(cfg, var, p, i, q, ke_all, c_all, length, scale,
                           use_pallas):
    """Absorbed-form attention over the compressed cache (paper Fig 1).

    score = q_rot . k_rot^T + (q_nope @ B_k[h]^T) . c^T;  out per head
    = (p . c) @ B_v[h] — the latent is attended directly, then lifted.
    """
    nh, dh = cfg.n_heads, cfg.d_head
    r2 = 2 * var.r
    d_ckv = var.d_ckv
    pre = f"l{i}."
    q_rot, q_nope = q[..., :r2], q[..., r2:]  # [B,nh,2r], [B,nh,dh-2r]
    bk = p[pre + "b_k"].reshape(d_ckv, nh, dh - r2)  # [C, nh, dn]
    q_lat = jnp.einsum("bhn,chn->bhc", q_nope, bk)  # absorbed query
    if use_pallas:
        o_lat = elite_attention_decode(q_rot, q_lat, ke_all, c_all, length,
                                       scale=scale)
    else:
        from .kernels.ref import ref_elite_attention_decode
        o_lat = ref_elite_attention_decode(q_rot, q_lat, ke_all, c_all,
                                           length, scale=scale)
    bv = p[pre + "b_v"].reshape(d_ckv, nh, dh)
    return jnp.einsum("bhc,chd->bhd", o_lat, bv)  # [B, nh, dh]


def _slrd_decode_attend(cfg, var, p, i, q, ke_all, ck_all, cv_all, valid,
                        scale):
    nh, dh = cfg.n_heads, cfg.d_head
    r2 = 2 * var.r
    pre = f"l{i}."
    q_rot, q_nope = q[..., :r2], q[..., r2:]
    bk = p[pre + "b_k"].reshape(var.d_ck, nh, dh - r2)
    q_lat = jnp.einsum("bhn,chn->bhc", q_nope, bk)
    s = (jnp.einsum("bhd,bshd->bhs", q_rot, ke_all)
         + jnp.einsum("bhc,bsc->bhs", q_lat, ck_all)) * scale
    s = jnp.where(valid[:, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsc->bhc", pr, cv_all)
    bv = p[pre + "b_v"].reshape(var.d_cv, nh, dh)
    return jnp.einsum("bhc,chd->bhd", o_lat, bv)


# --------------------------------------------------------------------------
# RoPElite search support (paper §3.1 Algorithm 1, Appendix B)
# --------------------------------------------------------------------------

def capture_qk(cfg: ModelConfig, p: Params, tokens):
    """Forward the *baseline mha* model, exporting pre-RoPE q/k per layer.

    Per Appendix B the capture uses full-RoPE attention in the forward pass
    while the search probes alternative rotations offline. Returns
    (q_pre [L,B,T,nh,dh], k_pre [L,B,T,nh,dh]).
    """
    var = Variant("mha")
    b, t = tokens.shape
    positions = jnp.arange(t, dtype=jnp.int32)
    causal = jnp.tril(jnp.ones((t, t), bool))
    scale = 1.0 / float(cfg.d_head) ** 0.5
    nh, dh = cfg.n_heads, cfg.d_head
    x = p["embed"][tokens]
    qs, ks = [], []
    for i in range(cfg.n_layers):
        pre = f"l{i}."
        xn = rmsnorm(x, p[pre + "attn_norm"])
        q_pre = _heads(xn @ p[pre + "wq"], nh, dh)
        k_pre = _heads(xn @ p[pre + "wk"], nh, dh)
        v = _heads(xn @ p[pre + "wv"], nh, dh)
        qs.append(q_pre)
        ks.append(k_pre)
        q = rk.apply_rope(q_pre, positions, cfg.rope_base)
        k = rk.apply_rope(k_pre, positions, cfg.rope_base)
        o = _attend(q, k, v, causal, scale)
        x = x + o.reshape(b, t, -1) @ p[pre + "wo"]
        xn = rmsnorm(x, p[pre + "ffn_norm"])
        x = x + swiglu(xn, p[pre + "w1"], p[pre + "w2"], p[pre + "w3"])
    return jnp.stack(qs), jnp.stack(ks)


def ropelite_delta(cfg: ModelConfig, q_pre, k_pre, elite_mask):
    """Algorithm 1 inner loop, vectorized over heads AND candidate chunks.

    Scores decompose per 2-D chunk: s_X = sum_j c_j(rot if j in X else lin),
    so s_{E ∪ {j}} = s_E + (c_j_rot − c_j_lin). One call returns

        distance[h, j] = || s_full − s_{E ∪ {j}} ||_1   (causal, scaled)

    for every head h and candidate j — the single-forward-pass parallelism
    of Appendix B. Already-elite chunks get +inf so argmin skips them.

    q_pre/k_pre: [B, T, nh, dh] pre-RoPE states for ONE layer;
    elite_mask: [nh, nc] in {0,1}. Returns [nh, nc] f32.
    """
    b, t, nh, dh = q_pre.shape
    nc = dh // 2
    positions = jnp.arange(t, dtype=jnp.int32)
    thetas = rk.chunk_thetas(nc, cfg.rope_base)
    cos, sin = rk.rope_cos_sin(positions, thetas)  # [T, nc]
    qc = q_pre.reshape(b, t, nh, nc, 2)
    kc = k_pre.reshape(b, t, nh, nc, 2)
    cs, sn = cos[None, :, None, :], sin[None, :, None, :]
    qr = rk.rotate_chunks(qc, cs, sn)
    kr = rk.rotate_chunks(kc, cs, sn)
    scale = 1.0 / float(dh) ** 0.5
    # Per-chunk score contributions [B, nh, nc, T, T].
    c_rot = jnp.einsum("bmhcx,bnhcx->bhcmn", qr, kr) * scale
    c_lin = jnp.einsum("bmhcx,bnhcx->bhcmn", qc, kc) * scale
    causal = jnp.tril(jnp.ones((t, t), bool))[None, None, None]
    m = elite_mask[None, :, :, None, None]
    s_full = jnp.sum(c_rot, axis=2)  # [B, nh, T, T]
    s_e = jnp.sum(m * c_rot + (1.0 - m) * c_lin, axis=2)
    delta = c_rot - c_lin  # [B, nh, nc, T, T]
    resid = s_full[:, :, None] - s_e[:, :, None] - delta
    dist = jnp.sum(jnp.abs(jnp.where(causal, resid, 0.0)), axis=(0, 3, 4))
    return dist + elite_mask * 1e30  # [nh, nc]


def contribution_scores(cfg: ModelConfig, q_pre, k_pre):
    """The `Contribution` baseline (§4.3.1): mean L2 norm of each RoPE
    chunk's q/k product magnitude per head. q_pre/k_pre: [L,B,T,nh,dh]
    -> [L, nh, nc]."""
    L, b, t, nh, dh = q_pre.shape
    nc = dh // 2
    qc = q_pre.reshape(L, b, t, nh, nc, 2)
    kc = k_pre.reshape(L, b, t, nh, nc, 2)
    qn = jnp.sqrt(jnp.sum(qc * qc, axis=-1)).mean(axis=(1, 2))  # [L, nh, nc]
    kn = jnp.sqrt(jnp.sum(kc * kc, axis=-1)).mean(axis=(1, 2))
    return qn * kn
