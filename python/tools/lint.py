#!/usr/bin/env python3
"""Toolchain-free runner for `elitekv lint` (DESIGN.md S21).

This is a statement-for-statement port of the Rust analyzer in
`rust/src/analysis/{lexer,rules,report}.rs`. The two runners are pinned
to byte-identical output by the differential tests in
`rust/tests/lint_tool.rs` and `python/tests/test_lint.py`: the same tree
must produce the same report, and `--dump-tokens FILE` must print the
same token stream as `elitekv lint --dump-tokens FILE`. Keep every
format string, message template, sort key, and scan order in lockstep
with the Rust side when editing either.

Usage:
    python3 python/tools/lint.py [--root DIR] [--dump-tokens FILE]

Exit codes: 0 clean, 1 findings, 2 usage error. With no --root the
repository root is derived from this file's location.
"""

import os
import sys

# ---------------------------------------------------------------------------
# Lexer (port of rust/src/analysis/lexer.rs)
# ---------------------------------------------------------------------------


class Token:
    """One lexed token: kind is the lowercase name the Rust side dumps."""

    __slots__ = ("kind", "text", "line", "col", "start", "end")

    def __init__(self, kind, text, line, col, start, end):
        self.kind = kind
        self.text = text
        self.line = line
        self.col = col
        self.start = start
        self.end = end


def is_id_start(c):
    return ord(c) >= 128 or "a" <= c <= "z" or "A" <= c <= "Z" or c == "_"


def is_id_cont(c):
    return is_id_start(c) or "0" <= c <= "9"


def is_ws(c):
    return c in " \t\r\n\x0b\x0c"


def is_digit(c):
    return "0" <= c <= "9"


def is_alnum(c):
    return "a" <= c <= "z" or "A" <= c <= "Z" or "0" <= c <= "9"


def scan_cooked(c, q):
    n = len(c)
    j = q + 1
    while j < n:
        if c[j] == "\\":
            j += 2
            continue
        if c[j] == '"':
            return j + 1, True
        j += 1
    return n, False


def scan_raw(c, j, hashes):
    n = len(c)
    while j < n:
        if c[j] == '"':
            m = 0
            while m < hashes and j + 1 + m < n and c[j + 1 + m] == "#":
                m += 1
            if m == hashes:
                return j + 1 + hashes, True
        j += 1
    return n, False


def scan_char_like(c, q):
    n = len(c)
    if q + 1 >= n:
        return None
    if c[q + 1] == "\\":
        j = q + 2
        if j < n:
            j += 1
        while j < n and c[j] != "'" and c[j] != "\n":
            j += 1
        if j < n and c[j] == "'":
            return j + 1, True
        return j, False
    if q + 2 < n and c[q + 2] == "'" and c[q + 1] != "'" and c[q + 1] != "\n":
        return q + 3, True
    return None


def scan_number(c, s):
    n = len(c)
    i = s + 1
    seen_dot = False
    while i < n:
        ch = c[i]
        if is_alnum(ch) or ch == "_":
            i += 1
        elif ch == "." and not seen_dot and i + 1 < n and is_digit(c[i + 1]):
            seen_dot = True
            i += 1
        elif (
            ch in "+-"
            and c[i - 1] in "eE"
            and i + 1 < n
            and is_digit(c[i + 1])
        ):
            i += 1
        else:
            break
    return i


def scan_prefixed(c, i):
    n = len(c)
    ch = c[i]
    if ch not in "rbc":
        return None
    pl = 1
    if ch in "bc" and i + 1 < n and c[i + 1] == "r":
        pl = 2
    k = i + pl
    h = 0
    while k + h < n and c[k + h] == "#":
        h += 1
    raw_capable = (ch == "r" and pl == 1) or pl == 2
    if raw_capable and k + h < n and c[k + h] == '"':
        end, ok = scan_raw(c, k + h + 1, h)
        msg = "" if ok else "unterminated raw string literal"
        return end, "str", msg
    if pl == 1 and h == 0 and ch in "bc" and k < n and c[k] == '"':
        end, ok = scan_cooked(c, k)
        msg = "" if ok else "unterminated string literal"
        return end, "str", msg
    if pl == 1 and h == 0 and ch == "b" and k < n and c[k] == "'":
        r = scan_char_like(c, k)
        if r is not None:
            end, ok = r
            msg = "" if ok else "unterminated character literal"
            return end, "char", msg
        return None
    if ch == "r" and pl == 1 and h == 1 and k + 1 < n and is_id_start(c[k + 1]):
        j = k + 1
        while j < n and is_id_cont(c[j]):
            j += 1
        return j, "ident", ""
    return None


def lex(src):
    """Total lex of `src`: returns (tokens, errors)."""
    c = list(src)
    n = len(c)
    toks = []
    errs = []
    i = 0
    line = 1
    col = 1
    while i < n:
        ch = c[i]
        if is_ws(ch):
            i += 1
            if ch == "\n":
                line += 1
                col = 1
            else:
                col += 1
            continue
        start = i
        end = i + 1
        kind = "punct"
        err = ""
        if ch == "/" and i + 1 < n and c[i + 1] == "/":
            j = i + 2
            while j < n and c[j] != "\n":
                j += 1
            end = j
            t = "".join(c[start:end])
            if (t.startswith("///") and not t.startswith("////")) or t.startswith("//!"):
                kind = "doc"
            else:
                kind = "comment"
        elif ch == "/" and i + 1 < n and c[i + 1] == "*":
            depth = 1
            j = i + 2
            while j < n and depth > 0:
                if c[j] == "/" and j + 1 < n and c[j + 1] == "*":
                    depth += 1
                    j += 2
                elif c[j] == "*" and j + 1 < n and c[j + 1] == "/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            end = j
            if depth > 0:
                err = "unterminated block comment"
            t = "".join(c[start:end])
            if t.startswith("/*!") or (
                t.startswith("/**") and not t.startswith("/***") and t != "/**/"
            ):
                kind = "doc"
            else:
                kind = "comment"
        elif ch == '"':
            end, ok = scan_cooked(c, i)
            kind = "str"
            if not ok:
                err = "unterminated string literal"
        elif ch == "'":
            r = scan_char_like(c, i)
            if r is not None:
                end, ok = r
                kind = "char"
                if not ok:
                    err = "unterminated character literal"
            elif i + 1 < n and is_id_start(c[i + 1]):
                j = i + 1
                while j < n and is_id_cont(c[j]):
                    j += 1
                end = j
                kind = "lifetime"
        elif is_digit(ch):
            end = scan_number(c, i)
            kind = "num"
        elif is_id_start(ch):
            r = scan_prefixed(c, i)
            if r is not None:
                end, kind, err = r
            else:
                j = i + 1
                while j < n and is_id_cont(c[j]):
                    j += 1
                end = j
                kind = "ident"
        if err:
            errs.append((line, err))
        text = "".join(c[start:end])
        toks.append(Token(kind, text, line, col, start, end))
        consumed = end - start
        nl = 0
        last = 0
        for off in range(start, end):
            if c[off] == "\n":
                nl += 1
                last = off - start
        if nl > 0:
            line += nl
            col = consumed - last
        else:
            col += consumed
        i = end
    return toks, errs


def escape(s):
    out = []
    for ch in s:
        if ch == "\\":
            out.append("\\\\")
        elif ch == "\n":
            out.append("\\n")
        elif ch == "\t":
            out.append("\\t")
        elif ch == "\r":
            out.append("\\r")
        elif " " <= ch <= "~":
            out.append(ch)
        else:
            out.append("\\u{%04x}" % ord(ch))
    return "".join(out)


def dump(src):
    toks, errs = lex(src)
    out = []
    for t in toks:
        out.append("%d:%d %s %s\n" % (t.line, t.col, t.kind, escape(t.text)))
    for line, msg in errs:
        out.append("error:%d %s\n" % (line, escape(msg)))
    return "".join(out)


# ---------------------------------------------------------------------------
# Report rendering (port of rust/src/analysis/report.rs)
# ---------------------------------------------------------------------------


def render(findings, files_scanned):
    """Findings are (path, line, rule, msg) tuples; render sorts,
    dedups, and appends the summary line — byte-identical to Rust."""
    ordered = sorted(findings)
    dedup = []
    for f in ordered:
        if not dedup or dedup[-1] != f:
            dedup.append(f)
    out = []
    for path, line, rule, msg in dedup:
        out.append("%s:%d %s %s\n" % (path, line, rule, msg))
    if not dedup:
        out.append("lint: clean (%d files scanned)\n" % files_scanned)
    else:
        out.append(
            "lint: %d finding(s) (%d files scanned)\n"
            % (len(dedup), files_scanned)
        )
    return "".join(out)


# ---------------------------------------------------------------------------
# Rule engine (port of rust/src/analysis/rules.rs)
# ---------------------------------------------------------------------------

SCAN_DIRS = ["rust/src", "rust/tests", "rust/benches", "examples"]
SKIP_DIR = "lint_fixtures"
R2_FILES = ["rust/src/native/kernels.rs", "rust/src/native/model.rs"]
R2_BANNED = [
    "HashMap",
    "HashSet",
    "Instant",
    "SystemTime",
    "thread_rng",
    "available_parallelism",
]
R3_DIR = "rust/src/coordinator/"
R3_FILES = ["rust/src/kvcache/radix.rs", "rust/src/kvcache/block.rs"]
R3_MACROS = ["panic", "unreachable", "todo", "unimplemented"]
R3_METHODS = ["unwrap", "expect"]
ARGS_API = ["get", "str_or", "usize_or", "u64_or", "f64_or", "has", "req"]
R8_DIR = "rust/src/native/simd/"
R8_BANNED = [
    "target_arch",
    "target_feature",
    "is_x86_feature_detected",
    "is_aarch64_feature_detected",
]
MAIN_RS = "rust/src/main.rs"
LIB_RS = "rust/src/lib.rs"
SCHED_RS = "rust/src/coordinator/scheduler.rs"

MALFORMED_MSG = (
    "malformed lint control comment (grammar: "
    "`// lint: allow(Rn[,Rn]) — reason`)"
)


class Attr:
    __slots__ = (
        "start_code",
        "end_code",
        "start_orig",
        "end_orig",
        "inner",
        "idents",
        "strs",
    )

    def __init__(self, start_code, end_code, start_orig, end_orig, inner,
                 idents, strs):
        self.start_code = start_code
        self.end_code = end_code
        self.start_orig = start_orig
        self.end_orig = end_orig
        self.inner = inner
        self.idents = idents
        self.strs = strs

    def is_testish(self):
        return "test" in self.idents

    def is_pjrt(self):
        return (
            "cfg" in self.idents
            and "feature" in self.idents
            and "not" not in self.idents
            and "pjrt" in self.strs
        )

    def is_docs_allow(self):
        return "allow" in self.idents and "missing_docs" in self.idents

    def is_doc(self):
        return "doc" in self.idents


class FileLex:
    __slots__ = (
        "toks",
        "errs",
        "code",
        "attrs",
        "test_spans",
        "pjrt_spans",
        "docs_allow_spans",
        "inner_pjrt",
        "mod_decls",
        "allows",
        "r0",
    )


def in_spans(spans, idx):
    return any(a <= idx <= b for a, b in spans)


def find_item_end(code_toks, s):
    n = len(code_toks)
    depth = 0
    m = s
    while m < n:
        t = code_toks[m].text
        if t == "(" or t == "[":
            depth += 1
        elif t == ")" or t == "]":
            if depth == 0:
                return m
            depth -= 1
        elif t == "{":
            if depth == 0:
                d = 1
                m2 = m + 1
                while m2 < n and d > 0:
                    t2 = code_toks[m2].text
                    if t2 in "([{":
                        d += 1
                    elif t2 in ")]}":
                        d -= 1
                    m2 += 1
                return m2 - 1 if m2 > 0 else 0
            depth += 1
        elif t == "}":
            if depth == 0:
                return m
            depth -= 1
        elif t == ";" and depth == 0:
            return m
        m += 1
    return n - 1 if n > 0 else 0


def parse_allow_body(rest):
    rest = rest.strip()
    if not rest.startswith("allow("):
        return [], MALFORMED_MSG
    close = rest.find(")")
    if close < 0:
        return [], MALFORMED_MSG
    inside = rest[6:close]
    rules = []
    err = None
    for part in inside.split(","):
        p = part.strip()
        valid = len(p) == 2 and p[0] == "R" and "1" <= p[1] <= "8"
        if valid:
            rules.append(p)
        else:
            err = "unknown rule `%s` in lint control comment" % p
    tail = rest[close + 1:].lstrip()
    sep = False
    for s in ("—", "–", "-", ":"):
        if tail.startswith(s):
            tail = tail[len(s):]
            sep = True
            break
    if not sep or not tail.strip():
        err = MALFORMED_MSG
    return rules, err


def unquote(s):
    t = s
    for p in ("br", "cr", "r", "b", "c"):
        if t.startswith(p) and len(t) > len(p) and t[len(p)] in "\"#'":
            t = t[len(p):]
            break
    t = t.strip("#")
    return t.strip("\"'")


def analyze(text):
    fl = FileLex()
    toks, errs = lex(text)
    fl.toks = toks
    fl.errs = errs
    code = [i for i, t in enumerate(toks) if t.kind not in ("comment", "doc")]
    fl.code = code
    code_toks = [toks[i] for i in code]
    n = len(code_toks)

    # ---- attributes ----
    attrs = []
    i = 0
    while i < n:
        if code_toks[i].text == "#":
            inner = i + 1 < n and code_toks[i + 1].text == "!"
            b = i + 1 + (1 if inner else 0)
            if b < n and code_toks[b].text == "[":
                depth = 1
                k = b + 1
                while k < n and depth > 0:
                    t = code_toks[k].text
                    if t == "[":
                        depth += 1
                    elif t == "]":
                        depth -= 1
                    if depth > 0:
                        k += 1
                end = min(k, n - 1)
                lo = min(b + 1, n)
                hi = max(min(end, n), lo)
                idents = []
                strs = []
                for ct in code_toks[lo:hi]:
                    if ct.kind == "ident":
                        idents.append(ct.text)
                    elif ct.kind == "str":
                        strs.append(unquote(ct.text))
                attrs.append(
                    Attr(i, end, code[i], code[end], inner, idents, strs)
                )
                i = end + 1
                continue
        i += 1
    fl.attrs = attrs

    # ---- attribute chains -> item spans ----
    test_spans = []
    pjrt_spans = []
    docs_allow_spans = []
    inner_pjrt = False
    j = 0
    while j < len(attrs):
        if attrs[j].inner:
            if attrs[j].is_pjrt():
                inner_pjrt = True
            j += 1
            continue
        chain_start = j
        while (
            j + 1 < len(attrs)
            and not attrs[j + 1].inner
            and attrs[j + 1].start_code == attrs[j].end_code + 1
        ):
            j += 1
        item_start = attrs[j].end_code + 1
        item_end = find_item_end(code_toks, item_start)
        span = (attrs[chain_start].start_code, item_end)
        for a in attrs[chain_start:j + 1]:
            if a.is_testish():
                test_spans.append(span)
            if a.is_pjrt():
                pjrt_spans.append(span)
            if a.is_docs_allow():
                docs_allow_spans.append(span)
        j += 1
    fl.test_spans = test_spans
    fl.pjrt_spans = pjrt_spans
    fl.docs_allow_spans = docs_allow_spans
    fl.inner_pjrt = inner_pjrt

    # ---- mod declarations ----
    mod_decls = []
    for t in range(n):
        if (
            code_toks[t].text == "mod"
            and code_toks[t].kind == "ident"
            and t + 1 < n
            and code_toks[t + 1].kind == "ident"
        ):
            mod_decls.append((
                code_toks[t + 1].text,
                in_spans(pjrt_spans, t),
                in_spans(docs_allow_spans, t),
            ))
    fl.mod_decls = mod_decls

    # ---- allow comments ----
    allows = {}
    r0 = []
    for ti, tok in enumerate(toks):
        if tok.kind not in ("comment", "doc"):
            continue
        if not tok.text.startswith("//"):
            continue
        body = tok.text[2:].lstrip("/!").lstrip()
        if not body.startswith("lint:"):
            continue
        rules, err = parse_allow_body(body[5:])
        if err is not None:
            r0.append((tok.line, err))
        target = tok.line
        for t2 in toks[ti + 1:]:
            if t2.kind not in ("comment", "doc"):
                target = t2.line
                break
        for r in rules:
            e = allows.setdefault(r, [])
            e.append(tok.line)
            e.append(target)
    fl.allows = allows
    fl.r0 = r0
    return fl


def extract_flags(text):
    c = list(text)
    n = len(c)
    out = []
    i = 0
    while i + 2 < n:
        if (
            c[i] == "-"
            and c[i + 1] == "-"
            and (i == 0 or c[i - 1] != "-")
            and "a" <= c[i + 2] <= "z"
        ):
            j = i + 2
            while j < n and ("a" <= c[j] <= "z" or is_digit(c[j]) or c[j] == "-"):
                j += 1
            flag = "".join(c[i + 2:j]).rstrip("-")
            if flag and flag not in out:
                out.append(flag)
            i = j
        else:
            i += 1
    return out


class CargoTarget:
    __slots__ = ("kind", "path", "path_line", "required")

    def __init__(self, kind, path_line):
        self.kind = kind
        self.path = ""
        self.path_line = path_line
        self.required = []


def parse_cargo(text):
    targets = []
    current = False
    for ln0, raw in enumerate(text.split("\n")):
        ln = ln0 + 1
        raw = raw.removesuffix("\r")
        line = []
        in_str = False
        for ch in raw:
            if ch == '"':
                in_str = not in_str
            if ch == "#" and not in_str:
                break
            line.append(ch)
        s = "".join(line).strip()
        if s.startswith("[["):
            name = s.strip("[]")
            if name in ("test", "bench", "example"):
                targets.append(CargoTarget(name, ln))
                current = True
            else:
                current = False
            continue
        if s.startswith("["):
            current = False
            continue
        if not current:
            continue
        if "=" not in s:
            continue
        key, val = s.split("=", 1)
        key = key.strip()
        quoted = val.split('"')[1::2]
        if targets:
            t = targets[-1]
            if key == "path" and quoted:
                t.path = quoted[0]
                t.path_line = ln
            elif key == "required-features":
                t.required = quoted
    return targets


def discover(root):
    out = []
    for d in SCAN_DIRS:
        base = os.path.join(root, d)
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(x for x in dirnames if x != SKIP_DIR)
            rel_dir = os.path.relpath(dirpath, root).replace(os.sep, "/")
            for name in sorted(filenames):
                if name.endswith(".rs"):
                    out.append(rel_dir + "/" + name)
    out.sort()
    return out


def mod_chain(rel):
    if not rel.startswith("rust/src/"):
        return []
    comps = rel[len("rust/src/"):].split("/")
    names = []
    for k, comp in enumerate(comps):
        if k + 1 == len(comps):
            stem = comp[:-3] if comp.endswith(".rs") else comp
            if stem not in ("mod", "lib", "main"):
                names.append(stem)
        else:
            names.append(comp)
    return names


def file_pjrt_gated(rel, lexmap, cargo):
    fl = lexmap.get(rel)
    if fl is not None and fl.inner_pjrt:
        return True
    if rel.startswith("rust/src/"):
        names = mod_chain(rel)
        for i in range(len(names)):
            if i == 0:
                decl_file = LIB_RS
            else:
                decl_file = "rust/src/" + "/".join(names[:i]) + "/mod.rs"
            dfl = lexmap.get(decl_file)
            if dfl is not None:
                for name, pjrt, _docs in dfl.mod_decls:
                    if name == names[i] and pjrt:
                        return True
        return False
    return any(
        t.path == rel and "pjrt" in t.required for t in cargo
    )


def has_inner_doc(fl):
    for t in fl.toks:
        if t.kind == "comment":
            continue
        return t.kind == "doc" and (
            t.text.startswith("//!") or t.text.startswith("/*!")
        )
    return False


def documented(fl, oi):
    by_end = {a.end_orig: a for a in fl.attrs}
    p = oi
    while p > 0:
        p -= 1
        tok = fl.toks[p]
        if tok.kind == "doc":
            return True
        if tok.kind == "comment":
            continue
        a = by_end.get(p)
        if a is not None:
            if a.is_doc() or a.is_docs_allow():
                return True
            if a.start_orig == 0:
                return False
            p = a.start_orig
            continue
        return False
    return False


def has_safety_comment(fl, oi):
    by_end = {a.end_orig: a for a in fl.attrs}
    p = oi
    while p > 0:
        p -= 1
        tok = fl.toks[p]
        if tok.kind == "comment":
            if tok.text.startswith("//") and tok.text[2:].lstrip().startswith(
                "SAFETY:"
            ):
                return True
            continue
        if tok.kind == "doc":
            continue
        a = by_end.get(p)
        if a is not None:
            if a.start_orig == 0:
                return False
            p = a.start_orig
            continue
        return False
    return False


def read_text(path):
    try:
        with open(path, "rb") as fh:
            data = fh.read()
    except OSError:
        data = b""
    return data.decode("utf-8", errors="replace")


def run(root):
    """Apply every rule under `root`; returns (findings, files_scanned)."""
    files = discover(root)
    lexmap = {}
    for f in files:
        lexmap[f] = analyze(read_text(os.path.join(root, f)))
    cargo = parse_cargo(read_text(os.path.join(root, "Cargo.toml")))
    readme_text = read_text(os.path.join(root, "README.md"))

    findings = []

    # ---- R0: malformed allow comments ----
    for f in files:
        for line, msg in lexmap[f].r0:
            findings.append((f, line, "R0", msg))

    # ---- R1: target registration <-> files ----
    for kind, prefix in (
        ("test", "rust/tests/"),
        ("bench", "rust/benches/"),
        ("example", "examples/"),
    ):
        regs = [t for t in cargo if t.kind == kind]
        for f in files:
            if f.startswith(prefix) and not any(t.path == f for t in regs):
                findings.append((
                    f,
                    1,
                    "R1",
                    "unregistered %s target: add a [[%s]] entry with "
                    'path = "%s" to Cargo.toml (autotests=false)'
                    % (kind, kind, f),
                ))
        for t in regs:
            if t.path and t.path.startswith(prefix) and t.path not in files:
                findings.append((
                    "Cargo.toml",
                    t.path_line,
                    "R1",
                    "[[%s]] entry points at missing file `%s`"
                    % (kind, t.path),
                ))

    # ---- per-file token rules ----
    for f in files:
        fl = lexmap[f]
        code_toks = [fl.toks[i] for i in fl.code]
        n = len(code_toks)

        # R6: delimiter balance + lexer errors.
        for line, msg in fl.errs:
            findings.append((f, line, "R6", msg))
        stack = []
        for ct in code_toks:
            tx = ct.text
            line = ct.line
            if tx in ("(", "[", "{"):
                stack.append((tx, line))
            elif tx in (")", "]", "}"):
                if not stack:
                    findings.append(
                        (f, line, "R6", "unmatched closing `%s`" % tx)
                    )
                else:
                    o, ol = stack.pop()
                    want = {"(": ")", "[": "]", "{": "}"}[o]
                    if tx != want:
                        findings.append((
                            f,
                            line,
                            "R6",
                            "mismatched delimiters: `%s` (line %d) "
                            "closed by `%s`" % (o, ol, tx),
                        ))
        for o, ol in stack:
            findings.append(
                (f, ol, "R6", "unclosed `%s` at end of file" % o)
            )

        # R2: determinism-contract files.
        if f in R2_FILES:
            for t in range(n):
                if (
                    code_toks[t].kind == "ident"
                    and code_toks[t].text in R2_BANNED
                    and not in_spans(fl.test_spans, t)
                ):
                    findings.append((
                        f,
                        code_toks[t].line,
                        "R2",
                        "nondeterminism-prone symbol `%s` in a "
                        "decode-path file (S17 bitwise contract)"
                        % code_toks[t].text,
                    ))

        # R3: serving-path panic freedom.
        if f.startswith(R3_DIR) or f in R3_FILES:
            for t in range(n):
                if in_spans(fl.test_spans, t):
                    continue
                tx = code_toks[t].text
                line = code_toks[t].line
                if (
                    code_toks[t].kind == "ident"
                    and tx in R3_METHODS
                    and t > 0
                    and code_toks[t - 1].text == "."
                    and t + 1 < n
                    and code_toks[t + 1].text == "("
                ):
                    findings.append((
                        f,
                        line,
                        "R3",
                        "`.%s()` in serving-path code (S11: return a "
                        "Result instead)" % tx,
                    ))
                elif (
                    code_toks[t].kind == "ident"
                    and tx in R3_MACROS
                    and t + 1 < n
                    and code_toks[t + 1].text == "!"
                ):
                    findings.append((
                        f,
                        line,
                        "R3",
                        "`%s!` in serving-path code (S11: return a "
                        "Result instead)" % tx,
                    ))
                elif (
                    tx == "["
                    and t > 0
                    and (
                        code_toks[t - 1].kind == "ident"
                        or code_toks[t - 1].text == ")"
                        or code_toks[t - 1].text == "]"
                    )
                    and t + 2 < n
                    and code_toks[t + 1].kind == "num"
                    and code_toks[t + 2].text == "]"
                ):
                    findings.append((
                        f,
                        line,
                        "R3",
                        "integer-literal index `[%s]` in serving-path "
                        "code (S11: use .get or a checked bound)"
                        % code_toks[t + 1].text,
                    ))

        # R4: xla references must be pjrt-gated.
        if not file_pjrt_gated(f, lexmap, cargo):
            for t in range(n):
                if (
                    code_toks[t].kind == "ident"
                    and code_toks[t].text == "xla"
                    and not in_spans(fl.pjrt_spans, t)
                ):
                    findings.append((
                        f,
                        code_toks[t].line,
                        "R4",
                        "reference to the `xla` crate outside "
                        '#[cfg(feature = "pjrt")]',
                    ))

        # R8: arch-specific code stays behind the simd dispatch layer.
        if f.startswith(R8_DIR):
            for t in range(n):
                if (
                    code_toks[t].kind == "ident"
                    and code_toks[t].text == "unsafe"
                    and t + 1 < n
                    and code_toks[t + 1].text == "fn"
                ):
                    s = t - 1 if t > 0 and code_toks[t - 1].text == "pub" else t
                    if not has_safety_comment(fl, fl.code[s]):
                        findings.append((
                            f,
                            code_toks[t].line,
                            "R8",
                            "`unsafe fn` without a `// SAFETY:` comment "
                            "in the simd module (S23: document the "
                            "contract the caller must uphold)",
                        ))
        else:
            for t in range(n):
                if code_toks[t].kind != "ident":
                    continue
                tx = code_toks[t].text
                named = None
                if tx in R8_BANNED:
                    named = tx
                elif (
                    tx == "arch"
                    and t >= 3
                    and code_toks[t - 1].text == ":"
                    and code_toks[t - 2].text == ":"
                    and code_toks[t - 3].text in ("std", "core")
                ):
                    named = "%s::arch" % code_toks[t - 3].text
                if named is not None:
                    findings.append((
                        f,
                        code_toks[t].line,
                        "R8",
                        "arch-specific identifier `%s` outside "
                        "rust/src/native/simd/ (S23: SIMD intrinsics "
                        "live behind the dispatch layer)" % named,
                    ))

    # ---- R5: doc coverage on the enforced surface ----
    enforced = []
    libfl = lexmap.get(LIB_RS)
    if libfl is not None:
        for name, _pjrt, docs_allowed in libfl.mod_decls:
            if not docs_allowed and name not in enforced:
                enforced.append(name)
    for f in files:
        if not f.startswith("rust/src/"):
            continue
        chain = mod_chain(f)
        in_scope = f == LIB_RS or (chain and chain[0] in enforced)
        if not in_scope or file_pjrt_gated(f, lexmap, cargo):
            continue
        fl = lexmap[f]
        code_toks = [fl.toks[i] for i in fl.code]
        n = len(code_toks)
        dir_ = f[:f.rfind("/")] if "/" in f else ""
        for t in range(n):
            if code_toks[t].text != "pub" or code_toks[t].kind != "ident":
                continue
            if (
                in_spans(fl.test_spans, t)
                or in_spans(fl.pjrt_spans, t)
                or in_spans(fl.docs_allow_spans, t)
            ):
                continue
            if t + 1 >= n:
                continue
            nxt = code_toks[t + 1].text
            if nxt == "(" or nxt == "use":
                continue
            if nxt == "mod" and t + 3 < n and code_toks[t + 3].text == ";":
                name = code_toks[t + 2].text
                sub = lexmap.get("%s/%s.rs" % (dir_, name))
                if sub is None:
                    sub = lexmap.get("%s/%s/mod.rs" % (dir_, name))
                if sub is not None and has_inner_doc(sub):
                    continue
            if not documented(fl, fl.code[t]):
                findings.append((
                    f,
                    code_toks[t].line,
                    "R5",
                    "undocumented `pub` item in a missing_docs-enforced "
                    "module (cargo doc -D warnings will fail)",
                ))

    # ---- R7: CLI flags <-> README table <-> SchedulerConfig ----
    mainfl = lexmap.get(MAIN_RS)
    if mainfl is not None:
        code_toks = [mainfl.toks[i] for i in mainfl.code]
        n = len(code_toks)
        used = []
        for t in range(n):
            if (
                code_toks[t].kind == "ident"
                and code_toks[t].text == "args"
                and t + 4 < n
                and code_toks[t + 1].text == "."
                and code_toks[t + 2].kind == "ident"
                and code_toks[t + 2].text in ARGS_API
                and code_toks[t + 3].text == "("
                and code_toks[t + 4].kind == "str"
            ):
                flag = unquote(code_toks[t + 4].text)
                if not any(u == flag for u, _ in used):
                    used.append((flag, code_toks[t].line))
        main_doc_flags = []
        for i in mainfl.code:
            if mainfl.toks[i].kind == "str":
                for fl2 in extract_flags(mainfl.toks[i].text):
                    if fl2 not in main_doc_flags:
                        main_doc_flags.append(fl2)
        readme_flags = extract_flags(readme_text)
        table_flags = []
        for ln0, raw in enumerate(readme_text.split("\n")):
            s = raw.removesuffix("\r").lstrip()
            if not s.startswith("|"):
                continue
            cs = list(s)
            cell = []
            k = 1
            while k < len(cs):
                if cs[k] == "|" and cs[k - 1] != "\\":
                    break
                cell.append(cs[k])
                k += 1
            for flag in extract_flags("".join(cell)):
                table_flags.append((flag, ln0 + 1))
        # R7a: stale table rows.
        for flag, ln in table_flags:
            if not any(u == flag for u, _ in used):
                findings.append((
                    "README.md",
                    ln,
                    "R7",
                    "README flag-table row names `--%s` but "
                    "rust/src/main.rs never reads it" % flag,
                ))
        # R7b: undocumented flags.
        for flag, ln in used:
            if flag not in main_doc_flags and flag not in readme_flags:
                findings.append((
                    MAIN_RS,
                    ln,
                    "R7",
                    "CLI flag `--%s` is undocumented (absent from the "
                    "main.rs help text and README.md)" % flag,
                ))
        # R7c: SchedulerConfig fields.
        schedfl = lexmap.get(SCHED_RS)
        if schedfl is not None:
            sc = [schedfl.toks[i] for i in schedfl.code]
            sn = len(sc)
            fields = []
            t = 0
            while t + 2 < sn:
                if (
                    sc[t].text == "struct"
                    and sc[t + 1].text == "SchedulerConfig"
                    and sc[t + 2].text == "{"
                ):
                    depth = 1
                    m = t + 3
                    while m < sn and depth > 0:
                        tx = sc[m].text
                        if tx in ("(", "[", "{"):
                            depth += 1
                        elif tx in (")", "]", "}"):
                            depth -= 1
                        elif (
                            tx == "pub"
                            and depth == 1
                            and m + 2 < sn
                            and sc[m + 1].kind == "ident"
                            and sc[m + 2].text == ":"
                        ):
                            doc = ""
                            p = schedfl.code[m]
                            while p > 0:
                                p -= 1
                                tk = schedfl.toks[p]
                                if tk.kind == "doc":
                                    doc = "%s %s" % (tk.text, doc)
                                elif tk.kind == "comment":
                                    continue
                                else:
                                    break
                            fields.append((
                                sc[m + 1].text,
                                sc[m + 1].line,
                                extract_flags(doc),
                            ))
                        m += 1
                    break
                t += 1
            table_set = [f2 for f2, _ in table_flags]
            for field, line, doc_flags in fields:
                kebab = field.replace("_", "-")
                cands = [kebab]
                for d in doc_flags:
                    if d not in cands:
                        cands.append(d)
                wired = [
                    c2 for c2 in cands if any(u == c2 for u, _ in used)
                ]
                if not wired:
                    findings.append((
                        SCHED_RS,
                        line,
                        "R7",
                        "SchedulerConfig field `%s` has no CLI flag in "
                        "main.rs (name its `--flag` in the field's doc "
                        "comment)" % field,
                    ))
                elif not any(w in table_set for w in wired):
                    findings.append((
                        SCHED_RS,
                        line,
                        "R7",
                        "SchedulerConfig flag `--%s` is missing from "
                        "the README flag table" % wired[0],
                    ))

    # ---- suppression ----
    kept = []
    for fi in findings:
        path, line, rule, _msg = fi
        suppressed = False
        if rule != "R0":
            fl = lexmap.get(path)
            if fl is not None:
                lines = fl.allows.get(rule)
                if lines is not None and line in lines:
                    suppressed = True
        if not suppressed:
            kept.append(fi)

    return kept, len(files)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv):
    root = None
    dump_file = None
    i = 1
    while i < len(argv):
        a = argv[i]
        if a == "--root" and i + 1 < len(argv):
            root = argv[i + 1]
            i += 2
        elif a == "--dump-tokens" and i + 1 < len(argv):
            dump_file = argv[i + 1]
            i += 2
        else:
            sys.stderr.write(
                "usage: lint.py [--root DIR] [--dump-tokens FILE]\n"
            )
            return 2
    if dump_file is not None:
        try:
            with open(dump_file, "rb") as fh:
                data = fh.read()
        except OSError as e:
            sys.stderr.write("error: %s\n" % e)
            return 1
        sys.stdout.write(dump(data.decode("utf-8", errors="replace")))
        return 0
    if root is None:
        here = os.path.abspath(__file__)
        root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    findings, files_scanned = run(root)
    sys.stdout.write(render(findings, files_scanned))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
