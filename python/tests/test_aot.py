"""AOT lowering sanity: manifests, HLO text, shape bookkeeping."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.configs import CONFIGS, TINY, Variant, parse_variant, table1_grid


def test_variant_tag_roundtrip():
    for var in (Variant("mha"), Variant("ropelite"),
                Variant("gqa", n_kv_heads=2),
                Variant("elitekv", r=8, d_ckv=128),
                Variant("slrd", r=4, d_ck=32, d_cv=64)):
        assert parse_variant(var.tag()) == var


def test_table1_grid_ratios():
    for cfg_name in ("tiny", "small"):
        cfg = CONFIGS[cfg_name]
        for label, var in table1_grid(cfg):
            assert abs(var.cache_ratio(cfg) - float(label) / 100) < 0.005, \
                (cfg_name, label, var.tag(), var.cache_ratio(cfg))


def test_core_pairs_unique_and_parseable():
    pairs = aot.core_pairs()
    for cname, tag in pairs:
        assert cname in CONFIGS
        parse_variant(tag)  # must not raise


def test_build_train_step_io_spec():
    var = Variant("elitekv", r=4, d_ckv=64)
    fn, in_sds, io = aot.build_train_step(TINY, var, 2, 16)
    n_params = len(M.param_specs(TINY, var))
    # params + m + v + step + lr + extras + tokens + targets + mask
    assert len(in_sds) == 3 * n_params + 2 + 1 + 3
    assert len(io.inputs) == len(in_sds)
    assert io.outputs[-2]["name"] == "loss"
    # output count: params*3 + step + loss + gnorm
    assert len(io.outputs) == 3 * n_params + 3


def test_lower_small_function_produces_hlo(tmp_path):
    """Lower the cheapest entry point end-to-end and check HLO text."""
    fn, in_sds, io = aot.build_ropelite_delta(TINY, 1, 16)
    lowered = jax.jit(fn, keep_unused=True).lower(*in_sds)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_manifest_written(tmp_path):
    aot.lower_pair(TINY, Variant("gqa", n_kv_heads=2), str(tmp_path),
                   only_fns={"eval_loss"})
    mpath = tmp_path / "tiny_gqa2.json"
    assert mpath.exists()
    man = json.loads(mpath.read_text())
    assert man["cache_per_token"] == 2 * 2 * TINY.d_head
    assert "eval_loss" in man["functions"]
    f = man["functions"]["eval_loss"]
    assert (tmp_path / f["file"]).exists()
    assert f["inputs"][0]["name"] == "param:embed"
    assert f["outputs"][0]["name"] == "sum_nll"


def test_decode_cache_io_order_matches_cache_specs():
    var = Variant("elitekv", r=2, d_ckv=32)
    fn, in_sds, io = aot.build_decode(TINY, var, 2, 64)
    cspecs = M.cache_specs(TINY, var, 2, 64)
    cache_inputs = [i for i in io.inputs if i["name"].startswith("cache:")]
    assert [i["name"][6:] for i in cache_inputs] == [n for n, _ in cspecs]
    cache_outputs = [o for o in io.outputs if o["name"].startswith("cache:")]
    assert [o["name"][6:] for o in cache_outputs] == [n for n, _ in cspecs]
    for i, (n, s) in zip(cache_inputs, cspecs):
        assert tuple(i["shape"]) == tuple(s)
