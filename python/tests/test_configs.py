"""Config/variant bookkeeping: cache formulas, grids, storage costs.

These assertions are mirrored by rust/src/config tests — the two layers
must agree on every geometry number or artifacts and runtime diverge.
"""

import numpy as np
import pytest

from compile import lrd
from compile.configs import (CONFIGS, SMALL, TINY, Variant, parse_variant,
                             table1_grid)


def test_chunk_count_is_half_head_dim():
    for cfg in CONFIGS.values():
        assert cfg.n_chunks == cfg.d_head // 2
        assert cfg.kv_elems_per_token == 2 * cfg.n_heads * cfg.d_head


def test_mha_structural_assumption():
    # The paper's storage simplifications assume d = n_h * d_h.
    for cfg in CONFIGS.values():
        assert cfg.d_model == cfg.n_heads * cfg.d_head


@pytest.mark.parametrize("cfg", [TINY, SMALL], ids=lambda c: c.name)
def test_cache_per_token_formulas(cfg):
    assert Variant("mha").cache_per_token(cfg) == 2 * cfg.n_heads * cfg.d_head
    g = Variant("gqa", n_kv_heads=2)
    assert g.cache_per_token(cfg) == 2 * 2 * cfg.d_head
    e = Variant("elitekv", r=4, d_ckv=64)
    assert e.cache_per_token(cfg) == 2 * 4 * cfg.n_heads + 64
    s = Variant("slrd", r=4, d_ck=32, d_cv=64)
    assert s.cache_per_token(cfg) == 2 * 4 * cfg.n_heads + 96


def test_ropelite_cache_is_full_size():
    # §3.1: RoPElite alone does not shrink the cache.
    for cfg in CONFIGS.values():
        assert (Variant("ropelite").cache_per_token(cfg)
                == Variant("mha").cache_per_token(cfg))


@pytest.mark.parametrize("cfg", [TINY, SMALL], ids=lambda c: c.name)
def test_grid_is_monotone_in_cache(cfg):
    grid = table1_grid(cfg)
    ratios = [float(label) for label, _ in grid]
    assert ratios == sorted(ratios, reverse=True)


@pytest.mark.parametrize("cfg", [TINY, SMALL], ids=lambda c: c.name)
def test_grid_no_extra_parameters(cfg):
    """Appendix C: converted variants must not add parameters."""
    base = lrd.storage_cost(cfg, Variant("mha"))
    for _, var in table1_grid(cfg):
        if var.kind == "elitekv":
            assert lrd.storage_cost(cfg, var) <= base, var.tag()


def test_parse_variant_rejects_garbage():
    for bad in ("mla", "elitekv", "gqa", "slrd_r4", "elitekv_r4"):
        with pytest.raises((ValueError, IndexError)):
            parse_variant(bad)


def test_jlrd_vs_slrd_cache_at_equal_params():
    """§3.2: at (approximately) equal parameter budgets J-LRD yields a
    strictly smaller cache than any S-LRD split (shared latent)."""
    cfg = SMALL
    r = 8
    var_j = Variant("elitekv", r=r, d_ckv=128)
    pj = lrd.storage_cost(cfg, var_j)
    cache_j = var_j.cache_per_token(cfg)
    found_comparable = False
    for ck in range(32, 512, 32):
        for cv in range(32, 512, 32):
            var_s = Variant("slrd", r=r, d_ck=ck, d_cv=cv)
            if abs(lrd.storage_cost(cfg, var_s) - pj) <= cfg.d_model:
                found_comparable = True
                assert var_s.cache_per_token(cfg) >= cache_j, (ck, cv)
    assert found_comparable
