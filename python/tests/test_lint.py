"""Tests for python/tools/lint.py — the toolchain-less lint runner.

Four layers:

* **Self-application** — linting this repository reports clean. In a
  container without the Rust toolchain this test IS the executable form
  of the project-contract audit (ROADMAP standing item).
* **Golden fixture report** — the fake mini-repo under
  ``rust/tests/lint_fixtures/`` makes every rule R0-R8 fire at least
  once; the rendered report is pinned to ``rust/tests/lint_expected.txt``
  (the same golden the Rust suite in ``rust/tests/lint_tool.rs`` pins,
  so both runners are anchored to one byte-exact artifact).
* **Lexer edge cases** — the literal forms that defeat naive scanners:
  raw strings with hash depths, quotes inside chars, nested block
  comments, byte/C strings, raw identifiers.
* **Seeded soup invariants** — a port of the Rust prop harness
  (``util/prop.rs`` seeding: fnv1a(name) ^ ELITEKV_PROP_SEED, one Pcg64
  stream per case) drives the same random token soups the Rust
  differential test feeds both lexers, checking totality and lossless
  span coverage on this side.
"""

import importlib.util
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
LINT_PY = os.path.join(REPO, "python", "tools", "lint.py")
FIXTURES = os.path.join(REPO, "rust", "tests", "lint_fixtures")
GOLDEN = os.path.join(REPO, "rust", "tests", "lint_expected.txt")

_spec = importlib.util.spec_from_file_location("elitekv_lint", LINT_PY)
lint = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(lint)


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------


def test_repo_is_clean():
    findings, scanned = lint.run(REPO)
    assert scanned > 0
    assert findings == [], lint.render(findings, scanned)


def test_fixture_report_matches_golden():
    findings, scanned = lint.run(FIXTURES)
    with open(GOLDEN, "r", encoding="utf-8") as fh:
        golden = fh.read()
    assert lint.render(findings, scanned) == golden, (
        "fixture report drifted; regenerate with `python3 "
        "python/tools/lint.py --root rust/tests/lint_fixtures > "
        "rust/tests/lint_expected.txt` if the change is intended"
    )


def test_fixture_corpus_fires_every_rule():
    findings, _ = lint.run(FIXTURES)
    fired = {rule for (_, _, rule, _) in findings}
    assert fired == {"R0", "R1", "R2", "R3", "R4", "R5", "R6", "R7", "R8"}


def test_cli_exit_codes():
    clean = subprocess.run(
        [sys.executable, LINT_PY, "--root", REPO],
        capture_output=True,
        text=True,
    )
    assert clean.returncode == 0, clean.stdout
    assert clean.stdout.startswith("lint: clean")
    dirty = subprocess.run(
        [sys.executable, LINT_PY, "--root", FIXTURES],
        capture_output=True,
        text=True,
    )
    assert dirty.returncode == 1
    usage = subprocess.run(
        [sys.executable, LINT_PY, "--no-such-flag"],
        capture_output=True,
        text=True,
    )
    assert usage.returncode == 2


def test_cli_dump_tokens_matches_module_dump(tmp_path):
    src = 'fn f() { r#"raw " inside"# }\n'
    p = tmp_path / "snippet.rs"
    p.write_text(src, encoding="utf-8")
    out = subprocess.run(
        [sys.executable, LINT_PY, "--dump-tokens", str(p)],
        capture_output=True,
        text=True,
    )
    assert out.returncode == 0
    assert out.stdout == lint.dump(src)


# ---------------------------------------------------------------------------
# Lexer edge cases
# ---------------------------------------------------------------------------


def test_raw_string_with_quote_is_one_token():
    toks, errs = lint.lex('let s = r#"has " quote"#;')
    assert not errs
    strs = [t for t in toks if t.kind == "str"]
    assert [t.text for t in strs] == ['r#"has " quote"#']


def test_raw_string_hash_depths():
    toks, errs = lint.lex('r##"inner "# close"## r"plain"')
    assert not errs
    assert [t.text for t in toks] == ['r##"inner "# close"##', 'r"plain"']


def test_byte_and_c_strings():
    toks, errs = lint.lex("b\"by\" br#\"rb\"# c\"cs\" cr#\"rc\"# b'x'")
    assert not errs
    assert [t.kind for t in toks] == ["str", "str", "str", "str", "char"]


def test_char_quote_and_lifetime_disambiguation():
    toks, errs = lint.lex("'\"' 'a' '\\'' 'static '_")
    assert not errs
    assert [t.kind for t in toks] == [
        "char",
        "char",
        "char",
        "lifetime",
        "lifetime",
    ]


def test_nested_block_comment_with_quotes():
    toks, errs = lint.lex('/* outer "quote /* inner */ still */ fn')
    assert not errs
    assert [t.kind for t in toks] == ["comment", "ident"]


def test_doc_comment_classification():
    cases = [
        ("/// d", "doc"),
        ("//! d", "doc"),
        ("//// not doc", "comment"),
        ("// plain", "comment"),
        ("/** d */", "doc"),
        ("/*! d */", "doc"),
        ("/*** not doc ***/", "comment"),
        ("/**/", "comment"),
    ]
    for src, want in cases:
        toks, errs = lint.lex(src)
        assert not errs, src
        assert [t.kind for t in toks] == [want], src


def test_raw_identifier_and_macro_hash():
    toks, errs = lint.lex("r#match x! # [cfg]")
    assert not errs
    assert [(t.kind, t.text) for t in toks][0] == ("ident", "r#match")


def test_unterminated_forms_are_total():
    for src, msg in [
        ('"open', "unterminated string literal"),
        ('r##"open"#', "unterminated raw string literal"),
        ("/* open", "unterminated block comment"),
        ("'\\n", "unterminated character literal"),
    ]:
        toks, errs = lint.lex(src)
        assert len(toks) == 1, src
        assert [m for (_, m) in errs] == [msg], src
    # A lone quote at end of input is a harmless punct, not an error.
    toks, errs = lint.lex("'")
    assert [t.kind for t in toks] == ["punct"]
    assert errs == []


def test_util_json_raw_strings_lex_clean():
    # Regression: the PR-5 ad-hoc bracket scanner miscounted the raw
    # strings in util/json.rs; the real lexer must not.
    path = os.path.join(REPO, "rust", "src", "util", "json.rs")
    toks, errs = lint.lex(lint.read_text(path))
    assert not errs
    depth = 0
    for t in toks:
        if t.kind == "punct" and t.text in "([{":
            depth += 1
        elif t.kind == "punct" and t.text in ")]}":
            depth -= 1
            assert depth >= 0
    assert depth == 0


# ---------------------------------------------------------------------------
# Seeded soup invariants (port of util/prop.rs + the Rust generator)
# ---------------------------------------------------------------------------

M64 = (1 << 64) - 1
M128 = (1 << 128) - 1
PCG_MUL = 0x2360ED051FC65DA44385DF649FCCF645


class Pcg64:
    """Port of rust/src/util/rng.rs (PCG-XSL-RR 128/64)."""

    def __init__(self, seed, seq):
        self.inc = (((seq & M64) << 1) | 1) & M128
        self.state = 0
        self.next_u64()
        self.state = (self.state + (seed & M64)) & M128
        self.next_u64()

    def next_u64(self):
        self.state = (self.state * PCG_MUL + self.inc) & M128
        rot = self.state >> 122
        xsl = ((self.state >> 64) ^ self.state) & M64
        return ((xsl >> rot) | (xsl << ((64 - rot) % 64))) & M64

    def below(self, n):
        # Lemire's method, matching rng.rs bit-for-bit.
        while True:
            m = self.next_u64() * n
            lo = m & M64
            if lo >= n or lo >= (M64 - n + 1) % n:
                return m >> 64

    def range(self, lo, hi):
        return lo + self.below(hi - lo)

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def chance(self, p):
        return self.f64() < p


def fnv1a(name):
    h = 0xCBF29CE484222325
    for b in name.encode("utf-8"):
        h ^= b
        h = (h * 0x100000001B3) & M64
    return h


def env_seed():
    raw = os.environ.get("ELITEKV_PROP_SEED", "").strip()
    if not raw:
        return 0
    try:
        if raw.lower().startswith("0x"):
            return int(raw[2:], 16)
        return int(raw)
    except ValueError:
        return 0


def env_cases(default):
    raw = os.environ.get("ELITEKV_PROP_CASES", "").strip()
    try:
        n = int(raw)
    except ValueError:
        return default
    return n if n > 0 else default


# Mirrors the SOUP/SEP/TAIL tables in rust/tests/lint_tool.rs exactly:
# same fragments, same order, same generator call sequence, so a given
# (name, seed, case) produces the identical soup on both sides.
SOUP = [
    "fn",
    "ident",
    "x7",
    "r#match",
    "_",
    "déjà_vu",
    "0",
    "42",
    "0x1f",
    "1.5e-3",
    "1_000u64",
    '"str \\" esc"',
    '"multi\nline"',
    'b"bytes"',
    'c"cstr"',
    'r"raw"',
    'r#"has " quote"#',
    'r##"nest "# deeper"##',
    'br#"raw bytes"#',
    "'a'",
    "'\\n'",
    "'\"'",
    "b'x'",
    "'static",
    "'_",
    "// line comment\n",
    "/// doc\n",
    "//! inner\n",
    "/* block */",
    "/* nested /* deep */ still */",
    "{",
    "}",
]
SEP = ["", " ", "\n", "\t", "  "]
TAIL = ['"never closed', "/* never closed", 'r##"never closed"#', "'"]


def gen_soup(rng):
    n = rng.range(1, 40)
    parts = []
    for _ in range(n):
        parts.append(SOUP[rng.range(0, len(SOUP))])
        parts.append(SEP[rng.range(0, len(SEP))])
    if rng.chance(0.15):
        parts.append(TAIL[rng.range(0, len(TAIL))])
    return "".join(parts)


def soups(name, cases):
    base = fnv1a(name) ^ env_seed()
    for case in range(env_cases(cases)):
        yield case, gen_soup(Pcg64(base, case))


def test_soup_lexing_is_total_and_lossless():
    # Same corpus the Rust differential test feeds both lexers.
    for name, cases in [
        ("lint.lexer.differential", 24),
        ("lint.lexer.lossless", 64),
    ]:
        for case, soup in soups(name, cases):
            toks, _errs = lint.lex(soup)
            prev = 0
            for t in toks:
                assert prev <= t.start < t.end <= len(soup), (name, case)
                gap = soup[prev : t.start]
                assert gap.strip() == "", (name, case, gap)
                assert soup[t.start : t.end] == t.text, (name, case)
                prev = t.end
            assert soup[prev:].strip() == "", (name, case)


def test_soup_dump_is_deterministic_and_parseable():
    for case, soup in soups("lint.lexer.deterministic", 16):
        d1 = lint.dump(soup)
        assert d1 == lint.dump(soup), case
        toks, errs = lint.lex(soup)
        lines = d1.splitlines()
        assert len(lines) == len(toks) + len(errs), case
        for line in lines:
            head = line.split(" ", 2)[0]
            if head.startswith("error:"):
                continue
            ln, _, col = head.partition(":")
            assert ln.isdigit() and col.isdigit(), line
