"""Weight-surgery + low-rank decomposition correctness (paper §3.2)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import lrd
from compile import model as M
from compile.configs import TINY, Variant

RNG = np.random.RandomState(13)


def _np_params(p):
    return {k: np.asarray(v) for k, v in p.items()}


def _random_elite(r, seed=0):
    rng = np.random.RandomState(seed)
    e = np.stack([
        np.stack([rng.choice(TINY.n_chunks, size=r, replace=False)
                  for _ in range(TINY.n_heads)])
        for _ in range(TINY.n_layers)])
    return e.astype(np.int64)


def test_head_permutation_is_permutation():
    e = np.asarray([3, 0, 7])
    perm = lrd.head_permutation(e, TINY.d_head)
    assert sorted(perm.tolist()) == list(range(TINY.d_head))
    assert perm[0] == 6 and perm[1] == 7  # chunk 3 -> dims 6,7 first


def test_full_rank_jlrd_equals_ropelite():
    """THE exactness invariant: full-rank J-LRD conversion of an MHA model
    must reproduce the RoPElite model (same elite set) to f32 noise."""
    r = 4
    elite = _random_elite(r, seed=1)
    p_mha = _np_params(M.init_params(TINY, Variant("mha"), 31))
    d_full = min(TINY.d_model,
                 2 * TINY.n_heads * TINY.d_head - 2 * r * TINY.n_heads)
    var_kv = Variant("elitekv", r=r, d_ckv=d_full)
    p_kv = lrd.convert_elitekv(TINY, p_mha, elite, d_full)
    ex_kv = {"theta_e": jnp.asarray(lrd.elite_thetas(TINY, elite))}
    var_rl = Variant("ropelite")
    ex_rl = {"elite_mask": jnp.asarray(lrd.elite_mask(TINY, elite))}
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (2, 20)), jnp.int32)
    out_rl = M.forward(TINY, var_rl, {k: jnp.asarray(v) for k, v in
                                      p_mha.items()}, ex_rl, toks)
    out_kv = M.forward(TINY, var_kv, {k: jnp.asarray(v) for k, v in
                                      p_kv.items()}, ex_kv, toks)
    np.testing.assert_allclose(np.asarray(out_kv), np.asarray(out_rl),
                               atol=2e-3, rtol=1e-3)


def test_full_rank_slrd_equals_ropelite():
    r = 4
    elite = _random_elite(r, seed=2)
    p_mha = _np_params(M.init_params(TINY, Variant("mha"), 32))
    d_ck = min(TINY.d_model, TINY.n_heads * (TINY.d_head - 2 * r))
    d_cv = min(TINY.d_model, TINY.n_heads * TINY.d_head)
    var = Variant("slrd", r=r, d_ck=d_ck, d_cv=d_cv)
    p_s = lrd.convert_slrd(TINY, p_mha, elite, d_ck, d_cv)
    ex = {"theta_e": jnp.asarray(lrd.elite_thetas(TINY, elite))}
    ex_rl = {"elite_mask": jnp.asarray(lrd.elite_mask(TINY, elite))}
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (2, 16)), jnp.int32)
    out_rl = M.forward(TINY, Variant("ropelite"),
                       {k: jnp.asarray(v) for k, v in p_mha.items()},
                       ex_rl, toks)
    out_s = M.forward(TINY, var, {k: jnp.asarray(v) for k, v in p_s.items()},
                      ex, toks)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_rl),
                               atol=2e-3, rtol=1e-3)


def test_svd_truncation_error_monotone():
    """Reconstruction error decreases as rank grows; full rank is exact."""
    w = RNG.randn(64, 96).astype(np.float32)
    errs = []
    for rank in (4, 8, 16, 32, 64):
        a, b = lrd.svd_truncate(w, rank)
        errs.append(float(np.linalg.norm(w - a @ b)))
    assert all(e1 >= e2 - 1e-5 for e1, e2 in zip(errs, errs[1:])), errs
    assert errs[-1] < 1e-3


def test_svd_is_optimal_rank_r():
    """Eckart–Young: SVD truncation beats a random projection of same rank."""
    w = RNG.randn(48, 80).astype(np.float32)
    rank = 8
    a, b = lrd.svd_truncate(w, rank)
    err_svd = np.linalg.norm(w - a @ b)
    q, _ = np.linalg.qr(RNG.randn(48, rank))
    err_rand = np.linalg.norm(w - q @ (q.T @ w))
    assert err_svd <= err_rand + 1e-5


def test_jlrd_beats_slrd_at_equal_cache():
    """Paper §4.3.2: at a fixed KV cache budget, J-LRD's joint factorization
    reconstructs [W_k_ne | W_v] at least as well as the best S-LRD split
    in aggregate (shared-information argument)."""
    d, cols_k, cols_v = 96, 64, 128
    base = RNG.randn(d, 32).astype(np.float32)
    wk = base @ RNG.randn(32, cols_k).astype(np.float32)
    wv = base @ RNG.randn(32, cols_v).astype(np.float32)
    wk += 0.05 * RNG.randn(*wk.shape).astype(np.float32)
    wv += 0.05 * RNG.randn(*wv.shape).astype(np.float32)
    budget = 40
    a, b = lrd.svd_truncate(np.concatenate([wk, wv], 1), budget)
    err_j = np.linalg.norm(np.concatenate([wk, wv], 1) - a @ b)
    best_s = np.inf
    for ck in range(8, budget - 7, 8):
        cv = budget - ck
        ak, bk = lrd.svd_truncate(wk, ck)
        av, bv = lrd.svd_truncate(wv, cv)
        err = np.sqrt(np.linalg.norm(wk - ak @ bk) ** 2
                      + np.linalg.norm(wv - av @ bv) ** 2)
        best_s = min(best_s, err)
    assert err_j <= best_s + 1e-4, (err_j, best_s)


def test_gqa_mean_pool_identity_when_full_groups():
    p = _np_params(M.init_params(TINY, Variant("mha"), 33))
    out = lrd.convert_gqa(TINY, p, TINY.n_heads)
    for k in p:
        np.testing.assert_array_equal(out[k], p[k])


def test_gqa_conversion_shapes():
    p = _np_params(M.init_params(TINY, Variant("mha"), 34))
    g = 2
    out = lrd.convert_gqa(TINY, p, g)
    assert out["l0.wk"].shape == (TINY.d_model, g * TINY.d_head)
    # forward runs with converted params
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (1, 8)), jnp.int32)
    logits = M.forward(TINY, Variant("gqa", n_kv_heads=g),
                       {k: jnp.asarray(v) for k, v in out.items()}, {}, toks)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_storage_cost_formulas():
    """Storage formulas reduce per the paper under the MHA assumption."""
    d, nh, dh = TINY.d_model, TINY.n_heads, TINY.d_head
    assert d == nh * dh  # MHA structural assumption of the paper
    r, ckv = 4, 64
    var = Variant("elitekv", r=r, d_ckv=ckv)
    got = lrd.storage_cost(TINY, var)
    simplified = 2 * r * nh * d + 3 * ckv * d - 2 * ckv * r * nh
    assert got == simplified
    var_s = Variant("slrd", r=r, d_ck=32, d_cv=64)
    got_s = lrd.storage_cost(TINY, var_s)
    dck, dcv = 32, 64
    simplified_s = (2 * dck + 2 * dcv + 2 * r * nh) * d - 2 * dck * r * nh
    assert got_s == simplified_s


def test_jlrd_cache_smaller_at_equal_params():
    """Paper's headline for J-LRD: same parameter budget -> smaller cache."""
    r = 4
    var_j = Variant("elitekv", r=r, d_ckv=96)
    params_j = lrd.storage_cost(TINY, var_j)
    # find the S-LRD config with the same params and best (smallest) cache
    best_cache = None
    for ck in range(16, 256, 16):
        for cv in range(16, 256, 16):
            var_s = Variant("slrd", r=r, d_ck=ck, d_cv=cv)
            if abs(lrd.storage_cost(TINY, var_s) - params_j) < 2000:
                c = var_s.cache_per_token(TINY)
                best_cache = c if best_cache is None else min(best_cache, c)
    assert best_cache is None or var_j.cache_per_token(TINY) <= best_cache
