"""Rust-native-backend parity oracle.

`RustModel` below is a line-by-line transcription of the native decode
path in rust/src/native/model.rs — same flat arrays, same index
arithmetic, same loop structure — checked against this package's jnp
model. Any logic/indexing drift between the two implementations shows
up as a numeric mismatch here, with no Rust toolchain needed.

KEEP IN SYNC: if rust/src/native/model.rs changes its equations or
cache layout, mirror the change here (and vice versa).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.configs import ModelConfig, Variant

rng = np.random.default_rng(0)

cfg = ModelConfig(name="t", d_model=32, n_layers=2, n_heads=4, d_head=8,
                  d_ffn=24, vocab=48, max_seq=16)

EPS = 1e-5

# ---------------------------------------------------------------------------
# Rust transcription (f64 numpy, mirroring rust/src/native exactly)
# ---------------------------------------------------------------------------

def ladder(base, nc):
    return [base ** (-i / nc) for i in range(nc)]

def rotate_pair(x, i0, ang):
    s, c = np.sin(ang), np.cos(ang)
    x0, x1 = x[i0], x[i0 + 1]
    x[i0] = x0 * c - x1 * s
    x[i0 + 1] = x0 * s + x1 * c

def rope_full(x, heads, dh, lad, pos):
    nc = dh // 2
    for h in range(heads):
        base = h * dh
        for ci, theta in enumerate(lad):
            rotate_pair(x, base + 2 * ci, pos * theta)

def rope_masked(x, heads, dh, lad, mask, pos):
    nc = dh // 2
    for h in range(heads):
        base = h * dh
        for ci, theta in enumerate(lad):
            if mask[h * nc + ci] != 0.0:
                rotate_pair(x, base + 2 * ci, pos * theta)

def rope_elite(x, heads, span, r, theta_e, pos):
    for h in range(heads):
        base = h * span
        for i in range(r):
            theta = theta_e[h * r + i]
            rotate_pair(x, base + 2 * i, pos * theta)

def rmsnorm(x, g):
    ms = float(np.mean(x * x))
    scale = 1.0 / np.sqrt(ms + EPS)
    return x * scale * g

def softmax(s):
    m = np.max(s)
    e = np.exp(s - m)
    return e / np.sum(e)

class RustModel:
    """Flat-weight mirror of NativeModel."""

    def __init__(self, cfg, var, params, sel):
        self.cfg = cfg
        self.var = var
        # flat f64 weights, same names
        self.w = {k: np.asarray(v, np.float64) for k, v in params.items()}
        self.ladder = ladder(cfg.rope_base, cfg.n_chunks)
        nh, nc = cfg.n_heads, cfg.n_chunks
        L = cfg.n_layers
        self.theta_e = np.zeros(0)
        self.elite_mask = np.zeros(0)
        if var.kind in ("elitekv", "slrd"):
            t = np.zeros(L * nh * var.r)
            for l in range(L):
                for h in range(nh):
                    for i, c in enumerate(sel[l][h]):
                        # f32 round-trip like rust's `as f32` table
                        t[(l * nh + h) * var.r + i] = np.float32(
                            cfg.rope_base ** (-c / nc))
            self.theta_e = t
        if var.kind == "ropelite":
            m = np.zeros(L * nh * nc)
            for l in range(L):
                for h in range(nh):
                    for c in sel[l][h]:
                        m[(l * nh + h) * nc + c] = 1.0
            self.elite_mask = m

    def empty_caches(self, b, s):
        cfg, var = self.cfg, self.var
        L, nh, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
        if var.kind in ("mha", "ropelite"):
            shapes = [(L, b, s, nh, dh), (L, b, s, nh, dh)]
        elif var.kind == "gqa":
            g = var.n_kv_heads
            shapes = [(L, b, s, g, dh), (L, b, s, g, dh)]
        elif var.kind == "elitekv":
            shapes = [(L, b, s, nh, 2 * var.r), (L, b, s, var.d_ckv)]
        else:
            shapes = [(L, b, s, nh, 2 * var.r), (L, b, s, var.d_ck),
                      (L, b, s, var.d_cv)]
        return [np.zeros(int(np.prod(sh))) for sh in shapes], shapes

    def rotate_q(self, layer, pos, q):
        cfg, var = self.cfg, self.var
        nh, dh, nc = cfg.n_heads, cfg.d_head, cfg.n_chunks
        if var.kind in ("mha", "gqa"):
            rope_full(q, nh, dh, self.ladder, pos)
        elif var.kind == "ropelite":
            m = self.elite_mask[layer * nh * nc:(layer + 1) * nh * nc]
            rope_masked(q, nh, dh, self.ladder, m, pos)
        else:
            r = var.r
            t = self.theta_e[layer * nh * r:(layer + 1) * nh * r]
            rope_elite(q, nh, dh, r, t, pos)

    def decode_token(self, caches, lane, pos, token, b, s):
        cfg, var = self.cfg, self.var
        d, nh, dh, nc = cfg.d_model, cfg.n_heads, cfg.d_head, cfg.n_chunks
        length = pos + 1
        scale = 1.0 / np.sqrt(dh)
        x = self.w["embed"][token].copy()
        for l in range(cfg.n_layers):
            p = f"l{l}."
            xn = rmsnorm(x, self.w[p + "attn_norm"])
            q = xn @ self.w[p + "wq"]
            self.rotate_q(l, pos, q)
            o = np.zeros(nh * dh)
            if var.kind in ("mha", "ropelite", "gqa"):
                g = var.n_kv_heads if var.kind == "gqa" else nh
                kw = g * dh
                k = xn @ self.w[p + "wk"]
                v = xn @ self.w[p + "wv"]
                if var.kind == "ropelite":
                    m = self.elite_mask[l * nh * nc:(l + 1) * nh * nc]
                    rope_masked(k, nh, dh, self.ladder, m, pos)
                else:
                    rope_full(k, g, dh, self.ladder, pos)
                base = ((l * b + lane) * s + pos) * kw
                caches[0][base:base + kw] = k
                caches[1][base:base + kw] = v
                kc, vc = caches[0], caches[1]
                lane_base = (l * b + lane) * s
                rep = nh // g
                for h in range(nh):
                    hk = h // rep
                    qh = q[h * dh:(h + 1) * dh]
                    sco = np.zeros(length)
                    for j in range(length):
                        off = (lane_base + j) * kw + hk * dh
                        sco[j] = qh @ kc[off:off + dh] * scale
                    pr = softmax(sco)
                    oh = o[h * dh:(h + 1) * dh]
                    for j in range(length):
                        off = (lane_base + j) * kw + hk * dh
                        oh += pr[j] * vc[off:off + dh]
            elif var.kind == "elitekv":
                r, d_ckv = var.r, var.d_ckv
                r2 = 2 * r
                dn = dh - r2
                kew = nh * r2
                ke = xn @ self.w[p + "wk_e"]
                t = self.theta_e[l * nh * r:(l + 1) * nh * r]
                rope_elite(ke, nh, r2, r, t, pos)
                lat = xn @ self.w[p + "a_kv"]
                ke_base = ((l * b + lane) * s + pos) * kew
                caches[0][ke_base:ke_base + kew] = ke
                c_base = ((l * b + lane) * s + pos) * d_ckv
                caches[1][c_base:c_base + d_ckv] = lat
                bk = self.w[p + "b_k"].reshape(-1)  # row-major [C, nh*dn]
                q_lat = np.zeros(nh * d_ckv)
                for cci in range(d_ckv):
                    row = bk[cci * nh * dn:(cci + 1) * nh * dn]
                    for h in range(nh):
                        qn = q[h * dh + r2:(h + 1) * dh]
                        q_lat[h * d_ckv + cci] = qn @ row[h * dn:(h + 1) * dn]
                kec, cc_all = caches[0], caches[1]
                lane_ke = (l * b + lane) * s
                lane_c = (l * b + lane) * s
                bv = self.w[p + "b_v"].reshape(-1)  # [C, nh*dh]
                for h in range(nh):
                    q_rot = q[h * dh:h * dh + r2]
                    ql = q_lat[h * d_ckv:(h + 1) * d_ckv]
                    sco = np.zeros(length)
                    for j in range(length):
                        ke_off = (lane_ke + j) * kew + h * r2
                        c_off = (lane_c + j) * d_ckv
                        sco[j] = (q_rot @ kec[ke_off:ke_off + r2]
                                  + ql @ cc_all[c_off:c_off + d_ckv]) * scale
                    pr = softmax(sco)
                    o_lat = np.zeros(d_ckv)
                    for j in range(length):
                        c_off = (lane_c + j) * d_ckv
                        o_lat += pr[j] * cc_all[c_off:c_off + d_ckv]
                    oh = o[h * dh:(h + 1) * dh]
                    for cci in range(d_ckv):
                        row = bv[cci * nh * dh + h * dh:
                                 cci * nh * dh + (h + 1) * dh]
                        oh += o_lat[cci] * row
            else:  # slrd
                r, d_ck, d_cv = var.r, var.d_ck, var.d_cv
                r2 = 2 * r
                dn = dh - r2
                kew = nh * r2
                ke = xn @ self.w[p + "wk_e"]
                t = self.theta_e[l * nh * r:(l + 1) * nh * r]
                rope_elite(ke, nh, r2, r, t, pos)
                ckv = xn @ self.w[p + "a_k"]
                cvv = xn @ self.w[p + "a_v"]
                ke_base = ((l * b + lane) * s + pos) * kew
                caches[0][ke_base:ke_base + kew] = ke
                ck_base = ((l * b + lane) * s + pos) * d_ck
                caches[1][ck_base:ck_base + d_ck] = ckv
                cv_base = ((l * b + lane) * s + pos) * d_cv
                caches[2][cv_base:cv_base + d_cv] = cvv
                bk = self.w[p + "b_k"].reshape(-1)
                q_lat = np.zeros(nh * d_ck)
                for cci in range(d_ck):
                    row = bk[cci * nh * dn:(cci + 1) * nh * dn]
                    for h in range(nh):
                        qn = q[h * dh + r2:(h + 1) * dh]
                        q_lat[h * d_ck + cci] = qn @ row[h * dn:(h + 1) * dn]
                kec, ck_all, cv_all = caches[0], caches[1], caches[2]
                lane_base = (l * b + lane) * s
                bv = self.w[p + "b_v"].reshape(-1)
                for h in range(nh):
                    q_rot = q[h * dh:h * dh + r2]
                    ql = q_lat[h * d_ck:(h + 1) * d_ck]
                    sco = np.zeros(length)
                    for j in range(length):
                        ke_off = (lane_base + j) * kew + h * r2
                        ck_off = (lane_base + j) * d_ck
                        sco[j] = (q_rot @ kec[ke_off:ke_off + r2]
                                  + ql @ ck_all[ck_off:ck_off + d_ck]) * scale
                    pr = softmax(sco)
                    o_lat = np.zeros(d_cv)
                    for j in range(length):
                        cv_off = (lane_base + j) * d_cv
                        o_lat += pr[j] * cv_all[cv_off:cv_off + d_cv]
                    oh = o[h * dh:(h + 1) * dh]
                    for cci in range(d_cv):
                        row = bv[cci * nh * dh + h * dh:
                                 cci * nh * dh + (h + 1) * dh]
                        oh += o_lat[cci] * row
            x = x + o @ self.w[p + "wo"]
            xn = rmsnorm(x, self.w[p + "ffn_norm"])
            h1 = xn @ self.w[p + "w1"]
            h3 = xn @ self.w[p + "w3"]
            hsw = (h1 / (1.0 + np.exp(-h1))) * h3
            x = x + hsw @ self.w[p + "w2"]
        xf = rmsnorm(x, self.w["final_norm"])
        return xf @ self.w["embed"].T


def run_variant(var):
    nh, nc, L = cfg.n_heads, cfg.n_chunks, cfg.n_layers
    params = M.init_params(cfg, var, 7)
    params = {k: np.asarray(v) for k, v in params.items()}
    # random distinct chunk selection per (layer, head)
    sel = [[list(rng.choice(nc, size=max(var.r, 1), replace=False))
            for _ in range(nh)] for _ in range(L)]
    extras = {}
    if var.kind == "ropelite":
        m = np.zeros((L, nh, nc), np.float32)
        for l in range(L):
            for h in range(nh):
                for c in sel[l][h]:
                    m[l, h, c] = 1.0
        extras["elite_mask"] = jnp.asarray(m)
    if var.kind in ("elitekv", "slrd"):
        t = np.zeros((L, nh, var.r), np.float32)
        for l in range(L):
            for h in range(nh):
                for i, c in enumerate(sel[l][h]):
                    t[l, h, i] = cfg.rope_base ** (-c / nc)
        extras["theta_e"] = jnp.asarray(t)

    b, s = 2, cfg.max_seq
    plen = 5
    prompts = rng.integers(1, cfg.vocab, size=(b, plen))
    tokens = np.zeros((b, s), np.int32)
    tokens[:, :plen] = prompts
    true_len = np.full((b,), plen, np.int32)

    jparams = {k: jnp.asarray(v, jnp.float32) for k, v in params.items()}
    out = M.prefill(cfg, var, jparams, extras, jnp.asarray(tokens),
                    jnp.asarray(true_len))
    j_logits, j_caches = np.asarray(out[0]), [np.asarray(c) for c in out[1:]]

    rm = RustModel(cfg, var, params, sel)
    caches, shapes = rm.empty_caches(b, s)
    r_logits = np.zeros((b, cfg.vocab))
    for lane in range(b):
        for i in range(plen):
            lg = rm.decode_token(caches, lane, i, int(tokens[lane, i]), b, s)
            if i == plen - 1:
                r_logits[lane] = lg

    dl = np.max(np.abs(r_logits - j_logits))
    # compare cache rows < plen
    dcache = 0.0
    for ci, sh in enumerate(shapes):
        mine = caches[ci].reshape(sh)
        theirs = j_caches[ci]
        assert theirs.shape == sh, (theirs.shape, sh)
        dcache = max(dcache,
                     float(np.max(np.abs(mine[:, :, :plen] -
                                         theirs[:, :, :plen]))))

    # one decode step
    tok = rng.integers(1, cfg.vocab, size=(b,))
    pos = np.full((b,), plen, np.int32)
    outs = M.decode_step(cfg, var, jparams, extras, jnp.asarray(tok, jnp.int32),
                         jnp.asarray(pos), [jnp.asarray(c) for c in j_caches])
    j_logits2 = np.asarray(outs[0])
    r_logits2 = np.zeros((b, cfg.vocab))
    for lane in range(b):
        r_logits2[lane] = rm.decode_token(caches, lane, plen,
                                          int(tok[lane]), b, s)
    dl2 = np.max(np.abs(r_logits2 - j_logits2))
    status = "OK " if max(dl, dl2, dcache) < 2e-4 else "FAIL"
    print(f"{status} {var.tag():<24} prefill-logits {dl:.2e}  "
          f"cache {dcache:.2e}  decode-logits {dl2:.2e}")
    return max(dl, dl2, dcache) < 2e-4


@pytest.mark.parametrize("var", [
    Variant("mha"),
    Variant("ropelite", r=2),
    Variant("gqa", n_kv_heads=2),
    Variant("elitekv", r=2, d_ckv=12),
    Variant("slrd", r=2, d_ck=10, d_cv=14),
], ids=lambda v: v.tag())
def test_rust_native_transcription_matches_jnp(var):
    assert run_variant(var)
