"""RoPElite search (Algorithm 1) correctness on the delta decomposition."""

import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.configs import TINY, Variant

RNG = np.random.RandomState(11)


def _qk():
    p = M.init_params(TINY, Variant("mha"), 21)
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (2, 16)), jnp.int32)
    q, k = M.capture_qk(TINY, p, toks)
    return q, k


def greedy_select(q_l, k_l, r):
    """Reference greedy driver (mirrors rust/src/search/ropelite.rs)."""
    nh, nc = TINY.n_heads, TINY.n_chunks
    mask = jnp.zeros((nh, nc))
    picks = []
    for _ in range(r):
        dist = M.ropelite_delta(TINY, q_l, k_l, mask)
        j = jnp.argmin(dist, axis=1)  # [nh]
        picks.append(np.asarray(j))
        mask = mask.at[jnp.arange(nh), j].set(1.0)
    return np.stack(picks, axis=1), mask  # [nh, r]


def test_delta_zero_when_last_chunk_added():
    """With all chunks but one elite, adding it reproduces full RoPE."""
    q, k = _qk()
    nh, nc = TINY.n_heads, TINY.n_chunks
    for col in (0, 3, nc - 1):
        mask = jnp.ones((nh, nc)).at[:, col].set(0.0)
        d = M.ropelite_delta(TINY, q[0], k[0], mask)
        assert float(jnp.max(d[:, col])) < 1e-3


def test_delta_masks_selected_chunks():
    """Already-elite chunks must be +inf so argmin never re-picks them."""
    q, k = _qk()
    nh, nc = TINY.n_heads, TINY.n_chunks
    mask = jnp.zeros((nh, nc)).at[:, 2].set(1.0)
    d = M.ropelite_delta(TINY, q[0], k[0], mask)
    assert float(jnp.min(d[:, 2])) > 1e20


def test_greedy_unique_picks_and_monotone_distance():
    q, k = _qk()
    r = 4
    picks, mask = greedy_select(q[1], k[1], r)
    for h in range(TINY.n_heads):
        assert len(set(picks[h].tolist())) == r, picks[h]
    # distance of the greedy-selected set decreases monotonically per step
    nh, nc = TINY.n_heads, TINY.n_chunks
    m = jnp.zeros((nh, nc))
    prev = None
    for i in range(r):
        d = M.ropelite_delta(TINY, q[1], k[1], m)
        best = jnp.min(d, axis=1)  # [nh]
        if prev is not None:
            assert bool(jnp.all(best <= prev + 1e-3)), i
        prev = best
        j = jnp.argmin(d, axis=1)
        m = m.at[jnp.arange(nh), j].set(1.0)


def test_greedy_beats_uniform_in_score_distance():
    """The greedy set approximates full-RoPE scores at least as well as a
    uniform frequency grid (the paper's §4.3.1 `Uniform` baseline)."""
    q, k = _qk()
    nh, nc = TINY.n_heads, TINY.n_chunks
    r = 4
    _, greedy_mask = greedy_select(q[0], k[0], r)

    def set_distance(mask):
        # distance of s_E from s_full, via the delta artifact trick:
        # pick any non-elite j and subtract its delta contribution back out.
        # Instead compute directly with one extra call: use a mask with all
        # chunks selected minus evaluation — simpler: evaluate via model fwd.
        d = M.ropelite_delta(TINY, q[0], k[0], 1.0 - (1.0 - mask))
        return d

    # Uniform grid per paper: r chunks evenly spaced.
    uni = np.zeros((nh, nc), np.float32)
    for idx in np.linspace(0, nc - 1, r).round().astype(int):
        uni[:, idx] = 1.0
    uni = jnp.asarray(uni)
    # compare ||s_full - s_E||_1 by summing min-deltas: evaluate the
    # residual with a probe chunk whose delta is ~0 (an already-elite one
    # flipped off) is fiddly; instead compare best achievable next-step
    # distance: greedy's frontier should be no worse than uniform's.
    d_greedy = float(jnp.min(M.ropelite_delta(TINY, q[0], k[0], greedy_mask)))
    d_uni = float(jnp.min(M.ropelite_delta(TINY, q[0], k[0], uni)))
    assert d_greedy <= d_uni * 1.05, (d_greedy, d_uni)


def test_contribution_scores_positive():
    q, k = _qk()
    c = M.contribution_scores(TINY, q, k)
    assert c.shape == (TINY.n_layers, TINY.n_heads, TINY.n_chunks)
    assert bool(jnp.all(c > 0))
