"""L2 model correctness across all architecture variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import TINY, Variant

RNG = np.random.RandomState(7)


def _extras(cfg, var, r_elite=4):
    if var.kind == "ropelite":
        m = jnp.zeros((cfg.n_layers, cfg.n_heads, cfg.n_chunks))
        return {"elite_mask": m.at[:, :, :r_elite].set(1.0)}
    if var.kind in ("elitekv", "slrd"):
        from compile.kernels.rope import chunk_thetas
        th = chunk_thetas(cfg.n_chunks, cfg.rope_base)[:var.r]
        return {"theta_e": jnp.broadcast_to(
            th[None, None, :], (cfg.n_layers, cfg.n_heads, var.r))}
    return {}


VARIANTS = [
    Variant("mha"),
    Variant("ropelite"),
    Variant("gqa", n_kv_heads=4),
    Variant("gqa", n_kv_heads=1),
    Variant("elitekv", r=4, d_ckv=64),
    Variant("elitekv", r=2, d_ckv=32),
    Variant("slrd", r=4, d_ck=32, d_cv=64),
]


@pytest.mark.parametrize("var", VARIANTS, ids=lambda v: v.tag())
def test_forward_shapes_finite(var):
    p = M.init_params(TINY, var, 0)
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (2, 16)), jnp.int32)
    logits = M.forward(TINY, var, p, _extras(TINY, var), toks)
    assert logits.shape == (2, 16, TINY.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("var", VARIANTS, ids=lambda v: v.tag())
def test_prefill_matches_forward(var):
    """Prefill's last-position logits == full forward logits."""
    p = M.init_params(TINY, var, 1)
    ex = _extras(TINY, var)
    b, s, t = 2, 64, 11
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (b, t)), jnp.int32)
    full = M.forward(TINY, var, p, ex, toks)
    padded = jnp.zeros((b, s), jnp.int32).at[:, :t].set(toks)
    out = M.prefill(TINY, var, p, ex, padded, jnp.asarray([t] * b, jnp.int32))
    np.testing.assert_allclose(np.asarray(out[0]),
                               np.asarray(full[:, t - 1]), atol=3e-5)


@pytest.mark.parametrize("var", VARIANTS, ids=lambda v: v.tag())
def test_decode_matches_forward(var):
    """prefill(t-1) + decode_step == forward logits at position t-1."""
    p = M.init_params(TINY, var, 2)
    ex = _extras(TINY, var)
    b, s, t = 2, 64, 9
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (b, t)), jnp.int32)
    full = M.forward(TINY, var, p, ex, toks)
    padded = jnp.zeros((b, s), jnp.int32).at[:, :t].set(toks)
    out = M.prefill(TINY, var, p, ex, padded,
                    jnp.asarray([t - 1] * b, jnp.int32))
    caches = list(out[1:])
    pos = jnp.asarray([t - 1] * b, jnp.int32)
    dec = M.decode_step(TINY, var, p, ex, toks[:, t - 1], pos, caches)
    np.testing.assert_allclose(np.asarray(dec[0]),
                               np.asarray(full[:, t - 1]), atol=3e-5)


def test_decode_multi_step_chain():
    """Decoding token-by-token reproduces full-sequence logits everywhere."""
    var = Variant("elitekv", r=4, d_ckv=64)
    p = M.init_params(TINY, var, 3)
    ex = _extras(TINY, var)
    b, s, t = 1, 64, 8
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (b, t)), jnp.int32)
    full = M.forward(TINY, var, p, ex, toks)
    padded = jnp.zeros((b, s), jnp.int32).at[:, :t].set(toks)
    out = M.prefill(TINY, var, p, ex, padded, jnp.asarray([1], jnp.int32))
    caches = list(out[1:])
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(full[:, 0]),
                               atol=3e-5)
    for i in range(1, t):
        pos = jnp.asarray([i], jnp.int32)
        dec = M.decode_step(TINY, var, p, ex, toks[:, i], pos, caches)
        caches = list(dec[1:])
        np.testing.assert_allclose(np.asarray(dec[0]),
                                   np.asarray(full[:, i]), atol=5e-5,
                                   err_msg=f"step {i}")


def test_pallas_decode_matches_jnp_decode():
    var = Variant("elitekv", r=4, d_ckv=64)
    p = M.init_params(TINY, var, 4)
    ex = _extras(TINY, var)
    b, s, t = 2, 64, 12
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (b, t)), jnp.int32)
    padded = jnp.zeros((b, s), jnp.int32).at[:, :t].set(toks)
    out = M.prefill(TINY, var, p, ex, padded,
                    jnp.asarray([t - 1] * b, jnp.int32))
    caches = list(out[1:])
    pos = jnp.asarray([t - 1] * b, jnp.int32)
    d1 = M.decode_step(TINY, var, p, ex, toks[:, t - 1], pos, caches,
                       use_pallas=False)
    d2 = M.decode_step(TINY, var, p, ex, toks[:, t - 1], pos, caches,
                       use_pallas=True)
    np.testing.assert_allclose(np.asarray(d1[0]), np.asarray(d2[0]),
                               atol=2e-5)


def test_ropelite_full_mask_equals_mha():
    """RoPElite with every chunk elite == baseline MHA (same params)."""
    var_m, var_r = Variant("mha"), Variant("ropelite")
    p = M.init_params(TINY, var_m, 5)
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (2, 12)), jnp.int32)
    full_mask = {"elite_mask": jnp.ones(
        (TINY.n_layers, TINY.n_heads, TINY.n_chunks))}
    a = M.forward(TINY, var_m, p, {}, toks)
    b = M.forward(TINY, var_r, p, full_mask, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_init_loss_near_uniform():
    var = Variant("mha")
    p = M.init_params(TINY, var, 6)
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (4, 32)), jnp.int32)
    tg = jnp.asarray(RNG.randint(0, TINY.vocab, (4, 32)), jnp.int32)
    loss = M.loss_fn(TINY, var, p, {}, toks, tg, jnp.ones((4, 32)))
    assert abs(float(loss) - np.log(TINY.vocab)) < 0.3


def test_train_step_learns_repeated_batch():
    """A few AdamW steps on one batch must drop the loss materially."""
    var = Variant("mha")
    p = M.init_params(TINY, var, 7)
    m = {k: jnp.zeros_like(x) for k, x in p.items()}
    v = {k: jnp.zeros_like(x) for k, x in p.items()}
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (4, 32)), jnp.int32)
    tg = jnp.roll(toks, -1, axis=1)
    mask = jnp.ones((4, 32))
    step = jnp.asarray(0, jnp.int32)
    losses = []
    jit_step = jax.jit(lambda p, m, v, s: M.train_step(
        TINY, var, p, m, v, s, jnp.float32(3e-3), {}, toks, tg, mask))
    for _ in range(12):
        p, m, v, step, loss, gnorm = jit_step(p, m, v, step)
        losses.append(float(loss))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0] - 1.0, losses


def test_train_step_gnorm_clip_applied():
    var = Variant("mha")
    p = M.init_params(TINY, var, 8)
    m = {k: jnp.zeros_like(x) for k, x in p.items()}
    v = {k: jnp.zeros_like(x) for k, x in p.items()}
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (2, 16)), jnp.int32)
    tg = jnp.asarray(RNG.randint(0, TINY.vocab, (2, 16)), jnp.int32)
    _, _, _, _, loss, gnorm = M.train_step(
        TINY, var, p, m, v, jnp.asarray(0, jnp.int32), jnp.float32(1e-3),
        {}, toks, tg, jnp.ones((2, 16)))
    assert float(gnorm) > 0.0


def test_eval_loss_matches_loss_fn():
    var = Variant("mha")
    p = M.init_params(TINY, var, 9)
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (2, 24)), jnp.int32)
    tg = jnp.asarray(RNG.randint(0, TINY.vocab, (2, 24)), jnp.int32)
    mask = jnp.ones((2, 24))
    s, n = M.eval_loss(TINY, var, p, {}, toks, tg, mask)
    mean = M.loss_fn(TINY, var, p, {}, toks, tg, mask)
    assert abs(float(s) / float(n) - float(mean)) < 1e-5


def test_eval_loss_respects_mask():
    """Masked positions must not contribute to NLL."""
    var = Variant("mha")
    p = M.init_params(TINY, var, 10)
    toks = jnp.asarray(RNG.randint(0, TINY.vocab, (1, 16)), jnp.int32)
    tg = jnp.asarray(RNG.randint(0, TINY.vocab, (1, 16)), jnp.int32)
    m_half = jnp.ones((1, 16)).at[:, 8:].set(0.0)
    s_half, n_half = M.eval_loss(TINY, var, p, {}, toks, tg, m_half)
    assert float(n_half) == 8.0
    # changing targets in the masked region must not change the sum
    tg2 = tg.at[:, 8:].set((tg[:, 8:] + 1) % TINY.vocab)
    s2, _ = M.eval_loss(TINY, var, p, {}, toks, tg2, m_half)
    assert abs(float(s_half) - float(s2)) < 1e-6


def test_cache_specs_sizes_match_paper_formula():
    """cache tensors' per-token element count == Variant.cache_per_token."""
    for var in VARIANTS:
        specs = M.cache_specs(TINY, var, batch=1, s=1)
        elems = sum(int(np.prod(s)) for _, s in specs) // TINY.n_layers
        assert elems == var.cache_per_token(TINY), var.tag()
