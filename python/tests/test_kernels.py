"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Hypothesis sweeps shapes and contents; tolerances are tight because both
paths run f32 on CPU.
"""

import pytest

pytest.importorskip(
    "hypothesis", reason="property sweeps need hypothesis installed"
)

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.elite_attention import (elite_attention_decode,
                                             rope_rotate_elite)
from compile.kernels.ref import (ref_elite_attention_decode,
                                 ref_rope_rotate_elite)
from compile.kernels import rope as rk

ATOL = 2e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    r=st.sampled_from([2, 4, 8]),
    c=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_elite_attention_matches_ref(b, h, s_blocks, r, c, seed):
    rng = np.random.default_rng(seed)
    block = 16
    s = s_blocks * block
    qr = _rand(rng, b, h, 2 * r)
    ql = _rand(rng, b, h, c)
    kr = _rand(rng, b, s, h, 2 * r)
    ckv = _rand(rng, b, s, h if False else c)  # [B,S,C]
    lengths = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    scale = 1.0 / np.sqrt(2 * r + c)
    got = elite_attention_decode(qr, ql, kr, ckv, lengths, scale=scale,
                                 block_s=block)
    want = ref_elite_attention_decode(qr, ql, kr, ckv, lengths, scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_elite_attention_length_one():
    """Only the first cache row attends when length == 1."""
    rng = np.random.default_rng(0)
    b, h, s, r2, c = 1, 2, 64, 4, 16
    qr, ql = _rand(rng, b, h, r2), _rand(rng, b, h, c)
    kr, ckv = _rand(rng, b, s, h, r2), _rand(rng, b, s, c)
    lengths = jnp.asarray([1], jnp.int32)
    got = elite_attention_decode(qr, ql, kr, ckv, lengths, scale=0.1)
    # softmax over one element == 1 -> output is exactly c_kv[0]
    np.testing.assert_allclose(
        np.asarray(got), np.broadcast_to(np.asarray(ckv)[:, 0][:, None, :],
                                         (b, h, c)), atol=ATOL)


def test_elite_attention_full_length():
    rng = np.random.default_rng(1)
    b, h, s, r2, c = 2, 2, 128, 8, 32
    qr, ql = _rand(rng, b, h, r2), _rand(rng, b, h, c)
    kr, ckv = _rand(rng, b, s, h, r2), _rand(rng, b, s, c)
    lengths = jnp.asarray([s, s], jnp.int32)
    got = elite_attention_decode(qr, ql, kr, ckv, lengths, scale=0.05)
    want = ref_elite_attention_decode(qr, ql, kr, ckv, lengths, scale=0.05)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 8),
    r=st.sampled_from([1, 2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rope_rotate_elite_matches_ref(b, h, r, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, h, 2 * r)
    cos = _rand(rng, b, h, r)
    sin = _rand(rng, b, h, r)
    got = rope_rotate_elite(x, cos, sin)
    want = ref_rope_rotate_elite(x, cos, sin)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_rope_rotation_preserves_norm():
    """True rotations (cos^2+sin^2=1) preserve chunk norms."""
    rng = np.random.default_rng(2)
    b, h, r = 2, 3, 5
    ang = jnp.asarray(rng.standard_normal((b, h, r)), jnp.float32)
    x = _rand(rng, b, h, 2 * r)
    out = rope_rotate_elite(x, jnp.cos(ang), jnp.sin(ang))
    n_in = np.linalg.norm(np.asarray(x).reshape(b, h, r, 2), axis=-1)
    n_out = np.linalg.norm(np.asarray(out).reshape(b, h, r, 2), axis=-1)
    np.testing.assert_allclose(n_in, n_out, atol=ATOL)


def test_rope_relative_position_property():
    """Paper Eq. 1a == 1b: (R(m t)q).(R(n t)k) == q.R((m-n)t).k"""
    rng = np.random.default_rng(3)
    base = 10000.0
    d = 16
    q = jnp.asarray(rng.standard_normal((1, 1, 1, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, d)), jnp.float32)
    for m, n in [(5, 3), (10, 0), (7, 7), (100, 1)]:
        qm = rk.apply_rope(q, jnp.asarray([m]), base)[0, 0, 0]
        kn = rk.apply_rope(k, jnp.asarray([n]), base)[0, 0, 0]
        krel = rk.apply_rope(k, jnp.asarray([n - m]), base)[0, 0, 0]
        q0 = np.asarray(q)[0, 0, 0]
        lhs = float(np.dot(np.asarray(qm), np.asarray(kn)))
        rhs = float(np.dot(q0, np.asarray(krel)))
        assert abs(lhs - rhs) < 1e-4, (m, n, lhs, rhs)


def test_rope_masked_blend():
    """mask==1 everywhere -> full RoPE; mask==0 -> identity."""
    rng = np.random.default_rng(4)
    b, t, h, d = 2, 8, 4, 16
    x = _rand(rng, b, t, h, d)
    pos = jnp.arange(t)
    ones = jnp.ones((h, d // 2))
    zeros = jnp.zeros((h, d // 2))
    full = rk.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.asarray(rk.apply_rope_masked(x, pos, 10000.0, ones)),
        np.asarray(full), atol=ATOL)
    np.testing.assert_allclose(
        np.asarray(rk.apply_rope_masked(x, pos, 10000.0, zeros)),
        np.asarray(x), atol=ATOL)


# ---------------------------------------------------------------------------
# S23 grounding: the Rust SIMD≡scalar tolerance vs a numpy oracle
# ---------------------------------------------------------------------------

S23_TOL_SCALE = 1e-6


def s23_tol(k):
    """Mirror of ``s23_tol`` in rust/tests/simd_kernels.rs (DESIGN.md
    S23): the cross-ISA budget for one f32 accumulation over k terms."""
    return S23_TOL_SCALE * (k + 1)


def _sequential_dot_f32(a, w):
    """Strict k-ascending f32 accumulation — the scalar kernel order."""
    s = np.float32(0.0)
    for j in range(len(a)):
        s = np.float32(s + np.float32(a[j] * w[j]))
    return float(s)


def _lane_blocked_dot_f32(a, w, lanes):
    """The SIMD accumulation order: ``lanes`` running sums over full
    blocks, reduced in ascending lane order, then the scalar tail.
    Takes two roundings per element where real FMA takes one, so its
    reassociation error upper-bounds the vector kernels'."""
    k = len(a)
    main = k - k % lanes
    acc = np.zeros(lanes, np.float32)
    for j0 in range(0, main, lanes):
        acc = (acc + a[j0:j0 + lanes] * w[j0:j0 + lanes]).astype(np.float32)
    s = np.float32(0.0)
    for lane in range(lanes):
        s = np.float32(s + acc[lane])
    for j in range(main, k):
        s = np.float32(s + np.float32(a[j] * w[j]))
    return float(s)


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(1, 1536),
    lanes=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_s23_tolerance_bounds_lane_reassociation(k, lanes, seed):
    """Grounds ``s23_tol(k) = 1e-6 * (k + 1)``: on standard-normal data
    the sequential-f32 order (scalar kernels), the lane-blocked order
    (AVX2's 8 / NEON's 4 running sums), and the f64 truth must all
    agree within the S23 budget, so SIMD-vs-scalar drift in the Rust
    differential suite stays well inside tolerance."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal(k).astype(np.float32)
    w = rng.standard_normal(k).astype(np.float32)
    seq = _sequential_dot_f32(a, w)
    blk = _lane_blocked_dot_f32(a, w, lanes)
    truth = float(np.dot(a.astype(np.float64), w.astype(np.float64)))
    tol = s23_tol(k)
    assert abs(seq - blk) <= tol, (k, lanes, seq, blk)
    assert abs(seq - truth) <= tol, (k, seq, truth)
    assert abs(blk - truth) <= tol, (k, lanes, blk, truth)


def test_rope_elite_matches_full_when_ladder():
    """apply_rope_elite with the standard ladder == apply_rope."""
    rng = np.random.default_rng(5)
    b, t, h, d = 1, 6, 2, 8
    nc = d // 2
    x = _rand(rng, b, t, h, d)
    pos = jnp.arange(t)
    thetas = rk.chunk_thetas(nc, 10000.0)
    theta_e = jnp.broadcast_to(thetas[None, :], (h, nc))
    got = rk.apply_rope_elite(x, pos, theta_e)
    want = rk.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)
