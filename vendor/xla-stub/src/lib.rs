//! Offline stub of the `xla` crate (PJRT bindings) API surface that
//! `elitekv::runtime::{engine, session}` compiles against.
//!
//! The real crate links the XLA C++ runtime, which cannot be built in the
//! offline container. This stub keeps `--features pjrt` *compiling* so the
//! PJRT code paths stay type-checked; every constructor returns an error
//! at runtime ("PJRT unavailable: xla stub build"). To actually execute
//! HLO artifacts, replace the `vendor/xla-stub` path dependency in the
//! workspace Cargo.toml with the real `xla` crate (see DESIGN.md §3).

const STUB_MSG: &str = "PJRT unavailable: this binary was built against the \
                        offline xla stub (vendor/xla-stub); use the native \
                        backend or link the real xla crate";

/// Stub error carrying the explanation above.
#[derive(Debug, Clone)]
pub struct Error(pub String);

fn stub_err<T>() -> Result<T, Error> {
    Err(Error(STUB_MSG.to_string()))
}

/// Element types the elitekv runtime exchanges with PJRT.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
}

/// Marker for host types that can cross the PJRT boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

#[derive(Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        stub_err()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        stub_err()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub_err()
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(
        &self,
        _inputs: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub_err()
    }
}

pub struct Literal(());

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        stub_err()
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        stub_err()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        stub_err()
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        stub_err()
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}
