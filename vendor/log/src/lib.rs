//! Offline substitute for the `log` crate: the subset of the facade the
//! elitekv workspace uses (leveled macros, a global `dyn Log` sink, and a
//! runtime max-level filter). API-compatible with `log` 0.4 for that
//! subset, so swapping the real crate back in is a Cargo.toml edit.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a log record (most to least severe).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Maximum-verbosity filter installed via [`set_max_level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata attached to a record (level only in this subset).
#[derive(Clone, Debug)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record: level + preformatted arguments.
pub struct Record<'a> {
    level: Level,
    args: fmt::Arguments<'a>,
    metadata: Metadata,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }
}

/// A log sink. Implementations must be thread-safe.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, args: fmt::Arguments<'_>) {
    if level > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level };
        if logger.enabled(&metadata) {
            let record = Record { level, args, metadata: metadata.clone() };
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Error, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Debug, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Trace, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_orders_against_filter() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Trace);
        assert!(Level::Error > LevelFilter::Off);
    }

    #[test]
    fn max_level_round_trips() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
    }

    #[test]
    fn macros_do_not_panic_without_logger() {
        info!("hello {}", 42);
        debug!("debug {x}", x = 1);
        error!("oops");
    }
}
