//! Offline substitute for the `anyhow` crate: a string-chain error type
//! plus the `anyhow!` / `bail!` / `ensure!` macros and the `Context`
//! extension trait. Covers the subset the elitekv workspace uses so the
//! real crate can be swapped back in with a Cargo.toml edit.
//!
//! Semantics mirrored from anyhow:
//! * `Display` prints the outermost message; `{:#}` prints the whole
//!   chain colon-separated ("ctx: cause: root").
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`
//!   (the source chain is flattened into the message chain eagerly).
//! * `Error` itself does NOT implement `std::error::Error`, which is what
//!   keeps the blanket `From` impl coherent.

use std::fmt;

/// A context-carrying error: `chain[0]` is the outermost message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context (outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Conversion helper behind [`Context`] (mirrors anyhow's private
/// `ext::StdError`): lets `.context(..)` apply both to standard errors
/// and to `Error` itself.
#[doc(hidden)]
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E>: Sized {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(
                concat!("condition failed: ", stringify!($cond)),
            ));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("load config")
            .unwrap_err();
        assert_eq!(e.to_string(), "load config");
        assert_eq!(format!("{e:#}"), "load config: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "missing file");
    }

    #[test]
    fn context_layers_on_anyhow_results_too() {
        fn inner() -> Result<()> {
            Err(anyhow!("root"))
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("no item {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "no item 3");
    }

    #[test]
    fn macros_cover_forms() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }
}
