//! Quickstart: the full EliteKV pipeline on the tiny model in one binary.
//!
//!   1. pretrain a baseline MHA transformer on the synthetic corpus
//!   2. RoPElite search (Algorithm 1) for each head's elite chunks
//!   3. J-LRD conversion to a 25 % KV cache
//!   4. brief uptraining
//!   5. compare perplexity + generate through the compressed cache
//!
//! Run: `make artifacts && cargo run --release --example quickstart`
//! (~5 minutes on one CPU core; tune steps via env QUICKSTART_STEPS)

use std::sync::Arc;

use anyhow::Result;

use elitekv::config::{ModelConfig, Variant};
use elitekv::convert;
use elitekv::coordinator::{GenParams, InferenceServer, Request};
use elitekv::data::CorpusGen;
use elitekv::runtime::{Engine, HostTensor, ModelRunner, PjrtBackend, TrainState};
use elitekv::search;
use elitekv::train::{TrainLoop, TrainOpts};

fn main() -> Result<()> {
    let steps: usize = std::env::var("QUICKSTART_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let cfg = ModelConfig::tiny();
    let engine = Arc::new(Engine::new()?);

    // 1. Pretrain the baseline.
    println!("[1/5] pretraining tiny MHA baseline ({steps} steps)...");
    let base_runner =
        ModelRunner::new(Arc::clone(&engine), "artifacts", "tiny", "mha")?;
    let params = base_runner.init(42)?;
    let mut state = TrainState::fresh(params);
    let opts = TrainOpts { steps, lr: 1e-3, log_every: 25, ..Default::default() };
    let mut lp = TrainLoop::new(&base_runner, &opts);
    let report = lp.run(&mut state, &opts)?;
    println!("      baseline ppl {:.2}", report.final_ppl);
    let base_ckpt = base_runner.ckpt_from_params(&state.params)?;

    // 2. RoPElite search.
    let r = cfg.n_chunks() / 4; // 2r dims per head stay rotated
    println!("[2/5] RoPElite greedy search (r = {r})...");
    let mut gen = CorpusGen::new(cfg.vocab, 1);
    gen.reseed(1, 0xca11b);
    let sel = search::ropelite_search(&base_runner, &state.params, &mut gen, r)?;
    println!("      layer 0 head 0 elite chunks: {:?}", sel.chunks[0][0]);

    // 3. J-LRD conversion to 25 % cache.
    let d_ckv = {
        let t = 0.25 * cfg.kv_elems_per_token() as f64
            - (2 * r * cfg.n_heads) as f64;
        (t as usize / 16) * 16
    };
    let variant = Variant::EliteKv { r, d_ckv };
    println!("[3/5] J-LRD conversion -> {} ({:.1}% cache)...",
             variant.tag(), 100.0 * variant.cache_ratio(&cfg));
    let converted = convert::convert_elitekv(&cfg, &base_ckpt, &sel, d_ckv)?;
    let mut kv_runner = ModelRunner::new(
        Arc::clone(&engine), "artifacts", "tiny", &variant.tag())?;
    let theta = convert::elitekv::elite_thetas_flat(&cfg, &sel);
    kv_runner.set_extras(vec![HostTensor::F32(
        theta, vec![cfg.n_layers, cfg.n_heads, r])])?;
    let kv_params = kv_runner.params_from_ckpt(&converted)?;

    // 4. Uptrain briefly.
    let up_steps = steps / 3;
    println!("[4/5] uptraining {up_steps} steps...");
    let mut kv_state = TrainState::fresh(kv_params);
    let opts = TrainOpts {
        steps: up_steps, lr: 3e-4, log_every: 25, data_seed: 7,
        ..Default::default()
    };
    let mut lp = TrainLoop::new(&kv_runner, &opts);
    let kv_report = lp.run(&mut kv_state, &opts)?;
    println!(
        "      ppl: baseline {:.2} -> converted+uptrained {:.2} at 25% cache",
        report.final_ppl, kv_report.final_ppl
    );

    // 5. Serve a few generations through the compressed cache.
    println!("[5/5] serving through the compressed KV cache...");
    let mut server = InferenceServer::new(
        Box::new(PjrtBackend::new(kv_runner, kv_state.params)), 8 << 20)?;
    let mut probe_gen = CorpusGen::new(cfg.vocab, 1);
    let prompt = probe_gen.stream(12);
    for i in 0..4 {
        server.submit(Request::new(
            i,
            prompt.clone(),
            GenParams { max_new_tokens: 12, ..Default::default() },
        ))?;
    }
    let responses = server.run_to_completion()?;
    for r in &responses {
        println!("      req {}: {} tokens, latency {:.0} ms",
                 r.id, r.tokens.len(), r.latency * 1e3);
    }
    println!(
        "      peak cache {} KiB ({} decode steps, {} prefills)",
        server.stats.peak_cache_bytes / 1024,
        server.stats.decode_steps,
        server.stats.prefills
    );
    println!("quickstart OK");
    Ok(())
}
