//! Serving scenario: multi-worker router + continuous batching over the
//! compressed KV cache, comparing capacity/latency against the baseline
//! layout under the same memory budget — the systems payoff of EliteKV
//! (paper intro: long-context, real-time serving is KV-cache bound).
//!
//! Run: cargo run --release --example serve_compressed -- \
//!        [--ckpt pretrained.ekvc] [--requests 32] [--budget-mb 2]
//!
//! Without --ckpt the demo initializes random weights (layout effects —
//! admission, cache bytes, batching — are weight-independent).

use std::sync::Arc;

use anyhow::Result;

use elitekv::cli::Args;
use elitekv::config::{ModelConfig, Variant};
use elitekv::coordinator::cluster::EngineFactory;
use elitekv::coordinator::{GenParams, InferenceServer, Request, Router};
use elitekv::data::{CorpusGen, ProbeSet};
use elitekv::kvcache::{BlockAllocator, CacheLayout};
use elitekv::runtime::{Engine, HostTensor, ModelRunner, PjrtBackend};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let n_requests = args.usize_or("requests", 32)?;
    let budget = args.usize_or("budget-mb", 2)? << 20;
    let cfg = ModelConfig::tiny();
    let nc = cfg.n_chunks();
    let variants = [
        Variant::Mha,
        Variant::Gqa { n_kv_heads: cfg.n_heads / 4 },
        Variant::EliteKv { r: nc / 4, d_ckv: 64 },
    ];

    println!("== capacity under a {} MiB cache budget ==", budget >> 20);
    for v in &variants {
        let layout = CacheLayout::new(&cfg, v.clone());
        let alloc = BlockAllocator::with_budget(
            budget, layout.bytes_per_token(), 16);
        println!(
            "  {:<18} cache {:>5.1}%  {:>8} tokens  {:>5} blocks",
            v.tag(),
            100.0 * layout.ratio,
            layout.tokens_in_budget(budget),
            alloc.n_blocks(),
        );
    }

    println!("\n== serving {} requests per variant ==", n_requests);
    let gen = CorpusGen::new(cfg.vocab, 1);
    let probes = ProbeSet::generate(&gen, n_requests.div_ceil(6), 2024);
    for v in &variants {
        let tag = v.tag();
        let mut server = build_server(&args, &tag, budget)?;
        let t0 = std::time::Instant::now();
        for (i, item) in probes.items.iter().take(n_requests).enumerate() {
            server.submit(Request::new(
                i as u64,
                item.prompt.clone(),
                GenParams { max_new_tokens: 8, ..Default::default() },
            ))?;
        }
        let responses = server.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
        println!(
            "  {:<18} {:>6.1} tok/s  peak cache {:>6} KiB  \
             {} prefills, {} decode steps",
            tag,
            toks as f64 / wall,
            server.stats.peak_cache_bytes / 1024,
            server.stats.prefills,
            server.stats.decode_steps,
        );
    }

    // Router demo: two workers behind a least-loaded router.
    println!("\n== leader/worker router (2 engines) ==");
    let mk = |args: &Args, budget: usize| -> EngineFactory {
        let tag = Variant::EliteKv { r: nc / 4, d_ckv: 64 }.tag();
        let ckpt = args.get("ckpt").map(|s| s.to_string());
        Box::new(move || {
            let args2 = match ckpt {
                Some(c) => format!("--ckpt {c}"),
                None => String::new(),
            };
            let parsed = elitekv::cli::Args::parse(
                args2.split_whitespace().map(String::from))?;
            build_server(&parsed, &tag, budget)
        })
    };
    let mut router = Router::new(vec![mk(&args, budget), mk(&args, budget)]);
    let t0 = std::time::Instant::now();
    for (i, item) in probes.items.iter().take(n_requests).enumerate() {
        router.submit(Request::new(
            1000 + i as u64,
            item.prompt.clone(),
            GenParams { max_new_tokens: 8, ..Default::default() },
        ))?;
    }
    let responses = router.drain()?;
    println!(
        "  routed {} requests across {} workers in {:.2}s",
        responses.len(),
        router.n_workers(),
        t0.elapsed().as_secs_f64()
    );
    println!("serve_compressed OK");
    Ok(())
}

/// Build a single-engine server for a variant, loading --ckpt when given
/// (extras default to the ladder-prefix selection for demo purposes).
fn build_server(
    args: &Args,
    tag: &str,
    budget: usize,
) -> Result<InferenceServer> {
    let engine = Arc::new(Engine::new()?);
    let mut runner = ModelRunner::new(engine, "artifacts", "tiny", tag)?;
    let cfg = runner.manifest.config.clone();
    if !runner.manifest.extras.is_empty() {
        // demo selection: first r chunks of the ladder per head
        let r = runner.manifest.variant.r().unwrap();
        let elite = vec![vec![(0..r).collect::<Vec<_>>(); cfg.n_heads];
                         cfg.n_layers];
        runner.set_extras(vec![HostTensor::F32(
            elitekv::rope::elite_thetas(&cfg, &elite),
            vec![cfg.n_layers, cfg.n_heads, r],
        )])?;
    }
    let params = match args.get("ckpt") {
        Some(path) => {
            let ckpt = elitekv::io::Checkpoint::load(path)?;
            runner.params_from_ckpt(&ckpt)?
        }
        None => runner.init(7)?,
    };
    InferenceServer::new(Box::new(PjrtBackend::new(runner, params)), budget)
}
