//! Native serving scenario: the zero-artifact path end-to-end.
//!
//! Builds a randomly initialized tiny model for the dense baseline and
//! the J-LRD compressed variant, serves the same probe-style request
//! stream through the continuous-batching coordinator on the pure-Rust
//! backend, and prints the capacity/latency comparison — no Python, no
//! `make artifacts`, no XLA toolchain.
//!
//! Run: cargo run --release --example native_serve -- \
//!        [--requests 16] [--max-new 12] [--budget-mb 8]

use anyhow::Result;

use elitekv::cli::Args;
use elitekv::config::{ModelConfig, Variant};
use elitekv::coordinator::{GenParams, InferenceServer, Request};
use elitekv::data::{CorpusGen, ProbeSet};
use elitekv::kvcache::CacheLayout;
use elitekv::native::{NativeModel, NativeRunner};
use elitekv::search::uniform_selection;
use elitekv::util::stats::percentile;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let n_requests = args.usize_or("requests", 16)?;
    let max_new = args.usize_or("max-new", 12)?;
    let budget = args.usize_or("budget-mb", 8)? << 20;
    let cfg = ModelConfig::tiny();
    let nc = cfg.n_chunks();
    let variants = [
        Variant::Mha,
        Variant::EliteKv { r: nc / 4, d_ckv: 64 }, // 25 % cache
    ];

    println!("== capacity under a {} MiB cache budget ==", budget >> 20);
    for v in &variants {
        let layout = CacheLayout::new(&cfg, v.clone());
        println!(
            "  {:<20} {:>6.1}% cache  {:>9} tokens fit",
            v.tag(),
            100.0 * layout.ratio,
            layout.tokens_in_budget(budget)
        );
    }

    println!(
        "\n== native backend: {n_requests} requests x {max_new} new tokens =="
    );
    println!(
        "{:<20} {:>9} {:>12} {:>12} {:>14}",
        "variant", "tok/s", "p50 ms", "p99 ms", "peak KiB"
    );
    for v in &variants {
        let sel = v.r().map(|r| uniform_selection(&cfg, r));
        let model = NativeModel::init(&cfg, v.clone(), 7, sel.as_ref())?;
        let runner = NativeRunner::new(model, 4, 128)?;
        let mut server = InferenceServer::new(Box::new(runner), budget)?;
        let gen = CorpusGen::new(cfg.vocab, 1);
        let probes = ProbeSet::generate(&gen, n_requests.div_ceil(6), 77);
        let t0 = std::time::Instant::now();
        for (i, item) in probes.items.iter().take(n_requests).enumerate() {
            server.submit(Request::new(
                i as u64,
                item.prompt.clone(),
                GenParams {
                    max_new_tokens: max_new,
                    stop_token: None, // force fixed-length decode
                    ..Default::default()
                },
            ))?;
        }
        let responses = server.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
        let mut lat: Vec<f64> =
            responses.iter().map(|r| r.latency * 1e3).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "{:<20} {:>9.1} {:>12.1} {:>12.1} {:>14}",
            v.tag(),
            toks as f64 / wall,
            percentile(&lat, 0.5),
            percentile(&lat, 0.99),
            server.stats.peak_cache_bytes / 1024,
        );
    }
    println!("\nnative_serve done (zero artifacts used)");
    Ok(())
}
