//! RoPElite search scenario: run Algorithm 1 against the Uniform and
//! Contribution baselines on one model and visualize how head-level
//! frequency preferences differ (the paper's Figure 2 story).
//!
//! Run: cargo run --release --example ropelite_search -- \
//!        [--ckpt pretrained_tiny.ekvc] [--r 4]
//!
//! Without --ckpt a short pretraining run is performed first (a trained
//! model is needed for heads to have real frequency preferences).

use std::sync::Arc;

use anyhow::Result;

use elitekv::cli::Args;
use elitekv::config::ModelConfig;
use elitekv::data::CorpusGen;
use elitekv::runtime::{Engine, ModelRunner, TrainState};
use elitekv::search;
use elitekv::train::{TrainLoop, TrainOpts};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let r = args.usize_or("r", 4)?;
    let cfg = ModelConfig::tiny();
    let engine = Arc::new(Engine::new()?);
    let runner = ModelRunner::new(engine, "artifacts", "tiny", "mha")?;

    let params = match args.get("ckpt") {
        Some(path) => {
            println!("loading {path}");
            runner.params_from_ckpt(&elitekv::io::Checkpoint::load(path)?)?
        }
        None => {
            let steps = args.usize_or("steps", 120)?;
            println!("no --ckpt: pretraining {steps} steps first...");
            let mut state = TrainState::fresh(runner.init(42)?);
            let opts =
                TrainOpts { steps, lr: 1e-3, log_every: 30, ..Default::default() };
            let mut lp = TrainLoop::new(&runner, &opts);
            lp.run(&mut state, &opts)?;
            state.params
        }
    };

    let mut gen = CorpusGen::new(cfg.vocab, 1);
    gen.reseed(1, 0xca11b);

    println!("\nRoPElite greedy search (r = {r})...");
    let t0 = std::time::Instant::now();
    let elite = search::ropelite_search(&runner, &params, &mut gen, r)?;
    println!("  done in {:.1}s", t0.elapsed().as_secs_f64());

    gen.reseed(1, 0xca11b);
    let contrib = search::contribution_selection(&runner, &params, &mut gen, r)?;
    let uniform = search::uniform_selection(&cfg, r);

    // Heat maps (paper Fig. 2: chunk 0 = highest frequency).
    let nc = cfg.n_chunks();
    for (name, sel) in [("RoPElite", &elite), ("Contribution", &contrib),
                        ("Uniform", &uniform)] {
        println!("\n{name} elite chunks (rows = layer.head, # = elite):");
        for (l, layer) in sel.chunks.iter().enumerate() {
            for (h, head) in layer.iter().enumerate() {
                let mut row = vec!['.'; nc];
                for &c in head {
                    row[c] = '#';
                }
                println!("  L{l}H{h}  |{}|", row.iter().collect::<String>());
            }
        }
    }

    // Agreement statistics: how head-specific is the greedy selection?
    let mut agree_contrib = 0usize;
    let mut agree_uniform = 0usize;
    let mut total = 0usize;
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_heads {
            let e: std::collections::HashSet<_> =
                elite.chunks[l][h].iter().collect();
            agree_contrib += contrib.chunks[l][h]
                .iter()
                .filter(|c| e.contains(c))
                .count();
            agree_uniform += uniform.chunks[l][h]
                .iter()
                .filter(|c| e.contains(c))
                .count();
            total += r;
        }
    }
    println!("\noverlap with RoPElite: contribution {:.0}%, uniform {:.0}%",
             100.0 * agree_contrib as f64 / total as f64,
             100.0 * agree_uniform as f64 / total as f64);
    println!("ropelite_search OK");
    Ok(())
}
