//! Ablation scenario: J-LRD vs S-LRD at matched KV-cache budgets (paper
//! §4.3.2 / Figure 5), plus the Appendix-C dimension-allocation solver.
//!
//! Run: cargo run --release --example ablation_lrd -- \
//!        [--ckpt pretrained_tiny.ekvc] [--steps 120]

use std::sync::Arc;

use anyhow::Result;

use elitekv::cli::Args;
use elitekv::config::ModelConfig;
use elitekv::convert::{self, allocation};
use elitekv::data::CorpusGen;
use elitekv::runtime::{Engine, HostTensor, ModelRunner, TrainState};
use elitekv::search;
use elitekv::train::{TrainLoop, TrainOpts};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let cfg = ModelConfig::tiny();
    let engine = Arc::new(Engine::new()?);
    let base_runner =
        ModelRunner::new(Arc::clone(&engine), "artifacts", "tiny", "mha")?;

    // A trained baseline (loaded or freshly pretrained).
    let params = match args.get("ckpt") {
        Some(p) => base_runner
            .params_from_ckpt(&elitekv::io::Checkpoint::load(p)?)?,
        None => {
            let steps = args.usize_or("steps", 120)?;
            println!("pretraining {steps} steps...");
            let mut st = TrainState::fresh(base_runner.init(42)?);
            let o = TrainOpts { steps, lr: 1e-3, log_every: 40,
                                ..Default::default() };
            TrainLoop::new(&base_runner, &o).run(&mut st, &o)?;
            st.params
        }
    };
    let base_ckpt = base_runner.ckpt_from_params(&params)?;

    // Appendix-C solver: shortlist (r, d_ckv) at a 25 % budget.
    let budget = cfg.kv_elems_per_token() / 4;
    let cands = allocation::enumerate_configs(&cfg, budget, 16);
    println!("Appendix-C shortlist at budget {budget} elems/token/layer:");
    for c in cands.iter().take(5) {
        println!(
            "  {:<18} cache {:>3}  param delta {:>9}",
            c.variant.tag(), c.cache_per_token, c.param_delta
        );
    }

    // Fig-5-style comparison: fixed latent budget, J-LRD vs S-LRD splits.
    let r = cfg.n_chunks() / 4;
    let latent = 128usize; // elems left for latents after 2*r*nh rotated
    let mut cal = CorpusGen::new(cfg.vocab, 1);
    cal.reseed(1, 0xca11b);
    let sel = search::ropelite_search(&base_runner, &params, &mut cal, r)?;
    let theta = convert::elitekv::elite_thetas_flat(&cfg, &sel);

    let eval = |tag: &str, ckpt: &elitekv::io::Checkpoint| -> Result<f64> {
        let mut runner = ModelRunner::new(
            Arc::clone(&engine), "artifacts", "tiny", tag)?;
        let rvar = runner.manifest.variant.r().unwrap();
        runner.set_extras(vec![HostTensor::F32(
            theta.clone(), vec![cfg.n_layers, cfg.n_heads, rvar])])?;
        let p = runner.params_from_ckpt(ckpt)?;
        let mut gen = CorpusGen::new(cfg.vocab, 1);
        gen.reseed(1, 0xe7a1);
        runner.perplexity(&p, &mut gen, 3)
    };

    println!("\nJ-LRD vs S-LRD at latent budget {latent} (r = {r}):");
    let jtag = format!("elitekv_r{r}_c{latent}");
    let jl = convert::convert_elitekv(&cfg, &base_ckpt, &sel, latent)?;
    let jppl = eval(&jtag, &jl)?;
    println!("  J-LRD {:<22} ppl {jppl:.3}", jtag);
    let mut best_s = f64::INFINITY;
    for frac in [0.25f64, 0.5, 0.75] {
        let ck = (((latent as f64 * frac) / 16.0).round() as usize * 16).max(16);
        let cv = latent - ck;
        if cv < 16 {
            continue;
        }
        let stag = format!("slrd_r{r}_ck{ck}_cv{cv}");
        let sl = convert::convert_slrd(&cfg, &base_ckpt, &sel, ck, cv)?;
        let sppl = eval(&stag, &sl)?;
        best_s = best_s.min(sppl);
        println!("  S-LRD {:<22} ppl {sppl:.3}", stag);
    }
    println!(
        "\n=> J-LRD {} the best S-LRD split at equal cache \
         ({jppl:.3} vs {best_s:.3}) — paper §4.3.2's claim",
        if jppl <= best_s { "beats" } else { "does NOT beat" }
    );
    println!("ablation_lrd OK");
    Ok(())
}
