//! End-to-end training driver (the repository's headline validation run):
//! train a transformer from scratch through the full three-layer stack —
//! Rust coordinator -> PJRT -> AOT-lowered JAX graph — logging the loss
//! curve, then convert to EliteKV and show recovery.
//!
//! Default config is `small` (~13 M params, ~10 s/step on one CPU core);
//! pass `--config 100m` for the ~97 M-parameter model (same code path;
//! step time ~1 min/step on this single-core CPU testbed, so budget
//! accordingly — EXPERIMENTS.md §E2E records the reference runs).
//!
//! Run: cargo run --release --example uptrain_e2e -- \
//!        [--config small] [--steps 300] [--uptrain 60] [--out results]

use std::io::Write;
use std::sync::Arc;

use anyhow::{Context, Result};

use elitekv::cli::Args;
use elitekv::config::{ModelConfig, Variant};
use elitekv::convert;
use elitekv::data::{CorpusGen, ProbeSet};
use elitekv::runtime::{Engine, HostTensor, ModelRunner, TrainState};
use elitekv::search;
use elitekv::train::{scorer, TrainLoop, TrainOpts};
use elitekv::util::Json;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let cfg_name = args.str_or("config", "small");
    let steps = args.usize_or("steps", 300)?;
    let up_steps = args.usize_or("uptrain", 60)?;
    let out_dir = args.str_or("out", "results");
    std::fs::create_dir_all(&out_dir)?;
    let cfg = ModelConfig::by_name(&cfg_name).context("unknown config")?;
    println!(
        "e2e: {} ({} layers, d={}, ~{:.0}M params), {} pretrain steps",
        cfg.name, cfg.n_layers, cfg.d_model,
        cfg.approx_params() as f64 / 1e6, steps
    );

    let engine = Arc::new(Engine::new()?);
    let runner =
        ModelRunner::new(Arc::clone(&engine), "artifacts", &cfg_name, "mha")?;

    // --- pretrain with a logged loss curve ---
    let params = runner.init(42)?;
    let mut state = TrainState::fresh(params);
    let opts = TrainOpts {
        steps,
        lr: 1e-3,
        eval_every: (steps / 6).max(1),
        eval_batches: 2,
        log_every: 10,
        data_seed: 1,
    };
    let mut lp = TrainLoop::new(&runner, &opts);
    let report = lp.run(&mut state, &opts)?;
    println!(
        "pretrain done: loss {:.4}, ppl {:.3}, {} tokens, {:.1}s \
         ({:.2} s/step)",
        report.final_loss, report.final_ppl, report.tokens_seen,
        report.seconds, report.seconds / steps as f64
    );
    // write the loss curve
    let curve = Json::Arr(
        report
            .points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("step", Json::num(p.step as f64)),
                    ("tokens", Json::num(p.tokens as f64)),
                    ("loss", Json::num(p.loss)),
                    ("ppl", p.ppl.map(Json::num).unwrap_or(Json::Null)),
                ])
            })
            .collect(),
    );
    let curve_path = format!("{out_dir}/e2e_{cfg_name}_losscurve.json");
    std::fs::write(&curve_path, curve.to_string())?;
    println!("loss curve -> {curve_path}");
    let mut f = std::fs::File::create(
        format!("{out_dir}/e2e_{cfg_name}_losscurve.tsv"))?;
    writeln!(f, "step\ttokens\tloss\tppl")?;
    for p in &report.points {
        writeln!(f, "{}\t{}\t{:.5}\t{}", p.step, p.tokens, p.loss,
                 p.ppl.map(|x| format!("{x:.4}")).unwrap_or_default())?;
    }

    // Save the pretrained checkpoint where the experiment harness caches
    // it, so `elitekv experiment` reuses this run instead of retraining.
    let mut ckpt = runner.ckpt_from_params(&state.params)?;
    ckpt.set_meta("pretrain_steps", steps);
    ckpt.set_meta("pretrain_tokens", report.tokens_seen);
    let ckpt_path = format!("{out_dir}/pretrained_{cfg_name}.ekvc");
    if !std::path::Path::new(&ckpt_path).exists() {
        ckpt.save(&ckpt_path)?;
        println!("checkpoint -> {ckpt_path}");
    }

    // --- probe the baseline ---
    let gen = CorpusGen::new(cfg.vocab, 1);
    let probes = ProbeSet::generate(&gen, 15, 99);
    let base_rep =
        scorer::full_report(&runner.as_backend(&state.params), &probes, 2)?;
    println!("baseline probes: avg {:.1}%, ppl {:.3}",
             100.0 * base_rep.scores.average, base_rep.ppl);

    // --- EliteKV at 25 % cache: search -> convert -> uptrain -> compare ---
    let r = cfg.n_chunks() / 4;
    let align = if cfg.d_model >= 512 { 32 } else { 16 };
    let d_ckv = {
        let t = 0.25 * cfg.kv_elems_per_token() as f64
            - (2 * r * cfg.n_heads) as f64;
        ((t / align as f64).round() as usize * align).max(align)
    };
    let variant = Variant::EliteKv { r, d_ckv };
    println!("EliteKV conversion: {} ({:.1}% cache)", variant.tag(),
             100.0 * variant.cache_ratio(&cfg));
    let mut cal = CorpusGen::new(cfg.vocab, 1);
    cal.reseed(1, 0xca11b);
    let sel = search::ropelite_search(&runner, &state.params, &mut cal, r)?;
    let base_ckpt = runner.ckpt_from_params(&state.params)?;
    let converted = convert::convert_elitekv(&cfg, &base_ckpt, &sel, d_ckv)?;
    let mut kv_runner = ModelRunner::new(
        Arc::clone(&engine), "artifacts", &cfg_name, &variant.tag())?;
    kv_runner.set_extras(vec![HostTensor::F32(
        convert::elitekv::elite_thetas_flat(&cfg, &sel),
        vec![cfg.n_layers, cfg.n_heads, r],
    )])?;
    let kv_params = kv_runner.params_from_ckpt(&converted)?;
    let mut kv_state = TrainState::fresh(kv_params);
    let opts = TrainOpts {
        steps: up_steps, lr: 3e-4, log_every: 10, data_seed: 7,
        ..Default::default()
    };
    let mut lp = TrainLoop::new(&kv_runner, &opts);
    let kv_report = lp.run(&mut kv_state, &opts)?;
    let kv_rep = scorer::full_report(
        &kv_runner.as_backend(&kv_state.params), &probes, 2)?;
    println!(
        "EliteKV@25%: ppl {:.3} (baseline {:.3}), probe avg {:.1}% \
         (baseline {:.1}%), uptrain tokens = {:.1}% of pretraining",
        kv_rep.ppl, base_rep.ppl,
        100.0 * kv_rep.scores.average, 100.0 * base_rep.scores.average,
        100.0 * kv_report.tokens_seen as f64 / report.tokens_seen as f64
    );

    let summary = Json::obj(vec![
        ("config", Json::str(cfg_name.as_str())),
        ("params_m", Json::num(cfg.approx_params() as f64 / 1e6)),
        ("pretrain_steps", Json::num(steps as f64)),
        ("pretrain_tokens", Json::num(report.tokens_seen as f64)),
        ("pretrain_final_loss", Json::num(report.final_loss)),
        ("pretrain_final_ppl", Json::num(report.final_ppl)),
        ("seconds_per_step", Json::num(report.seconds / steps as f64)),
        ("baseline_probe_avg", Json::num(base_rep.scores.average)),
        ("elitekv_variant", Json::str(&variant.tag())),
        ("elitekv_ppl", Json::num(kv_rep.ppl)),
        ("elitekv_probe_avg", Json::num(kv_rep.scores.average)),
        ("uptrain_tokens", Json::num(kv_report.tokens_seen as f64)),
    ]);
    let sum_path = format!("{out_dir}/e2e_{cfg_name}_summary.json");
    std::fs::write(&sum_path, summary.to_string())?;
    println!("summary -> {sum_path}\ne2e OK");
    Ok(())
}
