//! `cargo bench --bench native_kernels` — the kernel-layer microbench.
//!
//! Unlike the seed benches this target needs NO pjrt feature and no
//! artifacts: it times the batched GEMM kernels (DESIGN.md S17) on the
//! decode-step projection shapes of each model config, at several batch
//! sizes, plus one end-to-end batched decode step per serving variant.
//! Every row is emitted twice on SIMD-capable hosts — once on the
//! dispatched vector ISA and once forced to the scalar reference
//! (DESIGN.md S23) — so the SIMD speedup is a first-class measurement,
//! not an inference. CI compiles it with `cargo bench --no-run` so the
//! kernel API cannot rot silently.

use elitekv::bench::native::selection_for;
use elitekv::bench::{bench_ns, BenchOpts};
use elitekv::config::{ModelConfig, Variant};
use elitekv::native::kernels::{sgemm, sgemm_nt};
use elitekv::native::simd::{self, Isa};
use elitekv::native::{LaneStep, NativeModel};
use elitekv::tensor::Tensor;
use elitekv::util::Pcg64;

fn threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Time `c = a @ w` at the given shape and batch.
fn bench_sgemm(isa: &str, name: &str, m: usize, k: usize, n: usize) {
    let mut rng = Pcg64::seeded(0xbe);
    let w = Tensor::randn(vec![k, n], &mut rng);
    let a = Tensor::randn(vec![m, k], &mut rng).data;
    let mut c = vec![0.0f32; m * n];
    let t = threads();
    bench_ns(
        &format!("sgemm/{isa}/{name}/m{m}k{k}n{n}"),
        BenchOpts { warmup_iters: 2, iters: 15 },
        || {
            sgemm(&a, m, &w, &mut c, t);
            std::hint::black_box(&c);
        },
    );
}

/// Time the tied-logits dot-product GEMM `c = a @ embed^T`.
fn bench_logits(isa: &str, cfg: &ModelConfig, m: usize) {
    let mut rng = Pcg64::seeded(0xef);
    let embed = Tensor::randn(vec![cfg.vocab, cfg.d_model], &mut rng);
    let a = Tensor::randn(vec![m, cfg.d_model], &mut rng).data;
    let mut c = vec![0.0f32; m * cfg.vocab];
    let t = threads();
    bench_ns(
        &format!("sgemm_nt/{isa}/logits/{}/m{m}", cfg.name),
        BenchOpts { warmup_iters: 2, iters: 15 },
        || {
            sgemm_nt(&a, m, cfg.d_model, &embed.data, cfg.vocab, &mut c, t);
            std::hint::black_box(&c);
        },
    );
}

/// Time the fused-dequant latent GEMMs (DESIGN.md S19) at a decode-like
/// shape: scores `S = q_lat · Cᵀ` over `len` quantized latent rows and
/// `O_lat = P · C` back, vs their f32 twins on the dequantized window.
fn bench_q8_latent(isa: &str, cfg: &ModelConfig, len: usize) {
    use elitekv::kvcache::quant::{n_groups, quantize_row, QUANT_GROUP};
    use elitekv::native::kernels::{sgemm_nt_q8, sgemm_q8, sgemm_raw};
    let (nh, d_c) = (cfg.n_heads, cfg.d_model / 4);
    let mut rng = Pcg64::seeded(0x48);
    let q_lat = Tensor::randn(vec![nh, d_c], &mut rng).data;
    let c_rows = Tensor::randn(vec![len, d_c], &mut rng).data;
    let g = n_groups(d_c, QUANT_GROUP);
    let mut cq = vec![0i8; len * d_c];
    let mut cs = vec![0.0f32; len * g];
    for j in 0..len {
        quantize_row(
            &c_rows[j * d_c..(j + 1) * d_c],
            QUANT_GROUP,
            &mut cq[j * d_c..(j + 1) * d_c],
            &mut cs[j * g..(j + 1) * g],
        );
    }
    let t = threads();
    let mut scores = vec![0.0f32; nh * len];
    bench_ns(
        &format!("sgemm_nt_q8/{isa}/{}/len{len}", cfg.name),
        BenchOpts { warmup_iters: 2, iters: 15 },
        || {
            sgemm_nt_q8(&q_lat, nh, d_c, &cq, &cs, QUANT_GROUP, len, &mut scores, t);
            std::hint::black_box(&scores);
        },
    );
    bench_ns(
        &format!("sgemm_nt/{isa}/f32-twin/{}/len{len}", cfg.name),
        BenchOpts { warmup_iters: 2, iters: 15 },
        || {
            sgemm_nt(&q_lat, nh, d_c, &c_rows, len, &mut scores, t);
            std::hint::black_box(&scores);
        },
    );
    let p = Tensor::randn(vec![nh, len], &mut rng).data;
    let mut o_lat = vec![0.0f32; nh * d_c];
    bench_ns(
        &format!("sgemm_q8/{isa}/{}/len{len}", cfg.name),
        BenchOpts { warmup_iters: 2, iters: 15 },
        || {
            sgemm_q8(&p, nh, len, &cq, &cs, QUANT_GROUP, d_c, &mut o_lat, t, false);
            std::hint::black_box(&o_lat);
        },
    );
    bench_ns(
        &format!("sgemm_raw/{isa}/f32-twin/{}/len{len}", cfg.name),
        BenchOpts { warmup_iters: 2, iters: 15 },
        || {
            sgemm_raw(&p, nh, len, &c_rows, d_c, &mut o_lat, t, false);
            std::hint::black_box(&o_lat);
        },
    );
}

/// Time one full batched decode step for a serving variant.
fn bench_decode_step(isa: &str, cfg: &ModelConfig, variant: Variant, lanes: usize) {
    let tag = variant.tag();
    let sel = selection_for(cfg, &variant);
    let model = NativeModel::init(cfg, variant, 7, sel.as_ref())
        .expect("bench model init");
    let s = 64usize;
    let mut caches = model.empty_caches(lanes, s);
    let mut sc = model.batch_scratch(lanes);
    // warm the caches to a mid-window position so attention has work
    let t = threads();
    for pos in 0..16 {
        let steps: Vec<LaneStep> = (0..lanes)
            .map(|lane| LaneStep {
                lane,
                pos,
                token: (3 + lane + pos) as u32 % cfg.vocab as u32,
                want_logits: false,
            })
            .collect();
        model
            .decode_batch(&mut sc, &mut caches, &steps, t)
            .expect("warm decode");
    }
    let mut pos = 16usize;
    bench_ns(
        &format!("decode_step/{isa}/{}/{tag}/b{lanes}", cfg.name),
        BenchOpts { warmup_iters: 1, iters: 10 },
        || {
            let steps: Vec<LaneStep> = (0..lanes)
                .map(|lane| LaneStep {
                    lane,
                    pos,
                    token: (5 + lane) as u32,
                    want_logits: true,
                })
                .collect();
            let out = model
                .decode_batch(&mut sc, &mut caches, &steps, t)
                .expect("bench decode");
            std::hint::black_box(&out);
            pos = (pos + 1).min(s - 1);
        },
    );
}

fn main() {
    // Twin rows: the dispatched (widest) ISA first, then the scalar
    // reference forced, so each pair reads as the SIMD speedup. On a
    // scalar-only host there is only one ISA and one set of rows.
    let detected = simd::detect();
    let mut isas = vec![detected];
    if detected != Isa::Scalar {
        isas.push(Isa::Scalar);
    }
    for &isa in &isas {
        assert!(simd::force(isa), "detected/scalar ISA must be runnable");
        let tag = isa.name();
        println!("== kernel_isa: {tag} ==");
        for cfg in [ModelConfig::tiny(), ModelConfig::small()] {
            println!("== {} ==", cfg.name);
            let (d, nh, dh, ffn) =
                (cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ffn);
            for m in [1usize, 4, 8] {
                bench_sgemm(tag, &format!("{}/qkv", cfg.name), m, d, nh * dh);
                bench_sgemm(tag, &format!("{}/mlp", cfg.name), m, d, ffn);
                bench_logits(tag, &cfg, m);
            }
            for len in [64usize, 192] {
                bench_q8_latent(tag, &cfg, len);
            }
            let nc = cfg.n_chunks();
            for variant in [
                Variant::Mha,
                Variant::EliteKv { r: nc / 4, d_ckv: d / 4 },
            ] {
                bench_decode_step(tag, &cfg, variant, 4);
            }
        }
    }
    println!("native_kernels bench done");
}
