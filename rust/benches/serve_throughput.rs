//! End-to-end serving benchmark (the paper's implicit systems claim:
//! smaller KV cache -> cheaper decode steps and more capacity under a
//! fixed memory budget). Measures tokens/s, per-request latency, and
//! peak cache bytes per variant on the tiny model, plus the capacity
//! table under a fixed budget.

use std::sync::Arc;

use elitekv::config::{ModelConfig, Variant};
use elitekv::convert::{self, EliteSelection};
use elitekv::coordinator::{GenParams, InferenceServer, Request};
use elitekv::data::{CorpusGen, ProbeSet};
use elitekv::kvcache::CacheLayout;
use elitekv::runtime::{Engine, HostTensor, ModelRunner, PjrtBackend};
use elitekv::util::stats::percentile;

fn main() {
    let cfg = ModelConfig::tiny();
    let nc = cfg.n_chunks();
    let engine = Arc::new(Engine::new().expect("pjrt"));
    let n_requests: usize = 24;
    let max_new = 12;
    let budget = 16usize << 20;

    let variants = [
        Variant::Mha,
        Variant::Gqa { n_kv_heads: cfg.n_heads / 2 },
        Variant::Gqa { n_kv_heads: 1 },
        Variant::EliteKv { r: nc / 4, d_ckv: 64 },  // 25 %
        Variant::EliteKv { r: nc / 8, d_ckv: 32 },  // 12.5 %
    ];

    println!("== capacity at a {} MiB budget ==", budget >> 20);
    for v in &variants {
        let layout = CacheLayout::new(&cfg, v.clone());
        println!(
            "  {:<20} {:>6.1}% cache  {:>9} tokens fit",
            v.tag(), 100.0 * layout.ratio, layout.tokens_in_budget(budget)
        );
    }

    println!("\n== throughput/latency ({n_requests} requests x {max_new} new tokens) ==");
    println!("{:<20} {:>9} {:>12} {:>12} {:>14}",
             "variant", "tok/s", "p50 ms", "p99 ms", "peak KiB");
    for v in &variants {
        let tag = v.tag();
        let mut runner = ModelRunner::new(
            Arc::clone(&engine), "artifacts", &cfg.name, &tag)
            .expect("runner (run `make artifacts`)");
        if !runner.manifest.extras.is_empty() {
            let r = v.r().unwrap();
            let sel = EliteSelection {
                chunks: vec![vec![(0..r).collect(); cfg.n_heads];
                             cfg.n_layers],
            };
            runner
                .set_extras(vec![HostTensor::F32(
                    convert::elitekv::elite_thetas_flat(&cfg, &sel),
                    vec![cfg.n_layers, cfg.n_heads, r],
                )])
                .unwrap();
        }
        let params = runner.init(5).unwrap();
        let mut server = InferenceServer::new(
            Box::new(PjrtBackend::new(runner, params)), budget).unwrap();
        let gen = CorpusGen::new(cfg.vocab, 1);
        let probes = ProbeSet::generate(&gen, n_requests.div_ceil(6), 77);
        let t0 = std::time::Instant::now();
        for (i, item) in probes.items.iter().take(n_requests).enumerate() {
            server.submit(Request::new(
                i as u64,
                item.prompt.clone(),
                GenParams {
                    max_new_tokens: max_new,
                    stop_token: None, // force fixed-length decode
                    ..Default::default()
                },
            )).unwrap();
        }
        let responses = server.run_to_completion().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
        let mut lat: Vec<f64> =
            responses.iter().map(|r| r.latency * 1e3).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "{:<20} {:>9.1} {:>12.1} {:>12.1} {:>14}",
            tag,
            toks as f64 / wall,
            percentile(&lat, 0.5),
            percentile(&lat, 0.99),
            server.stats.peak_cache_bytes / 1024,
        );
    }
    println!("\nserve_throughput done");
}
