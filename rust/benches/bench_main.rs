//! Component benchmarks behind the paper's tables/figures (custom
//! harness; the offline crate set has no criterion). One section per
//! experiment, measuring the hot operations each experiment exercises:
//!
//!   [T1/F6]  train_step latency per architecture variant (uptraining cost)
//!   [T1]     eval_loss latency (benchmark scoring cost)
//!   [SRV]    prefill + decode latency per variant; pallas vs jnp decode
//!   [T2/F2]  ropelite_delta (Algorithm-1 inner step) + capture latency
//!   [F5]     J-LRD / S-LRD conversion (Jacobi SVD) wall time
//!   [SRV]    kv-cache substrate ops (block allocator, lane splice)
//!
//! Run: `make artifacts && cargo bench` (results also land in
//! EXPERIMENTS.md §Perf).

use std::sync::Arc;

use elitekv::bench::{bench, BenchOpts};
use elitekv::config::{ModelConfig, Variant};
use elitekv::convert::{self, EliteSelection};
use elitekv::data::CorpusGen;
use elitekv::kvcache::BlockAllocator;
use elitekv::runtime::{Engine, HostTensor, ModelRunner, TrainState};
use elitekv::tensor::Tensor;
use elitekv::util::Pcg64;

fn ladder_selection(cfg: &ModelConfig, r: usize) -> EliteSelection {
    EliteSelection {
        chunks: vec![vec![(0..r).collect(); cfg.n_heads]; cfg.n_layers],
    }
}

fn runner_for(
    engine: &Arc<Engine>,
    cfg: &ModelConfig,
    tag: &str,
) -> ModelRunner {
    let mut runner =
        ModelRunner::new(Arc::clone(engine), "artifacts", &cfg.name, tag)
            .expect("runner (run `make artifacts`)");
    if !runner.manifest.extras.is_empty() {
        let var = runner.manifest.variant.clone();
        // ropelite has no intrinsic r — bench with a quarter-ladder mask
        let r = var.r().unwrap_or(cfg.n_chunks() / 4);
        let sel = ladder_selection(cfg, r);
        let extras = match var {
            Variant::RopeLite => vec![HostTensor::F32(
                convert::elitekv::elite_mask_flat(cfg, &sel),
                vec![cfg.n_layers, cfg.n_heads, cfg.n_chunks()],
            )],
            _ => vec![HostTensor::F32(
                convert::elitekv::elite_thetas_flat(cfg, &sel),
                vec![cfg.n_layers, cfg.n_heads, r],
            )],
        };
        runner.set_extras(extras).unwrap();
    }
    runner
}

fn main() {
    let cfg = ModelConfig::tiny();
    let engine = Arc::new(Engine::new().expect("pjrt"));
    let opts = BenchOpts { warmup_iters: 2, iters: 8 };
    let nc = cfg.n_chunks();
    let variants = [
        "mha".to_string(),
        format!("gqa{}", cfg.n_heads / 4),
        format!("elitekv_r{}_c{}", nc / 4, 64),
        "ropelite".to_string(),
    ];

    println!("== [T1/F6] train_step per variant (tiny, batch 8 x 128) ==");
    for tag in &variants {
        let runner = runner_for(&engine, &cfg, tag);
        let params = runner.init(1).unwrap();
        let mut state = TrainState::fresh(params);
        let (b, t) = runner.train_shape().unwrap();
        let mut gen = CorpusGen::new(cfg.vocab, 1);
        let batch = gen.next_batch(b, t);
        bench(&format!("train_step/{tag}"), opts, || {
            runner.train_step(&mut state, &batch, 1e-3).unwrap();
        });
    }

    println!("\n== [T1] eval_loss per variant ==");
    for tag in &variants {
        let runner = runner_for(&engine, &cfg, tag);
        let params = runner.init(1).unwrap();
        let (b, t) = runner.eval_shape().unwrap();
        let mut gen = CorpusGen::new(cfg.vocab, 2);
        let batch = gen.next_batch(b, t);
        bench(&format!("eval_loss/{tag}"), opts, || {
            runner.eval_loss(&params, &batch).unwrap();
        });
    }

    println!("\n== [SRV] prefill + decode per variant (batch 4, S 256) ==");
    for tag in &variants {
        let runner = runner_for(&engine, &cfg, tag);
        let params = runner.init(1).unwrap();
        let (b, s) = runner.manifest.serve_shape().unwrap();
        let mut gen = CorpusGen::new(cfg.vocab, 3);
        let mut tokens = vec![0i32; b * s];
        for row in 0..b {
            for (i, &t) in gen.stream(32).iter().enumerate() {
                tokens[row * s + i] = t as i32;
            }
        }
        let lens = vec![32i32; b];
        bench(&format!("prefill/{tag}"), opts, || {
            runner.prefill(&params, &tokens, &lens).unwrap();
        });
        let (_l, caches) = runner.prefill(&params, &tokens, &lens).unwrap();
        let token = vec![7i32; b];
        let pos = vec![32i32; b];
        bench(&format!("decode/{tag}"), opts, || {
            runner
                .decode(&params, &token, &pos, caches.clone(), false)
                .unwrap();
        });
        if runner.manifest.functions.contains_key("decode_pallas") {
            bench(&format!("decode_pallas/{tag}"), opts, || {
                runner
                    .decode(&params, &token, &pos, caches.clone(), true)
                    .unwrap();
            });
        }
    }

    println!("\n== [T2/F2] RoPElite search primitives ==");
    {
        let runner = runner_for(&engine, &cfg, "mha");
        let params = runner.init(1).unwrap();
        let f = runner.manifest.function("capture_qk").unwrap();
        let tok = &f.inputs[f.input_index("tokens").unwrap()];
        let (b, t) = (tok.shape[0], tok.shape[1]);
        let mut gen = CorpusGen::new(cfg.vocab, 4);
        let tokens: Vec<i32> =
            gen.stream(b * t).iter().map(|&x| x as i32).collect();
        bench("capture_qk/tiny", opts, || {
            runner.capture_qk(&params, &tokens).unwrap();
        });
        let (q, k) = runner.capture_qk(&params, &tokens).unwrap();
        let per = b * t * cfg.n_heads * cfg.d_head;
        let q0 = HostTensor::F32(q.as_f32().unwrap()[..per].to_vec(),
                                 vec![b, t, cfg.n_heads, cfg.d_head]);
        let k0 = HostTensor::F32(k.as_f32().unwrap()[..per].to_vec(),
                                 vec![b, t, cfg.n_heads, cfg.d_head]);
        let mask = HostTensor::F32(vec![0.0; cfg.n_heads * nc],
                                   vec![cfg.n_heads, nc]);
        bench("ropelite_delta/layer", opts, || {
            runner.ropelite_delta(&q0, &k0, &mask).unwrap();
        });
    }

    println!("\n== [F5] conversion (Jacobi SVD weight surgery) ==");
    {
        let runner = runner_for(&engine, &cfg, "mha");
        let params = runner.init(1).unwrap();
        let ckpt = runner.ckpt_from_params(&params).unwrap();
        let sel = ladder_selection(&cfg, nc / 4);
        bench("convert/jlrd_tiny_c64",
              BenchOpts { warmup_iters: 1, iters: 3 }, || {
            convert::convert_elitekv(&cfg, &ckpt, &sel, 64).unwrap();
        });
        bench("convert/slrd_tiny_32_64",
              BenchOpts { warmup_iters: 1, iters: 3 }, || {
            convert::convert_slrd(&cfg, &ckpt, &sel, 32, 64).unwrap();
        });
        bench("convert/gqa2_tiny",
              BenchOpts { warmup_iters: 1, iters: 3 }, || {
            convert::convert_gqa(&cfg, &ckpt, 2).unwrap();
        });
    }

    println!("\n== [SRV] kv-cache substrate ops ==");
    {
        let many = BenchOpts { warmup_iters: 2, iters: 10 };
        bench("block_alloc/1k-seqs", many, || {
            let mut a = BlockAllocator::new(4096, 16);
            let mut chains = Vec::new();
            for i in 0..1000 {
                chains.push(a.alloc(17 + (i % 32)).unwrap());
            }
            for c in &chains {
                a.release(c);
            }
        });
        let mut rng = Pcg64::seeded(9);
        let a = Tensor::randn(vec![256, 512], &mut rng);
        bench("svd/256x512", BenchOpts { warmup_iters: 1, iters: 3 }, || {
            elitekv::linalg::svd_truncate(&a, 64);
        });
    }
    println!("\nbench_main done");
}
