//! Finding collection and byte-exact report rendering for `elitekv
//! lint`.
//!
//! The rendered report is a contract: `python/tools/lint.py` must emit
//! the identical bytes for the same tree (pinned by the differential
//! tests in `rust/tests/lint_tool.rs`), so ordering, dedup, and the
//! summary line formats are all fixed here and mirrored there.

/// One lint finding, anchored to a file and line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Root-relative path with forward slashes.
    pub path: String,
    /// 1-based anchor line (1 for file-level findings).
    pub line: usize,
    /// Rule identifier: `"R0"` … `"R7"`.
    pub rule: &'static str,
    /// Human-readable message (stable template text).
    pub msg: String,
}

impl Finding {
    /// Construct a finding (convenience for the rule engine).
    pub fn new(
        path: &str,
        line: usize,
        rule: &'static str,
        msg: String,
    ) -> Finding {
        Finding { path: path.to_string(), line, rule, msg }
    }
}

/// The result of a lint run: findings plus scan statistics.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// All surviving (non-suppressed) findings, unsorted.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files lexed.
    pub files_scanned: usize,
}

impl Report {
    /// True when no findings survived suppression.
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Render the report: one `path:line rule message` line per finding
    /// sorted by (path, line, rule, message) with exact duplicates
    /// removed, then a summary line. Byte-identical to the Python
    /// runner's output.
    pub fn render(&self) -> String {
        let mut sorted = self.findings.clone();
        sorted.sort_by(|a, b| {
            (&a.path, a.line, a.rule, &a.msg)
                .cmp(&(&b.path, b.line, b.rule, &b.msg))
        });
        sorted.dedup();
        let mut out = String::new();
        for f in &sorted {
            out.push_str(&format!(
                "{}:{} {} {}\n",
                f.path, f.line, f.rule, f.msg
            ));
        }
        if sorted.is_empty() {
            out.push_str(&format!(
                "lint: clean ({} files scanned)\n",
                self.files_scanned
            ));
        } else {
            out.push_str(&format!(
                "lint: {} finding(s) ({} files scanned)\n",
                sorted.len(),
                self.files_scanned
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_sorted_and_deduped() {
        let mut r = Report { findings: vec![], files_scanned: 3 };
        r.findings.push(Finding::new("b.rs", 2, "R3", "x".into()));
        r.findings.push(Finding::new("a.rs", 9, "R6", "y".into()));
        r.findings.push(Finding::new("b.rs", 2, "R3", "x".into()));
        r.findings.push(Finding::new("b.rs", 2, "R2", "z".into()));
        assert_eq!(
            r.render(),
            "a.rs:9 R6 y\nb.rs:2 R2 z\nb.rs:2 R3 x\n\
             lint: 3 finding(s) (3 files scanned)\n"
        );
    }

    #[test]
    fn clean_summary() {
        let r = Report { findings: vec![], files_scanned: 7 };
        assert!(r.is_clean());
        assert_eq!(r.render(), "lint: clean (7 files scanned)\n");
    }
}
