//! The `elitekv lint` rule engine: this repo's contracts as checks.
//!
//! Rules (DESIGN.md S21 documents each with its contract of origin):
//!
//! * **R1** — every file under `rust/tests/`, `rust/benches/`, and
//!   `examples/` is registered in `Cargo.toml` (the manifest sets
//!   `autotests=false`, so an unregistered suite silently never runs),
//!   and every registered target path exists.
//! * **R2** — no nondeterminism-prone symbols (`HashMap`, `HashSet`,
//!   `Instant`, `SystemTime`, …) in the decode-path files
//!   `native/kernels.rs` / `native/model.rs` (the S17 bitwise contract).
//! * **R3** — no `unwrap`/`expect`/`panic!`-family/integer-literal
//!   indexing in serving-path modules (`coordinator/*`, `kvcache/radix`,
//!   `kvcache/block`) outside `#[cfg(test)]` code (S11: a request must
//!   fail as a `Result`, not kill the engine).
//! * **R4** — references to the `xla` crate only under
//!   `#[cfg(feature = "pjrt")]` gating (S14), whether per-item, via a
//!   gated `mod` declaration chain, an inner `#![cfg…]`, or a
//!   `required-features` target entry.
//! * **R5** — every `pub` item visible to the default-feature `cargo
//!   doc` in a `missing_docs`-enforced module (parsed from `lib.rs`)
//!   carries a doc comment.
//! * **R6** — balanced `()[]{}` per file with full string/char/comment
//!   awareness (formalizing, and fixing the raw-string false positive
//!   of, the PR-5 ad-hoc bracket scanner), plus any lexer error.
//! * **R7** — CLI flags in `main.rs`, the README flag table, and
//!   `SchedulerConfig` fields agree.
//! * **R8** — arch-specific SIMD code stays behind the dispatch layer
//!   (S23): `target_arch` / `target_feature` / feature-detection
//!   identifiers and `std::arch` paths only under
//!   `rust/src/native/simd/`, where every `unsafe fn` must carry a
//!   `// SAFETY:` comment.
//!
//! Escape hatch: `// lint: allow(Rn[,Rn]) — reason` on (or directly
//! above) the offending line suppresses those rules there; a missing
//! reason or unknown rule is itself a finding (**R0**).

use std::collections::BTreeMap;
use std::path::Path;

use super::lexer::{lex, LexError, TokKind, Token};
use super::report::{Finding, Report};

/// Directories scanned for `.rs` files (root-relative).
const SCAN_DIRS: [&str; 4] =
    ["rust/src", "rust/tests", "rust/benches", "examples"];
/// Directory name holding lint test fixtures — never scanned.
const SKIP_DIR: &str = "lint_fixtures";
/// Files under the S17 determinism contract (R2).
const R2_FILES: [&str; 2] =
    ["rust/src/native/kernels.rs", "rust/src/native/model.rs"];
/// Symbols R2 bans in those files.
const R2_BANNED: [&str; 6] = [
    "HashMap",
    "HashSet",
    "Instant",
    "SystemTime",
    "thread_rng",
    "available_parallelism",
];
/// Serving-path scope for R3: one directory prefix...
const R3_DIR: &str = "rust/src/coordinator/";
/// ...plus individual kvcache files on the request path.
const R3_FILES: [&str; 2] =
    ["rust/src/kvcache/radix.rs", "rust/src/kvcache/block.rs"];
/// Panicking macros R3 bans.
const R3_MACROS: [&str; 4] =
    ["panic", "unreachable", "todo", "unimplemented"];
/// Panicking methods R3 bans.
const R3_METHODS: [&str; 2] = ["unwrap", "expect"];
/// `Args` accessor methods whose first argument names a CLI flag (R7).
const ARGS_API: [&str; 7] =
    ["get", "str_or", "usize_or", "u64_or", "f64_or", "has", "req"];
/// Directory prefix where arch-specific SIMD code may live (R8).
const R8_DIR: &str = "rust/src/native/simd/";
/// Arch-coupled identifiers R8 bans outside that directory.
const R8_BANNED: [&str; 4] = [
    "target_arch",
    "target_feature",
    "is_x86_feature_detected",
    "is_aarch64_feature_detected",
];
/// Contract-input files (R1/R5/R7 anchors).
const MAIN_RS: &str = "rust/src/main.rs";
const LIB_RS: &str = "rust/src/lib.rs";
const SCHED_RS: &str = "rust/src/coordinator/scheduler.rs";

/// One parsed `#[…]` / `#![…]` attribute with classification inputs.
#[derive(Clone, Debug)]
struct Attr {
    /// Code-token index of the leading `#`.
    start_code: usize,
    /// Code-token index of the closing `]`.
    end_code: usize,
    /// Original-token index of the leading `#`.
    start_orig: usize,
    /// Original-token index of the closing `]`.
    end_orig: usize,
    /// Inner attribute (`#![…]`)?
    inner: bool,
    /// Identifier tokens inside the brackets.
    idents: Vec<String>,
    /// Unquoted string-literal tokens inside the brackets.
    strs: Vec<String>,
}

impl Attr {
    fn is_testish(&self) -> bool {
        self.idents.iter().any(|s| s == "test")
    }

    fn is_pjrt(&self) -> bool {
        self.idents.iter().any(|s| s == "cfg")
            && self.idents.iter().any(|s| s == "feature")
            && !self.idents.iter().any(|s| s == "not")
            && self.strs.iter().any(|s| s == "pjrt")
    }

    fn is_docs_allow(&self) -> bool {
        self.idents.iter().any(|s| s == "allow")
            && self.idents.iter().any(|s| s == "missing_docs")
    }

    fn is_doc(&self) -> bool {
        self.idents.iter().any(|s| s == "doc")
    }
}

/// A `mod name;` / `mod name {` declaration found in a file.
#[derive(Clone, Debug)]
struct ModDecl {
    name: String,
    /// Declared under a `#[cfg(feature = "pjrt")]` span?
    pjrt: bool,
    /// Declared under an `#[allow(missing_docs)]` span?
    docs_allowed: bool,
}

/// Everything the rules need about one lexed `.rs` file.
struct FileLex {
    toks: Vec<Token>,
    errs: Vec<LexError>,
    /// Indices into `toks` of non-comment tokens.
    code: Vec<usize>,
    attrs: Vec<Attr>,
    /// Code-index spans (inclusive) gated by test-ish attributes.
    test_spans: Vec<(usize, usize)>,
    /// Code-index spans (inclusive) gated on `feature = "pjrt"`.
    pjrt_spans: Vec<(usize, usize)>,
    /// Code-index spans (inclusive) under `#[allow(missing_docs)]`.
    docs_allow_spans: Vec<(usize, usize)>,
    /// File carries an inner `#![cfg(feature = "pjrt")]`.
    inner_pjrt: bool,
    mod_decls: Vec<ModDecl>,
    /// `rule -> lines` where an allow comment suppresses findings.
    allows: BTreeMap<String, Vec<usize>>,
    /// R0 findings (malformed allow comments), path left empty.
    r0: Vec<(usize, String)>,
}

fn in_spans(spans: &[(usize, usize)], idx: usize) -> bool {
    spans.iter().any(|&(a, b)| a <= idx && idx <= b)
}

/// Find the code-token index closing the item that starts at `s`
/// (after its attributes): the matching `}` of its body, its `;`, or a
/// stray closer/end-of-file.
fn find_item_end(code_toks: &[&Token], s: usize) -> usize {
    let n = code_toks.len();
    let mut depth: i64 = 0;
    let mut m = s;
    while m < n {
        let t = code_toks[m].text.as_str();
        if t == "(" || t == "[" {
            depth += 1;
        } else if t == ")" || t == "]" {
            if depth == 0 {
                return m;
            }
            depth -= 1;
        } else if t == "{" {
            if depth == 0 {
                let mut d = 1i64;
                let mut m2 = m + 1;
                while m2 < n && d > 0 {
                    let t2 = code_toks[m2].text.as_str();
                    if t2 == "(" || t2 == "[" || t2 == "{" {
                        d += 1;
                    } else if t2 == ")" || t2 == "]" || t2 == "}" {
                        d -= 1;
                    }
                    m2 += 1;
                }
                return if m2 > 0 { m2 - 1 } else { 0 };
            }
            depth += 1;
        } else if t == "}" {
            if depth == 0 {
                return m;
            }
            depth -= 1;
        } else if t == ";" && depth == 0 {
            return m;
        }
        m += 1;
    }
    if n > 0 {
        n - 1
    } else {
        0
    }
}

/// Parse one allow comment body (text after `lint:`). Returns the list
/// of suppressed rules, or an error message for R0.
fn parse_allow_body(rest: &str) -> (Vec<String>, Option<String>) {
    let malformed = "malformed lint control comment (grammar: \
                     `// lint: allow(Rn[,Rn]) \u{2014} reason`)";
    let rest = rest.trim();
    if !rest.starts_with("allow(") {
        return (Vec::new(), Some(malformed.to_string()));
    }
    let close = match rest.find(')') {
        Some(c) => c,
        None => return (Vec::new(), Some(malformed.to_string())),
    };
    let inside = &rest[6..close];
    let mut rules: Vec<String> = Vec::new();
    let mut err: Option<String> = None;
    for part in inside.split(',') {
        let p = part.trim();
        let valid = p.len() == 2
            && p.starts_with('R')
            && ('1'..='8').contains(&p.chars().nth(1).unwrap_or('x'));
        if valid {
            rules.push(p.to_string());
        } else {
            err = Some(format!(
                "unknown rule `{p}` in lint control comment"
            ));
        }
    }
    let mut tail = rest[close + 1..].trim_start();
    let mut sep = false;
    for s in ["\u{2014}", "\u{2013}", "-", ":"] {
        if let Some(t) = tail.strip_prefix(s) {
            tail = t;
            sep = true;
            break;
        }
    }
    if !sep || tail.trim().is_empty() {
        err = Some(malformed.to_string());
    }
    (rules, err)
}

/// Lex and structurally annotate one file.
fn analyze(text: &str) -> FileLex {
    let (toks, errs) = lex(text);
    let mut code: Vec<usize> = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Comment && t.kind != TokKind::Doc {
            code.push(i);
        }
    }
    let code_toks: Vec<&Token> = code.iter().map(|&i| &toks[i]).collect();
    let n = code_toks.len();

    // ---- attributes ----
    let mut attrs: Vec<Attr> = Vec::new();
    let mut i = 0;
    while i < n {
        if code_toks[i].text == "#" {
            let inner = i + 1 < n && code_toks[i + 1].text == "!";
            let b = i + 1 + usize::from(inner);
            if b < n && code_toks[b].text == "[" {
                let mut depth = 1i64;
                let mut k = b + 1;
                while k < n && depth > 0 {
                    let t = code_toks[k].text.as_str();
                    if t == "[" {
                        depth += 1;
                    } else if t == "]" {
                        depth -= 1;
                    }
                    if depth > 0 {
                        k += 1;
                    }
                }
                let end = k.min(n - 1);
                let lo = (b + 1).min(n);
                let hi = end.min(n).max(lo);
                let mut idents: Vec<String> = Vec::new();
                let mut strs: Vec<String> = Vec::new();
                for ct in &code_toks[lo..hi] {
                    if ct.kind == TokKind::Ident {
                        idents.push(ct.text.clone());
                    } else if ct.kind == TokKind::Str {
                        strs.push(unquote(&ct.text));
                    }
                }
                attrs.push(Attr {
                    start_code: i,
                    end_code: end,
                    start_orig: code[i],
                    end_orig: code[end],
                    inner,
                    idents,
                    strs,
                });
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }

    // ---- attribute chains -> item spans ----
    let mut test_spans: Vec<(usize, usize)> = Vec::new();
    let mut pjrt_spans: Vec<(usize, usize)> = Vec::new();
    let mut docs_allow_spans: Vec<(usize, usize)> = Vec::new();
    let mut inner_pjrt = false;
    let mut j = 0;
    while j < attrs.len() {
        if attrs[j].inner {
            if attrs[j].is_pjrt() {
                inner_pjrt = true;
            }
            j += 1;
            continue;
        }
        let chain_start = j;
        while j + 1 < attrs.len()
            && !attrs[j + 1].inner
            && attrs[j + 1].start_code == attrs[j].end_code + 1
        {
            j += 1;
        }
        let item_start = attrs[j].end_code + 1;
        let item_end = find_item_end(&code_toks, item_start);
        let span = (attrs[chain_start].start_code, item_end);
        for a in &attrs[chain_start..=j] {
            if a.is_testish() {
                test_spans.push(span);
            }
            if a.is_pjrt() {
                pjrt_spans.push(span);
            }
            if a.is_docs_allow() {
                docs_allow_spans.push(span);
            }
        }
        j += 1;
    }

    // ---- mod declarations ----
    let mut mod_decls: Vec<ModDecl> = Vec::new();
    for t in 0..n {
        if code_toks[t].text == "mod"
            && code_toks[t].kind == TokKind::Ident
            && t + 1 < n
            && code_toks[t + 1].kind == TokKind::Ident
        {
            mod_decls.push(ModDecl {
                name: code_toks[t + 1].text.clone(),
                pjrt: in_spans(&pjrt_spans, t),
                docs_allowed: in_spans(&docs_allow_spans, t),
            });
        }
    }

    // ---- allow comments ----
    let mut allows: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    let mut r0: Vec<(usize, String)> = Vec::new();
    for (ti, tok) in toks.iter().enumerate() {
        if tok.kind != TokKind::Comment && tok.kind != TokKind::Doc {
            continue;
        }
        if !tok.text.starts_with("//") {
            continue;
        }
        let body = tok.text[2..]
            .trim_start_matches(&['/', '!'][..])
            .trim_start();
        let Some(rest) = body.strip_prefix("lint:") else {
            continue;
        };
        let (rules, err) = parse_allow_body(rest);
        if let Some(msg) = err {
            r0.push((tok.line, msg));
        }
        let mut target = tok.line;
        for t2 in &toks[ti + 1..] {
            if t2.kind != TokKind::Comment && t2.kind != TokKind::Doc {
                target = t2.line;
                break;
            }
        }
        for r in rules {
            let e = allows.entry(r).or_default();
            e.push(tok.line);
            e.push(target);
        }
    }

    FileLex {
        toks,
        errs,
        code,
        attrs,
        test_spans,
        pjrt_spans,
        docs_allow_spans,
        inner_pjrt,
        mod_decls,
        allows,
        r0,
    }
}

fn unquote(s: &str) -> String {
    let mut t = s;
    for p in ["br", "cr", "r", "b", "c"] {
        if let Some(rest) = t.strip_prefix(p) {
            if rest.starts_with(&['"', '#', '\''][..]) {
                t = rest;
                break;
            }
        }
    }
    let t = t.trim_matches('#');
    t.trim_matches(&['"', '\''][..]).to_string()
}

/// Extract `--flag` names from free text (README prose, help strings,
/// doc comments). A flag starts with `--[a-z]` and continues over
/// `[a-z0-9-]`; first-occurrence order, deduplicated.
fn extract_flags(text: &str) -> Vec<String> {
    let c: Vec<char> = text.chars().collect();
    let n = c.len();
    let mut out: Vec<String> = Vec::new();
    let mut i = 0;
    while i + 2 < n {
        if c[i] == '-'
            && c[i + 1] == '-'
            && (i == 0 || c[i - 1] != '-')
            && c[i + 2].is_ascii_lowercase()
        {
            let mut j = i + 2;
            while j < n
                && (c[j].is_ascii_lowercase()
                    || c[j].is_ascii_digit()
                    || c[j] == '-')
            {
                j += 1;
            }
            let flag: String = c[i + 2..j].iter().collect();
            let flag = flag.trim_end_matches('-').to_string();
            if !flag.is_empty() && !out.contains(&flag) {
                out.push(flag);
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

/// One `[[test]]`/`[[bench]]`/`[[example]]` entry from `Cargo.toml`.
struct CargoTarget {
    kind: String,
    path: String,
    path_line: usize,
    required: Vec<String>,
}

/// Line-based parse of the target tables in `Cargo.toml` (no TOML dep).
fn parse_cargo(text: &str) -> Vec<CargoTarget> {
    let mut targets: Vec<CargoTarget> = Vec::new();
    let mut current = false;
    for (ln0, raw) in text.lines().enumerate() {
        let ln = ln0 + 1;
        let mut line = String::new();
        let mut in_str = false;
        for ch in raw.chars() {
            if ch == '"' {
                in_str = !in_str;
            }
            if ch == '#' && !in_str {
                break;
            }
            line.push(ch);
        }
        let s = line.trim();
        if s.starts_with("[[") {
            let name = s.trim_matches(&['[', ']'][..]).to_string();
            if name == "test" || name == "bench" || name == "example" {
                targets.push(CargoTarget {
                    kind: name,
                    path: String::new(),
                    path_line: ln,
                    required: Vec::new(),
                });
                current = true;
            } else {
                current = false;
            }
            continue;
        }
        if s.starts_with('[') {
            current = false;
            continue;
        }
        if !current {
            continue;
        }
        let Some((key, val)) = s.split_once('=') else { continue };
        let key = key.trim();
        let quoted: Vec<String> = val
            .split('"')
            .skip(1)
            .step_by(2)
            .map(|x| x.to_string())
            .collect();
        if let Some(t) = targets.last_mut() {
            if key == "path" && !quoted.is_empty() {
                t.path = quoted[0].clone();
                t.path_line = ln;
            } else if key == "required-features" {
                t.required = quoted;
            }
        }
    }
    targets
}

/// Recursive `.rs` discovery under the scan dirs, sorted, fixture
/// directories excluded.
fn discover(root: &Path) -> Vec<String> {
    fn walk(dir: &Path, rel: &str, out: &mut Vec<String>) {
        let Ok(rd) = std::fs::read_dir(dir) else { return };
        let mut names: Vec<String> = Vec::new();
        for e in rd.flatten() {
            names.push(e.file_name().to_string_lossy().to_string());
        }
        names.sort();
        for name in names {
            let p = dir.join(&name);
            let r = format!("{rel}/{name}");
            if p.is_dir() {
                if name != SKIP_DIR {
                    walk(&p, &r, out);
                }
            } else if name.ends_with(".rs") {
                out.push(r);
            }
        }
    }
    let mut out = Vec::new();
    for d in SCAN_DIRS {
        walk(&root.join(d), d, &mut out);
    }
    out.sort();
    out
}

/// Module-chain names of a `rust/src` file: `rust/src/a/b.rs` ->
/// `[a, b]`, `rust/src/a/mod.rs` -> `[a]`, `lib.rs`/`main.rs` -> `[]`.
fn mod_chain(rel: &str) -> Vec<String> {
    let Some(sub) = rel.strip_prefix("rust/src/") else {
        return Vec::new();
    };
    let comps: Vec<&str> = sub.split('/').collect();
    let mut names: Vec<String> = Vec::new();
    for (k, comp) in comps.iter().enumerate() {
        if k + 1 == comps.len() {
            let stem = comp.trim_end_matches(".rs");
            if stem != "mod" && stem != "lib" && stem != "main" {
                names.push(stem.to_string());
            }
        } else {
            names.push(comp.to_string());
        }
    }
    names
}

/// Is a whole file compiled only under `--features pjrt`?
fn file_pjrt_gated(
    rel: &str,
    lexmap: &BTreeMap<String, FileLex>,
    cargo: &[CargoTarget],
) -> bool {
    if let Some(fl) = lexmap.get(rel) {
        if fl.inner_pjrt {
            return true;
        }
    }
    if rel.starts_with("rust/src/") {
        let names = mod_chain(rel);
        for i in 0..names.len() {
            let decl_file = if i == 0 {
                LIB_RS.to_string()
            } else {
                format!("rust/src/{}/mod.rs", names[..i].join("/"))
            };
            if let Some(fl) = lexmap.get(&decl_file) {
                for d in &fl.mod_decls {
                    if d.name == names[i] && d.pjrt {
                        return true;
                    }
                }
            }
        }
        return false;
    }
    cargo.iter().any(|t| {
        t.path == rel && t.required.iter().any(|r| r == "pjrt")
    })
}

/// Does a module file open with inner docs (`//!` / `/*!`)?
fn has_inner_doc(fl: &FileLex) -> bool {
    for t in &fl.toks {
        if t.kind == TokKind::Comment {
            continue;
        }
        return t.kind == TokKind::Doc
            && (t.text.starts_with("//!") || t.text.starts_with("/*!"));
    }
    false
}

/// Is the `pub` at original-token index `oi` documented? Walks back
/// over plain comments and attributes; a doc comment, `#[doc…]`, or
/// `#[allow(missing_docs)]` satisfies it.
fn documented(fl: &FileLex, oi: usize) -> bool {
    let by_end: BTreeMap<usize, &Attr> =
        fl.attrs.iter().map(|a| (a.end_orig, a)).collect();
    let mut p = oi;
    while p > 0 {
        p -= 1;
        let tok = &fl.toks[p];
        if tok.kind == TokKind::Doc {
            return true;
        }
        if tok.kind == TokKind::Comment {
            continue;
        }
        if let Some(a) = by_end.get(&p) {
            if a.is_doc() || a.is_docs_allow() {
                return true;
            }
            if a.start_orig == 0 {
                return false;
            }
            p = a.start_orig;
            continue;
        }
        return false;
    }
    false
}

/// Does the item whose first original token is at `oi` carry a
/// `// SAFETY:` comment (R8)? Walks back over plain comments, doc
/// comments, and attributes, accepting the first line comment whose
/// body opens with `SAFETY:`.
fn has_safety_comment(fl: &FileLex, oi: usize) -> bool {
    let by_end: BTreeMap<usize, &Attr> =
        fl.attrs.iter().map(|a| (a.end_orig, a)).collect();
    let mut p = oi;
    while p > 0 {
        p -= 1;
        let tok = &fl.toks[p];
        if tok.kind == TokKind::Comment {
            if tok.text.starts_with("//")
                && tok.text[2..].trim_start().starts_with("SAFETY:")
            {
                return true;
            }
            continue;
        }
        if tok.kind == TokKind::Doc {
            continue;
        }
        if let Some(a) = by_end.get(&p) {
            if a.start_orig == 0 {
                return false;
            }
            p = a.start_orig;
            continue;
        }
        return false;
    }
    false
}

/// Run every rule over the tree at `root` and return the report.
pub fn run(root: &Path) -> Report {
    let files = discover(root);
    let mut lexmap: BTreeMap<String, FileLex> = BTreeMap::new();
    for f in &files {
        let bytes = std::fs::read(root.join(f)).unwrap_or_default();
        let text = String::from_utf8_lossy(&bytes).to_string();
        lexmap.insert(f.clone(), analyze(&text));
    }
    let cargo_text = std::fs::read(root.join("Cargo.toml"))
        .map(|b| String::from_utf8_lossy(&b).to_string())
        .unwrap_or_default();
    let readme_text = std::fs::read(root.join("README.md"))
        .map(|b| String::from_utf8_lossy(&b).to_string())
        .unwrap_or_default();
    let cargo = parse_cargo(&cargo_text);

    let mut findings: Vec<Finding> = Vec::new();

    // ---- R0: malformed allow comments ----
    for f in &files {
        for (line, msg) in &lexmap[f].r0 {
            findings.push(Finding::new(f, *line, "R0", msg.clone()));
        }
    }

    // ---- R1: target registration <-> files ----
    for (kind, prefix) in [
        ("test", "rust/tests/"),
        ("bench", "rust/benches/"),
        ("example", "examples/"),
    ] {
        let regs: Vec<&CargoTarget> =
            cargo.iter().filter(|t| t.kind == kind).collect();
        for f in &files {
            if f.starts_with(prefix)
                && !regs.iter().any(|t| &t.path == f)
            {
                findings.push(Finding::new(
                    f,
                    1,
                    "R1",
                    format!(
                        "unregistered {kind} target: add a [[{kind}]] \
                         entry with path = \"{f}\" to Cargo.toml \
                         (autotests=false)"
                    ),
                ));
            }
        }
        for t in regs {
            if !t.path.is_empty()
                && t.path.starts_with(prefix)
                && !files.contains(&t.path)
            {
                findings.push(Finding::new(
                    "Cargo.toml",
                    t.path_line,
                    "R1",
                    format!(
                        "[[{kind}]] entry points at missing file `{}`",
                        t.path
                    ),
                ));
            }
        }
    }

    // ---- per-file token rules ----
    for f in &files {
        let fl = &lexmap[f];
        let code_toks: Vec<&Token> =
            fl.code.iter().map(|&i| &fl.toks[i]).collect();
        let n = code_toks.len();

        // R6: delimiter balance + lexer errors.
        for e in &fl.errs {
            findings.push(Finding::new(f, e.line, "R6", e.msg.clone()));
        }
        let mut stack: Vec<(String, usize)> = Vec::new();
        for ct in &code_toks {
            let tx = ct.text.as_str();
            let line = ct.line;
            if tx == "(" || tx == "[" || tx == "{" {
                stack.push((tx.to_string(), line));
            } else if tx == ")" || tx == "]" || tx == "}" {
                match stack.pop() {
                    None => findings.push(Finding::new(
                        f,
                        line,
                        "R6",
                        format!("unmatched closing `{tx}`"),
                    )),
                    Some((o, ol)) => {
                        let want = match o.as_str() {
                            "(" => ")",
                            "[" => "]",
                            _ => "}",
                        };
                        if tx != want {
                            findings.push(Finding::new(
                                f,
                                line,
                                "R6",
                                format!(
                                    "mismatched delimiters: `{o}` \
                                     (line {ol}) closed by `{tx}`"
                                ),
                            ));
                        }
                    }
                }
            }
        }
        for (o, ol) in &stack {
            findings.push(Finding::new(
                f,
                *ol,
                "R6",
                format!("unclosed `{o}` at end of file"),
            ));
        }

        // R2: determinism-contract files.
        if R2_FILES.contains(&f.as_str()) {
            for t in 0..n {
                if code_toks[t].kind == TokKind::Ident
                    && R2_BANNED.contains(&code_toks[t].text.as_str())
                    && !in_spans(&fl.test_spans, t)
                {
                    findings.push(Finding::new(
                        f,
                        code_toks[t].line,
                        "R2",
                        format!(
                            "nondeterminism-prone symbol `{}` in a \
                             decode-path file (S17 bitwise contract)",
                            code_toks[t].text
                        ),
                    ));
                }
            }
        }

        // R3: serving-path panic freedom.
        if f.starts_with(R3_DIR) || R3_FILES.contains(&f.as_str()) {
            for t in 0..n {
                if in_spans(&fl.test_spans, t) {
                    continue;
                }
                let tx = code_toks[t].text.as_str();
                let line = code_toks[t].line;
                if code_toks[t].kind == TokKind::Ident
                    && R3_METHODS.contains(&tx)
                    && t > 0
                    && code_toks[t - 1].text == "."
                    && t + 1 < n
                    && code_toks[t + 1].text == "("
                {
                    findings.push(Finding::new(
                        f,
                        line,
                        "R3",
                        format!(
                            "`.{tx}()` in serving-path code (S11: \
                             return a Result instead)"
                        ),
                    ));
                } else if code_toks[t].kind == TokKind::Ident
                    && R3_MACROS.contains(&tx)
                    && t + 1 < n
                    && code_toks[t + 1].text == "!"
                {
                    findings.push(Finding::new(
                        f,
                        line,
                        "R3",
                        format!(
                            "`{tx}!` in serving-path code (S11: \
                             return a Result instead)"
                        ),
                    ));
                } else if tx == "["
                    && t > 0
                    && (code_toks[t - 1].kind == TokKind::Ident
                        || code_toks[t - 1].text == ")"
                        || code_toks[t - 1].text == "]")
                    && t + 2 < n
                    && code_toks[t + 1].kind == TokKind::Num
                    && code_toks[t + 2].text == "]"
                {
                    findings.push(Finding::new(
                        f,
                        line,
                        "R3",
                        format!(
                            "integer-literal index `[{}]` in \
                             serving-path code (S11: use .get or a \
                             checked bound)",
                            code_toks[t + 1].text
                        ),
                    ));
                }
            }
        }

        // R4: xla references must be pjrt-gated.
        if !file_pjrt_gated(f, &lexmap, &cargo) {
            for t in 0..n {
                if code_toks[t].kind == TokKind::Ident
                    && code_toks[t].text == "xla"
                    && !in_spans(&fl.pjrt_spans, t)
                {
                    findings.push(Finding::new(
                        f,
                        code_toks[t].line,
                        "R4",
                        "reference to the `xla` crate outside \
                         #[cfg(feature = \"pjrt\")]"
                            .to_string(),
                    ));
                }
            }
        }

        // R8: arch-specific code stays behind the simd dispatch layer.
        if f.starts_with(R8_DIR) {
            for t in 0..n {
                if code_toks[t].kind == TokKind::Ident
                    && code_toks[t].text == "unsafe"
                    && t + 1 < n
                    && code_toks[t + 1].text == "fn"
                {
                    let s = if t > 0 && code_toks[t - 1].text == "pub" {
                        t - 1
                    } else {
                        t
                    };
                    if !has_safety_comment(fl, fl.code[s]) {
                        findings.push(Finding::new(
                            f,
                            code_toks[t].line,
                            "R8",
                            "`unsafe fn` without a `// SAFETY:` comment \
                             in the simd module (S23: document the \
                             contract the caller must uphold)"
                                .to_string(),
                        ));
                    }
                }
            }
        } else {
            for t in 0..n {
                if code_toks[t].kind != TokKind::Ident {
                    continue;
                }
                let tx = code_toks[t].text.as_str();
                let named = if R8_BANNED.contains(&tx) {
                    Some(tx.to_string())
                } else if tx == "arch"
                    && t >= 3
                    && code_toks[t - 1].text == ":"
                    && code_toks[t - 2].text == ":"
                    && (code_toks[t - 3].text == "std"
                        || code_toks[t - 3].text == "core")
                {
                    Some(format!("{}::arch", code_toks[t - 3].text))
                } else {
                    None
                };
                if let Some(name) = named {
                    findings.push(Finding::new(
                        f,
                        code_toks[t].line,
                        "R8",
                        format!(
                            "arch-specific identifier `{name}` outside \
                             rust/src/native/simd/ (S23: SIMD \
                             intrinsics live behind the dispatch layer)"
                        ),
                    ));
                }
            }
        }
    }

    // ---- R5: doc coverage on the enforced surface ----
    let mut enforced: Vec<String> = Vec::new();
    if let Some(libfl) = lexmap.get(LIB_RS) {
        for d in &libfl.mod_decls {
            if !d.docs_allowed && !enforced.contains(&d.name) {
                enforced.push(d.name.clone());
            }
        }
    }
    for f in &files {
        if !f.starts_with("rust/src/") {
            continue;
        }
        let chain = mod_chain(f);
        let in_scope = f == LIB_RS
            || (!chain.is_empty() && enforced.contains(&chain[0]));
        if !in_scope || file_pjrt_gated(f, &lexmap, &cargo) {
            continue;
        }
        let fl = &lexmap[f];
        let code_toks: Vec<&Token> =
            fl.code.iter().map(|&i| &fl.toks[i]).collect();
        let n = code_toks.len();
        let dir = match f.rfind('/') {
            Some(p) => &f[..p],
            None => "",
        };
        for t in 0..n {
            if code_toks[t].text != "pub"
                || code_toks[t].kind != TokKind::Ident
            {
                continue;
            }
            if in_spans(&fl.test_spans, t)
                || in_spans(&fl.pjrt_spans, t)
                || in_spans(&fl.docs_allow_spans, t)
            {
                continue;
            }
            if t + 1 >= n {
                continue;
            }
            let nxt = code_toks[t + 1].text.as_str();
            if nxt == "(" || nxt == "use" {
                continue;
            }
            if nxt == "mod"
                && t + 3 < n
                && code_toks[t + 3].text == ";"
            {
                let name = &code_toks[t + 2].text;
                let cand1 = format!("{dir}/{name}.rs");
                let cand2 = format!("{dir}/{name}/mod.rs");
                let sub = lexmap.get(&cand1).or_else(|| {
                    lexmap.get(&cand2)
                });
                if let Some(sfl) = sub {
                    if has_inner_doc(sfl) {
                        continue;
                    }
                }
            }
            if !documented(fl, fl.code[t]) {
                findings.push(Finding::new(
                    f,
                    code_toks[t].line,
                    "R5",
                    "undocumented `pub` item in a \
                     missing_docs-enforced module (cargo doc -D \
                     warnings will fail)"
                        .to_string(),
                ));
            }
        }
    }

    // ---- R7: CLI flags <-> README table <-> SchedulerConfig ----
    if let Some(mainfl) = lexmap.get(MAIN_RS) {
        let code_toks: Vec<&Token> =
            mainfl.code.iter().map(|&i| &mainfl.toks[i]).collect();
        let n = code_toks.len();
        let mut used: Vec<(String, usize)> = Vec::new();
        for t in 0..n {
            if code_toks[t].kind == TokKind::Ident
                && code_toks[t].text == "args"
                && t + 4 < n
                && code_toks[t + 1].text == "."
                && code_toks[t + 2].kind == TokKind::Ident
                && ARGS_API.contains(&code_toks[t + 2].text.as_str())
                && code_toks[t + 3].text == "("
                && code_toks[t + 4].kind == TokKind::Str
            {
                let flag = unquote(&code_toks[t + 4].text);
                if !used.iter().any(|(u, _)| *u == flag) {
                    used.push((flag, code_toks[t].line));
                }
            }
        }
        let mut main_doc_flags: Vec<String> = Vec::new();
        for &i in &mainfl.code {
            if mainfl.toks[i].kind == TokKind::Str {
                for fl2 in extract_flags(&mainfl.toks[i].text) {
                    if !main_doc_flags.contains(&fl2) {
                        main_doc_flags.push(fl2);
                    }
                }
            }
        }
        let readme_flags = extract_flags(&readme_text);
        let mut table_flags: Vec<(String, usize)> = Vec::new();
        for (ln0, raw) in readme_text.lines().enumerate() {
            let s = raw.trim_start();
            if !s.starts_with('|') {
                continue;
            }
            let cs: Vec<char> = s.chars().collect();
            let mut cell = String::new();
            let mut k = 1;
            while k < cs.len() {
                if cs[k] == '|' && cs[k - 1] != '\\' {
                    break;
                }
                cell.push(cs[k]);
                k += 1;
            }
            for flag in extract_flags(&cell) {
                table_flags.push((flag, ln0 + 1));
            }
        }
        // R7a: stale table rows.
        for (flag, ln) in &table_flags {
            if !used.iter().any(|(u, _)| u == flag) {
                findings.push(Finding::new(
                    "README.md",
                    *ln,
                    "R7",
                    format!(
                        "README flag-table row names `--{flag}` but \
                         rust/src/main.rs never reads it"
                    ),
                ));
            }
        }
        // R7b: undocumented flags.
        for (flag, ln) in &used {
            if !main_doc_flags.contains(flag)
                && !readme_flags.contains(flag)
            {
                findings.push(Finding::new(
                    MAIN_RS,
                    *ln,
                    "R7",
                    format!(
                        "CLI flag `--{flag}` is undocumented (absent \
                         from the main.rs help text and README.md)"
                    ),
                ));
            }
        }
        // R7c: SchedulerConfig fields.
        if let Some(schedfl) = lexmap.get(SCHED_RS) {
            let sc: Vec<&Token> =
                schedfl.code.iter().map(|&i| &schedfl.toks[i]).collect();
            let sn = sc.len();
            let mut fields: Vec<(String, usize, Vec<String>)> =
                Vec::new();
            let mut t = 0;
            while t + 2 < sn {
                if sc[t].text == "struct"
                    && sc[t + 1].text == "SchedulerConfig"
                    && sc[t + 2].text == "{"
                {
                    let mut depth = 1i64;
                    let mut m = t + 3;
                    while m < sn && depth > 0 {
                        let tx = sc[m].text.as_str();
                        if tx == "(" || tx == "[" || tx == "{" {
                            depth += 1;
                        } else if tx == ")" || tx == "]" || tx == "}" {
                            depth -= 1;
                        } else if tx == "pub"
                            && depth == 1
                            && m + 2 < sn
                            && sc[m + 1].kind == TokKind::Ident
                            && sc[m + 2].text == ":"
                        {
                            let mut doc = String::new();
                            let mut p = schedfl.code[m];
                            // Walk back over the original stream
                            // collecting contiguous doc comments.
                            while p > 0 {
                                p -= 1;
                                let tk = &schedfl.toks[p];
                                if tk.kind == TokKind::Doc {
                                    doc = format!("{} {doc}", tk.text);
                                } else if tk.kind == TokKind::Comment {
                                    continue;
                                } else {
                                    break;
                                }
                            }
                            fields.push((
                                sc[m + 1].text.clone(),
                                sc[m + 1].line,
                                extract_flags(&doc),
                            ));
                        }
                        m += 1;
                    }
                    break;
                }
                t += 1;
            }
            let table_set: Vec<String> = table_flags
                .iter()
                .map(|(f2, _)| f2.clone())
                .collect();
            for (field, line, doc_flags) in &fields {
                let kebab = field.replace('_', "-");
                let mut cands: Vec<String> = vec![kebab];
                for d in doc_flags {
                    if !cands.contains(d) {
                        cands.push(d.clone());
                    }
                }
                let wired: Vec<&String> = cands
                    .iter()
                    .filter(|c2| {
                        used.iter().any(|(u, _)| u == *c2)
                    })
                    .collect();
                if wired.is_empty() {
                    findings.push(Finding::new(
                        SCHED_RS,
                        *line,
                        "R7",
                        format!(
                            "SchedulerConfig field `{field}` has no \
                             CLI flag in main.rs (name its `--flag` \
                             in the field's doc comment)"
                        ),
                    ));
                } else if !wired
                    .iter()
                    .any(|w| table_set.iter().any(|tf| tf == *w))
                {
                    findings.push(Finding::new(
                        SCHED_RS,
                        *line,
                        "R7",
                        format!(
                            "SchedulerConfig flag `--{}` is missing \
                             from the README flag table",
                            wired[0]
                        ),
                    ));
                }
            }
        }
    }

    // ---- suppression ----
    let mut kept: Vec<Finding> = Vec::new();
    for fi in findings {
        let suppressed = fi.rule != "R0"
            && lexmap
                .get(&fi.path)
                .and_then(|fl| fl.allows.get(fi.rule))
                .map(|lines| lines.contains(&fi.line))
                .unwrap_or(false);
        if !suppressed {
            kept.push(fi);
        }
    }

    Report { findings: kept, files_scanned: files.len() }
}
