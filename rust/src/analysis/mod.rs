//! Static analysis for the repo's own contracts (`elitekv lint`).
//!
//! Three layers, each mirrored line-for-line by the toolchain-free
//! runner `python/tools/lint.py` (the differential tests in
//! `rust/tests/lint_tool.rs` pin both to byte-identical output):
//!
//! * [`lexer`] — a total, error-tolerant Rust lexer: comments
//!   (nested blocks, doc classification), cooked/raw/byte/C strings
//!   with arbitrary `#` depth, char vs lifetime disambiguation, raw
//!   identifiers. Never panics on malformed input; unterminated forms
//!   become [`lexer::LexError`]s and lexing continues.
//! * [`rules`] — the rule engine R1–R7 (plus R0 for malformed
//!   `// lint: allow(…)` control comments); see DESIGN.md S21 for the
//!   catalog and each rule's contract of origin.
//! * [`report`] — finding collection and byte-exact rendering
//!   (`path:line rule message`, sorted and deduplicated, summary line).
//!
//! Entry point: [`run_lint`].

pub mod lexer;
pub mod report;
pub mod rules;

use std::path::Path;

/// Lint the repository tree rooted at `root` and return the report.
///
/// Scans `rust/src`, `rust/tests`, `rust/benches`, and `examples` for
/// `.rs` files (skipping lint fixture corpora), reads `Cargo.toml` and
/// `README.md` as contract inputs, and applies every rule. The caller
/// decides what to do with findings; `elitekv lint` renders the report
/// and exits nonzero when [`report::Report::is_clean`] is false.
pub fn run_lint(root: &Path) -> report::Report {
    rules::run(root)
}
