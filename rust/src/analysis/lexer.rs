//! Error-tolerant Rust lexer for the `elitekv lint` static analyzer.
//!
//! Produces a flat token stream (identifiers, literals, comments,
//! punctuation) with line/column anchors, handling every literal form the
//! repo's own sources use: line/doc comments, nested block comments,
//! cooked strings with escapes, raw strings `r#"…"#` at any hash depth,
//! byte strings `b"…"`/`br#"…"#`, C strings `c"…"`/`cr#"…"#`, byte chars
//! `b'…'`, char literals (including `'"'` and `'\''`), lifetimes, raw
//! identifiers `r#ident`, and numeric literals with exponents.
//!
//! The lexer is *total*: malformed input (an unterminated string, say)
//! never panics — it consumes to end of file and records a [`LexError`]
//! that the rule engine surfaces as an R6 finding. Every consumed span is
//! covered by exactly one token and tokens never overlap, a property the
//! seeded soup tests pin (`gap chars are whitespace` + full coverage).
//!
//! `python/tools/lint.py` carries a statement-for-statement port of this
//! file; the differential suite in `rust/tests/lint_tool.rs` pins the two
//! to byte-identical `--dump-tokens` output and lint reports (DESIGN.md
//! S21).

/// Lexical class of a [`Token`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (includes raw identifiers `r#ident`).
    Ident,
    /// Numeric literal (integers, floats, any radix, with suffixes).
    Num,
    /// String-like literal: cooked, raw, byte, or C string.
    Str,
    /// Character or byte-character literal.
    Char,
    /// Lifetime such as `'a` or `'static`.
    Lifetime,
    /// Non-doc comment (line or block).
    Comment,
    /// Doc comment: `///`, `//!`, `/**`, or `/*!`.
    Doc,
    /// Any other single character (delimiters, operators, `#`, …).
    Punct,
}

impl TokKind {
    /// Stable lowercase name used by `--dump-tokens` (shared with the
    /// Python port byte-for-byte).
    pub fn as_str(self) -> &'static str {
        match self {
            TokKind::Ident => "ident",
            TokKind::Num => "num",
            TokKind::Str => "str",
            TokKind::Char => "char",
            TokKind::Lifetime => "lifetime",
            TokKind::Comment => "comment",
            TokKind::Doc => "doc",
            TokKind::Punct => "punct",
        }
    }
}

/// One lexed token with its exact source text and position.
#[derive(Clone, Debug)]
pub struct Token {
    /// Lexical class.
    pub kind: TokKind,
    /// Exact source text of the token (lossless).
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: usize,
    /// 1-based column (in chars) of the token's first character.
    pub col: usize,
    /// Char offset of the first character in the source.
    pub start: usize,
    /// Char offset one past the last character.
    pub end: usize,
}

/// A recoverable lexing problem (the lexer still consumed the input).
#[derive(Clone, Debug)]
pub struct LexError {
    /// 1-based line where the malformed construct starts.
    pub line: usize,
    /// Human-readable description (stable across the Rust/Python pair).
    pub msg: String,
}

fn is_id_start(c: char) -> bool {
    (c as u32) >= 128 || c.is_ascii_alphabetic() || c == '_'
}

fn is_id_cont(c: char) -> bool {
    (c as u32) >= 128 || c.is_ascii_alphanumeric() || c == '_'
}

fn is_ws(c: char) -> bool {
    matches!(c, ' ' | '\t' | '\r' | '\n' | '\u{b}' | '\u{c}')
}

/// Scan a cooked (escape-processing) string body starting at the opening
/// quote index `q`. Returns `(end, terminated)` where `end` is one past
/// the closing quote (or the source length when unterminated).
fn scan_cooked(c: &[char], q: usize) -> (usize, bool) {
    let n = c.len();
    let mut j = q + 1;
    while j < n {
        if c[j] == '\\' {
            j += 2;
            continue;
        }
        if c[j] == '"' {
            return (j + 1, true);
        }
        j += 1;
    }
    (n, false)
}

/// Scan a raw string body: `j` points one past the opening quote and the
/// closer is a quote followed by `hashes` `#` characters.
fn scan_raw(c: &[char], j: usize, hashes: usize) -> (usize, bool) {
    let n = c.len();
    let mut j = j;
    while j < n {
        if c[j] == '"' {
            let mut m = 0;
            while m < hashes && j + 1 + m < n && c[j + 1 + m] == '#' {
                m += 1;
            }
            if m == hashes {
                return (j + 1 + hashes, true);
            }
        }
        j += 1;
    }
    (n, false)
}

/// Scan a char-like literal whose opening quote is at `q`. Returns
/// `None` when the quote does not start a char literal (a lifetime or a
/// stray quote); otherwise `(end, terminated)`.
fn scan_char_like(c: &[char], q: usize) -> Option<(usize, bool)> {
    let n = c.len();
    if q + 1 >= n {
        return None;
    }
    if c[q + 1] == '\\' {
        // Escaped char: consume the escaped character, then scan to the
        // closing quote (handles `'\u{1f600}'` and `'\''`).
        let mut j = q + 2;
        if j < n {
            j += 1;
        }
        while j < n && c[j] != '\'' && c[j] != '\n' {
            j += 1;
        }
        if j < n && c[j] == '\'' {
            return Some((j + 1, true));
        }
        return Some((j, false));
    }
    if q + 2 < n && c[q + 2] == '\'' && c[q + 1] != '\'' && c[q + 1] != '\n'
    {
        return Some((q + 3, true));
    }
    None
}

/// Scan a numeric literal starting at digit index `s`; returns the end.
fn scan_number(c: &[char], s: usize) -> usize {
    let n = c.len();
    let mut i = s + 1;
    let mut seen_dot = false;
    while i < n {
        let ch = c[i];
        if ch.is_ascii_alphanumeric() || ch == '_' {
            i += 1;
        } else if ch == '.'
            && !seen_dot
            && i + 1 < n
            && c[i + 1].is_ascii_digit()
        {
            seen_dot = true;
            i += 1;
        } else if (ch == '+' || ch == '-')
            && (c[i - 1] == 'e' || c[i - 1] == 'E')
            && i + 1 < n
            && c[i + 1].is_ascii_digit()
        {
            i += 1;
        } else {
            break;
        }
    }
    i
}

/// Literal-prefix probe at index `i`: for `r`/`b`/`c`/`br`/`cr` starts,
/// classify what follows. Returns `(end, kind, err_msg)` when the prefix
/// begins a literal (or raw identifier), `None` when it is a plain
/// identifier start.
fn scan_prefixed(c: &[char], i: usize) -> Option<(usize, TokKind, String)> {
    let n = c.len();
    let ch = c[i];
    if ch != 'r' && ch != 'b' && ch != 'c' {
        return None;
    }
    let mut pl = 1;
    if (ch == 'b' || ch == 'c') && i + 1 < n && c[i + 1] == 'r' {
        pl = 2;
    }
    let k = i + pl;
    let mut h = 0;
    while k + h < n && c[k + h] == '#' {
        h += 1;
    }
    let raw_capable = (ch == 'r' && pl == 1) || pl == 2;
    if raw_capable && k + h < n && c[k + h] == '"' {
        let (end, ok) = scan_raw(c, k + h + 1, h);
        let msg = if ok {
            String::new()
        } else {
            "unterminated raw string literal".to_string()
        };
        return Some((end, TokKind::Str, msg));
    }
    if pl == 1 && h == 0 && (ch == 'b' || ch == 'c') && k < n && c[k] == '"'
    {
        let (end, ok) = scan_cooked(c, k);
        let msg = if ok {
            String::new()
        } else {
            "unterminated string literal".to_string()
        };
        return Some((end, TokKind::Str, msg));
    }
    if pl == 1 && h == 0 && ch == 'b' && k < n && c[k] == '\'' {
        if let Some((end, ok)) = scan_char_like(c, k) {
            let msg = if ok {
                String::new()
            } else {
                "unterminated character literal".to_string()
            };
            return Some((end, TokKind::Char, msg));
        }
        return None;
    }
    if ch == 'r' && pl == 1 && h == 1 && k + 1 < n && is_id_start(c[k + 1])
    {
        // Raw identifier `r#ident`.
        let mut j = k + 1;
        while j < n && is_id_cont(c[j]) {
            j += 1;
        }
        return Some((j, TokKind::Ident, String::new()));
    }
    None
}

/// Lex `src` to a complete token stream plus any recoverable errors.
///
/// Whitespace is skipped (token positions make it recoverable); every
/// non-whitespace char lands in exactly one token.
pub fn lex(src: &str) -> (Vec<Token>, Vec<LexError>) {
    let c: Vec<char> = src.chars().collect();
    let n = c.len();
    let mut toks: Vec<Token> = Vec::new();
    let mut errs: Vec<LexError> = Vec::new();
    let mut i = 0;
    let mut line = 1;
    let mut col = 1;
    while i < n {
        let ch = c[i];
        if is_ws(ch) {
            i += 1;
            if ch == '\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
            continue;
        }
        let start = i;
        let mut end = i + 1;
        let mut kind = TokKind::Punct;
        let mut err = String::new();
        if ch == '/' && i + 1 < n && c[i + 1] == '/' {
            let mut j = i + 2;
            while j < n && c[j] != '\n' {
                j += 1;
            }
            end = j;
            let t: String = c[start..end].iter().collect();
            kind = if (t.starts_with("///") && !t.starts_with("////"))
                || t.starts_with("//!")
            {
                TokKind::Doc
            } else {
                TokKind::Comment
            };
        } else if ch == '/' && i + 1 < n && c[i + 1] == '*' {
            let mut depth = 1;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if c[j] == '/' && j + 1 < n && c[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if c[j] == '*' && j + 1 < n && c[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            end = j;
            if depth > 0 {
                err = "unterminated block comment".to_string();
            }
            let t: String = c[start..end].iter().collect();
            kind = if t.starts_with("/*!")
                || (t.starts_with("/**")
                    && !t.starts_with("/***")
                    && t != "/**/")
            {
                TokKind::Doc
            } else {
                TokKind::Comment
            };
        } else if ch == '"' {
            let (e, ok) = scan_cooked(&c, i);
            end = e;
            kind = TokKind::Str;
            if !ok {
                err = "unterminated string literal".to_string();
            }
        } else if ch == '\'' {
            if let Some((e, ok)) = scan_char_like(&c, i) {
                end = e;
                kind = TokKind::Char;
                if !ok {
                    err = "unterminated character literal".to_string();
                }
            } else if i + 1 < n && is_id_start(c[i + 1]) {
                let mut j = i + 1;
                while j < n && is_id_cont(c[j]) {
                    j += 1;
                }
                end = j;
                kind = TokKind::Lifetime;
            }
        } else if ch.is_ascii_digit() {
            end = scan_number(&c, i);
            kind = TokKind::Num;
        } else if is_id_start(ch) {
            match scan_prefixed(&c, i) {
                Some((e, k, msg)) => {
                    end = e;
                    kind = k;
                    err = msg;
                }
                None => {
                    let mut j = i + 1;
                    while j < n && is_id_cont(c[j]) {
                        j += 1;
                    }
                    end = j;
                    kind = TokKind::Ident;
                }
            }
        }
        if !err.is_empty() {
            errs.push(LexError { line, msg: err });
        }
        let text: String = c[start..end].iter().collect();
        toks.push(Token { kind, text, line, col, start, end });
        let consumed = end - start;
        let mut nl = 0;
        let mut last = 0;
        for (off, ch2) in c[start..end].iter().enumerate() {
            if *ch2 == '\n' {
                nl += 1;
                last = off;
            }
        }
        if nl > 0 {
            line += nl;
            col = consumed - last;
        } else {
            col += consumed;
        }
        i = end;
    }
    (toks, errs)
}

/// Escape token text for `--dump-tokens`: printable ASCII passes
/// through, everything else becomes `\n`/`\t`/`\r`/`\\` or `\u{xxxx}` —
/// chosen so the Rust and Python dumps are byte-identical.
pub fn escape(s: &str) -> String {
    let mut out = String::new();
    for ch in s.chars() {
        if ch == '\\' {
            out.push_str("\\\\");
        } else if ch == '\n' {
            out.push_str("\\n");
        } else if ch == '\t' {
            out.push_str("\\t");
        } else if ch == '\r' {
            out.push_str("\\r");
        } else if (' '..='~').contains(&ch) {
            out.push(ch);
        } else {
            out.push_str(&format!("\\u{{{:04x}}}", ch as u32));
        }
    }
    out
}

/// Render the full `--dump-tokens` listing for `src` (one line per
/// token, then one `error:` line per recoverable lex error).
pub fn dump(src: &str) -> String {
    let (toks, errs) = lex(src);
    let mut out = String::new();
    for t in &toks {
        out.push_str(&format!(
            "{}:{} {} {}\n",
            t.line,
            t.col,
            t.kind.as_str(),
            escape(&t.text)
        ));
    }
    for e in &errs {
        out.push_str(&format!("error:{} {}\n", e.line, escape(&e.msg)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        let (toks, _) = lex(src);
        toks.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    fn texts_of(src: &str, kind: TokKind) -> Vec<String> {
        kinds(src)
            .into_iter()
            .filter(|(k, _)| *k == kind)
            .map(|(_, t)| t)
            .collect()
    }

    #[test]
    fn idents_numbers_punct() {
        let ks = kinds("let x2 = 0x1f + 1.5e-3;");
        let names: Vec<&str> =
            ks.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(
            names,
            vec!["let", "x2", "=", "0x1f", "+", "1.5e-3", ";"]
        );
        assert_eq!(ks[3].0, TokKind::Num);
        assert_eq!(ks[5].0, TokKind::Num);
    }

    #[test]
    fn raw_string_with_hashes_and_inner_quotes() {
        let src = r###"let s = r#"a "quoted" {brace"#;"###;
        let strs = texts_of(src, TokKind::Str);
        assert_eq!(strs, vec![r###"r#"a "quoted" {brace"#"###]);
        // The { inside the raw string must not register as a delimiter:
        let (toks, errs) = lex(src);
        assert!(errs.is_empty());
        assert!(toks.iter().all(|t| t.text != "{"));
    }

    #[test]
    fn nested_raw_hash_depths() {
        let src = "r##\"outer r#\"inner\"# still\"## end";
        let strs = texts_of(src, TokKind::Str);
        assert_eq!(strs, vec!["r##\"outer r#\"inner\"# still\"##"]);
    }

    #[test]
    fn byte_and_c_strings() {
        let src = "b\"bytes\" br#\"raw \" bytes\"# c\"cstr\" cr#\"x\"#";
        let strs = texts_of(src, TokKind::Str);
        assert_eq!(strs.len(), 4);
        assert_eq!(strs[0], "b\"bytes\"");
        assert_eq!(strs[1], "br#\"raw \" bytes\"#");
    }

    #[test]
    fn char_literals_including_quote_chars() {
        // '"' and '\'' are the classic scanner-breakers.
        let src = "let a = '\"'; let b = '\\''; let c = '\\u{1f600}';";
        let chars = texts_of(src, TokKind::Char);
        assert_eq!(chars, vec!["'\"'", "'\\''", "'\\u{1f600}'"]);
        let (_, errs) = lex(src);
        assert!(errs.is_empty());
    }

    #[test]
    fn byte_char_literal() {
        let chars = texts_of("m(b'x', b'\\n')", TokKind::Char);
        assert_eq!(chars, vec!["b'x'", "b'\\n'"]);
    }

    #[test]
    fn lifetimes_vs_chars() {
        let src = "fn f<'a>(x: &'a str) -> &'static str";
        let lts = texts_of(src, TokKind::Lifetime);
        assert_eq!(lts, vec!["'a", "'a", "'static"]);
        assert!(texts_of(src, TokKind::Char).is_empty());
    }

    #[test]
    fn block_comment_with_string_quotes_and_nesting() {
        let src = "a /* \"not a string { */ b /* outer /* inner */ } */ c";
        let ids = texts_of(src, TokKind::Ident);
        assert_eq!(ids, vec!["a", "b", "c"]);
        let comments = texts_of(src, TokKind::Comment);
        assert_eq!(comments.len(), 2);
    }

    #[test]
    fn doc_comment_classification() {
        assert_eq!(texts_of("/// d", TokKind::Doc).len(), 1);
        assert_eq!(texts_of("//! d", TokKind::Doc).len(), 1);
        assert_eq!(texts_of("//// not doc", TokKind::Doc).len(), 0);
        assert_eq!(texts_of("/** d */", TokKind::Doc).len(), 1);
        assert_eq!(texts_of("/*! d */", TokKind::Doc).len(), 1);
        assert_eq!(texts_of("/**/", TokKind::Doc).len(), 0);
        assert_eq!(texts_of("// plain", TokKind::Comment).len(), 1);
    }

    #[test]
    fn raw_identifier() {
        let ks = kinds("let r#type = 1;");
        assert_eq!(ks[1], (TokKind::Ident, "r#type".to_string()));
    }

    #[test]
    fn hash_in_macros_is_punct() {
        // `#` outside an attribute/raw-string context stays punctuation.
        let ks = kinds("#[derive(Debug)] struct S;");
        assert_eq!(ks[0], (TokKind::Punct, "#".to_string()));
        assert_eq!(ks[1], (TokKind::Punct, "[".to_string()));
    }

    #[test]
    fn unterminated_forms_are_total() {
        for src in ["\"abc", "r#\"abc", "/* abc", "'\\x"] {
            let (_, errs) = lex(src);
            assert_eq!(errs.len(), 1, "src={src:?}");
        }
    }

    #[test]
    fn positions_track_lines_and_cols() {
        let (toks, _) = lex("ab\n  cd \"x\ny\" ef");
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (2, 3));
        assert_eq!((toks[2].line, toks[2].col), (2, 6)); // the string
        assert_eq!((toks[3].line, toks[3].col), (3, 4)); // ef after it
    }

    #[test]
    fn lossless_span_coverage() {
        let src = "fn f() { r#\"x\"#; 'a'; /* c */ }\n";
        let (toks, _) = lex(src);
        let chars: Vec<char> = src.chars().collect();
        let mut pos = 0;
        for t in &toks {
            assert!(t.start >= pos);
            for &g in &chars[pos..t.start] {
                assert!(is_ws(g));
            }
            let text: String = chars[t.start..t.end].iter().collect();
            assert_eq!(text, t.text);
            pos = t.end;
        }
        for &g in &chars[pos..] {
            assert!(is_ws(g));
        }
    }

    /// Regression for the PR-5 ad-hoc bracket scanner: `util/json.rs`
    /// holds raw strings whose bodies contain unbalanced-looking quotes
    /// and braces (e.g. `r#"{"config": …"#`); a scanner without raw
    /// string handling miscounts them. The real lexer must see the
    /// actual file as balanced with zero errors.
    #[test]
    fn util_json_raw_strings_lex_clean() {
        let path = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/rust/src/util/json.rs"
        );
        let src = std::fs::read_to_string(path).unwrap();
        let (toks, errs) = lex(&src);
        assert!(errs.is_empty(), "{errs:?}");
        let mut depth: i64 = 0;
        for t in &toks {
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" | "{" => depth += 1,
                    ")" | "]" | "}" => depth -= 1,
                    _ => {}
                }
                assert!(depth >= 0, "negative depth at {}:{}", t.line,
                        t.col);
            }
        }
        assert_eq!(depth, 0, "util/json.rs must balance");
    }
}
