//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (DESIGN.md §7 experiment index) on the in-repo model family.
//!
//! Each experiment writes `results/<id>.json` (machine-readable series)
//! and prints a markdown table mirroring the paper's layout. Shared
//! stages (pretraining, RoPElite search) are cached on disk so the sweep
//! can resume.

pub mod experiments;
pub mod microbench;
pub mod pipeline;
pub mod report;

pub use microbench::{bench, bench_throughput, BenchOpts};
pub use pipeline::ExperimentCtx;
