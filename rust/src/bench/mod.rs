//! Experiment harness (DESIGN.md §7 experiment index).
//!
//! * [`microbench`] / [`report`] — the measurement + output substrate
//!   (criterion/serde stand-ins), always available.
//! * [`native`] — the artifact-free native-decode benchmark: tokens/s,
//!   per-step latency, ns/GEMM + GFLOP/s through the batched kernel
//!   layer, and cache bytes/token across the dense / RoPElite / S-LRD /
//!   J-LRD 50-25 % grid, emitted as machine-readable
//!   `BENCH_native_decode.json`.
//! * [`serve`] — the continuous-batching scheduler benchmark: one
//!   deterministic arrival trace replayed per variant under the same
//!   cache byte budget -> `BENCH_continuous_batching.json` (max
//!   concurrency, admission latency, block-pool occupancy, throughput).
//! * `pipeline` / `experiments` (feature `pjrt`) — the paper
//!   table/figure sweeps over the AOT artifacts; each writes
//!   `results/<id>.json` and a markdown table, with pretraining/search
//!   stages cached on disk so the sweep can resume.

pub mod microbench;
pub mod native;
pub mod report;
pub mod serve;

#[cfg(feature = "pjrt")]
pub mod experiments;
#[cfg(feature = "pjrt")]
pub mod pipeline;

pub use microbench::{bench, bench_ns, bench_throughput, BenchOpts};
pub use native::native_decode_bench;
pub use serve::continuous_batching_bench;
#[cfg(feature = "pjrt")]
pub use pipeline::ExperimentCtx;
