//! Native decode benchmark: the artifact-free perf baseline that seeds
//! the repo's CPU-hot-path trajectory.
//!
//! Sweeps the paper's serving grid — dense MHA, RoPElite (elite
//! frequency selection alone), S-LRD (split latents), and J-LRD at the
//! 50 % / 25 % cache points — on a randomly initialized model (decode
//! cost does not depend on weight values), measuring:
//!
//! * tokens/s across a full continuous-decode run through the batched
//!   GEMM kernel path ([`crate::native::kernels`], DESIGN.md S17),
//! * per-step latency (mean / p50 / p90 / p99 ms),
//! * ns per GEMM call + achieved GFLOP/s over the variant's decode-step
//!   projection shapes (the kernel-level roofline anchor),
//! * cache bytes per token (the paper's unit of account).
//!
//! Emits machine-readable JSON (default `BENCH_native_decode.json`) so
//! future perf PRs diff against a stable baseline.

use std::path::Path;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::bench::microbench::{bench_ns, BenchOpts};
use crate::config::{ModelConfig, Variant};
use crate::convert::EliteSelection;
use crate::kvcache::{CacheDtype, CacheLayout};
use crate::native::kernels::sgemm;
use crate::native::{NativeModel, NativeRunner};
use crate::runtime::Backend;
use crate::search::uniform_selection;
use crate::tensor::Tensor;
use crate::util::stats::Summary;
use crate::util::{Json, Pcg64};

/// Settings for one native decode sweep.
#[derive(Clone, Debug)]
pub struct NativeBenchOpts {
    /// Decode lanes driven per step (all lanes stay live for the run).
    pub batch: usize,
    /// Prompt tokens prefetched per lane before the timed decode.
    pub prompt_len: usize,
    /// Timed decode steps per variant.
    pub decode_steps: usize,
    /// Serving window the runner is built with.
    pub max_seq: usize,
    /// Top-k row budget for the sweep's sparse rows (DESIGN.md S20):
    /// every variant/dtype cell is re-measured with `--sparse-k` at this
    /// k after its dense pair. 0 disables the sparse rows entirely.
    pub sparse_k: usize,
}

impl Default for NativeBenchOpts {
    fn default() -> NativeBenchOpts {
        NativeBenchOpts {
            batch: 4,
            prompt_len: 16,
            decode_steps: 48,
            max_seq: 128,
            sparse_k: 8,
        }
    }
}

/// Default sweep — the acceptance grid: dense baseline, RoPElite (elite
/// frequency selection, full-size cache), S-LRD split latents, and the
/// paper's J-LRD 50 % and 25 % cache points.
pub fn default_sweep(cfg: &ModelConfig) -> Vec<Variant> {
    let nc = cfg.n_chunks();
    let d = cfg.d_model;
    vec![
        Variant::Mha,
        Variant::RopeLite,
        Variant::Slrd { r: nc / 4, d_ck: d / 8, d_cv: d / 8 },
        Variant::EliteKv { r: nc / 2, d_ckv: d / 2 },
        Variant::EliteKv { r: nc / 4, d_ckv: d / 4 },
    ]
}

/// The Uniform selection a variant needs to run (RoPElite has no
/// intrinsic r, so it borrows the 25 %-grid default). Public so the
/// kernel bench target measures exactly the models this sweep runs.
pub fn selection_for(cfg: &ModelConfig, variant: &Variant) -> Option<EliteSelection> {
    match variant {
        Variant::EliteKv { r, .. } | Variant::Slrd { r, .. } => {
            Some(uniform_selection(cfg, *r))
        }
        Variant::RopeLite => Some(uniform_selection(cfg, cfg.n_chunks() / 4)),
        _ => None,
    }
}

/// The (k, n) shapes of one decode step's per-layer projections for a
/// variant — the GEMM work the kernel microbench times.
fn decode_gemm_shapes(cfg: &ModelConfig, variant: &Variant) -> Vec<(usize, usize)> {
    let (d, nh, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head);
    let mut shapes = vec![(d, nh * dh)]; // wq
    match variant {
        Variant::Mha | Variant::RopeLite => {
            shapes.push((d, nh * dh)); // wk
            shapes.push((d, nh * dh)); // wv
        }
        Variant::Gqa { n_kv_heads } => {
            shapes.push((d, n_kv_heads * dh));
            shapes.push((d, n_kv_heads * dh));
        }
        Variant::EliteKv { r, d_ckv } => {
            shapes.push((d, nh * 2 * r)); // wk_e
            shapes.push((d, *d_ckv)); // a_kv
        }
        Variant::Slrd { r, d_ck, d_cv } => {
            shapes.push((d, nh * 2 * r)); // wk_e
            shapes.push((d, *d_ck)); // a_k
            shapes.push((d, *d_cv)); // a_v
        }
    }
    shapes.push((nh * dh, d)); // wo
    shapes.push((d, cfg.d_ffn)); // w1
    shapes.push((d, cfg.d_ffn)); // w3
    shapes.push((cfg.d_ffn, d)); // w2
    shapes
}

/// Time one pass of a variant's decode-step projection GEMMs at batch
/// `m`: returns (ns per GEMM call, achieved GFLOP/s).
fn gemm_microbench(cfg: &ModelConfig, variant: &Variant, m: usize) -> (f64, f64) {
    let shapes = decode_gemm_shapes(cfg, variant);
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut rng = Pcg64::seeded(0x6e77);
    let weights: Vec<Tensor> = shapes
        .iter()
        .map(|&(k, n)| Tensor::randn(vec![k, n], &mut rng))
        .collect();
    let inputs: Vec<Vec<f32>> = shapes
        .iter()
        .map(|&(k, _)| Tensor::randn(vec![m, k], &mut rng).data)
        .collect();
    let mut outputs: Vec<Vec<f32>> = shapes
        .iter()
        .map(|&(_, n)| vec![0.0f32; m * n])
        .collect();
    let flops_per_pass: usize =
        shapes.iter().map(|&(k, n)| 2 * m * k * n).sum();
    let s = bench_ns(
        &format!("native_gemm/{}/b{m}", variant.tag()),
        BenchOpts { warmup_iters: 2, iters: 12 },
        || {
            for ((w, a), c) in
                weights.iter().zip(&inputs).zip(outputs.iter_mut())
            {
                sgemm(a, m, w, c, threads);
            }
            std::hint::black_box(&outputs);
        },
    );
    let ns_per_call = s.mean / shapes.len() as f64;
    let gflops = flops_per_pass as f64 / s.mean; // flops per ns == GFLOP/s
    (ns_per_call, gflops)
}

/// Run one variant at one cache dtype: prefill `batch` prompts, then
/// `decode_steps` timed steps through the batched kernel path (fused
/// dequant at int8); returns the measured record.
fn bench_variant(
    cfg: &ModelConfig,
    variant: &Variant,
    opts: &NativeBenchOpts,
    dtype: CacheDtype,
    sparse_k: Option<usize>,
    gemm: (f64, f64),
) -> Result<Json> {
    ensure!(opts.prompt_len >= 1, "--prompt must be at least 1");
    ensure!(
        opts.prompt_len + opts.decode_steps <= opts.max_seq,
        "prompt ({}) + steps ({}) exceed the serving window ({}); \
         lower --steps/--prompt or raise --max-seq",
        opts.prompt_len,
        opts.decode_steps,
        opts.max_seq
    );
    let sel = selection_for(cfg, variant);
    let mut model =
        NativeModel::init(cfg, variant.clone(), 0xbe7c, sel.as_ref())?;
    model.set_cache_dtype(dtype);
    model.set_sparse_k(sparse_k);
    let runner = NativeRunner::new(model, opts.batch, opts.max_seq)?;
    let (b, s) = runner.serve_shape()?;
    let mut tokens = vec![0i32; b * s];
    for lane in 0..b {
        for i in 0..opts.prompt_len {
            tokens[lane * s + i] = (3 + (lane * 31 + i * 7) % 400) as i32;
        }
    }
    let lens = vec![opts.prompt_len as i32; b];
    let t_prefill = Instant::now();
    let (_logits, mut caches) = runner.prefill(&tokens, &lens)?;
    let prefill_ms = t_prefill.elapsed().as_secs_f64() * 1e3;

    let mut step_ms = Vec::with_capacity(opts.decode_steps);
    let mut pos: Vec<i32> = lens.clone();
    let token = vec![7i32; b];
    let t_total = Instant::now();
    for _ in 0..opts.decode_steps {
        let t0 = Instant::now();
        let (_l, c) = runner.decode(&token, &pos, caches, false)?;
        caches = c;
        step_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        for p in pos.iter_mut() {
            *p += 1;
        }
    }
    let wall = t_total.elapsed().as_secs_f64();
    let decoded = b * opts.decode_steps;
    let s_stats = Summary::of(&step_ms);
    let (gemm_ns, gemm_gflops) = gemm;
    let layout = CacheLayout::with_dtype(cfg, variant.clone(), dtype);
    Ok(Json::obj(vec![
        ("variant", Json::str(&variant.tag())),
        ("kernel_isa", Json::str(runner.kernel_isa())),
        ("cache_dtype", Json::str(dtype.tag())),
        ("sparse_k", Json::num(sparse_k.unwrap_or(0) as f64)),
        ("r", Json::num(variant.r().unwrap_or(0) as f64)),
        (
            "d_ckv",
            Json::num(match variant {
                Variant::EliteKv { d_ckv, .. } => *d_ckv as f64,
                _ => 0.0,
            }),
        ),
        ("cache_ratio", Json::num(layout.ratio)),
        ("cache_bytes_per_token", Json::num(layout.bytes_per_token() as f64)),
        ("prefill_ms", Json::num(prefill_ms)),
        ("tokens_per_s", Json::num(decoded as f64 / wall)),
        ("step_ms_mean", Json::num(s_stats.mean)),
        ("step_ms_p50", Json::num(s_stats.p50)),
        ("step_ms_p90", Json::num(s_stats.p90)),
        ("step_ms_p99", Json::num(s_stats.p99)),
        ("gemm_ns_per_call", Json::num(gemm_ns)),
        ("gemm_gflops", Json::num(gemm_gflops)),
        ("decode_steps", Json::num(opts.decode_steps as f64)),
        ("batch", Json::num(b as f64)),
    ]))
}

/// Sweep the native decode benchmark and write `out` as JSON.
pub fn native_decode_bench(
    cfg: &ModelConfig,
    variants: &[Variant],
    opts: &NativeBenchOpts,
    out: &Path,
) -> Result<Json> {
    let mut rows = Vec::new();
    // Each variant's cells: the f32/int8 dense pair, then (when
    // `opts.sparse_k > 0`) the same pair re-measured under sparse decode
    // — the sparse step-latency columns read directly against their
    // dense siblings two rows up.
    let mut grid: Vec<(CacheDtype, Option<usize>)> =
        vec![(CacheDtype::F32, None), (CacheDtype::Int8, None)];
    if opts.sparse_k > 0 {
        grid.push((CacheDtype::F32, Some(opts.sparse_k)));
        grid.push((CacheDtype::Int8, Some(opts.sparse_k)));
    }
    for variant in variants {
        // The projection-GEMM microbench times the dtype-independent
        // f32 weight GEMMs (weights are never quantized): measure once
        // per variant and share it across every dense/sparse dtype cell.
        let gemm = gemm_microbench(cfg, variant, opts.batch);
        for &(dtype, sk) in &grid {
            let sparse_tag =
                sk.map(|k| format!("+k{k}")).unwrap_or_default();
            log::info!(
                "native bench: {}{sparse_tag} ({})",
                variant.tag(),
                dtype.tag()
            );
            let row = bench_variant(cfg, variant, opts, dtype, sk, gemm)
                .with_context(|| {
                    format!(
                        "bench {}{sparse_tag} ({})",
                        variant.tag(),
                        dtype.tag()
                    )
                })?;
            println!(
                "bench native_decode/{:<24} {:<4} {:>8.1} tok/s  p50 \
                 {:>7.3} ms  {:>6} B/token",
                format!("{}{sparse_tag}", variant.tag()),
                dtype.tag(),
                row.req("tokens_per_s").as_f64().unwrap_or(0.0),
                row.req("step_ms_p50").as_f64().unwrap_or(0.0),
                row.req("cache_bytes_per_token").as_usize().unwrap_or(0),
            );
            rows.push(row);
        }
    }
    let json = Json::obj(vec![
        ("experiment", Json::str("native_decode")),
        ("backend", Json::str("native")),
        ("config", Json::str(&cfg.name)),
        ("batch", Json::num(opts.batch as f64)),
        ("prompt_len", Json::num(opts.prompt_len as f64)),
        ("decode_steps", Json::num(opts.decode_steps as f64)),
        ("max_seq", Json::num(opts.max_seq as f64)),
        ("sparse_k", Json::num(opts.sparse_k as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(out, json.to_string())?;
    log::info!("wrote {out:?}");
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_emits_complete_records() {
        let cfg = ModelConfig::tiny();
        let opts = NativeBenchOpts {
            batch: 1,
            prompt_len: 4,
            decode_steps: 3,
            max_seq: 16,
            sparse_k: 2,
        };
        let dir = std::env::temp_dir().join("elitekv_native_bench.json");
        let variants =
            vec![Variant::Mha, Variant::EliteKv { r: 4, d_ckv: 32 }];
        let json =
            native_decode_bench(&cfg, &variants, &opts, &dir).unwrap();
        let rows = json.req("rows").as_arr().unwrap();
        // every variant is measured as a dense f32/int8 pair plus a
        // sparse f32/int8 pair
        assert_eq!(rows.len(), 8);
        for row in rows {
            assert!(row.req("tokens_per_s").as_f64().unwrap() > 0.0);
            assert!(row.req("cache_bytes_per_token").as_usize().unwrap() > 0);
            assert!(row.req("gemm_ns_per_call").as_f64().unwrap() > 0.0);
            assert!(row.req("gemm_gflops").as_f64().unwrap() > 0.0);
            // the ISA column carries the dispatched microkernel choice
            let isa = row.req("kernel_isa").as_str().unwrap();
            assert_eq!(
                isa,
                crate::native::simd::active().name(),
                "bench row must report the dispatched kernel ISA"
            );
        }
        // compressed point caches fewer bytes than dense (f32 rows), and
        // each int8 row is exactly a quarter of its f32 sibling
        let dense = rows[0].req("cache_bytes_per_token").as_f64().unwrap();
        let comp = rows[4].req("cache_bytes_per_token").as_f64().unwrap();
        assert!(comp < dense);
        for pair in rows.chunks(2) {
            assert_eq!(pair[0].req("cache_dtype").as_str(), Some("f32"));
            assert_eq!(pair[1].req("cache_dtype").as_str(), Some("int8"));
            let bf =
                pair[0].req("cache_bytes_per_token").as_usize().unwrap();
            let bq =
                pair[1].req("cache_bytes_per_token").as_usize().unwrap();
            assert_eq!(bq * 4, bf);
        }
        // per variant: dense pair (sparse_k 0) then sparse pair (k > 0)
        for cell in rows.chunks(4) {
            assert_eq!(cell[0].req("sparse_k").as_usize(), Some(0));
            assert_eq!(cell[1].req("sparse_k").as_usize(), Some(0));
            assert_eq!(cell[2].req("sparse_k").as_usize(), Some(2));
            assert_eq!(cell[3].req("sparse_k").as_usize(), Some(2));
        }
        let text = std::fs::read_to_string(&dir).unwrap();
        assert!(Json::parse(&text).is_ok());
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn default_sweep_covers_the_acceptance_grid() {
        // dense, elite (ropelite), S-LRD, and J-LRD at 50 % and 25 %.
        let cfg = ModelConfig::tiny();
        let tags: Vec<String> =
            default_sweep(&cfg).iter().map(|v| v.tag()).collect();
        assert_eq!(tags.len(), 5);
        assert!(tags.contains(&"mha".to_string()));
        assert!(tags.contains(&"ropelite".to_string()));
        assert!(tags.iter().any(|t| t.starts_with("slrd_")));
        let jlrd: Vec<_> =
            tags.iter().filter(|t| t.starts_with("elitekv_")).collect();
        assert_eq!(jlrd.len(), 2);
        // every sweep variant can actually build (selection arity etc.)
        for v in default_sweep(&cfg) {
            let sel = selection_for(&cfg, &v);
            NativeModel::init(&cfg, v, 1, sel.as_ref()).unwrap();
        }
    }

    #[test]
    fn gemm_shapes_match_variant_projections() {
        let cfg = ModelConfig::tiny();
        // mha: wq wk wv wo w1 w3 w2 = 7; elitekv: wq wk_e a_kv wo w1 w3 w2
        assert_eq!(decode_gemm_shapes(&cfg, &Variant::Mha).len(), 7);
        assert_eq!(
            decode_gemm_shapes(&cfg, &Variant::EliteKv { r: 4, d_ckv: 64 })
                .len(),
            7
        );
        assert_eq!(
            decode_gemm_shapes(
                &cfg,
                &Variant::Slrd { r: 4, d_ck: 32, d_cv: 32 }
            )
            .len(),
            8
        );
    }
}
