//! Native decode benchmark: the artifact-free perf baseline that seeds
//! the repo's CPU-hot-path trajectory.
//!
//! Sweeps the J-LRD compression grid — (r, d_ckv) points plus the dense
//! MHA reference — on a randomly initialized model (decode cost does not
//! depend on weight values), measuring:
//!
//! * tokens/s across a full continuous-decode run,
//! * per-step latency (mean / p50 / p90 / p99 ms),
//! * cache bytes per token (the paper's unit of account).
//!
//! Emits machine-readable JSON (default `BENCH_native_decode.json`) so
//! future perf PRs diff against a stable baseline.

use std::path::Path;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::config::{ModelConfig, Variant};
use crate::kvcache::CacheLayout;
use crate::native::{NativeModel, NativeRunner};
use crate::runtime::Backend;
use crate::search::uniform_selection;
use crate::util::stats::Summary;
use crate::util::Json;

/// Settings for one native decode sweep.
#[derive(Clone, Debug)]
pub struct NativeBenchOpts {
    pub batch: usize,
    pub prompt_len: usize,
    pub decode_steps: usize,
    pub max_seq: usize,
}

impl Default for NativeBenchOpts {
    fn default() -> NativeBenchOpts {
        NativeBenchOpts {
            batch: 4,
            prompt_len: 16,
            decode_steps: 48,
            max_seq: 128,
        }
    }
}

/// Default sweep: the dense baseline plus the paper's 50/25/12.5 % points.
pub fn default_sweep(cfg: &ModelConfig) -> Vec<Variant> {
    let nc = cfg.n_chunks();
    vec![
        Variant::Mha,
        Variant::EliteKv { r: nc / 2, d_ckv: cfg.d_model / 2 },
        Variant::EliteKv { r: nc / 4, d_ckv: cfg.d_model / 4 },
        Variant::EliteKv { r: nc / 8, d_ckv: cfg.d_model / 8 },
    ]
}

/// Run one variant: prefill `batch` prompts, then `decode_steps` timed
/// steps; returns the measured record.
fn bench_variant(
    cfg: &ModelConfig,
    variant: &Variant,
    opts: &NativeBenchOpts,
) -> Result<Json> {
    ensure!(opts.prompt_len >= 1, "--prompt must be at least 1");
    ensure!(
        opts.prompt_len + opts.decode_steps <= opts.max_seq,
        "prompt ({}) + steps ({}) exceed the serving window ({}); \
         lower --steps/--prompt or raise --max-seq",
        opts.prompt_len,
        opts.decode_steps,
        opts.max_seq
    );
    let sel = variant.r().map(|r| uniform_selection(cfg, r));
    let model = NativeModel::init(cfg, variant.clone(), 0xbe7c, sel.as_ref())?;
    let runner = NativeRunner::new(model, opts.batch, opts.max_seq)?;
    let (b, s) = runner.serve_shape()?;
    let mut tokens = vec![0i32; b * s];
    for lane in 0..b {
        for i in 0..opts.prompt_len {
            tokens[lane * s + i] = (3 + (lane * 31 + i * 7) % 400) as i32;
        }
    }
    let lens = vec![opts.prompt_len as i32; b];
    let t_prefill = Instant::now();
    let (_logits, mut caches) = runner.prefill(&tokens, &lens)?;
    let prefill_ms = t_prefill.elapsed().as_secs_f64() * 1e3;

    let mut step_ms = Vec::with_capacity(opts.decode_steps);
    let mut pos: Vec<i32> = lens.clone();
    let token = vec![7i32; b];
    let t_total = Instant::now();
    for _ in 0..opts.decode_steps {
        let t0 = Instant::now();
        let (_l, c) = runner.decode(&token, &pos, caches, false)?;
        caches = c;
        step_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        for p in pos.iter_mut() {
            *p += 1;
        }
    }
    let wall = t_total.elapsed().as_secs_f64();
    let decoded = b * opts.decode_steps;
    let s_stats = Summary::of(&step_ms);
    let layout = CacheLayout::new(cfg, variant.clone());
    Ok(Json::obj(vec![
        ("variant", Json::str(&variant.tag())),
        ("r", Json::num(variant.r().unwrap_or(0) as f64)),
        (
            "d_ckv",
            Json::num(match variant {
                Variant::EliteKv { d_ckv, .. } => *d_ckv as f64,
                _ => 0.0,
            }),
        ),
        ("cache_ratio", Json::num(layout.ratio)),
        ("cache_bytes_per_token", Json::num(layout.bytes_per_token() as f64)),
        ("prefill_ms", Json::num(prefill_ms)),
        ("tokens_per_s", Json::num(decoded as f64 / wall)),
        ("step_ms_mean", Json::num(s_stats.mean)),
        ("step_ms_p50", Json::num(s_stats.p50)),
        ("step_ms_p90", Json::num(s_stats.p90)),
        ("step_ms_p99", Json::num(s_stats.p99)),
        ("decode_steps", Json::num(opts.decode_steps as f64)),
        ("batch", Json::num(b as f64)),
    ]))
}

/// Sweep the native decode benchmark and write `out` as JSON.
pub fn native_decode_bench(
    cfg: &ModelConfig,
    variants: &[Variant],
    opts: &NativeBenchOpts,
    out: &Path,
) -> Result<Json> {
    let mut rows = Vec::new();
    for variant in variants {
        log::info!("native bench: {}", variant.tag());
        let row = bench_variant(cfg, variant, opts)
            .with_context(|| format!("bench {}", variant.tag()))?;
        println!(
            "bench native_decode/{:<24} {:>8.1} tok/s  p50 {:>7.3} ms  \
             {:>6} B/token",
            variant.tag(),
            row.req("tokens_per_s").as_f64().unwrap_or(0.0),
            row.req("step_ms_p50").as_f64().unwrap_or(0.0),
            row.req("cache_bytes_per_token").as_usize().unwrap_or(0),
        );
        rows.push(row);
    }
    let json = Json::obj(vec![
        ("experiment", Json::str("native_decode")),
        ("backend", Json::str("native")),
        ("config", Json::str(&cfg.name)),
        ("batch", Json::num(opts.batch as f64)),
        ("prompt_len", Json::num(opts.prompt_len as f64)),
        ("decode_steps", Json::num(opts.decode_steps as f64)),
        ("max_seq", Json::num(opts.max_seq as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(out, json.to_string())?;
    log::info!("wrote {out:?}");
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_emits_complete_records() {
        let cfg = ModelConfig::tiny();
        let opts = NativeBenchOpts {
            batch: 1,
            prompt_len: 4,
            decode_steps: 3,
            max_seq: 16,
        };
        let dir = std::env::temp_dir().join("elitekv_native_bench.json");
        let variants =
            vec![Variant::Mha, Variant::EliteKv { r: 4, d_ckv: 32 }];
        let json =
            native_decode_bench(&cfg, &variants, &opts, &dir).unwrap();
        let rows = json.req("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        for row in rows {
            assert!(row.req("tokens_per_s").as_f64().unwrap() > 0.0);
            assert!(row.req("cache_bytes_per_token").as_usize().unwrap() > 0);
        }
        // compressed point caches fewer bytes than dense
        let dense = rows[0].req("cache_bytes_per_token").as_f64().unwrap();
        let comp = rows[1].req("cache_bytes_per_token").as_f64().unwrap();
        assert!(comp < dense);
        let text = std::fs::read_to_string(&dir).unwrap();
        assert!(Json::parse(&text).is_ok());
        std::fs::remove_file(dir).ok();
    }
}
