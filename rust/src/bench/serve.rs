//! Continuous-batching serving benchmark: replay one deterministic mixed
//! prefill/decode arrival trace through the scheduler for each variant
//! under the SAME cache byte budget, and measure what compression buys —
//! max concurrency, admission latency, block-pool occupancy, throughput.
//!
//! This is the paper's 75 % cache reduction expressed as a capacity win:
//! the pool is sized in bytes, so a J-LRD layout at ratio 0.25 holds 4x
//! the blocks of the dense baseline and admits more sequences at once.
//! Emits machine-readable JSON (default `BENCH_continuous_batching.json`).

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{ModelConfig, Variant};
use crate::coordinator::scheduler::{ArrivalTrace, SchedulerConfig, TraceOpts};
use crate::coordinator::InferenceServer;
use crate::kvcache::{CacheDtype, CacheLayout};
use crate::native::{NativeModel, NativeRunner};
use crate::search::uniform_selection;
use crate::util::Json;

/// Settings for one continuous-batching sweep.
#[derive(Clone, Debug)]
pub struct ServeBenchOpts {
    /// Decode lanes of the engine (`serve --max-batch`).
    pub max_batch: usize,
    /// Serving window per lane.
    pub max_seq: usize,
    /// Scheduler policy (block granularity + the shared byte budget).
    pub scheduler: SchedulerConfig,
    /// Workload shape (same trace replayed for every variant).
    pub trace: TraceOpts,
    /// Shared system-prompt length of the second, prefix-sharing trace
    /// (replayed per variant with the radix cache off and on; 0 skips
    /// the shared-prefix rows entirely).
    pub shared_prefix_tokens: usize,
    /// Trace seed.
    pub seed: u64,
}

impl Default for ServeBenchOpts {
    fn default() -> ServeBenchOpts {
        ServeBenchOpts {
            max_batch: 8,
            max_seq: 64,
            // 1 MiB: small enough that the dense pool, not the lane
            // count, is the binding constraint — the capacity effect is
            // visible instead of hidden behind idle lanes. At the tiny
            // config this is 8 dense blocks vs 32 J-LRD(25 %) blocks.
            scheduler: SchedulerConfig::with_budget(1 << 20),
            // Worst-case footprint 17..=32 tokens: exactly two 16-token
            // blocks per request either way, so concurrency is purely
            // pool-blocks / 2 (dense: 4) until the lane cap (8) binds.
            trace: TraceOpts {
                n_requests: 24,
                prompt_min: 8,
                prompt_max: 16,
                max_new_min: 9,
                max_new_max: 16,
                inter_arrival_steps: 1,
                shared_prefix_tokens: 0,
            },
            // Two full 16-token blocks of shared system prompt: every
            // request after the first can skip them under
            // --prefix-cache. Worst case 32+16+16 = 64 tokens still
            // fits the serving window.
            shared_prefix_tokens: 32,
            seed: 0x5eed,
        }
    }
}

/// Default variant pair: dense baseline vs. the paper's 25 % J-LRD point.
pub fn default_variants(cfg: &ModelConfig) -> Vec<Variant> {
    let nc = cfg.n_chunks();
    vec![
        Variant::Mha,
        Variant::EliteKv { r: nc / 4, d_ckv: cfg.d_model / 4 },
    ]
}

/// Replay `trace` through a fresh engine for one variant; returns the
/// measured record. `trace_tag` labels the workload ("mixed" /
/// "shared_prefix"), `prefix_cache` toggles the radix cache, and
/// `dtype` selects the cache element storage (the backend's slabs AND
/// the scheduler's byte accounting) for this run.
fn bench_variant(
    cfg: &ModelConfig,
    variant: &Variant,
    opts: &ServeBenchOpts,
    trace: &ArrivalTrace,
    trace_tag: &str,
    prefix_cache: bool,
    dtype: CacheDtype,
) -> Result<Json> {
    let sel = variant.r().map(|r| uniform_selection(cfg, r));
    let mut model =
        NativeModel::init(cfg, variant.clone(), opts.seed, sel.as_ref())?;
    model.set_cache_dtype(dtype);
    let runner = NativeRunner::new(model, opts.max_batch, opts.max_seq)?;
    let scheduler = SchedulerConfig {
        prefix_cache,
        cache_dtype: dtype,
        ..opts.scheduler.clone()
    };
    let mut server =
        InferenceServer::with_config(Box::new(runner), &scheduler)?;

    let t0 = Instant::now();
    let mut next_arrival = 0usize;
    let mut responses = Vec::with_capacity(trace.items.len());
    let mut engine_step = 0usize;
    while next_arrival < trace.items.len() || server.busy() {
        while next_arrival < trace.items.len()
            && trace.items[next_arrival].arrive_step <= engine_step
        {
            let mut req = trace.items[next_arrival].request.clone();
            // The trace's Instant was stamped at generation time; re-stamp
            // at (re)play so admission waits measure THIS variant's run.
            req.enqueued = Instant::now();
            server.submit(req)?;
            next_arrival += 1;
        }
        responses.extend(server.step()?);
        engine_step += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let stats = &server.stats;
    let mut waits = stats.admission_wait_recent_s.clone();
    waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let wait_p99 = if waits.is_empty() {
        0.0
    } else {
        crate::util::stats::percentile(&waits, 0.99)
    };
    let layout = CacheLayout::with_dtype(cfg, variant.clone(), dtype);
    Ok(Json::obj(vec![
        ("variant", Json::str(variant.tag())),
        ("trace", Json::str(trace_tag)),
        ("prefix_cache", Json::Bool(prefix_cache)),
        ("cache_dtype", Json::str(dtype.tag())),
        ("cache_ratio", Json::num(layout.ratio)),
        ("cache_bytes_per_token", Json::num(layout.bytes_per_token() as f64)),
        ("pool_blocks", Json::num(stats.blocks_total as f64)),
        ("completed", Json::num(responses.len() as f64)),
        ("generated_tokens", Json::num(toks as f64)),
        ("tokens_per_s", Json::num(toks as f64 / wall.max(1e-9))),
        ("max_concurrency", Json::num(stats.max_concurrency as f64)),
        ("admission_wait_mean_s", Json::num(stats.mean_admission_wait_s())),
        ("admission_wait_p99_s", Json::num(wait_p99)),
        ("peak_blocks_used", Json::num(stats.peak_blocks_used as f64)),
        ("mean_block_occupancy", Json::num(stats.mean_block_occupancy())),
        ("prefills", Json::num(stats.prefills as f64)),
        ("prefill_tokens", Json::num(stats.prefill_tokens as f64)),
        ("prefix_hits", Json::num(stats.prefix_hits as f64)),
        ("prefix_misses", Json::num(stats.prefix_misses as f64)),
        ("prefix_hit_tokens", Json::num(stats.prefix_hit_tokens as f64)),
        (
            "prefix_evicted_blocks",
            Json::num(stats.prefix_evicted_blocks as f64),
        ),
        ("decode_steps", Json::num(stats.decode_steps as f64)),
        ("peak_cache_kib", Json::num(stats.peak_cache_bytes as f64 / 1024.0)),
    ]))
}

/// Sweep the continuous-batching benchmark and write `out` as JSON.
pub fn continuous_batching_bench(
    cfg: &ModelConfig,
    variants: &[Variant],
    opts: &ServeBenchOpts,
    out: &Path,
) -> Result<Json> {
    let trace = ArrivalTrace::generate(cfg.vocab, opts.seed, &opts.trace);
    // The prefix-sharing workload: same shape, but every prompt starts
    // with one shared system prompt. Replayed per variant with the radix
    // cache off and on, so the JSON carries the direct saving (prefix
    // hit rate, fewer prefill tokens) under each cache layout.
    let shared_trace = (opts.shared_prefix_tokens > 0).then(|| {
        ArrivalTrace::generate(
            cfg.vocab,
            opts.seed ^ 0x5a5a,
            &TraceOpts {
                shared_prefix_tokens: opts.shared_prefix_tokens,
                ..opts.trace.clone()
            },
        )
    });
    let mut rows = Vec::new();
    for variant in variants {
        log::info!("continuous-batching bench: {}", variant.tag());
        // The mixed run honors the caller's `--prefix-cache` policy
        // (default off) and is measured as an f32/int8 PAIR — the same
        // trace under the same byte budget, so the JSON carries the
        // capacity effect of the dtype axis directly. The shared-prefix
        // pair is always measured with the radix cache off AND on, at
        // the caller's dtype.
        let mut runs: Vec<(&ArrivalTrace, &str, bool, CacheDtype)> = vec![
            (&trace, "mixed", opts.scheduler.prefix_cache, CacheDtype::F32),
            (&trace, "mixed", opts.scheduler.prefix_cache, CacheDtype::Int8),
        ];
        if let Some(st) = &shared_trace {
            runs.push((
                st,
                "shared_prefix",
                false,
                opts.scheduler.cache_dtype,
            ));
            runs.push((st, "shared_prefix", true, opts.scheduler.cache_dtype));
        }
        for (t, tag, pc, dtype) in runs {
            let row = bench_variant(cfg, variant, opts, t, tag, pc, dtype)
                .with_context(|| format!("bench {} ({tag})", variant.tag()))?;
            println!(
                "bench continuous_batching/{:<22} {:<13} {:<4} cache={:<3} \
                 {:>4} max-concurrency  {:>8.1} tok/s  prefill toks \
                 {:>6}  hits {:>3}",
                variant.tag(),
                tag,
                dtype.tag(),
                if pc { "on" } else { "off" },
                row.req("max_concurrency").as_usize().unwrap_or(0),
                row.req("tokens_per_s").as_f64().unwrap_or(0.0),
                row.req("prefill_tokens").as_usize().unwrap_or(0),
                row.req("prefix_hits").as_usize().unwrap_or(0),
            );
            rows.push(row);
        }
    }
    let json = Json::obj(vec![
        ("experiment", Json::str("continuous_batching")),
        ("backend", Json::str("native")),
        ("config", Json::str(cfg.name.clone())),
        ("max_batch", Json::num(opts.max_batch as f64)),
        ("max_seq", Json::num(opts.max_seq as f64)),
        ("block_tokens", Json::num(opts.scheduler.block_tokens as f64)),
        (
            "cache_budget_bytes",
            Json::num(opts.scheduler.cache_budget_bytes as f64),
        ),
        (
            "shared_prefix_tokens",
            Json::num(opts.shared_prefix_tokens as f64),
        ),
        ("n_requests", Json::num(trace.items.len() as f64)),
        ("trace_new_tokens", Json::num(trace.total_new_tokens() as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(out, json.to_string())?;
    log::info!("wrote {out:?}");
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance property: under one byte budget, the J-LRD 25 %
    /// layout reaches >= 4 concurrent sequences and strictly beats the
    /// dense baseline's max concurrency.
    #[test]
    fn compressed_variant_achieves_higher_concurrency() {
        let cfg = ModelConfig::tiny();
        let default = ServeBenchOpts::default();
        let opts = ServeBenchOpts {
            trace: TraceOpts {
                n_requests: 12,
                inter_arrival_steps: 0, // burst: expose the admission cap
                ..default.trace.clone()
            },
            ..default
        };
        let out = std::env::temp_dir().join("elitekv_cb_bench_test.json");
        let variants = default_variants(&cfg);
        let json =
            continuous_batching_bench(&cfg, &variants, &opts, &out).unwrap();
        let rows: Vec<&Json> = json
            .req("rows")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|r| {
                r.req("trace").as_str() == Some("mixed")
                    && r.req("cache_dtype").as_str() == Some("f32")
            })
            .collect();
        assert_eq!(rows.len(), 2);
        let mha = rows[0].req("max_concurrency").as_usize().unwrap();
        let ekv = rows[1].req("max_concurrency").as_usize().unwrap();
        assert!(ekv >= 4, "compressed concurrency {ekv} < 4");
        assert!(ekv > mha, "compressed {ekv} !> dense {mha}");
        // both served the full trace
        for row in rows {
            assert_eq!(row.req("completed").as_usize().unwrap(), 12);
        }
        let text = std::fs::read_to_string(&out).unwrap();
        assert!(Json::parse(&text).is_ok());
        std::fs::remove_file(out).ok();
    }

    /// The S19 acceptance property: at the SAME `--cache-budget-mb`,
    /// int8 strictly raises max concurrency over f32 for EVERY variant
    /// of the pair — the quantized pool holds 4x the blocks, and with
    /// enough lanes and a bursty trace the admission cap moves with it.
    /// Completion counts stay equal (quantization changes bytes, never
    /// the request stream).
    #[test]
    fn int8_strictly_raises_concurrency_at_same_budget() {
        let cfg = ModelConfig::tiny();
        let default = ServeBenchOpts::default();
        let opts = ServeBenchOpts {
            // enough lanes that the pool, not the lane count, caps f32
            // concurrency for both variants: at the 1 MiB budget and 2
            // blocks/request, dense f32 admits 4 (8-block pool), dense
            // int8 16; jlrd f32 admits 16, jlrd int8 all 24 (128-block
            // pool, request-bound)
            max_batch: 24,
            trace: TraceOpts {
                n_requests: 24,
                inter_arrival_steps: 0, // burst: expose the admission cap
                ..default.trace.clone()
            },
            shared_prefix_tokens: 0, // mixed pairs only: keep it fast
            ..default
        };
        let out = std::env::temp_dir().join("elitekv_cb_int8_test.json");
        let variants = default_variants(&cfg);
        let json =
            continuous_batching_bench(&cfg, &variants, &opts, &out).unwrap();
        std::fs::remove_file(&out).ok();
        for variant in &variants {
            let tag = variant.tag();
            let find = |dtype: &str| {
                json.req("rows")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .find(|r| {
                        r.req("variant").as_str() == Some(tag.as_str())
                            && r.req("cache_dtype").as_str() == Some(dtype)
                    })
                    .cloned()
                    .unwrap()
            };
            let (f, q) = (find("f32"), find("int8"));
            // the byte identity the concurrency claim rides on
            let (bf, bq) = (
                f.req("cache_bytes_per_token").as_usize().unwrap(),
                q.req("cache_bytes_per_token").as_usize().unwrap(),
            );
            assert_eq!(bq * 4, bf, "{tag}: int8 bytes/token != f32/4");
            assert_eq!(
                q.req("pool_blocks").as_usize().unwrap(),
                4 * f.req("pool_blocks").as_usize().unwrap(),
                "{tag}: int8 pool != 4x f32 pool at one budget"
            );
            let (cf, cq) = (
                f.req("max_concurrency").as_usize().unwrap(),
                q.req("max_concurrency").as_usize().unwrap(),
            );
            assert!(
                cq > cf,
                "{tag}: int8 concurrency {cq} !> f32 {cf} at equal budget"
            );
            assert_eq!(
                f.req("completed").as_usize().unwrap(),
                q.req("completed").as_usize().unwrap(),
                "{tag}: completions diverge across dtypes"
            );
        }
    }

    /// The shared-prefix acceptance property (ISSUE 4): with the radix
    /// cache on, the shared-system-prompt trace shows a nonzero prefix
    /// hit rate and strictly fewer prefilled tokens than the cache-off
    /// replay of the SAME trace, at unchanged completion counts.
    #[test]
    fn shared_prefix_trace_amortizes_prefills() {
        let cfg = ModelConfig::tiny();
        let default = ServeBenchOpts::default();
        let opts = ServeBenchOpts {
            trace: TraceOpts { n_requests: 10, ..default.trace.clone() },
            ..default
        };
        let out = std::env::temp_dir().join("elitekv_cb_prefix_test.json");
        let variants = default_variants(&cfg);
        let json =
            continuous_batching_bench(&cfg, &variants, &opts, &out).unwrap();
        std::fs::remove_file(&out).ok();
        for variant in variants {
            let tag = variant.tag();
            let find = |pc: bool| {
                json.req("rows")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .find(|r| {
                        r.req("variant").as_str() == Some(tag.as_str())
                            && r.req("trace").as_str()
                                == Some("shared_prefix")
                            && r.req("prefix_cache").as_bool() == Some(pc)
                    })
                    .cloned()
                    .unwrap()
            };
            let (off, on) = (find(false), find(true));
            assert_eq!(
                off.req("completed").as_usize(),
                on.req("completed").as_usize(),
                "{tag}: completion counts diverge"
            );
            assert!(
                on.req("prefix_hits").as_usize().unwrap() > 0,
                "{tag}: no prefix hits on the shared-prefix trace"
            );
            let (pt_off, pt_on) = (
                off.req("prefill_tokens").as_usize().unwrap(),
                on.req("prefill_tokens").as_usize().unwrap(),
            );
            assert!(
                pt_on < pt_off,
                "{tag}: prefix cache prefilled {pt_on} tokens, \
                 cache-off {pt_off}"
            );
            assert_eq!(
                off.req("prefix_hits").as_usize().unwrap(),
                0,
                "{tag}: cache-off run reported hits"
            );
        }
    }
}
