//! Continuous-batching serving benchmark: replay one deterministic mixed
//! prefill/decode arrival trace through the scheduler for each variant
//! under the SAME cache byte budget, and measure what compression buys —
//! max concurrency, admission latency, block-pool occupancy, throughput.
//!
//! This is the paper's 75 % cache reduction expressed as a capacity win:
//! the pool is sized in bytes, so a J-LRD layout at ratio 0.25 holds 4x
//! the blocks of the dense baseline and admits more sequences at once.
//! Emits machine-readable JSON (default `BENCH_continuous_batching.json`).

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{ModelConfig, Variant};
use crate::coordinator::scheduler::{
    ArrivalTrace, SchedulerConfig, TraceItem, TraceOpts,
};
use crate::coordinator::{
    EngineFactory, GenParams, InferenceServer, Request, RoutePolicyKind,
    Router,
};
use crate::data::CorpusGen;
use crate::kvcache::{CacheDtype, CacheLayout};
use crate::native::{NativeModel, NativeRunner};
use crate::search::uniform_selection;
use crate::util::stats::Summary;
use crate::util::Json;

/// Settings for one continuous-batching sweep.
#[derive(Clone, Debug)]
pub struct ServeBenchOpts {
    /// Decode lanes of the engine (`serve --max-batch`).
    pub max_batch: usize,
    /// Serving window per lane.
    pub max_seq: usize,
    /// Scheduler policy (block granularity + the shared byte budget).
    pub scheduler: SchedulerConfig,
    /// Workload shape (same trace replayed for every variant).
    pub trace: TraceOpts,
    /// Shared system-prompt length of the second, prefix-sharing trace
    /// (replayed per variant with the radix cache off and on; 0 skips
    /// the shared-prefix rows entirely).
    pub shared_prefix_tokens: usize,
    /// Top-k row budget of the long-context trace's sparse replays
    /// (DESIGN.md S20): each variant replays a long-prompt workload
    /// dense and then at `--sparse-k` this k, per dtype, so the
    /// selection's bandwidth win shows up as measured engine-step
    /// latency. 0 skips the long-context rows entirely. Each run's
    /// scheduler `sparse_k` is set from this knob (the caller's
    /// `scheduler.sparse_k` is ignored — the sweep owns the axis).
    pub sparse_k: usize,
    /// Chunk size of the long-prompt-stall pair (`--prefill-chunk`,
    /// DESIGN.md S22): a trace where a long prompt arrives while short
    /// requests are mid-decode is replayed monolithic (chunk 0) and
    /// chunked at this size, per dtype, so the JSON carries the
    /// decode-stall reduction (`max_decode_gap_s`) directly. 0 skips
    /// the stall rows entirely. The stall pair owns its chunk axis; the
    /// other workloads run at the caller's
    /// `scheduler.prefill_chunk_tokens`.
    pub prefill_chunk: usize,
    /// Worker count of the sharded-routing pair (DESIGN.md S24): the
    /// shared-prefix trace is replayed closed-loop through `--workers`
    /// N engine workers twice — blind least-loaded, then
    /// `route_policy` — so the JSON carries the affinity-routing hit
    /// rate win directly. < 2 skips the multi-worker rows entirely.
    pub workers: usize,
    /// Routing policy of the second multi-worker row (the first is
    /// always the blind [`RoutePolicyKind::LeastLoaded`] baseline).
    pub route_policy: RoutePolicyKind,
    /// Trace seed.
    pub seed: u64,
}

impl Default for ServeBenchOpts {
    fn default() -> ServeBenchOpts {
        ServeBenchOpts {
            max_batch: 8,
            max_seq: 64,
            // 1 MiB: small enough that the dense pool, not the lane
            // count, is the binding constraint — the capacity effect is
            // visible instead of hidden behind idle lanes. At the tiny
            // config this is 8 dense blocks vs 32 J-LRD(25 %) blocks.
            scheduler: SchedulerConfig::with_budget(1 << 20),
            // Worst-case footprint 17..=32 tokens: exactly two 16-token
            // blocks per request either way, so concurrency is purely
            // pool-blocks / 2 (dense: 4) until the lane cap (8) binds.
            trace: TraceOpts {
                n_requests: 24,
                prompt_min: 8,
                prompt_max: 16,
                max_new_min: 9,
                max_new_max: 16,
                inter_arrival_steps: 1,
                shared_prefix_tokens: 0,
            },
            // Two full 16-token blocks of shared system prompt: every
            // request after the first can skip them under
            // --prefix-cache. Worst case 32+16+16 = 64 tokens still
            // fits the serving window.
            shared_prefix_tokens: 32,
            // Long-context replays keep 8 of up to 63 rows — deep
            // enough selection pressure to measure, coarse enough that
            // greedy generations stay plausible at random init.
            sparse_k: 8,
            // 4-token chunks against a 44-token stall prompt: ~11
            // engine iterations of interleaved prefill, so the
            // monolithic-vs-chunked gap contrast is unmistakable.
            prefill_chunk: 4,
            // Two workers is the smallest cluster where blind routing
            // pays one extra shared-prefix miss — enough to measure
            // the affinity contrast without doubling bench time again.
            workers: 2,
            route_policy: RoutePolicyKind::PrefixAffinity,
            seed: 0x5eed,
        }
    }
}

/// Default variant pair: dense baseline vs. the paper's 25 % J-LRD point.
pub fn default_variants(cfg: &ModelConfig) -> Vec<Variant> {
    let nc = cfg.n_chunks();
    vec![
        Variant::Mha,
        Variant::EliteKv { r: nc / 4, d_ckv: cfg.d_model / 4 },
    ]
}

/// Replay `trace` through a fresh engine for one variant; returns the
/// measured record. `trace_tag` labels the workload ("mixed" /
/// "shared_prefix" / "long_context"), `prefix_cache` toggles the radix
/// cache, `dtype` selects the cache element storage (the backend's
/// slabs AND the scheduler's byte accounting), `sparse_k` runs the
/// engine under sparse decode (model and scheduler together, DESIGN.md
/// S20) for this run, and `prefill_chunk` sets the chunked-prefill
/// budget (S22; 0 = monolithic) for this run.
#[allow(clippy::too_many_arguments)]
fn bench_variant(
    cfg: &ModelConfig,
    variant: &Variant,
    opts: &ServeBenchOpts,
    trace: &ArrivalTrace,
    trace_tag: &str,
    prefix_cache: bool,
    dtype: CacheDtype,
    sparse_k: Option<usize>,
    prefill_chunk: usize,
) -> Result<Json> {
    let sel = variant.r().map(|r| uniform_selection(cfg, r));
    let mut model =
        NativeModel::init(cfg, variant.clone(), opts.seed, sel.as_ref())?;
    model.set_cache_dtype(dtype);
    model.set_sparse_k(sparse_k);
    let runner = NativeRunner::new(model, opts.max_batch, opts.max_seq)?;
    let scheduler = SchedulerConfig {
        prefix_cache,
        cache_dtype: dtype,
        sparse_k,
        prefill_chunk_tokens: prefill_chunk,
        ..opts.scheduler.clone()
    };
    let mut server =
        InferenceServer::with_config(Box::new(runner), &scheduler)?;

    let t0 = Instant::now();
    let mut next_arrival = 0usize;
    let mut responses = Vec::with_capacity(trace.items.len());
    let mut engine_step = 0usize;
    let mut step_ms = Vec::new();
    while next_arrival < trace.items.len() || server.busy() {
        while next_arrival < trace.items.len()
            && trace.items[next_arrival].arrive_step <= engine_step
        {
            let mut req = trace.items[next_arrival].request.clone();
            // The trace's Instant was stamped at generation time; re-stamp
            // at (re)play so admission waits measure THIS variant's run.
            req.enqueued = Instant::now();
            server.submit(req)?;
            next_arrival += 1;
        }
        let ts = Instant::now();
        responses.extend(server.step()?);
        step_ms.push(ts.elapsed().as_secs_f64() * 1e3);
        engine_step += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let step_stats = Summary::of(&step_ms);
    let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let stats = &server.stats;
    let mut waits = stats.admission_wait_recent_s.clone();
    waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let wait_p99 = if waits.is_empty() {
        0.0
    } else {
        crate::util::stats::percentile(&waits, 0.99)
    };
    // Per-request latency columns from the engine's bounded rings; a
    // trace with zero completions has no samples to summarize.
    let (ttft_p50, ttft_p95, ttft_p99, tpot_mean) =
        if stats.ttft_recent_s.is_empty() {
            (0.0, 0.0, 0.0, 0.0)
        } else {
            let t = Summary::of(&stats.ttft_recent_s);
            let g = Summary::of(&stats.tpot_recent_s);
            (t.p50, t.p95, t.p99, g.mean)
        };
    let layout = CacheLayout::with_dtype(cfg, variant.clone(), dtype);
    Ok(Json::obj(vec![
        ("variant", Json::str(variant.tag())),
        ("kernel_isa", Json::str(stats.kernel_isa)),
        ("trace", Json::str(trace_tag)),
        ("prefix_cache", Json::Bool(prefix_cache)),
        ("cache_dtype", Json::str(dtype.tag())),
        ("sparse_k", Json::num(sparse_k.unwrap_or(0) as f64)),
        ("prefill_chunk", Json::num(prefill_chunk as f64)),
        ("cache_ratio", Json::num(layout.ratio)),
        ("cache_bytes_per_token", Json::num(layout.bytes_per_token() as f64)),
        ("pool_blocks", Json::num(stats.blocks_total as f64)),
        ("completed", Json::num(responses.len() as f64)),
        ("generated_tokens", Json::num(toks as f64)),
        ("tokens_per_s", Json::num(toks as f64 / wall.max(1e-9))),
        ("max_concurrency", Json::num(stats.max_concurrency as f64)),
        ("admission_wait_mean_s", Json::num(stats.mean_admission_wait_s())),
        ("admission_wait_p99_s", Json::num(wait_p99)),
        ("ttft_p50_s", Json::num(ttft_p50)),
        ("ttft_p95_s", Json::num(ttft_p95)),
        ("ttft_p99_s", Json::num(ttft_p99)),
        ("tpot_mean_s", Json::num(tpot_mean)),
        ("max_decode_gap_s", Json::num(stats.max_decode_gap_s)),
        ("peak_blocks_used", Json::num(stats.peak_blocks_used as f64)),
        ("mean_block_occupancy", Json::num(stats.mean_block_occupancy())),
        ("prefills", Json::num(stats.prefills as f64)),
        ("prefill_tokens", Json::num(stats.prefill_tokens as f64)),
        ("prefix_hits", Json::num(stats.prefix_hits as f64)),
        ("prefix_misses", Json::num(stats.prefix_misses as f64)),
        ("prefix_hit_tokens", Json::num(stats.prefix_hit_tokens as f64)),
        (
            "prefix_evicted_blocks",
            Json::num(stats.prefix_evicted_blocks as f64),
        ),
        ("decode_steps", Json::num(stats.decode_steps as f64)),
        ("peak_cache_kib", Json::num(stats.peak_cache_bytes as f64 / 1024.0)),
        ("step_ms_mean", Json::num(step_stats.mean)),
        ("step_ms_p50", Json::num(step_stats.p50)),
        ("step_ms_p99", Json::num(step_stats.p99)),
        (
            "sparse_attended_rows",
            Json::num(stats.sparse_attended_rows as f64),
        ),
        ("sparse_dense_rows", Json::num(stats.sparse_dense_rows as f64)),
    ]))
}

/// The long-prompt-arrives-mid-decode workload (DESIGN.md S22): two
/// short requests start decoding at step 0, then a 44-token prompt
/// arrives at step 2 while they are mid-generation. Under monolithic
/// prefill the whole 44-token prompt is computed inside one engine
/// iteration, so the in-flight lanes see one giant inter-token gap;
/// chunked prefill spreads it across ~`44 / chunk` iterations. Sized to
/// the default bench budget: 2 + 2 + 4 sixteen-token blocks fill the
/// dense-f32 8-block pool exactly, so the long prompt still admits the
/// moment it arrives and the contrast is pure scheduling, not queueing.
fn stall_trace(vocab: usize, seed: u64) -> ArrivalTrace {
    let mut gen = CorpusGen::new(vocab, seed);
    let mk = |id: u64, arrive_step: usize, prompt: Vec<u32>, max_new: usize| {
        TraceItem {
            arrive_step,
            request: Request::new(
                id,
                prompt,
                GenParams {
                    max_new_tokens: max_new,
                    temperature: 0.0,
                    top_p: 1.0,
                    stop_token: None, // fixed-length: comparable work
                    seed: id,
                },
            ),
        }
    };
    let items = vec![
        mk(0, 0, gen.stream(8), 24),
        mk(1, 0, gen.stream(8), 24),
        mk(2, 2, gen.stream(44), 8),
    ];
    ArrivalTrace { items }
}

/// Closed-loop multi-worker replay (DESIGN.md S24): `workers`
/// identical engines (same variant, same init seed, same scheduler,
/// prefix cache ON) behind the sharded router, one request in flight
/// at a time so the routing decision for request k always sees the
/// cache deltas of requests 0..k — the policy contrast is then a
/// deterministic property of the routing, not an artifact of arrival
/// timing. Trace arrival steps are ignored (closed-loop serializes by
/// construction), so `tokens_per_s` here measures single-stream
/// engine throughput, not concurrency.
fn bench_multi_worker(
    cfg: &ModelConfig,
    variant: &Variant,
    opts: &ServeBenchOpts,
    trace: &ArrivalTrace,
    trace_tag: &str,
    policy: RoutePolicyKind,
    dtype: CacheDtype,
) -> Result<Json> {
    let workers = opts.workers;
    let scheduler = SchedulerConfig {
        prefix_cache: true,
        cache_dtype: dtype,
        sparse_k: None,
        prefill_chunk_tokens: 0,
        ..opts.scheduler.clone()
    };
    let factories: Vec<EngineFactory> = (0..workers)
        .map(|_| {
            let cfg = cfg.clone();
            let variant = variant.clone();
            let scheduler = scheduler.clone();
            let (max_batch, max_seq, seed) =
                (opts.max_batch, opts.max_seq, opts.seed);
            let f: EngineFactory = Box::new(move || {
                let sel = variant.r().map(|r| uniform_selection(&cfg, r));
                let mut model = NativeModel::init(
                    &cfg,
                    variant.clone(),
                    seed,
                    sel.as_ref(),
                )?;
                model.set_cache_dtype(dtype);
                model.set_sparse_k(None);
                let runner = NativeRunner::new(model, max_batch, max_seq)?;
                InferenceServer::with_config(Box::new(runner), &scheduler)
            });
            f
        })
        .collect();
    let mut router =
        Router::with_policy(factories, policy, scheduler.block_tokens);
    let t0 = Instant::now();
    for (k, item) in trace.items.iter().enumerate() {
        let mut req = item.request.clone();
        req.enqueued = Instant::now();
        router.submit(req)?;
        // Closed loop: wait for this request's response (and, by the
        // deltas-before-response ordering, its cache insertions)
        // before routing the next one.
        let deadline =
            Instant::now() + std::time::Duration::from_secs(120);
        while router.poll() <= k {
            anyhow::ensure!(
                Instant::now() < deadline,
                "multi-worker replay stalled at request {k}"
            );
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
    let responses = router.drain()?;
    let wall = t0.elapsed().as_secs_f64();
    let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let rs = router.route_stats();
    let worker_stats = router.stats();
    let mut hits = 0usize;
    let mut misses = 0usize;
    let mut hit_tokens = 0usize;
    let mut prefill_tokens = 0usize;
    let mut cached_blocks = 0usize;
    let mut per_worker_hit_rate = Vec::new();
    for (_, s) in &worker_stats {
        hits += s.prefix_hits;
        misses += s.prefix_misses;
        hit_tokens += s.prefix_hit_tokens;
        prefill_tokens += s.prefill_tokens;
        cached_blocks += s.prefix_cached_blocks;
        per_worker_hit_rate.push(Json::num(s.prefix_hit_rate()));
    }
    let admissions = hits + misses;
    let agg_rate = if admissions == 0 {
        0.0
    } else {
        hits as f64 / admissions as f64
    };
    let nums =
        |v: &[usize]| v.iter().map(|&x| Json::num(x as f64)).collect();
    Ok(Json::obj(vec![
        ("variant", Json::str(variant.tag())),
        ("trace", Json::str(trace_tag)),
        ("route_policy", Json::str(rs.policy)),
        ("workers", Json::num(workers as f64)),
        ("cache_dtype", Json::str(dtype.tag())),
        ("prefix_cache", Json::Bool(true)),
        ("completed", Json::num(responses.len() as f64)),
        ("generated_tokens", Json::num(toks as f64)),
        ("tokens_per_s", Json::num(toks as f64 / wall.max(1e-9))),
        ("prefix_hits", Json::num(hits as f64)),
        ("prefix_misses", Json::num(misses as f64)),
        ("prefix_hit_tokens", Json::num(hit_tokens as f64)),
        ("prefill_tokens", Json::num(prefill_tokens as f64)),
        ("aggregate_prefix_hit_rate", Json::num(agg_rate)),
        (
            "affinity_hits",
            Json::num(rs.affinity_hits.iter().sum::<usize>() as f64),
        ),
        (
            "affinity_blocks",
            Json::num(rs.affinity_blocks.iter().sum::<usize>() as f64),
        ),
        (
            "shadow_blocks",
            Json::num(rs.shadow_blocks.iter().sum::<usize>() as f64),
        ),
        ("prefix_cached_blocks", Json::num(cached_blocks as f64)),
        ("per_worker_routed", Json::Arr(nums(&rs.routed))),
        ("per_worker_affinity_hits", Json::Arr(nums(&rs.affinity_hits))),
        ("per_worker_prefix_hit_rate", Json::Arr(per_worker_hit_rate)),
        ("per_worker_shadow_blocks", Json::Arr(nums(&rs.shadow_blocks))),
    ]))
}

/// Sweep the continuous-batching benchmark and write `out` as JSON.
pub fn continuous_batching_bench(
    cfg: &ModelConfig,
    variants: &[Variant],
    opts: &ServeBenchOpts,
    out: &Path,
) -> Result<Json> {
    let trace = ArrivalTrace::generate(cfg.vocab, opts.seed, &opts.trace);
    // The prefix-sharing workload: same shape, but every prompt starts
    // with one shared system prompt. Replayed per variant with the radix
    // cache off and on, so the JSON carries the direct saving (prefix
    // hit rate, fewer prefill tokens) under each cache layout.
    let shared_trace = (opts.shared_prefix_tokens > 0).then(|| {
        ArrivalTrace::generate(
            cfg.vocab,
            opts.seed ^ 0x5a5a,
            &TraceOpts {
                shared_prefix_tokens: opts.shared_prefix_tokens,
                ..opts.trace.clone()
            },
        )
    });
    // The long-context workload: prompts near the serving window, so
    // every decode step attends a deep cache — the regime where the
    // sparse top-k selection (DESIGN.md S20) cuts real bandwidth.
    // Replayed dense then sparse per dtype; the step-latency columns of
    // a pair differ only by the selection.
    let long_trace = (opts.sparse_k > 0).then(|| {
        ArrivalTrace::generate(
            cfg.vocab,
            opts.seed ^ 0x10c7,
            &TraceOpts {
                prompt_min: 24,
                prompt_max: 40,
                max_new_min: 12,
                max_new_max: 24,
                shared_prefix_tokens: 0,
                ..opts.trace.clone()
            },
        )
    });
    // The stall workload (S22): replayed monolithic vs chunked per
    // dtype; the pair's `max_decode_gap_s` columns carry the headline.
    let stall = (opts.prefill_chunk > 0)
        .then(|| stall_trace(cfg.vocab, opts.seed ^ 0x57a11));
    let base_chunk = opts.scheduler.prefill_chunk_tokens;
    let mut rows = Vec::new();
    for variant in variants {
        log::info!("continuous-batching bench: {}", variant.tag());
        // The mixed run honors the caller's `--prefix-cache` policy
        // (default off) and is measured as an f32/int8 PAIR — the same
        // trace under the same byte budget, so the JSON carries the
        // capacity effect of the dtype axis directly. The shared-prefix
        // pair is always measured with the radix cache off AND on, at
        // the caller's dtype. The long-context rows are a dense/sparse
        // pair per dtype, radix cache off. The long-prompt-stall rows
        // come last: a monolithic/chunked pair per dtype.
        #[allow(clippy::type_complexity)]
        let mut runs: Vec<(
            &ArrivalTrace,
            &str,
            bool,
            CacheDtype,
            Option<usize>,
            usize,
        )> = vec![
            (
                &trace,
                "mixed",
                opts.scheduler.prefix_cache,
                CacheDtype::F32,
                None,
                base_chunk,
            ),
            (
                &trace,
                "mixed",
                opts.scheduler.prefix_cache,
                CacheDtype::Int8,
                None,
                base_chunk,
            ),
        ];
        if let Some(st) = &shared_trace {
            runs.push((
                st,
                "shared_prefix",
                false,
                opts.scheduler.cache_dtype,
                None,
                base_chunk,
            ));
            runs.push((
                st,
                "shared_prefix",
                true,
                opts.scheduler.cache_dtype,
                None,
                base_chunk,
            ));
        }
        if let Some(lt) = &long_trace {
            for dtype in [CacheDtype::F32, CacheDtype::Int8] {
                runs.push((
                    lt,
                    "long_context",
                    false,
                    dtype,
                    None,
                    base_chunk,
                ));
                runs.push((
                    lt,
                    "long_context",
                    false,
                    dtype,
                    Some(opts.sparse_k),
                    base_chunk,
                ));
            }
        }
        if let Some(st) = &stall {
            for dtype in [CacheDtype::F32, CacheDtype::Int8] {
                runs.push((st, "long_prompt_stall", false, dtype, None, 0));
                runs.push((
                    st,
                    "long_prompt_stall",
                    false,
                    dtype,
                    None,
                    opts.prefill_chunk,
                ));
            }
        }
        for (t, tag, pc, dtype, sk, pch) in runs {
            let row = bench_variant(
                cfg, variant, opts, t, tag, pc, dtype, sk, pch,
            )
            .with_context(|| format!("bench {} ({tag})", variant.tag()))?;
            println!(
                "bench continuous_batching/{:<22} {:<17} {:<4} cache={:<3} \
                 {:>4} max-concurrency  {:>8.1} tok/s  prefill toks \
                 {:>6}  hits {:>3}  step p50 {:>7.3} ms{}{}",
                variant.tag(),
                tag,
                dtype.tag(),
                if pc { "on" } else { "off" },
                row.req("max_concurrency").as_usize().unwrap_or(0),
                row.req("tokens_per_s").as_f64().unwrap_or(0.0),
                row.req("prefill_tokens").as_usize().unwrap_or(0),
                row.req("prefix_hits").as_usize().unwrap_or(0),
                row.req("step_ms_p50").as_f64().unwrap_or(0.0),
                sk.map(|k| format!("  sparse k={k}")).unwrap_or_default(),
                if tag == "long_prompt_stall" {
                    format!(
                        "  chunk={pch} max-gap {:.3} ms",
                        1e3 * row.req("max_decode_gap_s")
                            .as_f64()
                            .unwrap_or(0.0)
                    )
                } else {
                    String::new()
                },
            );
            rows.push(row);
        }
        // The sharded-routing pair (S24): the shared-prefix trace
        // replayed closed-loop through the cluster router — blind
        // least-loaded baseline first, then the caller's policy — at
        // the caller's dtype, radix cache on. At equal completions the
        // affinity row's aggregate prefix hit rate must strictly beat
        // the blind row's (pinned in-test).
        if opts.workers >= 2 {
            if let Some(st) = &shared_trace {
                for policy in
                    [RoutePolicyKind::LeastLoaded, opts.route_policy]
                {
                    let row = bench_multi_worker(
                        cfg,
                        variant,
                        opts,
                        st,
                        "multi_worker_shared_prefix",
                        policy,
                        opts.scheduler.cache_dtype,
                    )
                    .with_context(|| {
                        format!(
                            "bench {} (multi_worker {})",
                            variant.tag(),
                            policy.tag()
                        )
                    })?;
                    println!(
                        "bench continuous_batching/{:<22} {:<17} \
                         {} workers {:<12}  {:>8.1} tok/s  hit rate \
                         {:>5.1}%  affinity hits {:>3}  shadow blocks \
                         {:>4}",
                        variant.tag(),
                        "multi_worker",
                        opts.workers,
                        policy.tag(),
                        row.req("tokens_per_s").as_f64().unwrap_or(0.0),
                        100.0
                            * row
                                .req("aggregate_prefix_hit_rate")
                                .as_f64()
                                .unwrap_or(0.0),
                        row.req("affinity_hits").as_usize().unwrap_or(0),
                        row.req("shadow_blocks").as_usize().unwrap_or(0),
                    );
                    rows.push(row);
                }
            }
        }
    }
    let json = Json::obj(vec![
        ("experiment", Json::str("continuous_batching")),
        ("backend", Json::str("native")),
        ("config", Json::str(cfg.name.clone())),
        ("max_batch", Json::num(opts.max_batch as f64)),
        ("max_seq", Json::num(opts.max_seq as f64)),
        ("block_tokens", Json::num(opts.scheduler.block_tokens as f64)),
        (
            "cache_budget_bytes",
            Json::num(opts.scheduler.cache_budget_bytes as f64),
        ),
        (
            "shared_prefix_tokens",
            Json::num(opts.shared_prefix_tokens as f64),
        ),
        ("sparse_k", Json::num(opts.sparse_k as f64)),
        ("prefill_chunk", Json::num(opts.prefill_chunk as f64)),
        ("workers", Json::num(opts.workers as f64)),
        ("route_policy", Json::str(opts.route_policy.tag())),
        ("n_requests", Json::num(trace.items.len() as f64)),
        ("trace_new_tokens", Json::num(trace.total_new_tokens() as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(out, json.to_string())?;
    log::info!("wrote {out:?}");
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance property: under one byte budget, the J-LRD 25 %
    /// layout reaches >= 4 concurrent sequences and strictly beats the
    /// dense baseline's max concurrency.
    #[test]
    fn compressed_variant_achieves_higher_concurrency() {
        let cfg = ModelConfig::tiny();
        let default = ServeBenchOpts::default();
        let opts = ServeBenchOpts {
            trace: TraceOpts {
                n_requests: 12,
                inter_arrival_steps: 0, // burst: expose the admission cap
                ..default.trace.clone()
            },
            sparse_k: 0, // mixed + shared-prefix rows only: keep it fast
            prefill_chunk: 0,
            workers: 0, // the multi-worker pair has its own pin below
            ..default
        };
        let out = std::env::temp_dir().join("elitekv_cb_bench_test.json");
        let variants = default_variants(&cfg);
        let json =
            continuous_batching_bench(&cfg, &variants, &opts, &out).unwrap();
        let rows: Vec<&Json> = json
            .req("rows")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|r| {
                r.req("trace").as_str() == Some("mixed")
                    && r.req("cache_dtype").as_str() == Some("f32")
            })
            .collect();
        assert_eq!(rows.len(), 2);
        let mha = rows[0].req("max_concurrency").as_usize().unwrap();
        let ekv = rows[1].req("max_concurrency").as_usize().unwrap();
        assert!(ekv >= 4, "compressed concurrency {ekv} < 4");
        assert!(ekv > mha, "compressed {ekv} !> dense {mha}");
        // both served the full trace, reporting the dispatched ISA
        for row in rows {
            assert_eq!(row.req("completed").as_usize().unwrap(), 12);
            assert_eq!(
                row.req("kernel_isa").as_str(),
                Some(crate::native::simd::active().name()),
            );
        }
        let text = std::fs::read_to_string(&out).unwrap();
        assert!(Json::parse(&text).is_ok());
        std::fs::remove_file(out).ok();
    }

    /// The S19 acceptance property: at the SAME `--cache-budget-mb`,
    /// int8 strictly raises max concurrency over f32 for EVERY variant
    /// of the pair — the quantized pool holds 4x the blocks, and with
    /// enough lanes and a bursty trace the admission cap moves with it.
    /// Completion counts stay equal (quantization changes bytes, never
    /// the request stream).
    #[test]
    fn int8_strictly_raises_concurrency_at_same_budget() {
        let cfg = ModelConfig::tiny();
        let default = ServeBenchOpts::default();
        let opts = ServeBenchOpts {
            // enough lanes that the pool, not the lane count, caps f32
            // concurrency for both variants: at the 1 MiB budget and 2
            // blocks/request, dense f32 admits 4 (8-block pool), dense
            // int8 16; jlrd f32 admits 16, jlrd int8 all 24 (128-block
            // pool, request-bound)
            max_batch: 24,
            trace: TraceOpts {
                n_requests: 24,
                inter_arrival_steps: 0, // burst: expose the admission cap
                ..default.trace.clone()
            },
            shared_prefix_tokens: 0, // mixed pairs only: keep it fast
            sparse_k: 0,
            prefill_chunk: 0,
            ..default
        };
        let out = std::env::temp_dir().join("elitekv_cb_int8_test.json");
        let variants = default_variants(&cfg);
        let json =
            continuous_batching_bench(&cfg, &variants, &opts, &out).unwrap();
        std::fs::remove_file(&out).ok();
        for variant in &variants {
            let tag = variant.tag();
            let find = |dtype: &str| {
                json.req("rows")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .find(|r| {
                        r.req("variant").as_str() == Some(tag.as_str())
                            && r.req("cache_dtype").as_str() == Some(dtype)
                    })
                    .cloned()
                    .unwrap()
            };
            let (f, q) = (find("f32"), find("int8"));
            // the byte identity the concurrency claim rides on
            let (bf, bq) = (
                f.req("cache_bytes_per_token").as_usize().unwrap(),
                q.req("cache_bytes_per_token").as_usize().unwrap(),
            );
            assert_eq!(bq * 4, bf, "{tag}: int8 bytes/token != f32/4");
            assert_eq!(
                q.req("pool_blocks").as_usize().unwrap(),
                4 * f.req("pool_blocks").as_usize().unwrap(),
                "{tag}: int8 pool != 4x f32 pool at one budget"
            );
            let (cf, cq) = (
                f.req("max_concurrency").as_usize().unwrap(),
                q.req("max_concurrency").as_usize().unwrap(),
            );
            assert!(
                cq > cf,
                "{tag}: int8 concurrency {cq} !> f32 {cf} at equal budget"
            );
            assert_eq!(
                f.req("completed").as_usize().unwrap(),
                q.req("completed").as_usize().unwrap(),
                "{tag}: completions diverge across dtypes"
            );
        }
    }

    /// The shared-prefix acceptance property (ISSUE 4): with the radix
    /// cache on, the shared-system-prompt trace shows a nonzero prefix
    /// hit rate and strictly fewer prefilled tokens than the cache-off
    /// replay of the SAME trace, at unchanged completion counts.
    #[test]
    fn shared_prefix_trace_amortizes_prefills() {
        let cfg = ModelConfig::tiny();
        let default = ServeBenchOpts::default();
        let opts = ServeBenchOpts {
            trace: TraceOpts { n_requests: 10, ..default.trace.clone() },
            sparse_k: 0, // shared-prefix rows are the subject here
            prefill_chunk: 0,
            workers: 0, // the multi-worker pair has its own pin below
            ..default
        };
        let out = std::env::temp_dir().join("elitekv_cb_prefix_test.json");
        let variants = default_variants(&cfg);
        let json =
            continuous_batching_bench(&cfg, &variants, &opts, &out).unwrap();
        std::fs::remove_file(&out).ok();
        for variant in variants {
            let tag = variant.tag();
            let find = |pc: bool| {
                json.req("rows")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .find(|r| {
                        r.req("variant").as_str() == Some(tag.as_str())
                            && r.req("trace").as_str()
                                == Some("shared_prefix")
                            && r.req("prefix_cache").as_bool() == Some(pc)
                    })
                    .cloned()
                    .unwrap()
            };
            let (off, on) = (find(false), find(true));
            assert_eq!(
                off.req("completed").as_usize(),
                on.req("completed").as_usize(),
                "{tag}: completion counts diverge"
            );
            assert!(
                on.req("prefix_hits").as_usize().unwrap() > 0,
                "{tag}: no prefix hits on the shared-prefix trace"
            );
            let (pt_off, pt_on) = (
                off.req("prefill_tokens").as_usize().unwrap(),
                on.req("prefill_tokens").as_usize().unwrap(),
            );
            assert!(
                pt_on < pt_off,
                "{tag}: prefix cache prefilled {pt_on} tokens, \
                 cache-off {pt_off}"
            );
            assert_eq!(
                off.req("prefix_hits").as_usize().unwrap(),
                0,
                "{tag}: cache-off run reported hits"
            );
        }
    }

    /// The S22 acceptance property: on the long-prompt-arrives-mid-decode
    /// trace, chunked prefill strictly reduces the worst inter-token gap
    /// of in-flight lanes (`max_decode_gap_s`) vs the monolithic replay,
    /// at equal completion counts, for every variant × dtype pair — and
    /// the TTFT percentile columns are present and ordered.
    #[test]
    fn chunked_prefill_reduces_decode_stall() {
        let cfg = ModelConfig::tiny();
        let default = ServeBenchOpts::default();
        let opts = ServeBenchOpts {
            trace: TraceOpts {
                n_requests: 4, // keep the mixed rows cheap
                ..default.trace.clone()
            },
            shared_prefix_tokens: 0, // stall rows are the subject here
            sparse_k: 0,
            ..default
        };
        let out = std::env::temp_dir().join("elitekv_cb_stall_test.json");
        let variants = default_variants(&cfg);
        let json =
            continuous_batching_bench(&cfg, &variants, &opts, &out).unwrap();
        std::fs::remove_file(&out).ok();
        for variant in &variants {
            let tag = variant.tag();
            for dtype in ["f32", "int8"] {
                let find = |chunk: usize| {
                    json.req("rows")
                        .as_arr()
                        .unwrap()
                        .iter()
                        .find(|r| {
                            r.req("variant").as_str() == Some(tag.as_str())
                                && r.req("trace").as_str()
                                    == Some("long_prompt_stall")
                                && r.req("cache_dtype").as_str()
                                    == Some(dtype)
                                && r.req("prefill_chunk").as_usize()
                                    == Some(chunk)
                        })
                        .cloned()
                        .unwrap()
                };
                let (mono, chunked) =
                    (find(0), find(opts.prefill_chunk));
                // equal completions: chunking reschedules work, it
                // never changes the request stream
                assert_eq!(
                    mono.req("completed").as_usize().unwrap(),
                    3,
                    "{tag}/{dtype}: monolithic replay dropped requests"
                );
                assert_eq!(
                    chunked.req("completed").as_usize().unwrap(),
                    3,
                    "{tag}/{dtype}: chunked replay dropped requests"
                );
                let (gm, gc) = (
                    mono.req("max_decode_gap_s").as_f64().unwrap(),
                    chunked.req("max_decode_gap_s").as_f64().unwrap(),
                );
                assert!(
                    gc < gm,
                    "{tag}/{dtype}: chunked max gap {gc:.6}s !< \
                     monolithic {gm:.6}s"
                );
                for row in [&mono, &chunked] {
                    let (p50, p95, p99) = (
                        row.req("ttft_p50_s").as_f64().unwrap(),
                        row.req("ttft_p95_s").as_f64().unwrap(),
                        row.req("ttft_p99_s").as_f64().unwrap(),
                    );
                    assert!(
                        p50 > 0.0 && p50 <= p95 && p95 <= p99,
                        "{tag}/{dtype}: TTFT percentiles disordered \
                         ({p50}, {p95}, {p99})"
                    );
                    assert!(
                        row.req("tpot_mean_s").as_f64().unwrap() > 0.0,
                        "{tag}/{dtype}: zero TPOT on a multi-token trace"
                    );
                }
            }
        }
    }

    /// The S20 rows: the long-context trace replays dense then sparse
    /// per dtype. Sparse rows report a selection strictly smaller than
    /// the dense-equivalent row count; dense rows report zero; both
    /// replays of a pair complete the whole trace (sparsity changes
    /// which rows are attended, never the request stream).
    #[test]
    fn long_context_sparse_pair_reports_selection() {
        let cfg = ModelConfig::tiny();
        let default = ServeBenchOpts::default();
        let opts = ServeBenchOpts {
            trace: TraceOpts { n_requests: 6, ..default.trace.clone() },
            shared_prefix_tokens: 0, // long-context rows are the subject
            sparse_k: 4,
            prefill_chunk: 0,
            ..default
        };
        let out = std::env::temp_dir().join("elitekv_cb_sparse_test.json");
        let variants = vec![Variant::EliteKv {
            r: cfg.n_chunks() / 4,
            d_ckv: cfg.d_model / 4,
        }];
        let json =
            continuous_batching_bench(&cfg, &variants, &opts, &out).unwrap();
        std::fs::remove_file(&out).ok();
        let rows: Vec<&Json> = json
            .req("rows")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|r| r.req("trace").as_str() == Some("long_context"))
            .collect();
        // dense/sparse pair at f32 and at int8
        assert_eq!(rows.len(), 4);
        for row in &rows {
            assert_eq!(row.req("completed").as_usize().unwrap(), 6);
            let k = row.req("sparse_k").as_usize().unwrap();
            let att = row.req("sparse_attended_rows").as_usize().unwrap();
            let dns = row.req("sparse_dense_rows").as_usize().unwrap();
            if k == 0 {
                assert_eq!((att, dns), (0, 0), "dense row reported selection");
            } else {
                // prompts are at least 24 tokens, so every decode step
                // selects k=4 of >= 25 rows
                assert!(
                    att > 0 && att < dns,
                    "sparse row kept {att} of {dns} rows"
                );
            }
            assert!(row.req("step_ms_p50").as_f64().unwrap() > 0.0);
        }
    }

    /// The S24 acceptance property: on the shared-prefix trace replayed
    /// closed-loop over two workers, prefix-affinity routing yields a
    /// strictly higher aggregate prefix hit rate than blind least-loaded
    /// routing at equal completion counts — the shadow index turned
    /// cache locality into a routing signal. Also pins that the blind
    /// baseline really spreads load (both workers routed to) and that
    /// the router's shadow view matches the workers' real block gauges
    /// at drain.
    #[test]
    fn affinity_routing_beats_blind_on_shared_prefix_trace() {
        let cfg = ModelConfig::tiny();
        let default = ServeBenchOpts::default();
        let opts = ServeBenchOpts {
            trace: TraceOpts { n_requests: 10, ..default.trace.clone() },
            sparse_k: 0, // multi-worker rows are the subject here
            prefill_chunk: 0,
            workers: 2,
            ..default
        };
        let out = std::env::temp_dir().join("elitekv_cb_sharded_test.json");
        let variants = vec![Variant::EliteKv {
            r: cfg.n_chunks() / 4,
            d_ckv: cfg.d_model / 4,
        }];
        let json =
            continuous_batching_bench(&cfg, &variants, &opts, &out).unwrap();
        std::fs::remove_file(&out).ok();
        let find = |policy: &str| {
            json.req("rows")
                .as_arr()
                .unwrap()
                .iter()
                .find(|r| {
                    r.req("trace").as_str()
                        == Some("multi_worker_shared_prefix")
                        && r.req("route_policy").as_str() == Some(policy)
                })
                .cloned()
                .unwrap()
        };
        let (blind, affinity) = (find("least-loaded"), find("affinity"));
        // Equal completions: routing shards the stream, it never drops
        // or changes a request.
        for row in [&blind, &affinity] {
            assert_eq!(row.req("completed").as_usize().unwrap(), 10);
            assert_eq!(row.req("workers").as_usize().unwrap(), 2);
        }
        let (hb, ha) = (
            blind.req("aggregate_prefix_hit_rate").as_f64().unwrap(),
            affinity.req("aggregate_prefix_hit_rate").as_f64().unwrap(),
        );
        assert!(
            ha > hb,
            "affinity hit rate {ha:.3} !> blind hit rate {hb:.3}"
        );
        // The affinity row won because the shadow index actually fired.
        assert!(
            affinity.req("affinity_hits").as_usize().unwrap() >= 1,
            "affinity row routed without a single shadow-prefix hit"
        );
        // The blind baseline is a fair contrast only if it spreads the
        // stream: every worker must have been routed to.
        let routed: Vec<usize> = blind
            .req("per_worker_routed")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert!(
            routed.iter().all(|&n| n > 0),
            "blind routing starved a worker: {routed:?}"
        );
        // Shadow exactness at drain: the router's tokens-only mirror
        // holds exactly as many blocks as the workers' radix caches.
        for row in [&blind, &affinity] {
            assert_eq!(
                row.req("shadow_blocks").as_usize().unwrap(),
                row.req("prefix_cached_blocks").as_usize().unwrap(),
                "shadow index diverged from worker caches"
            );
        }
    }
}
