//! Result output: markdown tables + JSON series files.

use std::path::Path;

use anyhow::Result;

use crate::util::Json;

/// A markdown table builder (paper-style rows).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with the given column headers.
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// Print the table under a markdown section heading.
    pub fn print(&self, title: &str) {
        println!("\n## {title}\n");
        println!("{}", self.to_markdown());
    }
}

/// Write a JSON result record to `<results>/<name>.json`.
pub fn write_json(results: &Path, name: &str, value: &Json) -> Result<()> {
    let path = results.join(format!("{name}.json"));
    std::fs::write(&path, value.to_string())?;
    log::info!("wrote {path:?}");
    Ok(())
}

/// Append a markdown section to `<results>/REPORT.md`.
pub fn append_report(results: &Path, section: &str) -> Result<()> {
    use std::io::Write;
    let path = results.join("REPORT.md");
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    writeln!(f, "{section}")?;
    Ok(())
}

/// Format a [0, 1] ratio as a percentage with two decimals.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}", 100.0 * x)
}

/// Format a float with the given precision (table cells).
pub fn fmt_f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_layout() {
        let mut t = Table::new(&["Cache", "Method", "Avg"]);
        t.row(vec!["100.0".into(), "mha".into(), "58.1".into()]);
        let md = t.to_markdown();
        assert!(md.starts_with("| Cache | Method | Avg |\n"));
        assert!(md.contains("|---|---|---|"));
        assert!(md.contains("| 100.0 | mha | 58.1 |"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
