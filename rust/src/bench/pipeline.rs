//! Shared experiment stages with on-disk caching: pretraining, search,
//! conversion, uptraining, evaluation.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{ModelConfig, Variant};
use crate::convert::{self, EliteSelection};
use crate::data::{CorpusGen, ProbeSet};
use crate::io::Checkpoint;
use crate::runtime::{Engine, HostTensor, ModelRunner, TrainState};
use crate::search;
use crate::train::scorer;
use crate::train::{TrainLoop, TrainOpts};

/// Knobs for the whole experiment sweep.
#[derive(Clone, Debug)]
pub struct SweepOpts {
    pub pretrain_steps: usize,
    pub uptrain_steps: usize,
    pub pretrain_lr: f32,
    /// Paper §4.1: constant LR at the end-of-pretraining value.
    pub uptrain_lr: f32,
    pub probes_per_task: usize,
    pub ppl_batches: usize,
}

impl SweepOpts {
    /// Quick mode: tens of minutes on one CPU core; the paper's *shapes*
    /// (who wins, how gaps widen as cache shrinks) hold at this budget.
    pub fn quick() -> SweepOpts {
        SweepOpts {
            pretrain_steps: 600,
            uptrain_steps: 100,
            pretrain_lr: 1e-3,
            uptrain_lr: 3e-4,
            probes_per_task: 20,
            ppl_batches: 3,
        }
    }

    pub fn full() -> SweepOpts {
        SweepOpts {
            pretrain_steps: 1200,
            uptrain_steps: 240,
            pretrain_lr: 1e-3,
            uptrain_lr: 3e-4,
            probes_per_task: 50,
            ppl_batches: 8,
        }
    }
}

/// Engine + directories + caching for experiment stages.
pub struct ExperimentCtx {
    pub engine: Arc<Engine>,
    pub artifacts: PathBuf,
    pub results: PathBuf,
    pub opts: SweepOpts,
}

impl ExperimentCtx {
    pub fn new(
        artifacts: impl Into<PathBuf>,
        results: impl Into<PathBuf>,
        opts: SweepOpts,
    ) -> Result<ExperimentCtx> {
        let results = results.into();
        std::fs::create_dir_all(&results)?;
        Ok(ExperimentCtx {
            engine: Arc::new(Engine::new()?),
            artifacts: artifacts.into(),
            results,
            opts,
        })
    }

    pub fn runner(&self, cfg: &str, tag: &str) -> Result<ModelRunner> {
        ModelRunner::new(Arc::clone(&self.engine), &self.artifacts, cfg, tag)
    }

    /// Pretrain (or load the cached) baseline MHA model for a config.
    pub fn pretrained(&self, cfg_name: &str) -> Result<Checkpoint> {
        let path = self.results.join(format!("pretrained_{cfg_name}.ekvc"));
        if path.exists() {
            log::info!("using cached {path:?}");
            return Checkpoint::load(&path);
        }
        let runner = self.runner(cfg_name, "mha")?;
        log::info!("pretraining {cfg_name} for {} steps",
                   self.opts.pretrain_steps);
        let params = runner.init(42)?;
        let mut state = TrainState::fresh(params);
        let opts = TrainOpts {
            steps: self.opts.pretrain_steps,
            lr: self.opts.pretrain_lr,
            eval_every: 0,
            eval_batches: self.opts.ppl_batches,
            log_every: 50,
            data_seed: 1,
        };
        let mut lp = TrainLoop::new(&runner, &opts);
        let report = lp.run(&mut state, &opts)?;
        log::info!(
            "pretrain {cfg_name}: loss {:.3}, ppl {:.2}, {:.0}s",
            report.final_loss, report.final_ppl, report.seconds
        );
        let mut ckpt = runner.ckpt_from_params(&state.params)?;
        ckpt.set_meta("pretrain_steps", self.opts.pretrain_steps);
        ckpt.set_meta("pretrain_tokens", report.tokens_seen);
        ckpt.save(&path)?;
        Ok(ckpt)
    }

    /// RoPElite / baseline chunk selection with caching.
    pub fn selection(
        &self,
        cfg_name: &str,
        method: &str,
        r: usize,
    ) -> Result<EliteSelection> {
        let cfg = ModelConfig::by_name(cfg_name).context("config")?;
        if method == "uniform" {
            return Ok(search::uniform_selection(&cfg, r));
        }
        let path = self
            .results
            .join(format!("elite_{cfg_name}_{method}_r{r}.ekvc"));
        if path.exists() {
            return EliteSelection::from_checkpoint(&Checkpoint::load(&path)?,
                                                   &cfg);
        }
        let base = self.pretrained(cfg_name)?;
        let runner = self.runner(cfg_name, "mha")?;
        let params = runner.params_from_ckpt(&base)?;
        let mut gen = CorpusGen::new(cfg.vocab, 1);
        gen.reseed(1, 0xca11b); // calibration stream
        let sel = match method {
            "ropelite" => search::ropelite_search(&runner, &params, &mut gen, r)?,
            "contribution" => {
                search::contribution_selection(&runner, &params, &mut gen, r)?
            }
            m => anyhow::bail!("unknown search method `{m}`"),
        };
        sel.to_checkpoint(&cfg).save(&path)?;
        Ok(sel)
    }

    /// Build a ready-to-run ModelRunner for a converted variant: converts
    /// the pretrained baseline, installs extras, returns (runner, params).
    pub fn converted(
        &self,
        cfg_name: &str,
        variant: &Variant,
        method: &str,
    ) -> Result<(ModelRunner, Vec<HostTensor>, Option<EliteSelection>)> {
        let cfg = ModelConfig::by_name(cfg_name).context("config")?;
        let base = self.pretrained(cfg_name)?;
        let tag = variant.tag();
        let mut runner = self.runner(cfg_name, &tag)?;
        match variant {
            Variant::Mha => {
                let params = runner.params_from_ckpt(&base)?;
                Ok((runner, params, None))
            }
            Variant::RopeLite => {
                anyhow::bail!("use converted_ropelite with an explicit r")
            }
            Variant::Gqa { n_kv_heads } => {
                let ckpt = convert::convert_gqa(&cfg, &base, *n_kv_heads)?;
                let params = runner.params_from_ckpt(&ckpt)?;
                Ok((runner, params, None))
            }
            Variant::EliteKv { r, d_ckv } => {
                let sel = self.selection(cfg_name, method, *r)?;
                let ckpt = convert::convert_elitekv(&cfg, &base, &sel, *d_ckv)?;
                let params = runner.params_from_ckpt(&ckpt)?;
                let theta = convert::elitekv::elite_thetas_flat(&cfg, &sel);
                runner.set_extras(vec![HostTensor::F32(
                    theta,
                    vec![cfg.n_layers, cfg.n_heads, *r],
                )])?;
                Ok((runner, params, Some(sel)))
            }
            Variant::Slrd { r, d_ck, d_cv } => {
                let sel = self.selection(cfg_name, method, *r)?;
                let ckpt = convert::convert_slrd(&cfg, &base, &sel, *d_ck, *d_cv)?;
                let params = runner.params_from_ckpt(&ckpt)?;
                let theta = convert::elitekv::elite_thetas_flat(&cfg, &sel);
                runner.set_extras(vec![HostTensor::F32(
                    theta,
                    vec![cfg.n_layers, cfg.n_heads, *r],
                )])?;
                Ok((runner, params, Some(sel)))
            }
        }
    }

    /// RoPElite-only model (mask extras, weights unchanged).
    pub fn converted_ropelite(
        &self,
        cfg_name: &str,
        method: &str,
        r: usize,
    ) -> Result<(ModelRunner, Vec<HostTensor>)> {
        let cfg = ModelConfig::by_name(cfg_name).context("config")?;
        let base = self.pretrained(cfg_name)?;
        let sel = self.selection(cfg_name, method, r)?;
        let mut runner = self.runner(cfg_name, "ropelite")?;
        let mask = convert::elitekv::elite_mask_flat(&cfg, &sel);
        runner.set_extras(vec![HostTensor::F32(
            mask,
            vec![cfg.n_layers, cfg.n_heads, cfg.n_chunks()],
        )])?;
        let params = runner.params_from_ckpt(&base)?;
        Ok((runner, params))
    }

    /// Uptrain a converted model for the sweep's uptrain budget.
    /// Returns the trained state + report.
    pub fn uptrain(
        &self,
        runner: &ModelRunner,
        params: Vec<HostTensor>,
        steps: usize,
        eval_every: usize,
    ) -> Result<(TrainState, crate::train::TrainReport)> {
        let mut state = TrainState::fresh(params);
        let opts = TrainOpts {
            steps,
            lr: self.opts.uptrain_lr,
            eval_every,
            eval_batches: self.opts.ppl_batches,
            log_every: 50,
            data_seed: 7, // uptraining stream differs from pretraining
        };
        let mut lp = TrainLoop::new(runner, &opts);
        let report = lp.run(&mut state, &opts)?;
        Ok((state, report))
    }

    /// The standard evaluation bundle (probe battery + holdout ppl).
    pub fn evaluate(
        &self,
        runner: &ModelRunner,
        params: &[HostTensor],
    ) -> Result<scorer::ScoreReport> {
        let gen = CorpusGen::new(runner.manifest.config.vocab, 1);
        let probes = ProbeSet::generate(&gen, self.opts.probes_per_task, 99);
        scorer::full_report(
            &runner.as_backend(params),
            &probes,
            self.opts.ppl_batches,
        )
    }

    /// Tokens per pretraining run (for "uptraining proportion" axes).
    pub fn pretrain_tokens(&self, cfg_name: &str) -> Result<usize> {
        let runner = self.runner(cfg_name, "mha")?;
        let (b, t) = runner.train_shape()?;
        Ok(self.opts.pretrain_steps * b * t)
    }
}
