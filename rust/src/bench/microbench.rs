//! Micro-benchmark runner (criterion-core substitute): warmup + timed
//! iterations + summary statistics, with a stable one-line report format
//! that `cargo bench` emits for every paper table/figure target.

use std::time::Instant;

use crate::util::stats::Summary;

/// Benchmark settings.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Untimed iterations run first (cache warmup, allocator steady
    /// state).
    pub warmup_iters: usize,
    /// Timed iterations the summary is computed over.
    pub iters: usize,
}

impl Default for BenchOpts {
    fn default() -> BenchOpts {
        BenchOpts { warmup_iters: 3, iters: 10 }
    }
}

/// One warmup + timed-sample loop; `scale` converts seconds into the
/// reported unit (1e3 → ms, 1e9 → ns).
fn bench_scaled<F: FnMut()>(
    name: &str,
    opts: BenchOpts,
    scale: f64,
    unit: &str,
    mut f: F,
) -> Summary {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let samples: Vec<f64> = (0..opts.iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * scale
        })
        .collect();
    let s = Summary::of(&samples);
    println!("bench {name:<44} {}", s.fmt(unit));
    s
}

/// Measure `f` and report milliseconds per iteration.
pub fn bench<F: FnMut()>(name: &str, opts: BenchOpts, f: F) -> Summary {
    bench_scaled(name, opts, 1e3, "ms", f)
}

/// Measure `f` and report nanoseconds per iteration — the kernel-level
/// variant of [`bench`] for sub-millisecond work (a single GEMM call)
/// where milliseconds lose all precision.
pub fn bench_ns<F: FnMut()>(name: &str, opts: BenchOpts, f: F) -> Summary {
    bench_scaled(name, opts, 1e9, "ns", f)
}

/// Measure throughput: `f` returns a work count per call (e.g. tokens).
pub fn bench_throughput<F: FnMut() -> usize>(
    name: &str,
    opts: BenchOpts,
    unit: &str,
    mut f: F,
) -> f64 {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut total_work = 0usize;
    let t0 = Instant::now();
    for _ in 0..opts.iters {
        total_work += f();
    }
    let rate = total_work as f64 / t0.elapsed().as_secs_f64();
    println!("bench {name:<44} {rate:10.1} {unit}/s");
    rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_times() {
        let s = bench(
            "noop-spin",
            BenchOpts { warmup_iters: 1, iters: 5 },
            || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
        );
        assert!(s.mean >= 0.0);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn bench_ns_scales_milliseconds_up() {
        let s = bench_ns(
            "noop-ns",
            BenchOpts { warmup_iters: 0, iters: 4 },
            || {
                std::hint::black_box((0..100).sum::<u64>());
            },
        );
        assert!(s.mean >= 0.0);
        assert_eq!(s.n, 4);
    }

    #[test]
    fn throughput_counts_work() {
        let r = bench_throughput(
            "fixed-work",
            BenchOpts { warmup_iters: 0, iters: 3 },
            "items",
            || 100,
        );
        assert!(r > 0.0);
    }
}
