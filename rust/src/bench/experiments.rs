//! One function per paper table/figure (DESIGN.md §7 experiment index).

use anyhow::{Context, Result};

use crate::bench::pipeline::ExperimentCtx;
use crate::bench::report::{self, Table};
use crate::config::{table1_grid, ModelConfig, Variant};
use crate::convert::{self};
use crate::coordinator::{GenParams, InferenceServer, Request};
use crate::data::{CorpusGen, ProbeSet};
use crate::util::Json;

/// Table 1: EliteKV vs GQA across the cache-ratio grid, after uptraining.
pub fn table1(ctx: &ExperimentCtx, cfg_name: &str) -> Result<Json> {
    let cfg = ModelConfig::by_name(cfg_name).context("config")?;
    let mut table = Table::new(&[
        "Cache", "Method", "copy", "reverse", "recall", "induction",
        "arith", "sort", "Avg", "ppl",
    ]);
    let mut records = Vec::new();
    for (label, variant) in table1_grid(&cfg) {
        let tag = variant.tag();
        log::info!("table1 [{cfg_name}]: {label}% {tag}");
        let (runner, params) = match variant {
            Variant::Mha => {
                let (r, p, _) = ctx.converted(cfg_name, &variant, "ropelite")?;
                (r, p) // baseline evaluated as-is (no uptraining needed)
            }
            _ => {
                let (r, p, _) = ctx.converted(cfg_name, &variant, "ropelite")?;
                let (state, _rep) =
                    ctx.uptrain(&r, p, ctx.opts.uptrain_steps, 0)?;
                (r, state.params)
            }
        };
        let rep = ctx.evaluate(&runner, &params)?;
        let method = match variant {
            Variant::Mha => "baseline",
            Variant::Gqa { .. } => "GQA",
            _ => "EliteKV",
        };
        let mut cells = vec![label.to_string(), method.to_string()];
        for (_, acc) in &rep.scores.task_acc {
            cells.push(report::fmt_pct(*acc));
        }
        cells.push(report::fmt_pct(rep.scores.average));
        cells.push(report::fmt_f(rep.ppl, 3));
        table.row(cells);
        records.push(Json::obj(vec![
            ("cache", Json::str(label)),
            ("variant", Json::str(&tag)),
            ("method", Json::str(method)),
            ("avg", Json::num(rep.scores.average)),
            ("ppl", Json::num(rep.ppl)),
            (
                "tasks",
                Json::Arr(
                    rep.scores
                        .task_acc
                        .iter()
                        .map(|(k, v)| {
                            Json::obj(vec![
                                ("task", Json::str(k.as_str())),
                                ("acc", Json::num(*v)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    table.print(&format!("Table 1 ({cfg_name}): EliteKV vs GQA"));
    let json = Json::obj(vec![
        ("experiment", Json::str("table1")),
        ("config", Json::str(cfg_name)),
        ("rows", Json::Arr(records)),
    ]);
    report::write_json(&ctx.results, &format!("table1_{cfg_name}"), &json)?;
    report::append_report(
        &ctx.results,
        &format!("## Table 1 ({cfg_name})\n\n{}", table.to_markdown()),
    )?;
    Ok(json)
}

/// Table 2: Uniform vs Contribution vs RoPElite at shrinking r
/// (RoPElite-only models, short uptraining).
pub fn table2(ctx: &ExperimentCtx, cfg_name: &str) -> Result<Json> {
    let cfg = ModelConfig::by_name(cfg_name).context("config")?;
    let nc = cfg.n_chunks();
    let rs = [nc / 2, nc / 4, nc / 8, nc / 16.max(1)];
    let steps = (ctx.opts.uptrain_steps / 2).max(10); // paper: <0.1 % tokens
    let mut table = Table::new(&["Method", "r/2nc", "Avg", "ppl"]);
    let mut records = Vec::new();
    for method in ["uniform", "contribution", "ropelite"] {
        for &r in &rs {
            if r == 0 {
                continue;
            }
            log::info!("table2 [{cfg_name}]: {method} r={r}");
            let (runner, params) =
                ctx.converted_ropelite(cfg_name, method, r)?;
            let (state, _rep) = ctx.uptrain(&runner, params, steps, 0)?;
            let rep = ctx.evaluate(&runner, &state.params)?;
            table.row(vec![
                method.to_string(),
                format!("{r}/{nc}"),
                report::fmt_pct(rep.scores.average),
                report::fmt_f(rep.ppl, 3),
            ]);
            records.push(Json::obj(vec![
                ("method", Json::str(method)),
                ("r", Json::num(r as f64)),
                ("avg", Json::num(rep.scores.average)),
                ("ppl", Json::num(rep.ppl)),
            ]));
        }
    }
    table.print(&format!(
        "Table 2 ({cfg_name}): rotation-dimension search methods"
    ));
    let json = Json::obj(vec![
        ("experiment", Json::str("table2")),
        ("config", Json::str(cfg_name)),
        ("rows", Json::Arr(records)),
    ]);
    report::write_json(&ctx.results, &format!("table2_{cfg_name}"), &json)?;
    report::append_report(
        &ctx.results,
        &format!("## Table 2 ({cfg_name})\n\n{}", table.to_markdown()),
    )?;
    Ok(json)
}

/// Figure 2/8: elite-chunk heat map across layers/heads (CSV + ASCII).
pub fn fig2(ctx: &ExperimentCtx, cfg_name: &str, r: usize) -> Result<Json> {
    let cfg = ModelConfig::by_name(cfg_name).context("config")?;
    let sel = ctx.selection(cfg_name, "ropelite", r)?;
    let nc = cfg.n_chunks();
    // CSV: layer,head,slot,chunk
    let mut csv = String::from("layer,head,slot,chunk\n");
    for (l, layer) in sel.chunks.iter().enumerate() {
        for (h, head) in layer.iter().enumerate() {
            for (s, &c) in head.iter().enumerate() {
                csv.push_str(&format!("{l},{h},{s},{c}\n"));
            }
        }
    }
    let csv_path = ctx.results.join(format!("fig2_{cfg_name}_r{r}.csv"));
    std::fs::write(&csv_path, &csv)?;
    // ASCII heat map: rows = layer x head, cols = chunks (low idx = high
    // frequency, matching the paper's figure orientation).
    println!("\n## Figure 2 ({cfg_name}, r={r}): elite chunks (# = elite)\n");
    println!("          chunk 0 (high freq) {} {nc} (low freq)",
             " ".repeat(nc.saturating_sub(28)));
    for (l, layer) in sel.chunks.iter().enumerate() {
        for (h, head) in layer.iter().enumerate() {
            let mut row = vec!['.'; nc];
            for &c in head {
                row[c] = '#';
            }
            println!("L{l:02}H{h:02}  |{}|", row.iter().collect::<String>());
        }
    }
    // Frequency-band statistics (the paper's qualitative claims).
    let mut band_counts = [0usize; 3]; // high/mid/low thirds
    let mut shallow_high = 0usize;
    let mut total = 0usize;
    for (l, layer) in sel.chunks.iter().enumerate() {
        for head in layer {
            for &c in head {
                let band = (3 * c / nc).min(2);
                band_counts[band] += 1;
                if band == 0 && l < cfg.n_layers / 2 {
                    shallow_high += 1;
                }
                total += 1;
            }
        }
    }
    let json = Json::obj(vec![
        ("experiment", Json::str("fig2")),
        ("config", Json::str(cfg_name)),
        ("r", Json::num(r as f64)),
        ("csv", Json::str(csv_path.to_string_lossy().as_ref())),
        ("high_band", Json::num(band_counts[0] as f64 / total as f64)),
        ("mid_band", Json::num(band_counts[1] as f64 / total as f64)),
        ("low_band", Json::num(band_counts[2] as f64 / total as f64)),
        (
            "shallow_share_of_high",
            Json::num(if band_counts[0] > 0 {
                shallow_high as f64 / band_counts[0] as f64
            } else {
                0.0
            }),
        ),
    ]);
    report::write_json(&ctx.results, &format!("fig2_{cfg_name}_r{r}"), &json)?;
    Ok(json)
}

/// Figure 3: probe average vs uptraining proportion at several top-r.
pub fn fig3(ctx: &ExperimentCtx, cfg_name: &str) -> Result<Json> {
    let cfg = ModelConfig::by_name(cfg_name).context("config")?;
    let nc = cfg.n_chunks();
    let rs = [nc / 2, nc / 4, nc / 8];
    let pre_tokens = ctx.pretrain_tokens(cfg_name)? as f64;
    let steps = ctx.opts.uptrain_steps;
    let eval_every = (steps / 4).max(1);
    let mut series = Vec::new();
    let mut table = Table::new(&["r", "uptrain %", "ppl"]);
    for &r in &rs {
        log::info!("fig3 [{cfg_name}]: r={r}");
        let (runner, params) = ctx.converted_ropelite(cfg_name, "ropelite", r)?;
        let (_state, rep) = ctx.uptrain(&runner, params, steps, eval_every)?;
        let mut points = Vec::new();
        for p in rep.points.iter().filter(|p| p.ppl.is_some()) {
            let prop = p.tokens as f64 / pre_tokens;
            table.row(vec![
                r.to_string(),
                report::fmt_pct(prop),
                report::fmt_f(p.ppl.unwrap(), 3),
            ]);
            points.push(Json::obj(vec![
                ("tokens", Json::num(p.tokens as f64)),
                ("proportion", Json::num(prop)),
                ("ppl", Json::num(p.ppl.unwrap())),
            ]));
        }
        series.push(Json::obj(vec![
            ("r", Json::num(r as f64)),
            ("points", Json::Arr(points)),
        ]));
    }
    table.print(&format!("Figure 3 ({cfg_name}): recovery vs uptraining"));
    let json = Json::obj(vec![
        ("experiment", Json::str("fig3")),
        ("config", Json::str(cfg_name)),
        ("pretrain_tokens", Json::num(pre_tokens)),
        ("series", Json::Arr(series)),
    ]);
    report::write_json(&ctx.results, &format!("fig3_{cfg_name}"), &json)?;
    Ok(json)
}

/// Figure 5: S-LRD vs J-LRD perplexity at fixed cache budgets
/// (direct post-conversion ppl of a RoPElite-uptrained model).
pub fn fig5(ctx: &ExperimentCtx, cfg_name: &str) -> Result<Json> {
    let cfg = ModelConfig::by_name(cfg_name).context("config")?;
    let nc = cfg.n_chunks();
    // budgets mirror the aot core set for tiny (see aot.core_pairs)
    let budgets: &[(usize, usize)] = &[(nc / 4, 192), (nc / 4, 128), (nc / 8, 96)];
    let align = 16; // slrd split grid — must match aot.core_pairs exactly
    let mut table = Table::new(&["cache/layer", "r", "method", "split", "ppl"]);
    let mut records = Vec::new();
    for &(r, latent_budget) in budgets {
        let cache = 2 * r * cfg.n_heads + latent_budget;
        // J-LRD point
        let var_j = Variant::EliteKv { r, d_ckv: latent_budget };
        let (runner, params, _) = ctx.converted(cfg_name, &var_j, "ropelite")?;
        let rep = ctx.evaluate(&runner, &params)?;
        table.row(vec![
            cache.to_string(), r.to_string(), "J-LRD".into(), "-".into(),
            report::fmt_f(rep.ppl, 3),
        ]);
        records.push(Json::obj(vec![
            ("cache", Json::num(cache as f64)),
            ("r", Json::num(r as f64)),
            ("method", Json::str("jlrd")),
            ("ppl", Json::num(rep.ppl)),
        ]));
        // S-LRD splits (greedy-lite over three splits, paper §4.3.2)
        let mut best = f64::INFINITY;
        for frac in [0.25f64, 0.5, 0.75] {
            let ck = ((latent_budget as f64 * frac / align as f64).round()
                as usize * align).max(align);
            let cv = latent_budget.saturating_sub(ck);
            if cv < align {
                continue;
            }
            let var_s = Variant::Slrd { r, d_ck: ck, d_cv: cv };
            let Ok((runner, params, _)) =
                ctx.converted(cfg_name, &var_s, "ropelite")
            else {
                log::warn!("no artifact for {}; skipping", var_s.tag());
                continue;
            };
            let rep = ctx.evaluate(&runner, &params)?;
            best = best.min(rep.ppl);
            table.row(vec![
                cache.to_string(), r.to_string(), "S-LRD".into(),
                format!("{ck}/{cv}"), report::fmt_f(rep.ppl, 3),
            ]);
            records.push(Json::obj(vec![
                ("cache", Json::num(cache as f64)),
                ("r", Json::num(r as f64)),
                ("method", Json::str("slrd")),
                ("d_ck", Json::num(ck as f64)),
                ("d_cv", Json::num(cv as f64)),
                ("ppl", Json::num(rep.ppl)),
            ]));
        }
    }
    table.print(&format!("Figure 5 ({cfg_name}): S-LRD vs J-LRD"));
    let json = Json::obj(vec![
        ("experiment", Json::str("fig5")),
        ("config", Json::str(cfg_name)),
        ("rows", Json::Arr(records)),
    ]);
    report::write_json(&ctx.results, &format!("fig5_{cfg_name}"), &json)?;
    report::append_report(
        &ctx.results,
        &format!("## Figure 5 ({cfg_name})\n\n{}", table.to_markdown()),
    )?;
    Ok(json)
}

/// Figure 6: probe-average recovery trend during uptraining, per ratio.
pub fn fig6(ctx: &ExperimentCtx, cfg_name: &str) -> Result<Json> {
    let cfg = ModelConfig::by_name(cfg_name).context("config")?;
    let grid: Vec<(String, Variant)> = table1_grid(&cfg)
        .into_iter()
        .filter(|(_, v)| matches!(v, Variant::EliteKv { .. }))
        .map(|(l, v)| (l.to_string(), v))
        .collect();
    let steps = ctx.opts.uptrain_steps;
    let eval_every = (steps / 4).max(1);
    let mut series = Vec::new();
    let mut table = Table::new(&["cache %", "tokens", "ppl"]);
    for (label, variant) in grid {
        log::info!("fig6 [{cfg_name}]: {label}% {}", variant.tag());
        let (runner, params, _) = ctx.converted(cfg_name, &variant, "ropelite")?;
        let (_state, rep) = ctx.uptrain(&runner, params, steps, eval_every)?;
        let mut points = Vec::new();
        for p in rep.points.iter().filter(|p| p.ppl.is_some()) {
            table.row(vec![
                label.clone(),
                p.tokens.to_string(),
                report::fmt_f(p.ppl.unwrap(), 3),
            ]);
            points.push(Json::obj(vec![
                ("tokens", Json::num(p.tokens as f64)),
                ("ppl", Json::num(p.ppl.unwrap())),
            ]));
        }
        series.push(Json::obj(vec![
            ("cache", Json::str(label.as_str())),
            ("variant", Json::str(&variant.tag())),
            ("points", Json::Arr(points)),
        ]));
    }
    table.print(&format!("Figure 6 ({cfg_name}): recovery during uptraining"));
    let json = Json::obj(vec![
        ("experiment", Json::str("fig6")),
        ("config", Json::str(cfg_name)),
        ("series", Json::Arr(series)),
    ]);
    report::write_json(&ctx.results, &format!("fig6_{cfg_name}"), &json)?;
    Ok(json)
}

/// Figure 7: relative performance loss across model scales.
pub fn fig7(ctx: &ExperimentCtx, cfg_names: &[&str]) -> Result<Json> {
    let mut table = Table::new(&["model", "cache %", "rel. avg loss %"]);
    let mut records = Vec::new();
    for &cfg_name in cfg_names {
        let cfg = ModelConfig::by_name(cfg_name).context("config")?;
        // baseline score
        let (runner, params, _) =
            ctx.converted(cfg_name, &Variant::Mha, "ropelite")?;
        let base = ctx.evaluate(&runner, &params)?;
        let nc = cfg.n_chunks();
        for (label, r, frac) in [
            ("50.0", nc / 2, 0.5f64),
            ("25.0", nc / 4, 0.25),
            ("12.5", nc / 8, 0.125),
        ] {
            let rot = 2 * r * cfg.n_heads;
            let align = convert::allocation::alignment(&cfg);
            let target = frac * cfg.kv_elems_per_token() as f64 - rot as f64;
            let d_ckv = ((target / align as f64).round() as usize * align)
                .max(align);
            let variant = Variant::EliteKv { r, d_ckv };
            log::info!("fig7 [{cfg_name}]: {label}% {}", variant.tag());
            let (runner, params, _) =
                ctx.converted(cfg_name, &variant, "ropelite")?;
            let (state, _rep) =
                ctx.uptrain(&runner, params, ctx.opts.uptrain_steps, 0)?;
            let rep = ctx.evaluate(&runner, &state.params)?;
            let rel_loss = (base.scores.average - rep.scores.average)
                / base.scores.average.max(1e-9);
            table.row(vec![
                cfg_name.to_string(),
                label.to_string(),
                report::fmt_pct(rel_loss),
            ]);
            records.push(Json::obj(vec![
                ("model", Json::str(cfg_name)),
                ("cache", Json::str(label)),
                ("base_avg", Json::num(base.scores.average)),
                ("avg", Json::num(rep.scores.average)),
                ("rel_loss", Json::num(rel_loss)),
            ]));
        }
    }
    table.print("Figure 7: relative loss across model scales");
    let json = Json::obj(vec![
        ("experiment", Json::str("fig7")),
        ("rows", Json::Arr(records)),
    ]);
    report::write_json(&ctx.results, "fig7", &json)?;
    report::append_report(
        &ctx.results,
        &format!("## Figure 7\n\n{}", table.to_markdown()),
    )?;
    Ok(json)
}

/// Serving benchmark: throughput/latency/cache bytes per variant — the
/// systems-level consequence of cache compression.
pub fn serve_bench(
    ctx: &ExperimentCtx,
    cfg_name: &str,
    n_requests: usize,
) -> Result<Json> {
    let cfg = ModelConfig::by_name(cfg_name).context("config")?;
    let nc = cfg.n_chunks();
    let variants = vec![
        Variant::Mha,
        Variant::Gqa { n_kv_heads: cfg.n_heads / 4 },
        Variant::EliteKv {
            r: nc / 4,
            d_ckv: {
                let align = convert::allocation::alignment(&cfg);
                let t = 0.25 * cfg.kv_elems_per_token() as f64
                    - (2 * (nc / 4) * cfg.n_heads) as f64;
                ((t / align as f64).round() as usize * align).max(align)
            },
        },
    ];
    let mut table = Table::new(&[
        "variant", "cache %", "tok/s", "p50 latency ms", "p99 latency ms",
        "peak cache KiB",
    ]);
    let mut records = Vec::new();
    for variant in variants {
        log::info!("serve_bench [{cfg_name}]: {}", variant.tag());
        let (runner, params, _) = ctx.converted(cfg_name, &variant, "ropelite")?;
        let ratio = variant.cache_ratio(&cfg);
        let backend = crate::runtime::PjrtBackend::new(runner, params);
        let mut server = InferenceServer::new(Box::new(backend), 64 << 20)?;
        // probe-like prompts as the workload
        let gen = CorpusGen::new(cfg.vocab, 1);
        let probes = ProbeSet::generate(&gen, n_requests.div_ceil(6), 1234);
        let t0 = std::time::Instant::now();
        for (i, item) in probes.items.iter().take(n_requests).enumerate() {
            server.submit(Request::new(
                i as u64,
                item.prompt.clone(),
                GenParams { max_new_tokens: 16, ..Default::default() },
            ))?;
        }
        let responses = server.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
        let mut lat: Vec<f64> =
            responses.iter().map(|r| r.latency * 1e3).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = crate::util::stats::percentile(&lat, 0.5);
        let p99 = crate::util::stats::percentile(&lat, 0.99);
        table.row(vec![
            variant.tag(),
            report::fmt_pct(ratio),
            report::fmt_f(toks as f64 / wall, 1),
            report::fmt_f(p50, 1),
            report::fmt_f(p99, 1),
            format!("{}", server.stats.peak_cache_bytes / 1024),
        ]);
        records.push(Json::obj(vec![
            ("variant", Json::str(&variant.tag())),
            ("cache_ratio", Json::num(ratio)),
            ("tokens_per_s", Json::num(toks as f64 / wall)),
            ("p50_ms", Json::num(p50)),
            ("p99_ms", Json::num(p99)),
            ("peak_cache_bytes",
             Json::num(server.stats.peak_cache_bytes as f64)),
            ("decode_steps", Json::num(server.stats.decode_steps as f64)),
            ("completed", Json::num(server.stats.completed as f64)),
        ]));
    }
    table.print(&format!("Serving benchmark ({cfg_name})"));
    let json = Json::obj(vec![
        ("experiment", Json::str("serve")),
        ("config", Json::str(cfg_name)),
        ("rows", Json::Arr(records)),
    ]);
    report::write_json(&ctx.results, &format!("serve_{cfg_name}"), &json)?;
    report::append_report(
        &ctx.results,
        &format!("## Serving ({cfg_name})\n\n{}", table.to_markdown()),
    )?;
    Ok(json)
}
