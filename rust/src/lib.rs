//! # EliteKV — scalable KV cache compression
//!
//! Reproduction of *"EliteKV: Scalable KV Cache Compression via RoPE
//! Frequency Selection and Joint Low-Rank Projection"* (2025) as a
//! Rust-first stack. This crate is the self-contained coordinator that
//! pretrains, searches (RoPElite, Algorithm 1), converts (J-LRD / S-LRD /
//! GQA weight surgery with the in-repo Jacobi SVD), uptrains, serves, and
//! benchmarks the models.
//!
//! Two serving engines sit behind one [`runtime::Backend`] trait:
//!
//! * the **native** backend ([`native`]) — the full EliteKV forward path
//!   in pure Rust, reading the compressed latent cache directly; zero
//!   Python, zero artifacts, always available;
//! * the **PJRT** backend (`--features pjrt`) — AOT-lowered HLO artifacts
//!   executed through the PJRT CPU client, for training and parity runs.
//!
//! Python never runs on the request path either way.
//!
//! Module map (see DESIGN.md §4 at the repository root for the full
//! system inventory):
//!
//! * [`util`]    — PRNG, JSON, statistics, thread pool, property testing
//! * [`tensor`]  — minimal CPU f32 tensor with the ops conversion needs
//! * [`linalg`]  — one-sided Jacobi SVD (substrate for J-LRD / S-LRD)
//! * [`io`]      — checkpoint binary format + artifact manifests
//! * [`config`]  — model family / variant / run configuration
//! * [`rope`]    — host-side RoPE math (frequency ladders, elite thetas)
//! * [`data`]    — synthetic corpus generator, probe tasks, tokenizer
//! * [`runtime`] — the `Backend` trait + PJRT engine (feature `pjrt`)
//! * [`native`]  — pure-Rust decode backend over the latent KV cache
//! * [`convert`] — GQA / EliteKV / S-LRD weight surgery + dim allocation
//! * [`search`]  — RoPElite greedy driver + Uniform/Contribution baselines
//! * [`train`]   — training loops (feature `pjrt`) + backend-generic scorer
//! * [`kvcache`] — paged KV-cache manager with per-variant slab layouts
//! * [`coordinator`] — serving: router, continuous batcher, scheduler
//! * [`bench`]   — experiment harness (paper tables/figures + native perf)
//! * [`analysis`] — `elitekv lint`: Rust lexer + project-contract rules

// Doc coverage is warned on crate-wide and enforced (the CI docs job
// runs rustdoc with `-D warnings`) for the serving surface this repo is
// growing: `kvcache`, `coordinator`, `runtime`, `native`, and `bench`.
// The offline crate substitutes and pipeline-internal modules carry
// targeted allows below — tracked doc debt on non-serving code, lifted
// module by module as those layers get their own doc passes.
#![warn(missing_docs)]

pub mod analysis;
pub mod bench;
#[allow(missing_docs)]
pub mod cli;
#[allow(missing_docs)]
pub mod config;
#[allow(missing_docs)]
pub mod convert;
pub mod coordinator;
#[allow(missing_docs)]
pub mod data;
#[allow(missing_docs)]
pub mod io;
pub mod kvcache;
#[allow(missing_docs)]
pub mod linalg;
pub mod native;
#[allow(missing_docs)]
pub mod rope;
pub mod runtime;
#[allow(missing_docs)]
pub mod search;
#[allow(missing_docs)]
pub mod tensor;
#[allow(missing_docs)]
pub mod train;
#[allow(missing_docs)]
pub mod util;

/// Repository-relative default artifact directory.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Repository-relative default results directory for experiments.
pub const RESULTS_DIR: &str = "results";
