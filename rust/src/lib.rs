//! # EliteKV — scalable KV cache compression
//!
//! Reproduction of *"EliteKV: Scalable KV Cache Compression via RoPE
//! Frequency Selection and Joint Low-Rank Projection"* (2025) as a
//! three-layer Rust + JAX + Pallas stack. This crate is Layer 3: the
//! self-contained coordinator that pretrains, searches (RoPElite,
//! Algorithm 1), converts (J-LRD / S-LRD / GQA weight surgery with the
//! in-repo Jacobi SVD), uptrains, serves, and benchmarks the models —
//! executing AOT-lowered HLO artifacts through the PJRT CPU client.
//! Python never runs on the request path.
//!
//! Module map (see DESIGN.md §4 for the full system inventory):
//!
//! * [`util`]    — PRNG, JSON, statistics, thread pool, property testing
//! * [`tensor`]  — minimal CPU f32 tensor with the ops conversion needs
//! * [`linalg`]  — one-sided Jacobi SVD (substrate for J-LRD / S-LRD)
//! * [`io`]      — checkpoint binary format + artifact manifests
//! * [`config`]  — model family / variant / run configuration
//! * [`rope`]    — host-side RoPE math (frequency ladders, elite thetas)
//! * [`data`]    — synthetic corpus generator, probe tasks, tokenizer
//! * [`runtime`] — PJRT engine: load HLO text, compile, execute
//! * [`convert`] — GQA / EliteKV / S-LRD weight surgery + dim allocation
//! * [`search`]  — RoPElite greedy driver + Uniform/Contribution baselines
//! * [`train`]   — pretraining / uptraining loops with metrics
//! * [`kvcache`] — paged KV-cache manager with per-variant layouts
//! * [`coordinator`] — serving: router, continuous batcher, scheduler
//! * [`bench`]   — experiment harness regenerating every paper table/figure

pub mod bench;
pub mod cli;
pub mod config;
pub mod convert;
pub mod coordinator;
pub mod data;
pub mod io;
pub mod kvcache;
pub mod linalg;
pub mod rope;
pub mod runtime;
pub mod search;
pub mod tensor;
pub mod train;
pub mod util;

/// Repository-relative default artifact directory.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Repository-relative default results directory for experiments.
pub const RESULTS_DIR: &str = "results";
