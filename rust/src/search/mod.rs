//! RoPElite search (paper §3.1, Algorithm 1) and the §4.3.1 baselines.
//!
//! The greedy driver runs in Rust; the vectorized inner step (distances
//! for every head x candidate chunk in one call — Appendix B's
//! single-forward-pass parallelism via the incremental-delta trick, see
//! DESIGN.md §6) executes as the `ropelite_delta` HLO artifact and is
//! therefore gated on `--features pjrt`. The Uniform baseline is pure
//! Rust and doubles as the native backend's default selection.

pub mod ropelite;

pub use ropelite::uniform_selection;

#[cfg(feature = "pjrt")]
pub use ropelite::{contribution_selection, ropelite_search};
