//! Greedy elite-chunk search + Uniform / Contribution baselines.
//!
//! The greedy driver and the Contribution baseline run the `capture_qk` /
//! `ropelite_delta` artifacts and therefore need `--features pjrt`; the
//! Uniform baseline is pure Rust and always available (the native
//! backend's default selection).

use crate::config::ModelConfig;
use crate::convert::EliteSelection;

#[cfg(feature = "pjrt")]
use anyhow::{bail, Context, Result};
#[cfg(feature = "pjrt")]
use crate::runtime::{HostTensor, ModelRunner};

/// Capture pre-RoPE q/k on a calibration stream drawn from `gen`.
/// Returns per-layer tensors sliced out of the stacked capture.
#[cfg(feature = "pjrt")]
pub fn capture_calibration(
    runner: &ModelRunner,
    params: &[HostTensor],
    gen: &mut crate::data::CorpusGen,
) -> Result<(Vec<HostTensor>, Vec<HostTensor>)> {
    let f = runner.manifest.function("capture_qk")?;
    let tok = &f.inputs[f.input_index("tokens").context("tokens")?];
    let (b, t) = (tok.shape[0], tok.shape[1]);
    let tokens: Vec<i32> = gen.stream(b * t).iter().map(|&x| x as i32).collect();
    let (q, k) = runner.capture_qk(params, &tokens)?;
    let cfg = &runner.manifest.config;
    Ok((split_layers(&q, cfg)?, split_layers(&k, cfg)?))
}

#[cfg(feature = "pjrt")]
fn split_layers(x: &HostTensor, cfg: &ModelConfig) -> Result<Vec<HostTensor>> {
    let shape = x.shape().to_vec();
    if shape.len() != 5 || shape[0] != cfg.n_layers {
        bail!("expected [L,B,T,nh,dh] capture, got {shape:?}");
    }
    let per = shape[1..].iter().product::<usize>();
    let data = x.as_f32()?;
    Ok((0..cfg.n_layers)
        .map(|l| {
            HostTensor::F32(
                data[l * per..(l + 1) * per].to_vec(),
                shape[1..].to_vec(),
            )
        })
        .collect())
}

/// Algorithm 1: greedy top-r elite chunks per head, per layer.
///
/// For each layer, r iterations of (delta artifact -> per-head argmin ->
/// mask update). All heads of a layer advance in lock-step within one
/// artifact call; layers are independent.
#[cfg(feature = "pjrt")]
pub fn ropelite_search(
    runner: &ModelRunner,
    params: &[HostTensor],
    gen: &mut crate::data::CorpusGen,
    r: usize,
) -> Result<EliteSelection> {
    let cfg = runner.manifest.config.clone();
    let (nc, nh) = (cfg.n_chunks(), cfg.n_heads);
    if r == 0 || r > nc {
        bail!("r={r} out of range (1..={nc})");
    }
    let (qs, ks) = capture_calibration(runner, params, gen)?;
    let mut chunks = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        let mut mask = vec![0.0f32; nh * nc];
        let mut picks: Vec<Vec<usize>> = vec![Vec::with_capacity(r); nh];
        for _i in 0..r {
            let m = HostTensor::F32(mask.clone(), vec![nh, nc]);
            let dist = runner.ropelite_delta(&qs[l], &ks[l], &m)?;
            let d = dist.as_f32()?;
            for h in 0..nh {
                let row = &d[h * nc..(h + 1) * nc];
                let (j, _) = row
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap();
                picks[h].push(j);
                mask[h * nc + j] = 1.0;
            }
        }
        chunks.push(picks);
        log::info!("ropelite: layer {l} done");
    }
    let sel = EliteSelection { chunks };
    sel.validate(&cfg)?;
    Ok(sel)
}

/// `Uniform` baseline: the same r evenly spaced chunks for every head.
pub fn uniform_selection(cfg: &ModelConfig, r: usize) -> EliteSelection {
    let row = crate::rope::uniform_chunks(cfg.n_chunks(), r);
    EliteSelection {
        chunks: vec![vec![row; cfg.n_heads]; cfg.n_layers],
    }
}

/// `Contribution` baseline (Hong et al. 2024): top-r chunks per head by
/// the L2-norm score-contribution measure, computed by the contribution
/// artifact over the same calibration capture.
#[cfg(feature = "pjrt")]
pub fn contribution_selection(
    runner: &ModelRunner,
    params: &[HostTensor],
    gen: &mut crate::data::CorpusGen,
    r: usize,
) -> Result<EliteSelection> {
    let cfg = runner.manifest.config.clone();
    let f = runner.manifest.function("capture_qk")?;
    let tok = &f.inputs[f.input_index("tokens").context("tokens")?];
    let (b, t) = (tok.shape[0], tok.shape[1]);
    let tokens: Vec<i32> = gen.stream(b * t).iter().map(|&x| x as i32).collect();
    let (q, k) = runner.capture_qk(params, &tokens)?;
    let scores = runner.contribution(&q, &k)?;
    let s = scores.as_f32()?;
    let (nc, nh) = (cfg.n_chunks(), cfg.n_heads);
    let chunks = (0..cfg.n_layers)
        .map(|l| {
            (0..nh)
                .map(|h| {
                    let row = &s[(l * nh + h) * nc..(l * nh + h + 1) * nc];
                    let mut idx: Vec<usize> = (0..nc).collect();
                    idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
                    idx.truncate(r);
                    idx
                })
                .collect()
        })
        .collect();
    let sel = EliteSelection { chunks };
    sel.validate(&cfg)?;
    Ok(sel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_selection_shape_and_spread() {
        let cfg = ModelConfig::tiny();
        let s = uniform_selection(&cfg, 4);
        s.validate(&cfg).unwrap();
        assert_eq!(s.chunks[0][0], vec![0, 5, 10, 15]);
        // identical across heads and layers (that's the point of Uniform)
        assert_eq!(s.chunks[0][0], s.chunks[3][7]);
    }

    #[test]
    fn uniform_r1_and_full() {
        let cfg = ModelConfig::tiny();
        assert_eq!(uniform_selection(&cfg, 1).chunks[0][0], vec![0]);
        let full = uniform_selection(&cfg, cfg.n_chunks());
        assert_eq!(full.chunks[0][0].len(), cfg.n_chunks());
    }
}
