//! Numerical linear algebra substrate: the SVD backing J-LRD / S-LRD
//! weight surgery (paper §2.3, §3.2).

pub mod svd;

pub use svd::{svd, svd_truncate, Svd};
