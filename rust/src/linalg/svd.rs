//! One-sided Jacobi SVD (Hestenes method) in f64, with rank truncation.
//!
//! Sizes here are small (the converter decomposes [d, ~2*d] projection
//! blocks, d <= 768), so the O(n^2) sweep cost is acceptable and Jacobi
//! gives high relative accuracy — important because the paper's exactness
//! invariant (full-rank J-LRD == RoPElite) is validated to f32 noise.

use crate::tensor::Tensor;

/// Thin SVD result: `a ≈ u * diag(s) * vt` with descending singular values.
pub struct Svd {
    /// [m, k] left singular vectors (k = min(m, n))
    pub u: Tensor,
    /// [k] singular values, descending
    pub s: Vec<f32>,
    /// [k, n] right singular vectors (transposed)
    pub vt: Tensor,
}

const MAX_SWEEPS: usize = 60;
const TOL: f64 = 1e-12;

/// Compute the thin SVD of a 2-D tensor via one-sided Jacobi on A (or on
/// A^T when m < n, transposing the result back).
pub fn svd(a: &Tensor) -> Svd {
    assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape[0], a.shape[1]);
    if m < n {
        // svd(A^T) = (V, S, U^T) -> swap
        let r = svd(&a.t());
        return Svd { u: r.vt.t(), s: r.s, vt: r.u.t() };
    }
    // Work on columns of A (m >= n): orthogonalize column pairs.
    let k = n;
    // Column-major working copy in f64 for accumulation accuracy.
    let mut cols: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| a.at2(i, j) as f64).collect())
        .collect();
    let mut v = vec![vec![0.0f64; n]; n];
    for (j, row) in v.iter_mut().enumerate() {
        row[j] = 1.0;
    }

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    app += cols[p][i] * cols[p][i];
                    aqq += cols[q][i] * cols[q][i];
                    apq += cols[p][i] * cols[q][i];
                }
                if apq.abs() <= TOL * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p, q) off-diagonal of A^T A.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let (xp, xq) = (cols[p][i], cols[q][i]);
                    cols[p][i] = c * xp - s * xq;
                    cols[q][i] = s * xp + c * xq;
                }
                for i in 0..n {
                    let (vp, vq) = (v[p][i], v[q][i]);
                    v[p][i] = c * vp - s * vq;
                    v[q][i] = s * vp + c * vq;
                }
            }
        }
        if off < TOL {
            break;
        }
    }

    // Singular values = column norms; U = normalized columns.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = cols
        .iter()
        .map(|c| c.iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut u = Tensor::zeros(vec![m, k]);
    let mut s_out = Vec::with_capacity(k);
    let mut vt = Tensor::zeros(vec![k, n]);
    for (rank, &ci) in order.iter().enumerate() {
        let nrm = norms[ci];
        s_out.push(nrm as f32);
        if nrm > 1e-300 {
            for i in 0..m {
                u.set2(i, rank, (cols[ci][i] / nrm) as f32);
            }
        } else if rank < m {
            u.set2(rank, rank, 1.0); // arbitrary unit vector for null dims
        }
        for j in 0..n {
            vt.set2(rank, j, v[ci][j] as f32);
        }
    }
    Svd { u, s: s_out, vt }
}

/// Rank-r truncation per the paper (§2.3): A = U[:, :r],
/// B = diag(S[:r]) Vt[:r, :]. Returns (A [m,r], B [r,n]).
pub fn svd_truncate(a: &Tensor, rank: usize) -> (Tensor, Tensor) {
    let d = svd(a);
    let (m, n) = (a.shape[0], a.shape[1]);
    let k = d.s.len();
    let r = rank.min(k);
    let mut au = Tensor::zeros(vec![m, r]);
    for i in 0..m {
        for j in 0..r {
            au.set2(i, j, d.u.at2(i, j));
        }
    }
    let mut b = Tensor::zeros(vec![r, n]);
    for i in 0..r {
        for j in 0..n {
            b.set2(i, j, d.s[i] * d.vt.at2(i, j));
        }
    }
    (au, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn reconstruct(d: &Svd) -> Tensor {
        let k = d.s.len();
        let mut sv = Tensor::zeros(vec![k, d.vt.shape[1]]);
        for i in 0..k {
            for j in 0..d.vt.shape[1] {
                sv.set2(i, j, d.s[i] * d.vt.at2(i, j));
            }
        }
        d.u.matmul(&sv)
    }

    #[test]
    fn reconstructs_tall_matrix() {
        let mut rng = Pcg64::seeded(10);
        let a = Tensor::randn(vec![24, 9], &mut rng);
        let d = svd(&a);
        assert!(a.max_abs_diff(&reconstruct(&d)) < 1e-4);
        // descending singular values
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
    }

    #[test]
    fn reconstructs_wide_matrix() {
        let mut rng = Pcg64::seeded(11);
        let a = Tensor::randn(vec![7, 31], &mut rng);
        let d = svd(&a);
        assert!(a.max_abs_diff(&reconstruct(&d)) < 1e-4);
    }

    #[test]
    fn u_columns_orthonormal() {
        let mut rng = Pcg64::seeded(12);
        let a = Tensor::randn(vec![16, 8], &mut rng);
        let d = svd(&a);
        let gram = d.u.t().matmul(&d.u);
        for i in 0..8 {
            for j in 0..8 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((gram.at2(i, j) - want).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn matches_known_diagonal() {
        let a = Tensor::new(vec![3, 3],
                            vec![3.0, 0., 0., 0., 1.0, 0., 0., 0., 2.0]);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-5);
        assert!((d.s[1] - 2.0).abs() < 1e-5);
        assert!((d.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn truncation_error_matches_tail_energy() {
        // Eckart–Young: ||A - A_r||_F^2 == sum of squared tail singulars.
        let mut rng = Pcg64::seeded(13);
        let a = Tensor::randn(vec![20, 12], &mut rng);
        let d = svd(&a);
        for r in [2usize, 5, 9] {
            let (u, b) = svd_truncate(&a, r);
            let err = a.sub(&u.matmul(&b)).fro();
            let tail: f64 = d.s[r..]
                .iter()
                .map(|&s| (s as f64) * (s as f64))
                .sum::<f64>()
                .sqrt();
            assert!((err - tail).abs() < 1e-3, "r={r}: {err} vs {tail}");
        }
    }

    #[test]
    fn full_rank_truncation_is_exact() {
        let mut rng = Pcg64::seeded(14);
        let a = Tensor::randn(vec![10, 18], &mut rng);
        let (u, b) = svd_truncate(&a, 10);
        assert!(a.max_abs_diff(&u.matmul(&b)) < 1e-4);
    }

    #[test]
    fn rank_deficient_input() {
        // rank-2 matrix: outer products
        let mut rng = Pcg64::seeded(15);
        let x = Tensor::randn(vec![12, 2], &mut rng);
        let y = Tensor::randn(vec![2, 9], &mut rng);
        let a = x.matmul(&y);
        let d = svd(&a);
        assert!(d.s[2] < 1e-4, "third singular value should vanish: {:?}",
                &d.s[..4]);
        let (u, b) = svd_truncate(&a, 2);
        assert!(a.max_abs_diff(&u.matmul(&b)) < 1e-3);
    }
}
