//! Data pipeline substrate: the synthetic pretraining corpus (RefinedWeb
//! stand-in), the probe-task battery (lm-eval-harness stand-in), and a
//! byte-pair tokenizer for real-text ingestion.
//!
//! DESIGN.md §2 documents the substitution: the corpus is a procedural
//! language with genuine positional structure (copy/reversal/recall spans,
//! arithmetic, Zipfian template grammar, a persistent fact table), so RoPE
//! heads must learn distinct frequency roles — the property RoPElite
//! search and uptraining exercise.

pub mod corpus;
pub mod probes;
pub mod tokenizer;

pub use corpus::{Batch, CorpusGen, SPECIAL_TOKENS};
pub use probes::{ProbeKind, ProbeSet};
pub use tokenizer::Bpe;
