//! Probe-task battery: the repository's stand-in for the paper's 8-task
//! lm-evaluation-harness suite (Table 1/2 columns).
//!
//! Each probe is a (prompt, expected-continuation) pair drawn from the
//! same distributions the corpus pretrains on; the score of a task is
//! exact-match accuracy of greedy decoding, and `average` mirrors the
//! paper's "Avg." column.

use crate::data::corpus::{self, CorpusGen, ARITH, BOS, COPY, EQ, FACT, PLUS,
                          REV, SEP, SORT};
use crate::util::Pcg64;

/// The six capability probes (paper: BoolQ/HellaSwag/... analog).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ProbeKind {
    Copy,
    Reverse,
    Recall,
    Induction,
    Arith,
    Sort,
}

pub const ALL_PROBES: [ProbeKind; 6] = [
    ProbeKind::Copy,
    ProbeKind::Reverse,
    ProbeKind::Recall,
    ProbeKind::Induction,
    ProbeKind::Arith,
    ProbeKind::Sort,
];

impl ProbeKind {
    pub fn name(&self) -> &'static str {
        match self {
            ProbeKind::Copy => "copy",
            ProbeKind::Reverse => "reverse",
            ProbeKind::Recall => "recall",
            ProbeKind::Induction => "induction",
            ProbeKind::Arith => "arith",
            ProbeKind::Sort => "sort",
        }
    }
}

/// One evaluation item: greedy-decode `answer.len()` tokens after `prompt`
/// and compare exactly.
#[derive(Clone, Debug)]
pub struct ProbeItem {
    pub kind: ProbeKind,
    pub prompt: Vec<u32>,
    pub answer: Vec<u32>,
}

/// A deterministic evaluation set over all probe kinds.
pub struct ProbeSet {
    pub items: Vec<ProbeItem>,
}

impl ProbeSet {
    /// `n_per_task` items per probe kind, drawn against `gen`'s world.
    /// The probe stream is independent of the training stream but shares
    /// the fact table.
    pub fn generate(gen: &CorpusGen, n_per_task: usize, seed: u64) -> ProbeSet {
        let mut rng = Pcg64::new(seed, 0x9806e);
        let mut items = Vec::new();
        for kind in ALL_PROBES {
            for _ in 0..n_per_task {
                items.push(make_item(gen, kind, &mut rng));
            }
        }
        ProbeSet { items }
    }

    /// Aggregate exact-match accuracy per task given per-item pass flags
    /// (same order as `items`).
    pub fn score(&self, passed: &[bool]) -> Scores {
        assert_eq!(passed.len(), self.items.len());
        let mut per = std::collections::BTreeMap::new();
        for (item, &ok) in self.items.iter().zip(passed) {
            let e = per.entry(item.kind.name()).or_insert((0usize, 0usize));
            e.1 += 1;
            if ok {
                e.0 += 1;
            }
        }
        let task_acc: Vec<(String, f64)> = per
            .iter()
            .map(|(k, (hit, tot))| (k.to_string(), *hit as f64 / *tot as f64))
            .collect();
        let average =
            task_acc.iter().map(|(_, a)| a).sum::<f64>() / task_acc.len() as f64;
        Scores { task_acc, average }
    }

    /// Longest answer length (the decode budget the scorer needs).
    pub fn max_answer_len(&self) -> usize {
        self.items.iter().map(|i| i.answer.len()).max().unwrap_or(0)
    }
}

/// Per-task accuracies + their mean (the paper's Avg. column).
#[derive(Clone, Debug)]
pub struct Scores {
    pub task_acc: Vec<(String, f64)>,
    pub average: f64,
}

fn word(rng: &mut Pcg64, gen: &CorpusGen) -> u32 {
    // non-entity words, mirroring corpus sampler constraints
    let lo = gen.n_entities();
    let n_words = gen.vocab - corpus::WORD_BASE as usize;
    corpus::WORD_BASE + rng.range(lo, n_words) as u32
}

fn make_item(gen: &CorpusGen, kind: ProbeKind, rng: &mut Pcg64) -> ProbeItem {
    match kind {
        ProbeKind::Copy => {
            let len = rng.range(2, 7);
            let span: Vec<u32> = (0..len).map(|_| word(rng, gen)).collect();
            let mut prompt = vec![BOS, COPY];
            prompt.extend(&span);
            prompt.push(SEP);
            ProbeItem { kind, prompt, answer: span }
        }
        ProbeKind::Reverse => {
            let len = rng.range(2, 6);
            let span: Vec<u32> = (0..len).map(|_| word(rng, gen)).collect();
            let mut prompt = vec![BOS, REV];
            prompt.extend(&span);
            prompt.push(SEP);
            ProbeItem {
                kind,
                prompt,
                answer: span.iter().rev().copied().collect(),
            }
        }
        ProbeKind::Recall => {
            let e = rng.range(0, gen.n_entities());
            let prompt = vec![BOS, FACT, gen.entity_token(e), SEP];
            ProbeItem { kind, prompt, answer: vec![gen.fact_object(e)] }
        }
        ProbeKind::Induction => {
            // x y ... filler ... x -> y (classic induction-head probe);
            // the pattern pair uses distinct words so the answer is unique.
            let x = word(rng, gen);
            let mut y = word(rng, gen);
            while y == x {
                y = word(rng, gen);
            }
            let mut prompt = vec![BOS, x, y];
            for _ in 0..rng.range(2, 6) {
                let mut f = word(rng, gen);
                while f == x || f == y {
                    f = word(rng, gen);
                }
                prompt.push(f);
            }
            prompt.push(x);
            ProbeItem { kind, prompt, answer: vec![y] }
        }
        ProbeKind::Arith => {
            let a = rng.below(10) as u32;
            let b = rng.below(10) as u32;
            let prompt =
                vec![BOS, ARITH, corpus::digit(a), PLUS, corpus::digit(b), EQ];
            ProbeItem { kind, prompt, answer: vec![corpus::digit((a + b) % 10)] }
        }
        ProbeKind::Sort => {
            let len = rng.range(2, 6);
            let mut ds: Vec<u32> = (0..len).map(|_| rng.below(10) as u32).collect();
            let mut prompt = vec![BOS, SORT];
            prompt.extend(ds.iter().map(|&d| corpus::digit(d)));
            prompt.push(SEP);
            ds.sort_unstable();
            ProbeItem {
                kind,
                prompt,
                answer: ds.iter().map(|&d| corpus::digit(d)).collect(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> CorpusGen {
        CorpusGen::new(512, 7)
    }

    #[test]
    fn generates_all_kinds() {
        let g = gen();
        let set = ProbeSet::generate(&g, 5, 1);
        assert_eq!(set.items.len(), 30);
        for kind in ALL_PROBES {
            assert_eq!(set.items.iter().filter(|i| i.kind == kind).count(), 5);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = gen();
        let a = ProbeSet::generate(&g, 4, 9);
        let b = ProbeSet::generate(&g, 4, 9);
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn recall_answers_match_world() {
        let g = gen();
        let set = ProbeSet::generate(&g, 20, 2);
        for item in set.items.iter().filter(|i| i.kind == ProbeKind::Recall) {
            let e = (item.prompt[2] - corpus::WORD_BASE) as usize;
            assert_eq!(item.answer, vec![g.fact_object(e)]);
        }
    }

    #[test]
    fn induction_answer_is_second_of_pair() {
        let g = gen();
        let set = ProbeSet::generate(&g, 20, 3);
        for item in set.items.iter().filter(|i| i.kind == ProbeKind::Induction) {
            let x = item.prompt[1];
            assert_eq!(*item.prompt.last().unwrap(), x);
            assert_eq!(item.answer[0], item.prompt[2]);
        }
    }

    #[test]
    fn scoring_aggregates_correctly() {
        let g = gen();
        let set = ProbeSet::generate(&g, 2, 4);
        // pass exactly the first item of each pair
        let passed: Vec<bool> =
            set.items.iter().enumerate().map(|(i, _)| i % 2 == 0).collect();
        let s = set.score(&passed);
        assert_eq!(s.task_acc.len(), 6);
        for (_, acc) in &s.task_acc {
            assert!((acc - 0.5).abs() < 1e-9);
        }
        assert!((s.average - 0.5).abs() < 1e-9);
    }

    #[test]
    fn prompts_fit_serving_window() {
        let g = gen();
        let set = ProbeSet::generate(&g, 50, 5);
        for i in &set.items {
            assert!(i.prompt.len() + i.answer.len() <= 64);
        }
    }
}
