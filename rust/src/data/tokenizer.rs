//! Byte-pair-encoding tokenizer substrate for real-text ingestion.
//!
//! The synthetic corpus emits token ids directly; this BPE exists for the
//! quickstart path where a user feeds plain text, and as the data-pipeline
//! substrate the paper's ecosystem assumes (RefinedWeb is tokenized text).
//! Greedy merge training over bytes, longest-match encoding.

use std::collections::HashMap;

/// A trained byte-level BPE vocabulary.
#[derive(Clone, Debug)]
pub struct Bpe {
    /// token id -> byte sequence. ids 0..256 are raw bytes.
    pub pieces: Vec<Vec<u8>>,
    /// merge ranks: (left id, right id) -> merged id
    merges: HashMap<(u32, u32), u32>,
}

impl Bpe {
    /// Train `n_merges` merges on `text`.
    pub fn train(text: &str, n_merges: usize) -> Bpe {
        let mut pieces: Vec<Vec<u8>> = (0..256u16).map(|b| vec![b as u8]).collect();
        let mut merges = HashMap::new();
        let mut seq: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        for _ in 0..n_merges {
            // count adjacent pairs
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            let Some((&pair, &count)) = counts
                .iter()
                .max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if count < 2 {
                break;
            }
            let new_id = pieces.len() as u32;
            let mut merged = pieces[pair.0 as usize].clone();
            merged.extend(&pieces[pair.1 as usize]);
            pieces.push(merged);
            merges.insert(pair, new_id);
            // apply the merge to the working sequence
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
        }
        Bpe { pieces, merges }
    }

    pub fn vocab_size(&self) -> usize {
        self.pieces.len()
    }

    /// Encode text by iteratively applying the lowest-id (earliest-trained)
    /// applicable merge — the standard BPE encode order.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut seq: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        loop {
            let mut best: Option<(u32, usize)> = None; // (merged id, pos)
            for (i, w) in seq.windows(2).enumerate() {
                if let Some(&m) = self.merges.get(&(w[0], w[1])) {
                    if best.map(|(b, _)| m < b).unwrap_or(true) {
                        best = Some((m, i));
                    }
                }
            }
            let Some((m, _)) = best else { break };
            // apply this merge everywhere
            let pair = *self
                .merges
                .iter()
                .find(|(_, &v)| v == m)
                .map(|(k, _)| k)
                .unwrap();
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(m);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
        }
        seq
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            bytes.extend(&self.pieces[id as usize]);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "the quick brown fox jumps over the lazy dog. \
                          the quick brown fox jumps again and again. \
                          the lazy dog sleeps while the quick fox runs.";

    #[test]
    fn roundtrip_exact() {
        let bpe = Bpe::train(SAMPLE, 50);
        let ids = bpe.encode(SAMPLE);
        assert_eq!(bpe.decode(&ids), SAMPLE);
    }

    #[test]
    fn merges_compress() {
        let bpe = Bpe::train(SAMPLE, 50);
        let ids = bpe.encode(SAMPLE);
        assert!(ids.len() < SAMPLE.len(), "{} !< {}", ids.len(), SAMPLE.len());
        assert!(bpe.vocab_size() > 256);
    }

    #[test]
    fn handles_unseen_text() {
        let bpe = Bpe::train(SAMPLE, 30);
        let other = "zebra xylophone ðŸ¦“"; // bytes unseen in training
        let ids = bpe.encode(other);
        assert_eq!(bpe.decode(&ids), other);
    }

    #[test]
    fn zero_merges_is_byte_level() {
        let bpe = Bpe::train(SAMPLE, 0);
        assert_eq!(bpe.vocab_size(), 256);
        let ids = bpe.encode("abc");
        assert_eq!(ids, vec![97, 98, 99]);
    }

    #[test]
    fn trained_merge_used_in_encoding() {
        let text = "aaaaaaaaaa";
        let bpe = Bpe::train(text, 3);
        let ids = bpe.encode("aaaa");
        assert!(ids.len() < 4);
        assert_eq!(bpe.decode(&ids), "aaaa");
    }
}
