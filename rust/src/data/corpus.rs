//! Procedural pretraining corpus over a fixed token vocabulary.
//!
//! Token id map (stable across vocab sizes; words fill the remainder):
//!   0 PAD   1 BOS   2 EOS   3 SEP   4 COPY   5 REV   6 FACT  7 SORT
//!   8 ARITH 9 PLUS 10 EQ   11 Q    12..16 reserved
//!   16..26 digits 0-9
//!   26..vocab words (Zipf-distributed content vocabulary)
//!
//! Sentence kinds (mixture):
//!   grammar   — [w][w][w][w][w] template chains, Zipf draw (syntax analog)
//!   fact      — FACT e SEP o: persistent entity->object map (knowledge)
//!   copy      — COPY w.. SEP w..                (induction / long range)
//!   reverse   — REV  w.. SEP reversed(w..)
//!   sort      — SORT d.. SEP sorted(d..)
//!   arith     — ARITH a PLUS b EQ (a+b mod 10)
//!
//! Every probe task (probes.rs) draws from the same distributions, so
//! pretraining makes the probes learnable — mirroring how the paper's
//! benchmarks measure capabilities the base model was trained to have.

use crate::util::rng::{Pcg64, ZipfTable};

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const SEP: u32 = 3;
pub const COPY: u32 = 4;
pub const REV: u32 = 5;
pub const FACT: u32 = 6;
pub const SORT: u32 = 7;
pub const ARITH: u32 = 8;
pub const PLUS: u32 = 9;
pub const EQ: u32 = 10;
pub const Q: u32 = 11;
pub const DIGIT_BASE: u32 = 16;
pub const WORD_BASE: u32 = 26;

/// Number of reserved (non-word) token ids.
pub const SPECIAL_TOKENS: u32 = WORD_BASE;

pub fn digit(d: u32) -> u32 {
    debug_assert!(d < 10);
    DIGIT_BASE + d
}

/// One training batch in the layout train_step expects.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,  // [B*T]
    pub targets: Vec<i32>, // [B*T]
    pub mask: Vec<f32>,    // [B*T]
    pub batch: usize,
    pub seq: usize,
}

/// Deterministic corpus generator. Same (vocab, seed) -> same language:
/// the fact table, word frequencies, and sentence stream all derive from
/// the seed, so pretraining / uptraining / eval share one world.
pub struct CorpusGen {
    pub vocab: usize,
    n_words: usize,
    zipf: ZipfTable,
    /// entity word -> object word (the persistent "world knowledge").
    facts: Vec<u32>,
    n_entities: usize,
    rng: Pcg64,
}

impl CorpusGen {
    pub fn new(vocab: usize, seed: u64) -> CorpusGen {
        assert!(vocab > WORD_BASE as usize + 32, "vocab too small");
        let n_words = vocab - WORD_BASE as usize;
        let n_entities = (n_words / 4).min(128);
        // The fact table is drawn from a *fixed* stream so that train and
        // eval instances agree on the world.
        let mut world = Pcg64::new(seed, 0xfac7);
        let facts = (0..n_entities)
            .map(|_| WORD_BASE + world.below(n_words as u64) as u32)
            .collect();
        CorpusGen {
            vocab,
            n_words,
            zipf: ZipfTable::new(n_words, 1.1),
            facts,
            n_entities,
            rng: Pcg64::new(seed, 0xc0de),
        }
    }

    /// Reset the sentence stream (fact table unchanged).
    pub fn reseed(&mut self, seed: u64, stream: u64) {
        self.rng = Pcg64::new(seed, stream);
    }

    pub fn n_entities(&self) -> usize {
        self.n_entities
    }

    /// The object word for entity index e (probe ground truth).
    pub fn fact_object(&self, e: usize) -> u32 {
        self.facts[e]
    }

    pub fn entity_token(&self, e: usize) -> u32 {
        WORD_BASE + e as u32
    }

    fn word(&mut self) -> u32 {
        WORD_BASE + self.zipf.sample(&mut self.rng) as u32
    }

    fn non_entity_word(&mut self) -> u32 {
        // words outside the entity range, so facts stay unambiguous
        let lo = self.n_entities;
        WORD_BASE + self.rng.range(lo, self.n_words) as u32
    }

    // ---------------- sentence samplers ----------------

    pub fn sent_grammar(&mut self, out: &mut Vec<u32>) {
        let len = self.rng.range(4, 9);
        for _ in 0..len {
            let w = self.word();
            out.push(w);
        }
        out.push(EOS);
    }

    pub fn sent_fact(&mut self, out: &mut Vec<u32>) {
        let e = self.rng.range(0, self.n_entities);
        out.push(FACT);
        out.push(self.entity_token(e));
        out.push(SEP);
        out.push(self.facts[e]);
        out.push(EOS);
    }

    pub fn sent_copy(&mut self, out: &mut Vec<u32>) {
        let len = self.rng.range(2, 7);
        let span: Vec<u32> = (0..len).map(|_| self.non_entity_word()).collect();
        out.push(COPY);
        out.extend(&span);
        out.push(SEP);
        out.extend(&span);
        out.push(EOS);
    }

    pub fn sent_reverse(&mut self, out: &mut Vec<u32>) {
        let len = self.rng.range(2, 6);
        let span: Vec<u32> = (0..len).map(|_| self.non_entity_word()).collect();
        out.push(REV);
        out.extend(&span);
        out.push(SEP);
        out.extend(span.iter().rev());
        out.push(EOS);
    }

    pub fn sent_sort(&mut self, out: &mut Vec<u32>) {
        let len = self.rng.range(2, 6);
        let mut ds: Vec<u32> = (0..len)
            .map(|_| self.rng.below(10) as u32)
            .collect();
        out.push(SORT);
        out.extend(ds.iter().map(|&d| digit(d)));
        out.push(SEP);
        ds.sort_unstable();
        out.extend(ds.iter().map(|&d| digit(d)));
        out.push(EOS);
    }

    pub fn sent_arith(&mut self, out: &mut Vec<u32>) {
        let a = self.rng.below(10) as u32;
        let b = self.rng.below(10) as u32;
        out.push(ARITH);
        out.push(digit(a));
        out.push(PLUS);
        out.push(digit(b));
        out.push(EQ);
        out.push(digit((a + b) % 10));
        out.push(EOS);
    }

    /// Append one mixture-drawn sentence.
    pub fn sentence(&mut self, out: &mut Vec<u32>) {
        match self.rng.below(10) {
            0..=3 => self.sent_grammar(out),
            4 => self.sent_fact(out),
            5 => self.sent_copy(out),
            6 => self.sent_reverse(out),
            7 => self.sent_sort(out),
            8 => self.sent_arith(out),
            _ => self.sent_copy(out),
        }
    }

    /// Fill a continuous token stream of exactly `n` tokens.
    pub fn stream(&mut self, n: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(n + 16);
        out.push(BOS);
        while out.len() < n {
            self.sentence(&mut out);
        }
        out.truncate(n);
        out
    }

    /// Next-token-prediction batch: tokens[t] predicts tokens[t+1].
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> Batch {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let s = self.stream(seq + 1);
            tokens.extend(s[..seq].iter().map(|&t| t as i32));
            targets.extend(s[1..].iter().map(|&t| t as i32));
        }
        Batch {
            tokens,
            targets,
            mask: vec![1.0; batch * seq],
            batch,
            seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = CorpusGen::new(512, 7);
        let mut b = CorpusGen::new(512, 7);
        assert_eq!(a.stream(256), b.stream(256));
    }

    #[test]
    fn fact_table_stable_across_streams() {
        let a = CorpusGen::new(512, 7);
        let mut b = CorpusGen::new(512, 7);
        b.reseed(99, 1234); // different sentence stream...
        for e in 0..a.n_entities() {
            assert_eq!(a.fact_object(e), b.fact_object(e)); // ...same world
        }
    }

    #[test]
    fn tokens_in_range() {
        let mut g = CorpusGen::new(512, 1);
        for &t in &g.stream(4096) {
            assert!((t as usize) < 512, "token {t} out of vocab");
        }
    }

    #[test]
    fn copy_sentences_are_consistent() {
        let mut g = CorpusGen::new(512, 2);
        for _ in 0..50 {
            let mut s = Vec::new();
            g.sent_copy(&mut s);
            assert_eq!(s[0], COPY);
            let sep = s.iter().position(|&t| t == SEP).unwrap();
            let span = &s[1..sep];
            let echo = &s[sep + 1..s.len() - 1];
            assert_eq!(span, echo);
        }
    }

    #[test]
    fn sort_sentences_sorted() {
        let mut g = CorpusGen::new(512, 3);
        for _ in 0..50 {
            let mut s = Vec::new();
            g.sent_sort(&mut s);
            let sep = s.iter().position(|&t| t == SEP).unwrap();
            let out = &s[sep + 1..s.len() - 1];
            assert!(out.windows(2).all(|w| w[0] <= w[1]));
            assert_eq!(out.len(), sep - 1);
        }
    }

    #[test]
    fn arith_sentences_correct() {
        let mut g = CorpusGen::new(512, 4);
        for _ in 0..50 {
            let mut s = Vec::new();
            g.sent_arith(&mut s);
            assert_eq!(s.len(), 7);
            let a = s[1] - DIGIT_BASE;
            let b = s[3] - DIGIT_BASE;
            let c = s[5] - DIGIT_BASE;
            assert_eq!(c, (a + b) % 10);
        }
    }

    #[test]
    fn fact_sentences_match_table() {
        let mut g = CorpusGen::new(512, 5);
        for _ in 0..50 {
            let mut s = Vec::new();
            g.sent_fact(&mut s);
            let e = (s[1] - WORD_BASE) as usize;
            assert_eq!(s[3], g.fact_object(e));
        }
    }

    #[test]
    fn batch_shapes_and_shift() {
        let mut g = CorpusGen::new(512, 6);
        let b = g.next_batch(3, 32);
        assert_eq!(b.tokens.len(), 96);
        assert_eq!(b.targets.len(), 96);
        assert_eq!(b.mask.len(), 96);
        // target[t] is token[t+1] within each row
        for row in 0..3 {
            for t in 0..31 {
                assert_eq!(b.targets[row * 32 + t], b.tokens[row * 32 + t + 1]);
            }
        }
    }

    #[test]
    fn larger_vocab_for_100m() {
        let mut g = CorpusGen::new(2048, 1);
        let s = g.stream(2048);
        assert!(s.iter().any(|&t| t as usize > 512));
    }
}
