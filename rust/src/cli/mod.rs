//! Command-line argument parser substrate (offline substitute for clap).
//!
//! Grammar: `elitekv <command> [subcommand] [--flag value] [--switch] [pos]`.
//! Flags may appear anywhere after the command. Values parse on demand.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: positionals + `--key value` / `--switch` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got `{v}`")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got `{v}`")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects a float, got `{v}`")),
        }
    }

    /// Required flag.
    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key)
            .with_context(|| format!("missing required flag --{key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_mixed() {
        // NOTE: flags greedily take the next non-flag token as their value,
        // so positionals go before trailing switches (or use --switch=val).
        let a = parse("train ckpt.ekvc --config small --steps 100 --verbose");
        assert_eq!(a.pos(0), Some("train"));
        assert_eq!(a.pos(1), Some("ckpt.ekvc"));
        assert_eq!(a.get("config"), Some("small"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("serve --batch=8 --lr=0.001");
        assert_eq!(a.usize_or("batch", 0).unwrap(), 8);
        assert!((a.f64_or("lr", 0.0).unwrap() - 0.001).abs() < 1e-12);
    }

    #[test]
    fn trailing_switch() {
        let a = parse("eval --fast");
        assert!(a.has("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("x --steps abc");
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.usize_or("steps", 42).unwrap(), 42);
        assert_eq!(a.str_or("config", "tiny"), "tiny");
    }
}
