//! Model family and variant configuration (mirrors python/compile/configs.py
//! — the manifest produced by aot.py is the authoritative source at runtime;
//! this module provides the same grids for planning and experiments).

/// Static shape of one model in the family.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub rope_base: f64,
}

impl ModelConfig {
    /// Number of 2-D RoPE chunks per head (|I| in the paper).
    pub fn n_chunks(&self) -> usize {
        self.d_head / 2
    }

    /// Vanilla KV cache elements per token per layer.
    pub fn kv_elems_per_token(&self) -> usize {
        2 * self.n_heads * self.d_head
    }

    pub fn tiny() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(), d_model: 256, n_layers: 4, n_heads: 8,
            d_head: 32, d_ffn: 704, vocab: 512, max_seq: 256,
            rope_base: 10000.0,
        }
    }

    pub fn small() -> ModelConfig {
        ModelConfig {
            name: "small".into(), d_model: 512, n_layers: 8, n_heads: 8,
            d_head: 64, d_ffn: 1408, vocab: 512, max_seq: 256,
            rope_base: 10000.0,
        }
    }

    pub fn m100() -> ModelConfig {
        ModelConfig {
            name: "100m".into(), d_model: 768, n_layers: 12, n_heads: 12,
            d_head: 64, d_ffn: 2048, vocab: 2048, max_seq: 256,
            rope_base: 10000.0,
        }
    }

    pub fn by_name(name: &str) -> Option<ModelConfig> {
        match name {
            "tiny" => Some(Self::tiny()),
            "small" => Some(Self::small()),
            "100m" => Some(Self::m100()),
            _ => None,
        }
    }

    /// Approximate parameter count (tied embeddings).
    pub fn approx_params(&self) -> usize {
        let d = self.d_model;
        let per_layer = 4 * d * self.n_heads * self.d_head / self.n_heads
            * self.n_heads // attn (wq,wk,wv,wo at full width)
            + 3 * d * self.d_ffn
            + 2 * d;
        self.vocab * d + self.n_layers * per_layer + d
    }
}

/// Architecture variant (paper §3). Mirrors `configs.Variant`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Variant {
    Mha,
    RopeLite,
    Gqa { n_kv_heads: usize },
    EliteKv { r: usize, d_ckv: usize },
    Slrd { r: usize, d_ck: usize, d_cv: usize },
}

impl Variant {
    pub fn tag(&self) -> String {
        match self {
            Variant::Mha => "mha".into(),
            Variant::RopeLite => "ropelite".into(),
            Variant::Gqa { n_kv_heads } => format!("gqa{n_kv_heads}"),
            Variant::EliteKv { r, d_ckv } => format!("elitekv_r{r}_c{d_ckv}"),
            Variant::Slrd { r, d_ck, d_cv } => {
                format!("slrd_r{r}_ck{d_ck}_cv{d_cv}")
            }
        }
    }

    pub fn parse(tag: &str) -> Option<Variant> {
        if tag == "mha" {
            return Some(Variant::Mha);
        }
        if tag == "ropelite" {
            return Some(Variant::RopeLite);
        }
        if let Some(rest) = tag.strip_prefix("gqa") {
            return rest.parse().ok().map(|g| Variant::Gqa { n_kv_heads: g });
        }
        if let Some(rest) = tag.strip_prefix("elitekv_r") {
            let (r, c) = rest.split_once("_c")?;
            return Some(Variant::EliteKv {
                r: r.parse().ok()?,
                d_ckv: c.parse().ok()?,
            });
        }
        if let Some(rest) = tag.strip_prefix("slrd_r") {
            let (r, rest) = rest.split_once("_ck")?;
            let (ck, cv) = rest.split_once("_cv")?;
            return Some(Variant::Slrd {
                r: r.parse().ok()?,
                d_ck: ck.parse().ok()?,
                d_cv: cv.parse().ok()?,
            });
        }
        None
    }

    /// KV cache elements per token per layer (paper §3.2 formulas).
    pub fn cache_per_token(&self, cfg: &ModelConfig) -> usize {
        match self {
            Variant::Mha | Variant::RopeLite => cfg.kv_elems_per_token(),
            Variant::Gqa { n_kv_heads } => 2 * n_kv_heads * cfg.d_head,
            Variant::EliteKv { r, d_ckv } => 2 * r * cfg.n_heads + d_ckv,
            Variant::Slrd { r, d_ck, d_cv } => {
                2 * r * cfg.n_heads + d_ck + d_cv
            }
        }
    }

    pub fn cache_ratio(&self, cfg: &ModelConfig) -> f64 {
        self.cache_per_token(cfg) as f64 / cfg.kv_elems_per_token() as f64
    }

    /// KV-projection parameter count per layer (paper §3.2 storage cost).
    pub fn storage_cost(&self, cfg: &ModelConfig) -> usize {
        let (d, nh, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head);
        match self {
            Variant::Mha | Variant::RopeLite => 2 * d * nh * dh,
            Variant::Gqa { n_kv_heads } => 2 * d * n_kv_heads * dh,
            Variant::EliteKv { r, d_ckv } => {
                2 * r * nh * d + d_ckv * (d + 2 * dh * nh - 2 * r * nh)
            }
            Variant::Slrd { r, d_ck, d_cv } => {
                2 * r * nh * d
                    + d_ck * (d + dh * nh - 2 * r * nh)
                    + d_cv * (d + dh * nh)
            }
        }
    }

    /// Elite chunks per head, if the variant has them.
    pub fn r(&self) -> Option<usize> {
        match self {
            Variant::EliteKv { r, .. } | Variant::Slrd { r, .. } => Some(*r),
            _ => None,
        }
    }
}

/// The paper's Table-1 cache-ratio grid realized for a config
/// (label, variant) — mirrors configs.table1_grid.
pub fn table1_grid(cfg: &ModelConfig) -> Vec<(&'static str, Variant)> {
    let nc = cfg.n_chunks();
    let g = |ratio: f64, r: usize| {
        let align = if cfg.d_model >= 512 { 32 } else { 16 };
        let target =
            ratio * cfg.kv_elems_per_token() as f64 - (2 * r * cfg.n_heads) as f64;
        let c = ((target / align as f64).round() as usize * align).max(align);
        Variant::EliteKv { r, d_ckv: c }
    };
    vec![
        ("100.0", Variant::Mha),
        ("50.0", Variant::EliteKv { r: nc / 2, d_ckv: cfg.d_model / 2 }),
        ("50.0", Variant::Gqa { n_kv_heads: cfg.n_heads / 2 }),
        ("34.4", g(0.344, nc / 4)),
        ("28.1", g(0.281, nc / 4)),
        ("25.0", g(0.25, nc / 4)),
        ("25.0", Variant::Gqa { n_kv_heads: cfg.n_heads / 4 }),
        ("21.9", g(0.219, nc / 8)),
        ("12.5", g(0.125, nc / 8)),
        ("12.5", Variant::Gqa { n_kv_heads: 1 }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_roundtrip() {
        for v in [
            Variant::Mha,
            Variant::RopeLite,
            Variant::Gqa { n_kv_heads: 2 },
            Variant::EliteKv { r: 8, d_ckv: 128 },
            Variant::Slrd { r: 4, d_ck: 32, d_cv: 64 },
        ] {
            assert_eq!(Variant::parse(&v.tag()), Some(v));
        }
        assert_eq!(Variant::parse("bogus"), None);
    }

    #[test]
    fn table1_ratios_match_labels() {
        for cfg in [ModelConfig::tiny(), ModelConfig::small()] {
            for (label, var) in table1_grid(&cfg) {
                let want: f64 = label.parse::<f64>().unwrap() / 100.0;
                let got = var.cache_ratio(&cfg);
                assert!(
                    (got - want).abs() < 0.005,
                    "{} {}: {} vs {}", cfg.name, var.tag(), got, want
                );
            }
        }
    }

    #[test]
    fn jlrd_storage_simplification() {
        // 2 r nh d + d_ckv (d + 2 dh nh − 2 r nh) == 2 r nh d + 3 c d − 2 c r nh
        // under the MHA assumption d = nh * dh.
        let cfg = ModelConfig::small();
        assert_eq!(cfg.d_model, cfg.n_heads * cfg.d_head);
        let v = Variant::EliteKv { r: 8, d_ckv: 160 };
        let got = v.storage_cost(&cfg);
        let d = cfg.d_model;
        let rn = 8 * cfg.n_heads;
        assert_eq!(got, 2 * rn * d + 3 * 160 * d - 2 * 160 * rn);
    }

    #[test]
    fn configs_resolve_by_name() {
        assert_eq!(ModelConfig::by_name("tiny").unwrap().d_model, 256);
        assert_eq!(ModelConfig::by_name("small").unwrap().n_layers, 8);
        assert_eq!(ModelConfig::by_name("100m").unwrap().n_heads, 12);
        assert!(ModelConfig::by_name("7b").is_none());
    }
}
