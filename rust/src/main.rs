//! `elitekv` — the coordinator CLI.
//!
//! Subcommands (run `elitekv help` for details):
//!   pretrain    train a baseline MHA model from scratch on the synthetic
//!               corpus and save a checkpoint [pjrt]
//!   search      RoPElite (Algorithm 1) / Uniform / Contribution chunk
//!               selection on a pretrained checkpoint (uniform is native)
//!   convert     weight surgery: MHA checkpoint -> gqa / elitekv / slrd
//!               (pure Rust, no artifacts needed)
//!   uptrain     uptrain a converted checkpoint (paper §4.1 recipe) [pjrt]
//!   eval        probe battery + holdout perplexity for a checkpoint
//!               (native backend by default)
//!   serve       run the inference engine on a synthetic request stream;
//!               `--backend native` (default) needs zero artifacts,
//!               `--backend pjrt` executes the AOT path
//!   bench       native decode benchmark -> BENCH_native_decode.json
//!   experiment  regenerate paper tables/figures [pjrt]
//!
//! Python never runs here: the native backend computes the forward pass
//! in-process; the optional pjrt feature executes AOT-compiled HLO
//! artifacts through the PJRT CPU client (`make artifacts` first).

use anyhow::{bail, Context, Result};

use elitekv::cli::Args;
use elitekv::config::{ModelConfig, Variant};
use elitekv::convert::{self, EliteSelection};
use elitekv::coordinator::{
    EngineFactory, GenParams, InferenceServer, Request, RoutePolicyKind,
    Router, SchedulerConfig,
};
use elitekv::data::{CorpusGen, ProbeSet};
use elitekv::io::Checkpoint;
use elitekv::native::{NativeModel, NativeRunner};
use elitekv::runtime::Backend;
use elitekv::search;
use elitekv::train::scorer;

#[cfg(feature = "pjrt")]
use std::sync::Arc;

#[cfg(feature = "pjrt")]
use elitekv::bench::experiments;
#[cfg(feature = "pjrt")]
use elitekv::bench::pipeline::{ExperimentCtx, SweepOpts};
#[cfg(feature = "pjrt")]
use elitekv::runtime::{Engine, HostTensor, ModelRunner, PjrtBackend, TrainState};
#[cfg(feature = "pjrt")]
use elitekv::train::{TrainLoop, TrainOpts};

fn main() {
    init_logger();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.pos(0).unwrap_or("help") {
        "pretrain" => cmd_pretrain(args),
        "search" => cmd_search(args),
        "convert" => cmd_convert(args),
        "uptrain" => cmd_uptrain(args),
        "eval" => cmd_eval(args),
        "serve" => cmd_serve(args),
        "bench" => cmd_bench(args),
        "lint" => cmd_lint(args),
        "experiment" => cmd_experiment(args),
        "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `elitekv help`)"),
    }
}

const HELP: &str = "\
elitekv — EliteKV reproduction coordinator

USAGE: elitekv <command> [flags]

COMMANDS
  serve      [--backend native|pjrt] --config C --variant TAG
             [--ckpt PATH] [--selection PATH] [--requests N] [--max-new N]
             [--max-batch B] [--max-seq S] [--block-tokens N]
             [--cache-budget-mb N] [--cache-dtype f32|int8]
             [--sparse-k N] [--prefill-chunk N] [--optimistic-admission]
             [--prefix-cache] [--temperature F] [--top-p F] [--seed N]
             [--r N (ropelite uniform fallback)] [--pallas]
             [--workers N] [--route-policy affinity|least-loaded]
             native backend (default): no artifacts needed; random-init
             weights unless --ckpt points at a (converted) checkpoint.
             Requests are continuously batched: admission is gated on the
             block pool (--cache-budget-mb / --block-tokens), lanes
             recycle the moment a sequence finishes. --prefix-cache
             (native only) retains finished prompts' full-block prefixes
             in a radix tree and prefills only the novel suffix of later
             prompts (LRU-evicted under pool pressure). --cache-dtype
             int8 (native only) stores the cache slabs group-quantized —
             1/4 the bytes/token, so the same budget admits ~4x the
             tokens — with dequantization fused into the decode GEMMs.
             --sparse-k N (native only) attends only the top-N cache
             rows per decode step, picked by a cheap latent-space
             scoring pass (N >= sequence length reproduces dense decode
             bitwise). --prefill-chunk N (native only) splits prompt
             prefill into N-token chunks interleaved with decode steps,
             so live lanes never stall behind one long prompt; 0 (the
             default) prefills each admission whole. Chunked and
             monolithic runs are bitwise identical per request.
             --workers N (native only, N >= 2) shards the stream over N
             identical engine worker threads behind the cluster router
             (DESIGN.md S24); --route-policy picks how: `affinity` (the
             default) routes each request to the worker whose shadow
             radix index holds its longest cached prefix, `least-loaded`
             routes blind. Routing never changes any request's tokens.
  bench      [--config C] [--steps N] [--batch B] [--prompt N]
             [--out PATH]   native decode sweep -> BENCH_native_decode.json
             (every variant at cache dtype f32 AND int8, each measured
             dense and again at --sparse-k N; 0 skips the sparse rows)
             then a continuous-batching capacity sweep
             [--max-batch B] [--cb-requests N] [--cb-max-seq S]
             [--block-tokens N] [--cache-budget-mb N] [--cb-out PATH]
             [--shared-prefix N] [--sparse-k N] [--prefill-chunk N]
             [--workers N] [--route-policy affinity|least-loaded]
             -> BENCH_continuous_batching.json (dense vs J-LRD max
             concurrency under one cache budget with an f32/int8 pair
             per variant, plus a shared-system-prompt trace replayed
             with the prefix radix cache off/on, plus a long-context
             trace replayed dense vs sparse at --sparse-k, plus a
             long-prompt-arrives-mid-decode trace replayed monolithic
             vs chunked at --prefill-chunk; rows carry TTFT p50/p95/p99,
             mean TPOT, and the max inter-token gap; plus — when
             --workers >= 2 — the shared-prefix trace replayed
             closed-loop through the sharded router under blind
             least-loaded AND --route-policy routing, with per-worker
             routed/affinity-hit/hit-rate/shadow columns)
  eval       [--backend native|pjrt] --config C --variant TAG [--ckpt PATH]
             [--selection PATH] [--probes N] [--seed N] [--r N]
             [--cache-dtype f32|int8]  (int8, native only: score the
             probe battery/perplexity over the QUANTIZED decode cache —
             the accuracy side of the S19 capacity trade)
  convert    --config C --ckpt PATH --variant TAG [--selection PATH]
             [--out PATH]   (pure Rust; no artifacts needed)
  search     --config C --r N --method uniform [--out PATH]
             (ropelite/contribution methods additionally need --ckpt and
              a pjrt build)
  pretrain   --config tiny|small|100m --steps N [--lr F] [--out PATH] [pjrt]
  uptrain    --config C --variant TAG --ckpt PATH [--selection PATH]
             --steps N [--lr F] [--out PATH] [pjrt]
  lint       [--root DIR] [--dump-tokens FILE]
             project-contract static analysis (DESIGN.md S21): test/bench
             registration (R1), decode-path determinism (R2), serving-path
             panic freedom (R3), pjrt gating (R4), doc coverage (R5),
             delimiter balance (R6), CLI-flag drift (R7). Prints
             `file:line rule message` and exits nonzero on any finding not
             covered by a `// lint: allow(Rn) — reason` comment.
             `python3 python/tools/lint.py` is the line-identical
             toolchain-free runner; --dump-tokens prints the lexer's
             token stream for one file (differential-test hook).
  experiment <table1|table2|fig2|fig3|fig5|fig6|fig7|serve|all>
             [--config tiny] [--out results] [--models A,B] [--full] [pjrt]

COMMON FLAGS
  --artifacts DIR   artifact directory for pjrt commands (default: artifacts)
  ELITEKV_LOG=debug|info|warn|error controls logging

Commands marked [pjrt] execute AOT HLO artifacts and require a build with
`--features pjrt` plus `make artifacts`; everything else is pure Rust.
";

fn init_logger() {
    struct Stderr;
    impl log::Log for Stderr {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level().as_str().to_lowercase(),
                          r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: Stderr = Stderr;
    let _ = log::set_logger(&LOGGER);
    let level = match std::env::var("ELITEKV_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        _ => log::LevelFilter::Info,
    };
    log::set_max_level(level);
}

// ---------------------------------------------------------------------------
// Native backend construction
// ---------------------------------------------------------------------------

/// Selection for variants that need one: `--selection PATH` wins, else the
/// Uniform baseline. For elitekv/slrd the selection's r must match the
/// variant; ropelite has no intrinsic r, so a selection file of any r is
/// accepted and the Uniform fallback takes its r from `--r`.
fn load_selection(
    args: &Args,
    cfg: &ModelConfig,
    variant: &Variant,
) -> Result<Option<EliteSelection>> {
    let from_file = |path: &str| -> Result<EliteSelection> {
        EliteSelection::from_checkpoint(&Checkpoint::load(path)?, cfg)
    };
    match variant {
        Variant::EliteKv { r, .. } | Variant::Slrd { r, .. } => {
            if let Some(path) = args.get("selection") {
                let sel = from_file(path)?;
                anyhow::ensure!(
                    sel.r() == *r,
                    "selection r={} but variant `{}` needs r={r}",
                    sel.r(),
                    variant.tag()
                );
                return Ok(Some(sel));
            }
            log::info!("no --selection: using the Uniform baseline at r={r}");
            Ok(Some(search::uniform_selection(cfg, *r)))
        }
        Variant::RopeLite => {
            if let Some(path) = args.get("selection") {
                return Ok(Some(from_file(path)?));
            }
            let r = args.usize_or("r", cfg.n_chunks() / 4)?;
            log::info!("no --selection: using the Uniform baseline at r={r}");
            Ok(Some(search::uniform_selection(cfg, r)))
        }
        _ => Ok(None),
    }
}

/// Build the native backend from flags: checkpoint weights when `--ckpt`
/// is given, random init otherwise (layout/serving behavior is
/// weight-independent, so the artifact-free demo path stays honest).
///
/// Selection precedence for a checkpoint: `--selection` file, then the
/// selection embedded by `convert` (converted elite weights are permuted
/// by a specific chunk order — a mismatched selection would rotate the
/// wrong frequencies silently), then the Uniform fallback (random-init
/// weights only, where any consistent order is fine).
fn native_backend(args: &Args) -> Result<NativeRunner> {
    let cfg_name = args.str_or("config", "tiny");
    let cfg = ModelConfig::by_name(&cfg_name).context("unknown config")?;
    let tag = args.str_or("variant", "elitekv_r4_c64");
    let variant = Variant::parse(&tag)
        .with_context(|| format!("bad variant tag `{tag}`"))?;
    let model = match args.get("ckpt") {
        Some(path) => {
            let ckpt = Checkpoint::load(path)?;
            let sel = if args.get("selection").is_some() {
                load_selection(args, &cfg, &variant)?
            } else if ckpt.tensors.contains_key("elite.l0") {
                log::info!("using the selection embedded in {path}");
                Some(EliteSelection::from_checkpoint(&ckpt, &cfg)?)
            } else if matches!(
                variant,
                Variant::EliteKv { .. } | Variant::Slrd { .. }
            ) {
                // A converted elite checkpoint's weights are permuted by a
                // specific chunk order; guessing one would rotate the
                // wrong frequencies silently.
                bail!(
                    "checkpoint {path} has no embedded elite selection; \
                     pass --selection (the file used at convert time)"
                );
            } else {
                load_selection(args, &cfg, &variant)?
            };
            NativeModel::from_checkpoint(
                cfg.clone(), variant, ckpt, sel.as_ref())?
        }
        None => {
            let sel = load_selection(args, &cfg, &variant)?;
            log::info!("no --ckpt: random-init native weights");
            NativeModel::init(
                &cfg,
                variant,
                args.u64_or("seed", 42)?,
                sel.as_ref(),
            )?
        }
    };
    let mut model = model;
    model.set_cache_dtype(cache_dtype(args)?);
    model.set_sparse_k(sparse_k(args)?);
    // `--max-batch` is the scheduler-facing name; `--batch` stays as the
    // historical alias.
    let batch =
        args.usize_or("max-batch", args.usize_or("batch", 4)?)?;
    let max_seq = args.usize_or("max-seq", cfg.max_seq.min(256))?;
    NativeRunner::new(model, batch, max_seq)
}

/// `--cache-dtype f32|int8` (DESIGN.md S19): the cache element storage
/// of the native backend's slabs AND the scheduler's byte accounting —
/// parsed once so the two can never disagree.
fn cache_dtype(args: &Args) -> Result<elitekv::kvcache::CacheDtype> {
    let tag = args.str_or("cache-dtype", "f32");
    elitekv::kvcache::CacheDtype::parse(&tag)
        .with_context(|| format!("bad --cache-dtype `{tag}` (f32|int8)"))
}

/// `--sparse-k N` (DESIGN.md S20): the sparse-decode row budget of the
/// native backend AND the scheduler config — parsed once (and clamped to
/// >= 1, matching [`NativeModel::set_sparse_k`]) so the engine's
/// config-vs-backend agreement check can never trip on CLI input.
fn sparse_k(args: &Args) -> Result<Option<usize>> {
    Ok(match args.get("sparse-k") {
        Some(_) => Some(args.usize_or("sparse-k", 1)?.max(1)),
        None => None,
    })
}

/// Scheduler policy from the shared serve/bench flags. The commands
/// differ only in their default budget (serve: 64 MiB; bench: the
/// deliberately tight `ServeBenchOpts` budget).
fn scheduler_config(
    args: &Args,
    default_budget_mb: usize,
    default_block_tokens: usize,
) -> Result<SchedulerConfig> {
    Ok(SchedulerConfig {
        block_tokens: args.usize_or("block-tokens", default_block_tokens)?,
        cache_budget_bytes: args
            .usize_or("cache-budget-mb", default_budget_mb)?
            << 20,
        conservative: !args.has("optimistic-admission"),
        prefix_cache: args.has("prefix-cache"),
        cache_dtype: cache_dtype(args)?,
        sparse_k: sparse_k(args)?,
        prefill_chunk_tokens: args.usize_or("prefill-chunk", 0)?,
    })
}

// ---------------------------------------------------------------------------
// Commands
// ---------------------------------------------------------------------------

fn cmd_serve(args: &Args) -> Result<()> {
    let workers = args.usize_or("workers", 1)?;
    let route_policy =
        RoutePolicyKind::parse(&args.str_or("route-policy", "affinity"))?;
    if workers > 1 {
        return cmd_serve_sharded(args, workers, route_policy);
    }
    let backend = args.str_or("backend", "native");
    let boxed: Box<dyn Backend> = match backend.as_str() {
        "native" => Box::new(native_backend(args)?),
        "pjrt" => pjrt_serving_backend(args)?,
        other => bail!("unknown backend `{other}` (native|pjrt)"),
    };
    let n = args.usize_or("requests", 24)?;
    let max_new = args.usize_or("max-new", 16)?;
    let temperature = args.f64_or("temperature", 0.0)? as f32;
    let top_p = args.f64_or("top-p", 1.0)? as f32;
    let vocab = boxed.config().vocab;
    let kind = boxed.kind();
    let variant_tag = boxed.variant().tag();
    let mut server =
        InferenceServer::with_config(boxed, &scheduler_config(args, 64, 16)?)?;
    server.use_pallas = args.has("pallas");
    let gen = CorpusGen::new(vocab, 1);
    let probes = ProbeSet::generate(&gen, n.div_ceil(6), 7777);
    let t0 = std::time::Instant::now();
    for (i, item) in probes.items.iter().take(n).enumerate() {
        server.submit(Request::new(
            i as u64,
            item.prompt.clone(),
            GenParams {
                max_new_tokens: max_new,
                temperature,
                top_p,
                ..Default::default()
            },
        ))?;
    }
    let responses = server.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
    println!(
        "[{kind}/{variant_tag}] served {} requests, {} tokens in {:.2}s \
         ({:.1} tok/s); prefills {}, decode steps {}, peak cache {} KiB",
        responses.len(), toks, wall, toks as f64 / wall,
        server.stats.prefills, server.stats.decode_steps,
        server.stats.peak_cache_bytes / 1024
    );
    println!(
        "  scheduler: {} blocks of {} tokens, peak used {}, mean \
         occupancy {:.1}%, max concurrency {}, mean admission wait \
         {:.2} ms",
        server.stats.blocks_total,
        server.queue.allocator.block_tokens,
        server.stats.peak_blocks_used,
        100.0 * server.stats.mean_block_occupancy(),
        server.stats.max_concurrency,
        1e3 * server.stats.mean_admission_wait_s(),
    );
    if !server.stats.ttft_recent_s.is_empty() {
        let ttft =
            elitekv::util::stats::Summary::of(&server.stats.ttft_recent_s);
        let tpot =
            elitekv::util::stats::Summary::of(&server.stats.tpot_recent_s);
        println!(
            "  latency: ttft p50 {:.2} / p95 {:.2} / p99 {:.2} ms, \
             tpot mean {:.3} ms, max inter-token gap {:.2} ms",
            1e3 * ttft.p50,
            1e3 * ttft.p95,
            1e3 * ttft.p99,
            1e3 * tpot.mean,
            1e3 * server.stats.max_decode_gap_s,
        );
    }
    if args.has("prefix-cache") {
        println!(
            "  prefix cache: {} hits / {} misses, {} tokens reused \
             ({} prefilled), {} blocks held, {} evicted",
            server.stats.prefix_hits,
            server.stats.prefix_misses,
            server.stats.prefix_hit_tokens,
            server.stats.prefill_tokens,
            server.stats.prefix_cached_blocks,
            server.stats.prefix_evicted_blocks,
        );
    }
    Ok(())
}

/// `serve --workers N` (N >= 2): shard the synthetic request stream
/// over N identical native engines behind the cluster router
/// (DESIGN.md S24), then print aggregate throughput plus per-worker
/// routing, shadow, and prefix-hit columns.
fn cmd_serve_sharded(
    args: &Args,
    workers: usize,
    route_policy: RoutePolicyKind,
) -> Result<()> {
    let backend = args.str_or("backend", "native");
    if backend != "native" {
        bail!("--workers > 1 currently supports the native backend only");
    }
    let cfg_name = args.str_or("config", "tiny");
    let cfg = ModelConfig::by_name(&cfg_name).context("unknown config")?;
    let scheduler = scheduler_config(args, 64, 16)?;
    let n = args.usize_or("requests", 24)?;
    let max_new = args.usize_or("max-new", 16)?;
    let temperature = args.f64_or("temperature", 0.0)? as f32;
    let top_p = args.f64_or("top-p", 1.0)? as f32;
    let use_pallas = args.has("pallas");
    let factories: Vec<EngineFactory> = (0..workers)
        .map(|_| {
            let args = args.clone();
            let scheduler = scheduler.clone();
            let f: EngineFactory = Box::new(move || {
                let runner = native_backend(&args)?;
                let mut server = InferenceServer::with_config(
                    Box::new(runner),
                    &scheduler,
                )?;
                server.use_pallas = use_pallas;
                Ok(server)
            });
            f
        })
        .collect();
    let mut router =
        Router::with_policy(factories, route_policy, scheduler.block_tokens);
    let gen = CorpusGen::new(cfg.vocab, 1);
    let probes = ProbeSet::generate(&gen, n.div_ceil(6), 7777);
    let t0 = std::time::Instant::now();
    for (i, item) in probes.items.iter().take(n).enumerate() {
        router.submit(Request::new(
            i as u64,
            item.prompt.clone(),
            GenParams {
                max_new_tokens: max_new,
                temperature,
                top_p,
                ..Default::default()
            },
        ))?;
    }
    let responses = router.drain()?;
    let wall = t0.elapsed().as_secs_f64();
    let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
    println!(
        "[native/sharded] {workers} workers ({} routing): served {} \
         requests, {} tokens in {:.2}s ({:.1} tok/s)",
        route_policy.tag(),
        responses.len(),
        toks,
        wall,
        toks as f64 / wall.max(1e-9),
    );
    let rs = router.route_stats();
    for (w, stats) in router.stats() {
        println!(
            "  worker {w}: routed {}, affinity hits {} ({} shadowed \
             blocks claimed), shadow {} blocks, prefix hit rate {:.0}%, \
             prefills {}, decode steps {}, peak cache {} KiB",
            rs.routed.get(w).copied().unwrap_or(0),
            rs.affinity_hits.get(w).copied().unwrap_or(0),
            rs.affinity_blocks.get(w).copied().unwrap_or(0),
            rs.shadow_blocks.get(w).copied().unwrap_or(0),
            100.0 * stats.prefix_hit_rate(),
            stats.prefills,
            stats.decode_steps,
            stats.peak_cache_bytes / 1024,
        );
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let cfg = ModelConfig::by_name(&cfg_name).context("unknown config")?;
    let native_defaults = elitekv::bench::native::NativeBenchOpts::default();
    let opts = elitekv::bench::native::NativeBenchOpts {
        batch: args.usize_or("batch", 4)?,
        prompt_len: args.usize_or("prompt", 16)?,
        decode_steps: args.usize_or("steps", 48)?,
        max_seq: args.usize_or("max-seq", cfg.max_seq.min(128))?,
        sparse_k: args.usize_or("sparse-k", native_defaults.sparse_k)?,
    };
    let out = args.str_or("out", "BENCH_native_decode.json");
    let variants = elitekv::bench::native::default_sweep(&cfg);
    elitekv::bench::native_decode_bench(
        &cfg,
        &variants,
        &opts,
        std::path::Path::new(&out),
    )?;
    println!("wrote {out}");

    // Continuous-batching scheduler sweep: same trace, same byte budget,
    // dense vs compressed -> the capacity numbers.
    let defaults = elitekv::bench::serve::ServeBenchOpts::default();
    let cb_opts = elitekv::bench::serve::ServeBenchOpts {
        max_batch: args.usize_or("max-batch", defaults.max_batch)?,
        max_seq: args.usize_or("cb-max-seq", defaults.max_seq)?,
        scheduler: scheduler_config(
            args,
            defaults.scheduler.cache_budget_bytes >> 20,
            defaults.scheduler.block_tokens,
        )?,
        trace: elitekv::coordinator::TraceOpts {
            n_requests: args
                .usize_or("cb-requests", defaults.trace.n_requests)?,
            ..defaults.trace
        },
        shared_prefix_tokens: args
            .usize_or("shared-prefix", defaults.shared_prefix_tokens)?,
        sparse_k: args.usize_or("sparse-k", defaults.sparse_k)?,
        prefill_chunk: args
            .usize_or("prefill-chunk", defaults.prefill_chunk)?,
        workers: args.usize_or("workers", defaults.workers)?,
        route_policy: RoutePolicyKind::parse(
            &args.str_or("route-policy", defaults.route_policy.tag()),
        )?,
        seed: args.u64_or("seed", defaults.seed)?,
    };
    let cb_out = args.str_or("cb-out", "BENCH_continuous_batching.json");
    let cb_variants = elitekv::bench::serve::default_variants(&cfg);
    elitekv::bench::continuous_batching_bench(
        &cfg,
        &cb_variants,
        &cb_opts,
        std::path::Path::new(&cb_out),
    )?;
    println!("wrote {cb_out}");
    Ok(())
}

/// `elitekv lint`: run the project-contract static analyzer (see
/// `elitekv::analysis` and DESIGN.md S21). `--dump-tokens FILE` instead
/// prints the lexer's token stream for one file — the hook the
/// Rust↔Python differential tests use to compare lexers directly.
fn cmd_lint(args: &Args) -> Result<()> {
    if let Some(path) = args.get("dump-tokens") {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read {path}"))?;
        let text = String::from_utf8_lossy(&bytes);
        print!("{}", elitekv::analysis::lexer::dump(&text));
        return Ok(());
    }
    let root = args.str_or("root", ".");
    let report = elitekv::analysis::run_lint(std::path::Path::new(&root));
    print!("{}", report.render());
    if !report.is_clean() {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let backend = args.str_or("backend", "native");
    let n = args.usize_or("probes", 25)?;
    match backend.as_str() {
        "native" => {
            let runner = native_backend(args)?;
            let gen = CorpusGen::new(runner.config().vocab, 1);
            let probes = ProbeSet::generate(&gen, n, 99);
            let rep = scorer::full_report(&runner, &probes, 4)?;
            print_eval(runner.variant(), runner.config(), &rep);
            Ok(())
        }
        "pjrt" => pjrt_eval(args, n),
        other => bail!("unknown backend `{other}` (native|pjrt)"),
    }
}

fn print_eval(
    variant: &Variant,
    cfg: &ModelConfig,
    rep: &scorer::ScoreReport,
) {
    println!(
        "variant {} (cache {:.1}%)",
        variant.tag(),
        100.0 * variant.cache_ratio(cfg)
    );
    for (task, acc) in &rep.scores.task_acc {
        println!("  {task:<10} {:6.2}", 100.0 * acc);
    }
    println!("  {:<10} {:6.2}", "Avg.", 100.0 * rep.scores.average);
    println!("  {:<10} {:6.3}", "ppl", rep.ppl);
}

fn cmd_convert(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let cfg = ModelConfig::by_name(&cfg_name).context("config")?;
    let tag = args.req("variant")?;
    let variant = Variant::parse(tag).context("bad variant tag")?;
    let base = Checkpoint::load(args.req("ckpt")?)?;
    let out = args.str_or("out", &format!("{cfg_name}_{tag}.ekvc"));
    let converted = match &variant {
        Variant::Gqa { n_kv_heads } => {
            convert::convert_gqa(&cfg, &base, *n_kv_heads)?
        }
        Variant::EliteKv { r, d_ckv } => {
            let sel = EliteSelection::from_checkpoint(
                &Checkpoint::load(args.req("selection")?)?, &cfg)?;
            anyhow::ensure!(sel.r() == *r, "selection r mismatch");
            convert::convert_elitekv(&cfg, &base, &sel, *d_ckv)?
        }
        Variant::Slrd { r, d_ck, d_cv } => {
            let sel = EliteSelection::from_checkpoint(
                &Checkpoint::load(args.req("selection")?)?, &cfg)?;
            anyhow::ensure!(sel.r() == *r, "selection r mismatch");
            convert::convert_slrd(&cfg, &base, &sel, *d_ck, *d_cv)?
        }
        v => bail!("convert does not apply to `{}`", v.tag()),
    };
    converted.save(&out)?;
    println!(
        "converted -> {out} (cache ratio {:.1}%)",
        100.0 * variant.cache_ratio(&cfg)
    );
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let r = args.usize_or("r", 4)?;
    let method = args.str_or("method", "ropelite");
    let out =
        args.str_or("out", &format!("elite_{cfg_name}_{method}_r{r}.ekvc"));
    let cfg = ModelConfig::by_name(&cfg_name).context("config")?;
    if method == "uniform" {
        let sel = search::uniform_selection(&cfg, r);
        sel.to_checkpoint(&cfg).save(&out)?;
        println!("saved {out} (uniform selection, r={r})");
        return Ok(());
    }
    pjrt_search(args, &cfg, &cfg_name, &method, r, &out)
}

// ---------------------------------------------------------------------------
// PJRT-only paths (gated; graceful error otherwise)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", elitekv::ARTIFACTS_DIR)
}

/// Build a runner + params (+extras from a selection file) for a variant.
#[cfg(feature = "pjrt")]
fn load_model(
    args: &Args,
    cfg_name: &str,
    tag: &str,
) -> Result<(ModelRunner, Vec<HostTensor>)> {
    let engine = Arc::new(Engine::new()?);
    let mut runner =
        ModelRunner::new(engine, artifacts_dir(args), cfg_name, tag)?;
    let cfg = runner.manifest.config.clone();
    let variant = runner.manifest.variant.clone();
    if !runner.manifest.extras.is_empty() {
        let sel_path = args.req("selection")?;
        let sel = EliteSelection::from_checkpoint(
            &Checkpoint::load(sel_path)?, &cfg)?;
        match variant {
            Variant::RopeLite => {
                let mask = convert::elitekv::elite_mask_flat(&cfg, &sel);
                runner.set_extras(vec![HostTensor::F32(
                    mask, vec![cfg.n_layers, cfg.n_heads, cfg.n_chunks()])])?;
            }
            Variant::EliteKv { r, .. } | Variant::Slrd { r, .. } => {
                anyhow::ensure!(sel.r() == r, "selection r mismatch");
                let theta = convert::elitekv::elite_thetas_flat(&cfg, &sel);
                runner.set_extras(vec![HostTensor::F32(
                    theta, vec![cfg.n_layers, cfg.n_heads, r])])?;
            }
            _ => {}
        }
    }
    let ckpt = Checkpoint::load(args.req("ckpt")?)?;
    let params = runner.params_from_ckpt(&ckpt)?;
    Ok((runner, params))
}

#[cfg(feature = "pjrt")]
fn pjrt_serving_backend(args: &Args) -> Result<Box<dyn Backend>> {
    let cfg_name = args.str_or("config", "tiny");
    let tag = args.req("variant")?.to_string();
    let (runner, params) = load_model(args, &cfg_name, &tag)?;
    Ok(Box::new(PjrtBackend::new(runner, params)))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_serving_backend(_args: &Args) -> Result<Box<dyn Backend>> {
    bail!("this build has no PJRT backend; rebuild with --features pjrt \
           or use --backend native")
}

#[cfg(feature = "pjrt")]
fn pjrt_eval(args: &Args, n: usize) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let tag = args.req("variant")?.to_string();
    let (runner, params) = load_model(args, &cfg_name, &tag)?;
    let gen = CorpusGen::new(runner.manifest.config.vocab, 1);
    let probes = ProbeSet::generate(&gen, n, 99);
    let rep = scorer::full_report(&runner.as_backend(&params), &probes, 4)?;
    let cfg = runner.manifest.config.clone();
    let variant = runner.manifest.variant.clone();
    print_eval(&variant, &cfg, &rep);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_eval(_args: &Args, _n: usize) -> Result<()> {
    bail!("eval --backend pjrt needs a build with --features pjrt; \
           use --backend native")
}

#[cfg(feature = "pjrt")]
fn pjrt_search(
    args: &Args,
    cfg: &ModelConfig,
    cfg_name: &str,
    method: &str,
    r: usize,
    out: &str,
) -> Result<()> {
    let engine = Arc::new(Engine::new()?);
    let runner =
        ModelRunner::new(engine, artifacts_dir(args), cfg_name, "mha")?;
    let ckpt = Checkpoint::load(args.req("ckpt")?)?;
    let params = runner.params_from_ckpt(&ckpt)?;
    let mut gen = CorpusGen::new(cfg.vocab, 1);
    gen.reseed(1, 0xca11b);
    let t0 = std::time::Instant::now();
    let sel = match method {
        "ropelite" => search::ropelite_search(&runner, &params, &mut gen, r)?,
        "contribution" => {
            search::contribution_selection(&runner, &params, &mut gen, r)?
        }
        m => bail!("unknown method `{m}`"),
    };
    println!(
        "search `{method}` r={r} done in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
    sel.to_checkpoint(cfg).save(out)?;
    println!("saved {out}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_search(
    _args: &Args,
    _cfg: &ModelConfig,
    _cfg_name: &str,
    method: &str,
    _r: usize,
    _out: &str,
) -> Result<()> {
    bail!("search method `{method}` runs the capture/delta artifacts and \
           needs --features pjrt; `--method uniform` works natively")
}

#[cfg(feature = "pjrt")]
fn cmd_pretrain(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let steps = args.usize_or("steps", 300)?;
    let lr = args.f64_or("lr", 1e-3)? as f32;
    let out = args.str_or("out", &format!("pretrained_{cfg_name}.ekvc"));
    let engine = Arc::new(Engine::new()?);
    let runner =
        ModelRunner::new(engine, artifacts_dir(args), &cfg_name, "mha")?;
    let params = runner.init(args.usize_or("seed", 42)? as i32)?;
    let mut state = TrainState::fresh(params);
    let opts = TrainOpts { steps, lr, log_every: 20, ..Default::default() };
    let mut lp = TrainLoop::new(&runner, &opts);
    let report = lp.run(&mut state, &opts)?;
    println!(
        "pretrained {cfg_name}: {} steps, {} tokens, loss {:.4}, ppl {:.3} \
         ({:.1}s, {:.2} s/step)",
        steps, report.tokens_seen, report.final_loss, report.final_ppl,
        report.seconds, report.seconds / steps as f64
    );
    let mut ckpt = runner.ckpt_from_params(&state.params)?;
    ckpt.set_meta("pretrain_steps", steps);
    ckpt.set_meta("pretrain_tokens", report.tokens_seen);
    ckpt.save(&out)?;
    println!("saved {out}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_pretrain(_args: &Args) -> Result<()> {
    bail!("pretrain drives the AdamW train_step artifact and needs a build \
           with --features pjrt (plus `make artifacts`)")
}

#[cfg(feature = "pjrt")]
fn cmd_uptrain(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let tag = args.req("variant")?.to_string();
    let steps = args.usize_or("steps", 60)?;
    let lr = args.f64_or("lr", 3e-4)? as f32;
    let out = args.str_or("out", &format!("uptrained_{cfg_name}_{tag}.ekvc"));
    let (runner, params) = load_model(args, &cfg_name, &tag)?;
    let mut state = TrainState::fresh(params);
    let opts = TrainOpts {
        steps, lr, log_every: 20, data_seed: 7, ..Default::default()
    };
    let mut lp = TrainLoop::new(&runner, &opts);
    let report = lp.run(&mut state, &opts)?;
    println!(
        "uptrained {tag}: loss {:.4}, ppl {:.3} ({:.1}s)",
        report.final_loss, report.final_ppl, report.seconds
    );
    let mut out_ckpt = runner.ckpt_from_params(&state.params)?;
    // Keep the elite selection embedded: the permuted weights are only
    // meaningful together with it (see convert::elitekv::embed_selection).
    if let Some(sel_path) = args.get("selection") {
        let cfg = runner.manifest.config.clone();
        if let Ok(sel) = EliteSelection::from_checkpoint(
            &Checkpoint::load(sel_path)?, &cfg)
        {
            convert::elitekv::embed_selection(&mut out_ckpt, &cfg, &sel);
        }
    }
    out_ckpt.save(&out)?;
    println!("saved {out}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_uptrain(_args: &Args) -> Result<()> {
    bail!("uptrain drives the AdamW train_step artifact and needs a build \
           with --features pjrt (plus `make artifacts`)")
}

#[cfg(feature = "pjrt")]
fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.pos(1).unwrap_or("all");
    let cfg_name = args.str_or("config", "tiny");
    let results = args.str_or("out", elitekv::RESULTS_DIR);
    let opts = if args.has("full") {
        SweepOpts::full()
    } else {
        SweepOpts::quick()
    };
    let ctx = ExperimentCtx::new(artifacts_dir(args), &results, opts)?;
    match which {
        "table1" => {
            experiments::table1(&ctx, &cfg_name)?;
        }
        "table2" => {
            experiments::table2(&ctx, &cfg_name)?;
        }
        "fig2" => {
            let cfg = ModelConfig::by_name(&cfg_name).context("config")?;
            let r = args.usize_or("r", cfg.n_chunks() / 2)?;
            experiments::fig2(&ctx, &cfg_name, r)?;
        }
        "fig3" => {
            experiments::fig3(&ctx, &cfg_name)?;
        }
        "fig5" => {
            experiments::fig5(&ctx, "tiny")?;
        }
        "fig6" => {
            experiments::fig6(&ctx, &cfg_name)?;
        }
        "fig7" => {
            let models = args.str_or("models", "tiny,small");
            let names: Vec<&str> = models.split(',').collect();
            experiments::fig7(&ctx, &names)?;
        }
        "serve" => {
            experiments::serve_bench(&ctx, &cfg_name,
                                     args.usize_or("requests", 24)?)?;
        }
        "all" => {
            experiments::table1(&ctx, &cfg_name)?;
            experiments::table2(&ctx, &cfg_name)?;
            let cfg = ModelConfig::by_name(&cfg_name).context("config")?;
            experiments::fig2(&ctx, &cfg_name, cfg.n_chunks() / 2)?;
            experiments::fig3(&ctx, &cfg_name)?;
            experiments::fig5(&ctx, "tiny")?;
            experiments::fig6(&ctx, &cfg_name)?;
            experiments::fig7(&ctx, &["tiny", "small"])?;
            experiments::serve_bench(&ctx, &cfg_name, 24)?;
        }
        other => bail!("unknown experiment `{other}`"),
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_experiment(_args: &Args) -> Result<()> {
    bail!("the paper-sweep experiments replay the AOT artifacts and need a \
           build with --features pjrt; `elitekv bench` runs the native \
           decode benchmark instead")
}
