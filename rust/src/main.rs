//! `elitekv` — the Layer-3 coordinator CLI.
//!
//! Subcommands (run `elitekv help` for details):
//!   pretrain    train a baseline MHA model from scratch on the synthetic
//!               corpus and save a checkpoint
//!   search      RoPElite (Algorithm 1) / Uniform / Contribution chunk
//!               selection on a pretrained checkpoint
//!   convert     weight surgery: MHA checkpoint -> gqa / elitekv / slrd
//!   uptrain     uptrain a converted checkpoint (paper §4.1 recipe)
//!   eval        probe battery + holdout perplexity for a checkpoint
//!   serve       run the inference engine on a synthetic request stream
//!   experiment  regenerate paper tables/figures (table1, table2, fig2,
//!               fig3, fig5, fig6, fig7, serve, all)
//!
//! Python never runs here: all model compute executes from AOT-compiled
//! HLO artifacts through the PJRT CPU client (`make artifacts` first).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use elitekv::bench::experiments;
use elitekv::bench::pipeline::{ExperimentCtx, SweepOpts};
use elitekv::cli::Args;
use elitekv::config::{ModelConfig, Variant};
use elitekv::convert::{self, EliteSelection};
use elitekv::coordinator::{GenParams, InferenceServer, Request};
use elitekv::data::{CorpusGen, ProbeSet};
use elitekv::io::Checkpoint;
use elitekv::runtime::{Engine, HostTensor, ModelRunner, TrainState};
use elitekv::search;
use elitekv::train::{scorer, TrainLoop, TrainOpts};

fn main() {
    init_logger();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.pos(0).unwrap_or("help") {
        "pretrain" => cmd_pretrain(args),
        "search" => cmd_search(args),
        "convert" => cmd_convert(args),
        "uptrain" => cmd_uptrain(args),
        "eval" => cmd_eval(args),
        "serve" => cmd_serve(args),
        "experiment" => cmd_experiment(args),
        "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `elitekv help`)"),
    }
}

const HELP: &str = "\
elitekv — EliteKV reproduction coordinator

USAGE: elitekv <command> [flags]

COMMANDS
  pretrain   --config tiny|small|100m --steps N [--lr F] [--out PATH]
  search     --config C --ckpt PATH --r N [--method ropelite|uniform|contribution]
             [--out PATH]
  convert    --config C --ckpt PATH --variant TAG [--selection PATH] [--out PATH]
  uptrain    --config C --variant TAG --ckpt PATH [--selection PATH]
             --steps N [--lr F] [--out PATH]
  eval       --config C --variant TAG --ckpt PATH [--selection PATH]
             [--probes N]
  serve      --config C --variant TAG --ckpt PATH [--selection PATH]
             [--requests N] [--max-new N] [--pallas]
  experiment <table1|table2|fig2|fig3|fig5|fig6|fig7|serve|all>
             [--config tiny] [--out results] [--full]

COMMON FLAGS
  --artifacts DIR   artifact directory (default: artifacts)
  ELITEKV_LOG=debug|info|warn|error controls logging
";

fn init_logger() {
    struct Stderr;
    impl log::Log for Stderr {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level().as_str().to_lowercase(),
                          r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: Stderr = Stderr;
    let _ = log::set_logger(&LOGGER);
    let level = match std::env::var("ELITEKV_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        _ => log::LevelFilter::Info,
    };
    log::set_max_level(level);
}

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", elitekv::ARTIFACTS_DIR)
}

/// Build a runner + params (+extras from a selection file) for a variant.
fn load_model(
    args: &Args,
    cfg_name: &str,
    tag: &str,
) -> Result<(ModelRunner, Vec<HostTensor>)> {
    let engine = Arc::new(Engine::new()?);
    let mut runner =
        ModelRunner::new(engine, artifacts_dir(args), cfg_name, tag)?;
    let cfg = runner.manifest.config.clone();
    let variant = runner.manifest.variant.clone();
    if !runner.manifest.extras.is_empty() {
        let sel_path = args.req("selection")?;
        let sel = EliteSelection::from_checkpoint(
            &Checkpoint::load(sel_path)?, &cfg)?;
        match variant {
            Variant::RopeLite => {
                let mask = convert::elitekv::elite_mask_flat(&cfg, &sel);
                runner.set_extras(vec![HostTensor::F32(
                    mask, vec![cfg.n_layers, cfg.n_heads, cfg.n_chunks()])])?;
            }
            Variant::EliteKv { r, .. } | Variant::Slrd { r, .. } => {
                anyhow::ensure!(sel.r() == r, "selection r mismatch");
                let theta = convert::elitekv::elite_thetas_flat(&cfg, &sel);
                runner.set_extras(vec![HostTensor::F32(
                    theta, vec![cfg.n_layers, cfg.n_heads, r])])?;
            }
            _ => {}
        }
    }
    let ckpt = Checkpoint::load(args.req("ckpt")?)?;
    let params = runner.params_from_ckpt(&ckpt)?;
    Ok((runner, params))
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let steps = args.usize_or("steps", 300)?;
    let lr = args.f64_or("lr", 1e-3)? as f32;
    let out = args.str_or("out", &format!("pretrained_{cfg_name}.ekvc"));
    let engine = Arc::new(Engine::new()?);
    let runner =
        ModelRunner::new(engine, artifacts_dir(args), &cfg_name, "mha")?;
    let params = runner.init(args.usize_or("seed", 42)? as i32)?;
    let mut state = TrainState::fresh(params);
    let opts = TrainOpts { steps, lr, log_every: 20, ..Default::default() };
    let mut lp = TrainLoop::new(&runner, &opts);
    let report = lp.run(&mut state, &opts)?;
    println!(
        "pretrained {cfg_name}: {} steps, {} tokens, loss {:.4}, ppl {:.3} \
         ({:.1}s, {:.2} s/step)",
        steps, report.tokens_seen, report.final_loss, report.final_ppl,
        report.seconds, report.seconds / steps as f64
    );
    let mut ckpt = runner.ckpt_from_params(&state.params)?;
    ckpt.set_meta("pretrain_steps", steps);
    ckpt.set_meta("pretrain_tokens", report.tokens_seen);
    ckpt.save(&out)?;
    println!("saved {out}");
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let r = args.usize_or("r", 4)?;
    let method = args.str_or("method", "ropelite");
    let out =
        args.str_or("out", &format!("elite_{cfg_name}_{method}_r{r}.ekvc"));
    let cfg = ModelConfig::by_name(&cfg_name).context("config")?;
    let engine = Arc::new(Engine::new()?);
    let runner =
        ModelRunner::new(engine, artifacts_dir(args), &cfg_name, "mha")?;
    let ckpt = Checkpoint::load(args.req("ckpt")?)?;
    let params = runner.params_from_ckpt(&ckpt)?;
    let mut gen = CorpusGen::new(cfg.vocab, 1);
    gen.reseed(1, 0xca11b);
    let t0 = std::time::Instant::now();
    let sel = match method.as_str() {
        "ropelite" => search::ropelite_search(&runner, &params, &mut gen, r)?,
        "uniform" => search::uniform_selection(&cfg, r),
        "contribution" => {
            search::contribution_selection(&runner, &params, &mut gen, r)?
        }
        m => bail!("unknown method `{m}`"),
    };
    println!(
        "search `{method}` r={r} done in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
    sel.to_checkpoint(&cfg).save(&out)?;
    println!("saved {out}");
    Ok(())
}

fn cmd_convert(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let cfg = ModelConfig::by_name(&cfg_name).context("config")?;
    let tag = args.req("variant")?;
    let variant = Variant::parse(tag).context("bad variant tag")?;
    let base = Checkpoint::load(args.req("ckpt")?)?;
    let out = args.str_or("out", &format!("{cfg_name}_{tag}.ekvc"));
    let converted = match &variant {
        Variant::Gqa { n_kv_heads } => {
            convert::convert_gqa(&cfg, &base, *n_kv_heads)?
        }
        Variant::EliteKv { r, d_ckv } => {
            let sel = EliteSelection::from_checkpoint(
                &Checkpoint::load(args.req("selection")?)?, &cfg)?;
            anyhow::ensure!(sel.r() == *r, "selection r mismatch");
            convert::convert_elitekv(&cfg, &base, &sel, *d_ckv)?
        }
        Variant::Slrd { r, d_ck, d_cv } => {
            let sel = EliteSelection::from_checkpoint(
                &Checkpoint::load(args.req("selection")?)?, &cfg)?;
            anyhow::ensure!(sel.r() == *r, "selection r mismatch");
            convert::convert_slrd(&cfg, &base, &sel, *d_ck, *d_cv)?
        }
        v => bail!("convert does not apply to `{}`", v.tag()),
    };
    converted.save(&out)?;
    println!(
        "converted -> {out} (cache ratio {:.1}%)",
        100.0 * variant.cache_ratio(&cfg)
    );
    Ok(())
}

fn cmd_uptrain(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let tag = args.req("variant")?.to_string();
    let steps = args.usize_or("steps", 60)?;
    let lr = args.f64_or("lr", 3e-4)? as f32;
    let out = args.str_or("out", &format!("uptrained_{cfg_name}_{tag}.ekvc"));
    let (runner, params) = load_model(args, &cfg_name, &tag)?;
    let mut state = TrainState::fresh(params);
    let opts = TrainOpts {
        steps, lr, log_every: 20, data_seed: 7, ..Default::default()
    };
    let mut lp = TrainLoop::new(&runner, &opts);
    let report = lp.run(&mut state, &opts)?;
    println!(
        "uptrained {tag}: loss {:.4}, ppl {:.3} ({:.1}s)",
        report.final_loss, report.final_ppl, report.seconds
    );
    runner.ckpt_from_params(&state.params)?.save(&out)?;
    println!("saved {out}");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let tag = args.req("variant")?.to_string();
    let (runner, params) = load_model(args, &cfg_name, &tag)?;
    let n = args.usize_or("probes", 25)?;
    let gen = CorpusGen::new(runner.manifest.config.vocab, 1);
    let probes = ProbeSet::generate(&gen, n, 99);
    let rep = scorer::full_report(&runner, &params, &probes, 4)?;
    println!(
        "variant {tag} (cache {:.1}%)",
        100.0 * runner.manifest.cache_ratio
    );
    for (task, acc) in &rep.scores.task_acc {
        println!("  {task:<10} {:6.2}", 100.0 * acc);
    }
    println!("  {:<10} {:6.2}", "Avg.", 100.0 * rep.scores.average);
    println!("  {:<10} {:6.3}", "ppl", rep.ppl);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "tiny");
    let tag = args.req("variant")?.to_string();
    let n = args.usize_or("requests", 24)?;
    let max_new = args.usize_or("max-new", 16)?;
    let (runner, params) = load_model(args, &cfg_name, &tag)?;
    let vocab = runner.manifest.config.vocab;
    let mut server = InferenceServer::new(runner, params, 64 << 20)?;
    server.use_pallas = args.has("pallas");
    let gen = CorpusGen::new(vocab, 1);
    let probes = ProbeSet::generate(&gen, n.div_ceil(6), 7777);
    let t0 = std::time::Instant::now();
    for (i, item) in probes.items.iter().take(n).enumerate() {
        server.submit(Request::new(
            i as u64,
            item.prompt.clone(),
            GenParams { max_new_tokens: max_new, ..Default::default() },
        ));
    }
    let responses = server.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    let toks: usize = responses.iter().map(|r| r.tokens.len()).sum();
    println!(
        "served {} requests, {} tokens in {:.2}s ({:.1} tok/s); \
         prefills {}, decode steps {}, peak cache {} KiB",
        responses.len(), toks, wall, toks as f64 / wall,
        server.stats.prefills, server.stats.decode_steps,
        server.stats.peak_cache_bytes / 1024
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.pos(1).unwrap_or("all");
    let cfg_name = args.str_or("config", "tiny");
    let results = args.str_or("out", elitekv::RESULTS_DIR);
    let opts = if args.has("full") {
        SweepOpts::full()
    } else {
        SweepOpts::quick()
    };
    let ctx = ExperimentCtx::new(artifacts_dir(args), &results, opts)?;
    match which {
        "table1" => {
            experiments::table1(&ctx, &cfg_name)?;
        }
        "table2" => {
            experiments::table2(&ctx, &cfg_name)?;
        }
        "fig2" => {
            let cfg = ModelConfig::by_name(&cfg_name).context("config")?;
            let r = args.usize_or("r", cfg.n_chunks() / 2)?;
            experiments::fig2(&ctx, &cfg_name, r)?;
        }
        "fig3" => {
            experiments::fig3(&ctx, &cfg_name)?;
        }
        "fig5" => {
            experiments::fig5(&ctx, "tiny")?;
        }
        "fig6" => {
            experiments::fig6(&ctx, &cfg_name)?;
        }
        "fig7" => {
            let models = args.str_or("models", "tiny,small");
            let names: Vec<&str> = models.split(',').collect();
            experiments::fig7(&ctx, &names)?;
        }
        "serve" => {
            experiments::serve_bench(&ctx, &cfg_name,
                                     args.usize_or("requests", 24)?)?;
        }
        "all" => {
            experiments::table1(&ctx, &cfg_name)?;
            experiments::table2(&ctx, &cfg_name)?;
            let cfg = ModelConfig::by_name(&cfg_name).context("config")?;
            experiments::fig2(&ctx, &cfg_name, cfg.n_chunks() / 2)?;
            experiments::fig3(&ctx, &cfg_name)?;
            experiments::fig5(&ctx, "tiny")?;
            experiments::fig6(&ctx, &cfg_name)?;
            experiments::fig7(&ctx, &["tiny", "small"])?;
            experiments::serve_bench(&ctx, &cfg_name, 24)?;
        }
        other => bail!("unknown experiment `{other}`"),
    }
    Ok(())
}
