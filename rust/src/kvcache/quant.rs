//! Int8 cache-row quantization (DESIGN.md S19): symmetric group-wise
//! quantize/dequantize of decode cache rows, plus [`SlabRows`], the
//! dtype-carrying row payload the radix cache and the prefix-splice path
//! exchange.
//!
//! The quantized unit is one *cache row* — the span one token writes
//! into one layer of one slab (the latent `c_kv` vector, a head-stacked
//! rotated elite key, or a dense K/V row; see
//! [`crate::kvcache::layout::slab_row_widths`]). Each row is tiled into
//! groups of [`QUANT_GROUP`] elements along the latent/head dim; a group
//! stores `round(x / scale)` clamped to `[-127, 127]` with one f32
//! `scale = max|x| / 127`. Groups never span tokens or layers, so a
//! row's quantized bytes + scales are a self-contained unit: the radix
//! cache can store, slice, and splice them without any round-trip
//! through f32 — a prefix hit replays the *exact* quantized bytes the
//! original prefill wrote, which is what makes prefix-cache-on ≡ off
//! bitwise within the int8 dtype.
//!
//! Dequantization is the single expression `(q as f32) * scale`
//! ([`dequant`]); every consumer — the window dequantizers in
//! `native::model`, the fused-dequant GEMM panels in `native::kernels`
//! — goes through it, so all paths see bit-identical f32 values for the
//! same stored bytes.

use anyhow::{bail, Result};

/// Elements per quantization group along the row (latent/head) dim.
/// 32 keeps the worst-case group-max dilution low (a row outlier only
/// costs its own 32-element group precision) while the scale overhead
/// stays at 4/32 = 12.5 % of the int8 payload — pool metadata, outside
/// the per-token byte budget (DESIGN.md S19).
pub const QUANT_GROUP: usize = 32;

/// Number of scale groups for a row of `w` elements.
pub fn n_groups(w: usize, group: usize) -> usize {
    w.div_ceil(group)
}

/// THE dequantization expression. Inlined everywhere so the fused GEMM
/// panels and the window dequantizers produce bit-identical f32 values
/// for the same stored bytes.
#[inline(always)]
pub fn dequant(q: i8, scale: f32) -> f32 {
    q as f32 * scale
}

/// Quantize one row: per group of `group` elements, `scale = max|x|/127`
/// and `q = round(x / scale)` clamped to `[-127, 127]` (an all-zero
/// group stores scale 0 and zeros — exact). `q.len() == src.len()`,
/// `scales.len() == n_groups(src.len(), group)`.
pub fn quantize_row(src: &[f32], group: usize, q: &mut [i8], scales: &mut [f32]) {
    debug_assert_eq!(q.len(), src.len());
    debug_assert_eq!(scales.len(), n_groups(src.len(), group));
    for (gi, (chunk, qchunk)) in
        src.chunks(group).zip(q.chunks_mut(group)).enumerate()
    {
        let maxabs = chunk.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        if maxabs == 0.0 {
            scales[gi] = 0.0;
            qchunk.fill(0);
            continue;
        }
        let scale = maxabs / 127.0;
        scales[gi] = scale;
        let inv = 127.0 / maxabs;
        for (qv, &x) in qchunk.iter_mut().zip(chunk) {
            *qv = (x * inv).round().clamp(-127.0, 127.0) as i8;
        }
    }
}

/// Dequantize one row quantized by [`quantize_row`] into `out`.
pub fn dequantize_row(q: &[i8], scales: &[f32], group: usize, out: &mut [f32]) {
    debug_assert_eq!(q.len(), out.len());
    debug_assert_eq!(scales.len(), n_groups(q.len(), group));
    for ((qchunk, ochunk), &scale) in
        q.chunks(group).zip(out.chunks_mut(group)).zip(scales.iter())
    {
        for (o, &qv) in ochunk.iter_mut().zip(qchunk) {
            *o = dequant(qv, scale);
        }
    }
}

/// Dtype-carrying slab row payload: the rows of one slab for a run of
/// tokens, laid out `[L, tokens, w]` (and, quantized, scales
/// `[L, tokens, g]` with `g = n_groups(w, group)`). This is the exchange
/// type between the radix cache (which stores rows in their cache dtype
/// so prefix hits splice without an f32 round-trip), the admission path,
/// and the engine's prefix splice/extract.
#[derive(Clone, Debug, PartialEq)]
pub enum SlabRows {
    /// f32 rows `[L, tokens, w]` flat.
    F32(Vec<f32>),
    /// Group-quantized rows: payload `[L, tokens, w]` i8 flat plus
    /// per-row-group scales `[L, tokens, g]` f32 flat.
    Q8 {
        /// Quantized payload `[L, tokens, w]`.
        data: Vec<i8>,
        /// Per-row-group scales `[L, tokens, g]`.
        scales: Vec<f32>,
    },
}

impl SlabRows {
    /// Zero-filled rows for `layers * tokens` rows of width `w`
    /// (`g` scale groups per row when quantized). `q8` selects the arm.
    pub fn zeros(q8: bool, layers: usize, tokens: usize, w: usize, g: usize) -> SlabRows {
        if q8 {
            SlabRows::Q8 {
                data: vec![0i8; layers * tokens * w],
                scales: vec![0.0f32; layers * tokens * g],
            }
        } else {
            SlabRows::F32(vec![0.0f32; layers * tokens * w])
        }
    }

    /// True for the quantized arm.
    pub fn is_q8(&self) -> bool {
        matches!(self, SlabRows::Q8 { .. })
    }

    /// Validate this payload covers `layers * tokens` rows of width `w`
    /// with `g` scale groups per row, and matches the expected arm.
    pub fn check(
        &self,
        q8: bool,
        layers: usize,
        tokens: usize,
        w: usize,
        g: usize,
    ) -> Result<()> {
        match self {
            SlabRows::F32(d) => {
                if q8 {
                    bail!("expected quantized rows, got f32");
                }
                if d.len() != layers * tokens * w {
                    bail!(
                        "f32 rows: {} elems != {} expected",
                        d.len(),
                        layers * tokens * w
                    );
                }
            }
            SlabRows::Q8 { data, scales } => {
                if !q8 {
                    bail!("expected f32 rows, got quantized");
                }
                if data.len() != layers * tokens * w
                    || scales.len() != layers * tokens * g
                {
                    bail!(
                        "q8 rows: {} elems / {} scales != {} / {} expected",
                        data.len(),
                        scales.len(),
                        layers * tokens * w,
                        layers * tokens * g
                    );
                }
            }
        }
        Ok(())
    }

    /// Copy token range `[src_from, src_from + n)` of every layer from
    /// `src` (laid out for `src_tokens` tokens) into `[dst_from,
    /// dst_from + n)` of `self` (laid out for `dst_tokens` tokens).
    /// Both sides must be the same arm, width `w`, `g` groups per row.
    #[allow(clippy::too_many_arguments)]
    pub fn copy_tokens(
        &mut self,
        dst_tokens: usize,
        dst_from: usize,
        src: &SlabRows,
        src_tokens: usize,
        src_from: usize,
        n: usize,
        layers: usize,
        w: usize,
        g: usize,
    ) {
        match (self, src) {
            (SlabRows::F32(d), SlabRows::F32(s)) => {
                for l in 0..layers {
                    let so = (l * src_tokens + src_from) * w;
                    let dof = (l * dst_tokens + dst_from) * w;
                    d[dof..dof + n * w].copy_from_slice(&s[so..so + n * w]);
                }
            }
            (
                SlabRows::Q8 { data: dd, scales: ds },
                SlabRows::Q8 { data: sd, scales: ss },
            ) => {
                for l in 0..layers {
                    let so = (l * src_tokens + src_from) * w;
                    let dof = (l * dst_tokens + dst_from) * w;
                    dd[dof..dof + n * w].copy_from_slice(&sd[so..so + n * w]);
                    let so = (l * src_tokens + src_from) * g;
                    let dof = (l * dst_tokens + dst_from) * g;
                    ds[dof..dof + n * g].copy_from_slice(&ss[so..so + n * g]);
                }
            }
            _ => unreachable!("SlabRows dtype mismatch (checked at insert)"),
        }
    }

    /// Extract token range `[from, to)` of every layer as a fresh
    /// payload (the radix `slice`/`split` primitive).
    pub fn slice_tokens(
        &self,
        total_tokens: usize,
        from: usize,
        to: usize,
        layers: usize,
        w: usize,
        g: usize,
    ) -> SlabRows {
        let mut out =
            SlabRows::zeros(self.is_q8(), layers, to - from, w, g);
        out.copy_tokens(
            to - from,
            0,
            self,
            total_tokens,
            from,
            to - from,
            layers,
            w,
            g,
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn randn_row(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        crate::tensor::Tensor::randn(vec![n], &mut rng).data
    }

    /// The error-budget pin (ISSUE 5): per element, symmetric group
    /// quantization bounds |x - deq(q)| by scale/2 = group_max/254 —
    /// half a quantization step of the group's own max.
    #[test]
    fn roundtrip_error_bounded_by_half_step_per_group() {
        for (w, seed) in [(64usize, 1u64), (48, 2), (33, 3), (256, 4)] {
            let src = randn_row(w, seed);
            let g = n_groups(w, QUANT_GROUP);
            let mut q = vec![0i8; w];
            let mut scales = vec![0.0f32; g];
            quantize_row(&src, QUANT_GROUP, &mut q, &mut scales);
            let mut back = vec![0.0f32; w];
            dequantize_row(&q, &scales, QUANT_GROUP, &mut back);
            for (gi, (chunk, bchunk)) in src
                .chunks(QUANT_GROUP)
                .zip(back.chunks(QUANT_GROUP))
                .enumerate()
            {
                let maxabs =
                    chunk.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                let bound = maxabs / 254.0 + 1e-7;
                for (x, b) in chunk.iter().zip(bchunk) {
                    assert!(
                        (x - b).abs() <= bound,
                        "group {gi}: |{x} - {b}| > {bound}"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_rows_are_exact_and_max_hits_127() {
        let src = vec![0.0f32; 32];
        let mut q = vec![1i8; 32];
        let mut scales = vec![1.0f32; 1];
        quantize_row(&src, QUANT_GROUP, &mut q, &mut scales);
        assert!(q.iter().all(|&v| v == 0));
        assert_eq!(scales[0], 0.0);
        let mut back = vec![9.0f32; 32];
        dequantize_row(&q, &scales, QUANT_GROUP, &mut back);
        assert!(back.iter().all(|&v| v == 0.0));

        // the group max quantizes to exactly +-127 and round-trips to
        // itself (127 * maxabs/127)
        let mut src = vec![0.25f32; 32];
        src[7] = -2.0;
        quantize_row(&src, QUANT_GROUP, &mut q, &mut scales);
        assert_eq!(q[7], -127);
        let mut back = vec![0.0f32; 32];
        dequantize_row(&q, &scales, QUANT_GROUP, &mut back);
        assert!((back[7] + 2.0).abs() < 1e-6);
    }

    #[test]
    fn partial_last_group_quantizes_independently() {
        // 48 elements = one full group + one 16-element tail; a huge
        // outlier in the tail must not dilute the first group's scale.
        let mut src = vec![0.01f32; 48];
        src[40] = 100.0;
        let g = n_groups(48, QUANT_GROUP);
        assert_eq!(g, 2);
        let mut q = vec![0i8; 48];
        let mut scales = vec![0.0f32; g];
        quantize_row(&src, QUANT_GROUP, &mut q, &mut scales);
        let mut back = vec![0.0f32; 48];
        dequantize_row(&q, &scales, QUANT_GROUP, &mut back);
        // first group keeps ~full precision despite the tail outlier
        for i in 0..32 {
            assert!((back[i] - 0.01).abs() < 0.01 / 127.0 + 1e-7);
        }
        assert!((back[40] - 100.0).abs() < 100.0 / 254.0 + 1e-4);
    }

    #[test]
    fn slab_rows_slice_and_copy_round_trip() {
        let (layers, tokens, w) = (2usize, 6usize, 8usize);
        let g = n_groups(w, QUANT_GROUP);
        // position-dependent f32 rows
        let data: Vec<f32> = (0..layers * tokens * w)
            .map(|i| i as f32 / 7.0)
            .collect();
        let rows = SlabRows::F32(data.clone());
        let mid = rows.slice_tokens(tokens, 2, 5, layers, w, g);
        let SlabRows::F32(m) = &mid else { panic!() };
        for l in 0..layers {
            for t in 0..3 {
                let want = &data[(l * tokens + 2 + t) * w..][..w];
                let got = &m[(l * 3 + t) * w..][..w];
                assert_eq!(want, got);
            }
        }
        // q8 arm: quantize per row, slice, and the sliced bytes+scales
        // must equal the directly quantized sub-rows (no re-round-trip)
        let mut qd = vec![0i8; layers * tokens * w];
        let mut qs = vec![0.0f32; layers * tokens * g];
        for r in 0..layers * tokens {
            quantize_row(
                &data[r * w..(r + 1) * w],
                QUANT_GROUP,
                &mut qd[r * w..(r + 1) * w],
                &mut qs[r * g..(r + 1) * g],
            );
        }
        let qrows = SlabRows::Q8 { data: qd.clone(), scales: qs.clone() };
        let qmid = qrows.slice_tokens(tokens, 2, 5, layers, w, g);
        let SlabRows::Q8 { data: md, scales: ms } = &qmid else { panic!() };
        for l in 0..layers {
            for t in 0..3 {
                let r_src = l * tokens + 2 + t;
                let r_dst = l * 3 + t;
                assert_eq!(
                    &qd[r_src * w..(r_src + 1) * w],
                    &md[r_dst * w..(r_dst + 1) * w]
                );
                assert_eq!(
                    &qs[r_src * g..(r_src + 1) * g],
                    &ms[r_dst * g..(r_dst + 1) * g]
                );
            }
        }
        qmid.check(true, layers, 3, w, g).unwrap();
        assert!(qmid.check(false, layers, 3, w, g).is_err());
        assert!(qmid.check(true, layers, 4, w, g).is_err());
    }
}
