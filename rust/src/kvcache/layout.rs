//! Cache geometry per architecture variant, including the named decode
//! slab shapes both backends share (DESIGN.md S10).

use crate::config::{ModelConfig, Variant};

/// Named decode-cache slab shapes for one variant, stacked over layers:
/// each entry is (name, [L, B, S, ...]). This is the layout contract the
/// PJRT artifacts bake in (python/compile/model.py::cache_specs) and the
/// native backend allocates directly:
///
/// * mha/ropelite — dense `cache_k` / `cache_v` `[L,B,S,nh,dh]`
/// * gqa          — grouped `cache_k` / `cache_v` `[L,B,S,g,dh]`
/// * elitekv      — rotated elite keys `cache_ke` `[L,B,S,nh,2r]` plus the
///   **shared** J-LRD latent slab `cache_c` `[L,B,S,d_ckv]`
/// * slrd         — `cache_ke` plus **split** latents `cache_ck` / `cache_cv`
pub fn slab_specs(
    cfg: &ModelConfig,
    variant: &Variant,
    batch: usize,
    s: usize,
) -> Vec<(&'static str, Vec<usize>)> {
    let (l, nh, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
    match variant {
        Variant::Mha | Variant::RopeLite => vec![
            ("cache_k", vec![l, batch, s, nh, dh]),
            ("cache_v", vec![l, batch, s, nh, dh]),
        ],
        Variant::Gqa { n_kv_heads } => vec![
            ("cache_k", vec![l, batch, s, *n_kv_heads, dh]),
            ("cache_v", vec![l, batch, s, *n_kv_heads, dh]),
        ],
        Variant::EliteKv { r, d_ckv } => vec![
            ("cache_ke", vec![l, batch, s, nh, 2 * r]),
            ("cache_c", vec![l, batch, s, *d_ckv]),
        ],
        Variant::Slrd { r, d_ck, d_cv } => vec![
            ("cache_ke", vec![l, batch, s, nh, 2 * r]),
            ("cache_ck", vec![l, batch, s, *d_ck]),
            ("cache_cv", vec![l, batch, s, *d_cv]),
        ],
    }
}

/// Bytes per f32 element.
const ELEM: usize = 4;

/// Geometry of one variant's decode cache.
#[derive(Clone, Debug)]
pub struct CacheLayout {
    /// The architecture variant the geometry describes.
    pub variant: Variant,
    /// Model depth (cache slabs stack over layers).
    pub n_layers: usize,
    /// f32 elements per token per layer (the paper's unit of account).
    pub elems_per_token_layer: usize,
    /// Ratio vs. the vanilla MHA cache of the same config.
    pub ratio: f64,
}

impl CacheLayout {
    /// Cache geometry of `variant` served on `cfg`.
    pub fn new(cfg: &ModelConfig, variant: Variant) -> CacheLayout {
        let elems = variant.cache_per_token(cfg);
        CacheLayout {
            ratio: variant.cache_ratio(cfg),
            elems_per_token_layer: elems,
            n_layers: cfg.n_layers,
            variant,
        }
    }

    /// Bytes of cache consumed by one token across all layers.
    pub fn bytes_per_token(&self) -> usize {
        self.elems_per_token_layer * self.n_layers * ELEM
    }

    /// Bytes for a sequence of `len` tokens.
    pub fn bytes_for_seq(&self, len: usize) -> usize {
        self.bytes_per_token() * len
    }

    /// Max concurrent tokens a memory budget supports (the capacity story:
    /// smaller cache -> more sequences or longer contexts).
    pub fn tokens_in_budget(&self, budget_bytes: usize) -> usize {
        budget_bytes / self.bytes_per_token()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ratios_hold() {
        let cfg = ModelConfig::small();
        let base = CacheLayout::new(&cfg, Variant::Mha);
        let ekv = CacheLayout::new(&cfg, Variant::EliteKv { r: 8, d_ckv: 128 });
        assert_eq!(base.elems_per_token_layer, 1024);
        assert_eq!(ekv.elems_per_token_layer, 256);
        assert!((ekv.ratio - 0.25).abs() < 1e-12);
        // 4x more tokens fit in the same budget
        let budget = 1 << 20;
        assert_eq!(
            ekv.tokens_in_budget(budget),
            4 * base.tokens_in_budget(budget)
        );
    }

    #[test]
    fn bytes_scale_with_layers() {
        let cfg = ModelConfig::tiny();
        let l = CacheLayout::new(&cfg, Variant::Mha);
        assert_eq!(l.bytes_per_token(), 512 * 4 * cfg.n_layers);
        assert_eq!(l.bytes_for_seq(10), 10 * l.bytes_per_token());
    }

    #[test]
    fn gqa_matches_head_fraction() {
        let cfg = ModelConfig::small();
        let g = CacheLayout::new(&cfg, Variant::Gqa { n_kv_heads: 2 });
        assert!((g.ratio - 0.25).abs() < 1e-12);
    }

    #[test]
    fn slab_specs_account_for_every_cached_element() {
        // The sum of per-token elements across a variant's slabs must equal
        // the paper's cache_per_token formula — the slab layout IS the
        // compression claim made concrete.
        let cfg = ModelConfig::tiny();
        for variant in [
            Variant::Mha,
            Variant::RopeLite,
            Variant::Gqa { n_kv_heads: 2 },
            Variant::EliteKv { r: 4, d_ckv: 64 },
            Variant::Slrd { r: 4, d_ck: 32, d_cv: 48 },
        ] {
            let slabs = slab_specs(&cfg, &variant, 4, 256);
            let per_token: usize = slabs
                .iter()
                .map(|(_, shape)| shape[3..].iter().product::<usize>())
                .sum();
            assert_eq!(
                per_token,
                variant.cache_per_token(&cfg),
                "variant {}",
                variant.tag()
            );
            for (_, shape) in &slabs {
                assert_eq!(&shape[..3], &[cfg.n_layers, 4, 256]);
            }
        }
    }
}
