//! Cache geometry per architecture variant.

use crate::config::{ModelConfig, Variant};

/// Bytes per f32 element.
const ELEM: usize = 4;

/// Geometry of one variant's decode cache.
#[derive(Clone, Debug)]
pub struct CacheLayout {
    pub variant: Variant,
    pub n_layers: usize,
    /// f32 elements per token per layer (the paper's unit of account).
    pub elems_per_token_layer: usize,
    /// Ratio vs. the vanilla MHA cache of the same config.
    pub ratio: f64,
}

impl CacheLayout {
    pub fn new(cfg: &ModelConfig, variant: Variant) -> CacheLayout {
        let elems = variant.cache_per_token(cfg);
        CacheLayout {
            ratio: variant.cache_ratio(cfg),
            elems_per_token_layer: elems,
            n_layers: cfg.n_layers,
            variant,
        }
    }

    /// Bytes of cache consumed by one token across all layers.
    pub fn bytes_per_token(&self) -> usize {
        self.elems_per_token_layer * self.n_layers * ELEM
    }

    /// Bytes for a sequence of `len` tokens.
    pub fn bytes_for_seq(&self, len: usize) -> usize {
        self.bytes_per_token() * len
    }

    /// Max concurrent tokens a memory budget supports (the capacity story:
    /// smaller cache -> more sequences or longer contexts).
    pub fn tokens_in_budget(&self, budget_bytes: usize) -> usize {
        budget_bytes / self.bytes_per_token()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ratios_hold() {
        let cfg = ModelConfig::small();
        let base = CacheLayout::new(&cfg, Variant::Mha);
        let ekv = CacheLayout::new(&cfg, Variant::EliteKv { r: 8, d_ckv: 128 });
        assert_eq!(base.elems_per_token_layer, 1024);
        assert_eq!(ekv.elems_per_token_layer, 256);
        assert!((ekv.ratio - 0.25).abs() < 1e-12);
        // 4x more tokens fit in the same budget
        let budget = 1 << 20;
        assert_eq!(
            ekv.tokens_in_budget(budget),
            4 * base.tokens_in_budget(budget)
        );
    }

    #[test]
    fn bytes_scale_with_layers() {
        let cfg = ModelConfig::tiny();
        let l = CacheLayout::new(&cfg, Variant::Mha);
        assert_eq!(l.bytes_per_token(), 512 * 4 * cfg.n_layers);
        assert_eq!(l.bytes_for_seq(10), 10 * l.bytes_per_token());
    }

    #[test]
    fn gqa_matches_head_fraction() {
        let cfg = ModelConfig::small();
        let g = CacheLayout::new(&cfg, Variant::Gqa { n_kv_heads: 2 });
        assert!((g.ratio - 0.25).abs() < 1e-12);
    }
}
