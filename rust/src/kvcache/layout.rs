//! Cache geometry per architecture variant, including the named decode
//! slab shapes both backends share (DESIGN.md S10) and the cache element
//! dtype axis (DESIGN.md S19): the same slab *shapes* can be stored as
//! f32 rows or as group-quantized int8 rows, and every byte-accounting
//! consumer (block pool sizing, admission control, the serving bench)
//! reads the dtype through [`CacheLayout`].

use crate::config::{ModelConfig, Variant};

/// Element storage of the decode cache slabs (DESIGN.md S19).
///
/// * [`CacheDtype::F32`] — 4 bytes/element, the exact-serving baseline.
/// * [`CacheDtype::Int8`] — 1 byte/element, symmetric group-quantized
///   rows (group size [`crate::kvcache::quant::QUANT_GROUP`] over the
///   row/latent dim) with one f32 scale per group stored alongside the
///   payload. Scale metadata is accounted as pool metadata outside the
///   per-token byte budget — like vLLM's block tables, it is a few
///   percent of the payload and amortizes per block — so
///   `bytes_per_token` compounds the paper's low-rank reduction by
///   exactly 4x.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheDtype {
    /// Full-precision f32 cache rows (4 bytes per element).
    F32,
    /// Symmetric group-quantized int8 cache rows (1 byte per element
    /// plus per-group f32 scale metadata).
    Int8,
}

impl CacheDtype {
    /// Payload bytes per cache element.
    pub fn bytes_per_elem(&self) -> usize {
        match self {
            CacheDtype::F32 => 4,
            CacheDtype::Int8 => 1,
        }
    }

    /// CLI/report tag ("f32" / "int8").
    pub fn tag(&self) -> &'static str {
        match self {
            CacheDtype::F32 => "f32",
            CacheDtype::Int8 => "int8",
        }
    }

    /// Parse a `--cache-dtype` value.
    pub fn parse(s: &str) -> Option<CacheDtype> {
        match s {
            "f32" => Some(CacheDtype::F32),
            "int8" | "i8" | "q8" => Some(CacheDtype::Int8),
            _ => None,
        }
    }
}

/// Named decode-cache slab shapes for one variant, stacked over layers:
/// each entry is (name, [L, B, S, ...]). This is the layout contract the
/// PJRT artifacts bake in (python/compile/model.py::cache_specs) and the
/// native backend allocates directly:
///
/// * mha/ropelite — dense `cache_k` / `cache_v` `[L,B,S,nh,dh]`
/// * gqa          — grouped `cache_k` / `cache_v` `[L,B,S,g,dh]`
/// * elitekv      — rotated elite keys `cache_ke` `[L,B,S,nh,2r]` plus the
///   **shared** J-LRD latent slab `cache_c` `[L,B,S,d_ckv]`
/// * slrd         — `cache_ke` plus **split** latents `cache_ck` / `cache_cv`
///
/// Shapes are dtype-independent; at [`CacheDtype::Int8`] the same shapes
/// are stored as group-quantized i8 payloads with per-row-group f32
/// scales (see [`slab_row_widths`] for the quantization row width of
/// each slab, and `runtime::HostTensor::Q8` for the storage form).
pub fn slab_specs(
    cfg: &ModelConfig,
    variant: &Variant,
    batch: usize,
    s: usize,
) -> Vec<(&'static str, Vec<usize>)> {
    let (l, nh, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
    match variant {
        Variant::Mha | Variant::RopeLite => vec![
            ("cache_k", vec![l, batch, s, nh, dh]),
            ("cache_v", vec![l, batch, s, nh, dh]),
        ],
        Variant::Gqa { n_kv_heads } => vec![
            ("cache_k", vec![l, batch, s, *n_kv_heads, dh]),
            ("cache_v", vec![l, batch, s, *n_kv_heads, dh]),
        ],
        Variant::EliteKv { r, d_ckv } => vec![
            ("cache_ke", vec![l, batch, s, nh, 2 * r]),
            ("cache_c", vec![l, batch, s, *d_ckv]),
        ],
        Variant::Slrd { r, d_ck, d_cv } => vec![
            ("cache_ke", vec![l, batch, s, nh, 2 * r]),
            ("cache_ck", vec![l, batch, s, *d_ck]),
            ("cache_cv", vec![l, batch, s, *d_cv]),
        ],
    }
}

/// Per-slab quantization row width: the f32 elements one token writes
/// into one layer of each slab (`shape[3..].product()`). This is the
/// span int8 quantization groups tile (group-wise over the latent /
/// head dims, never across tokens or layers), and the row stride the
/// radix cache stores rows at.
pub fn slab_row_widths(cfg: &ModelConfig, variant: &Variant) -> Vec<usize> {
    slab_specs(cfg, variant, 1, 1)
        .iter()
        .map(|(_, shape)| shape[3..].iter().product())
        .collect()
}

/// Geometry of one variant's decode cache.
#[derive(Clone, Debug)]
pub struct CacheLayout {
    /// The architecture variant the geometry describes.
    pub variant: Variant,
    /// Model depth (cache slabs stack over layers).
    pub n_layers: usize,
    /// Cache elements per token per layer (the paper's unit of account;
    /// dtype-independent).
    pub elems_per_token_layer: usize,
    /// Ratio vs. the vanilla MHA cache of the same config (element
    /// count, dtype-independent).
    pub ratio: f64,
    /// Element storage of the slabs — the second compression axis.
    pub dtype: CacheDtype,
}

impl CacheLayout {
    /// Cache geometry of `variant` served on `cfg` at f32 (the exact
    /// baseline; see [`CacheLayout::with_dtype`] for the int8 axis).
    pub fn new(cfg: &ModelConfig, variant: Variant) -> CacheLayout {
        CacheLayout::with_dtype(cfg, variant, CacheDtype::F32)
    }

    /// Cache geometry of `variant` served on `cfg` with an explicit
    /// element dtype.
    pub fn with_dtype(
        cfg: &ModelConfig,
        variant: Variant,
        dtype: CacheDtype,
    ) -> CacheLayout {
        let elems = variant.cache_per_token(cfg);
        CacheLayout {
            ratio: variant.cache_ratio(cfg),
            elems_per_token_layer: elems,
            n_layers: cfg.n_layers,
            variant,
            dtype,
        }
    }

    /// Bytes of cache payload consumed by one token across all layers.
    /// At int8 this is exactly 1/4 of the f32 figure — the compounding
    /// multiplier on the paper's low-rank element reduction (per-group
    /// scale metadata is pool metadata, not per-token payload; DESIGN.md
    /// S19).
    pub fn bytes_per_token(&self) -> usize {
        self.elems_per_token_layer * self.n_layers * self.dtype.bytes_per_elem()
    }

    /// Bytes for a sequence of `len` tokens.
    pub fn bytes_for_seq(&self, len: usize) -> usize {
        self.bytes_per_token() * len
    }

    /// Max concurrent tokens a memory budget supports (the capacity story:
    /// smaller cache -> more sequences or longer contexts).
    pub fn tokens_in_budget(&self, budget_bytes: usize) -> usize {
        budget_bytes / self.bytes_per_token()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ratios_hold() {
        let cfg = ModelConfig::small();
        let base = CacheLayout::new(&cfg, Variant::Mha);
        let ekv = CacheLayout::new(&cfg, Variant::EliteKv { r: 8, d_ckv: 128 });
        assert_eq!(base.elems_per_token_layer, 1024);
        assert_eq!(ekv.elems_per_token_layer, 256);
        assert!((ekv.ratio - 0.25).abs() < 1e-12);
        // 4x more tokens fit in the same budget
        let budget = 1 << 20;
        assert_eq!(
            ekv.tokens_in_budget(budget),
            4 * base.tokens_in_budget(budget)
        );
    }

    #[test]
    fn int8_quarters_bytes_and_quadruples_capacity() {
        // The acceptance identity: at int8 the jlrd-25 layout's
        // bytes_per_token is EXACTLY 1/4 of the f32 value (scale
        // metadata is pool metadata, not per-token payload), so the
        // compression compounds to 16x vs the dense f32 baseline.
        let cfg = ModelConfig::small();
        let var = Variant::EliteKv { r: 8, d_ckv: 128 };
        let f32l = CacheLayout::new(&cfg, var.clone());
        let i8l = CacheLayout::with_dtype(&cfg, var, CacheDtype::Int8);
        assert_eq!(i8l.bytes_per_token() * 4, f32l.bytes_per_token());
        let dense = CacheLayout::new(&cfg, Variant::Mha);
        assert_eq!(i8l.bytes_per_token() * 16, dense.bytes_per_token());
        // capacity: 4x tokens vs f32 same-variant, 16x vs dense f32
        let budget = 1 << 22;
        assert_eq!(
            i8l.tokens_in_budget(budget),
            4 * f32l.tokens_in_budget(budget)
        );
        assert_eq!(
            i8l.tokens_in_budget(budget),
            16 * dense.tokens_in_budget(budget)
        );
    }

    #[test]
    fn dtype_tags_round_trip() {
        for d in [CacheDtype::F32, CacheDtype::Int8] {
            assert_eq!(CacheDtype::parse(d.tag()), Some(d));
        }
        assert_eq!(CacheDtype::parse("fp16"), None);
    }

    #[test]
    fn row_widths_match_slab_specs() {
        let cfg = ModelConfig::tiny();
        for variant in [
            Variant::Mha,
            Variant::EliteKv { r: 4, d_ckv: 64 },
            Variant::Slrd { r: 4, d_ck: 32, d_cv: 48 },
        ] {
            let widths = slab_row_widths(&cfg, &variant);
            let specs = slab_specs(&cfg, &variant, 4, 8);
            assert_eq!(widths.len(), specs.len());
            for (w, (_, shape)) in widths.iter().zip(&specs) {
                assert_eq!(*w, shape[3..].iter().product::<usize>());
            }
        }
    }

    #[test]
    fn bytes_scale_with_layers() {
        let cfg = ModelConfig::tiny();
        let l = CacheLayout::new(&cfg, Variant::Mha);
        assert_eq!(l.bytes_per_token(), 512 * 4 * cfg.n_layers);
        assert_eq!(l.bytes_for_seq(10), 10 * l.bytes_per_token());
    }

    #[test]
    fn gqa_matches_head_fraction() {
        let cfg = ModelConfig::small();
        let g = CacheLayout::new(&cfg, Variant::Gqa { n_kv_heads: 2 });
        assert!((g.ratio - 0.25).abs() < 1e-12);
    }

    #[test]
    fn slab_specs_account_for_every_cached_element() {
        // The sum of per-token elements across a variant's slabs must equal
        // the paper's cache_per_token formula — the slab layout IS the
        // compression claim made concrete.
        let cfg = ModelConfig::tiny();
        for variant in [
            Variant::Mha,
            Variant::RopeLite,
            Variant::Gqa { n_kv_heads: 2 },
            Variant::EliteKv { r: 4, d_ckv: 64 },
            Variant::Slrd { r: 4, d_ck: 32, d_cv: 48 },
        ] {
            let slabs = slab_specs(&cfg, &variant, 4, 256);
            let per_token: usize = slabs
                .iter()
                .map(|(_, shape)| shape[3..].iter().product::<usize>())
                .sum();
            assert_eq!(
                per_token,
                variant.cache_per_token(&cfg),
                "variant {}",
                variant.tag()
            );
            for (_, shape) in &slabs {
                assert_eq!(&shape[..3], &[cfg.n_layers, 4, 256]);
            }
        }
    }
}
