//! KV-cache management substrate (DESIGN.md S10).
//!
//! Three pieces:
//! * [`layout`]  — per-variant cache geometry and byte accounting, plus
//!   the named decode slab shapes (`slab_specs`) both backends share;
//!   this is where the paper's headline claim (2·r·n_h + d_ckv elements
//!   per token per layer instead of 2·n_h·d_h) becomes measurable, and
//!   where the J-LRD shared-latent vs S-LRD split-latent slabs are
//!   defined.
//! * [`block`]   — a paged block allocator with ref-counting (vLLM-style):
//!   admission control and memory budgeting for the serving coordinator.
//! * [`manager`] — slot-based cache state bound to the fixed-batch decode
//!   lanes: owns the cache tensors, assigns sequence slots, tracks
//!   lengths, and reports live cache bytes.
//! * [`radix`]   — the prefix radix cache (DESIGN.md S18): automatic
//!   cross-request sharing of block-aligned prompt prefixes over the
//!   refcounted pool, with longest-prefix lookup on admission,
//!   insert-on-free, and LRU leaf eviction under pool pressure.

pub mod block;
pub mod layout;
pub mod manager;
pub mod radix;

pub use block::BlockAllocator;
pub use layout::{slab_specs, CacheLayout};
pub use manager::SlotManager;
pub use radix::{PrefixHit, PrefixStats, RadixCache};
