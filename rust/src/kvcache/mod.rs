//! KV-cache management substrate (DESIGN.md S10).
//!
//! Three pieces:
//! * [`layout`]  — per-variant cache geometry and byte accounting, plus
//!   the named decode slab shapes (`slab_specs`) both backends share;
//!   this is where the paper's headline claim (2·r·n_h + d_ckv elements
//!   per token per layer instead of 2·n_h·d_h) becomes measurable, and
//!   where the J-LRD shared-latent vs S-LRD split-latent slabs are
//!   defined.
//! * [`block`]   — a paged block allocator with ref-counting (vLLM-style):
//!   admission control and memory budgeting for the serving coordinator.
//! * [`manager`] — slot-based cache state bound to the fixed-batch decode
//!   lanes: owns the cache tensors, assigns sequence slots, tracks
//!   lengths, and reports live cache bytes.
//! * [`radix`]   — the prefix radix cache (DESIGN.md S18): automatic
//!   cross-request sharing of block-aligned prompt prefixes over the
//!   refcounted pool, with longest-prefix lookup on admission,
//!   insert-on-free, and LRU leaf eviction under pool pressure.
//! * [`quant`]   — int8 cache-row quantization (DESIGN.md S19): the
//!   symmetric group-wise quantize/dequantize primitives behind
//!   [`layout::CacheDtype::Int8`], and [`quant::SlabRows`], the
//!   dtype-carrying row payload the radix cache stores so prefix hits
//!   splice quantized bytes without an f32 round-trip.

pub mod block;
pub mod layout;
pub mod manager;
pub mod quant;
pub mod radix;

pub use block::BlockAllocator;
pub use layout::{slab_row_widths, slab_specs, CacheDtype, CacheLayout};
pub use manager::SlotManager;
pub use quant::SlabRows;
pub use radix::{PrefixEvent, PrefixHit, PrefixStats, RadixCache};
