//! KV-cache management substrate (DESIGN.md S10).
//!
//! Three pieces:
//! * [`layout`]  — per-variant cache geometry and byte accounting; this is
//!   where the paper's headline claim (2·r·n_h + d_ckv elements per token
//!   per layer instead of 2·n_h·d_h) becomes measurable.
//! * [`block`]   — a paged block allocator with ref-counting (vLLM-style):
//!   admission control and memory budgeting for the serving coordinator.
//! * [`manager`] — slot-based cache state bound to the fixed-batch decode
//!   artifacts: owns the cache tensors, assigns sequence slots, tracks
//!   lengths, and reports live cache bytes.

pub mod block;
pub mod layout;
pub mod manager;

pub use block::BlockAllocator;
pub use layout::CacheLayout;
pub use manager::SlotManager;
