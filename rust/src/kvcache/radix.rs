//! Prefix radix cache: automatic cross-request KV-prefix sharing over
//! the paged block pool (DESIGN.md S18).
//!
//! EliteKV's J-LRD layout makes prefix reuse unusually cheap: a token's
//! cache entry is one shared latent row (`c_kv`) plus a small rotated
//! elite key, so a shared system prompt is a single compressed chain to
//! refcount — no per-head K/V pair to reconcile. This module is the
//! structure that exploits it:
//!
//! * the tree is **block-granular**: every node owns a block-aligned
//!   token run (`tokens.len() == blocks.len() * block_tokens`) and the
//!   slab rows computed for those tokens, keyed from its parent by the
//!   run's first block of tokens. Partial blocks are never cached — a
//!   trailing partial block would be mutated by whichever request is
//!   still appending to it, breaking aliasing.
//! * **insert-on-free**: when a request completes, the full-block prefix
//!   of its *prompt* is inserted; the novel tail of the path `fork`s the
//!   request's chain (per-block refcount bump in the
//!   [`BlockAllocator`]), so the cache owns its own references and the
//!   blocks stay accounted in the pool after the request releases.
//! * **longest-prefix lookup** on admission: the matched chain is
//!   `fork`ed to the caller (copy-on-write is automatic: the new request
//!   writes only positions `>= matched`, which live in freshly allocated
//!   blocks — shared blocks are never written twice).
//! * **LRU eviction** under pool pressure: least-recently-used leaves
//!   release the cache's block references until enough blocks are free;
//!   interior nodes are never evicted before their children (prefix
//!   closure is preserved).
//!
//! The cache stores the actual slab rows (`[L, run, w]` per slab) next
//! to each node because the serving runtimes use dense per-lane slabs:
//! a prefix hit is replayed by splicing the stored rows into the
//! admitted lane and prefilling only the suffix. The refcounted blocks
//! are the byte accounting for exactly that stored copy.
//!
//! Rows are stored in the engine's cache dtype ([`SlabRows`]): f32, or
//! — under `--cache-dtype int8` (DESIGN.md S19) — the quantized i8
//! payload plus its per-row-group scales. Quantized rows are captured
//! and replayed as stored bytes, never round-tripped through f32, so a
//! prefix hit splices exactly what the original prefill wrote and
//! cache-on ≡ cache-off stays bitwise *within* a dtype.

use std::collections::HashMap;

use anyhow::{bail, ensure, Result};

use crate::kvcache::block::{BlockAllocator, BlockId};
use crate::kvcache::layout::CacheDtype;
use crate::kvcache::quant::{n_groups, SlabRows, QUANT_GROUP};

/// Cumulative + gauge counters of one [`RadixCache`].
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefixStats {
    /// Admissions that reused at least one cached block.
    pub hits: usize,
    /// Admissions that found no cached prefix.
    pub misses: usize,
    /// Total prompt tokens served from the cache instead of prefilled.
    pub hit_tokens: usize,
    /// Blocks released by LRU eviction (cumulative).
    pub evicted_blocks: usize,
    /// Blocks currently held by the cache (gauge).
    pub cached_blocks: usize,
}

/// Result of a longest-prefix [`RadixCache::lookup`].
#[derive(Debug, Default)]
pub struct PrefixHit {
    /// Matched prompt tokens (a multiple of `block_tokens`; 0 = miss).
    pub tokens: usize,
    /// Forked block chain covering the matched tokens — the caller owns
    /// these references and must `release` them with the rest of its
    /// chain.
    pub chain: Vec<BlockId>,
    /// Stored slab rows for the matched tokens, one `[L, tokens, w]`
    /// payload per cache slab, in the engine's cache dtype.
    pub rows: Vec<SlabRows>,
}

/// One change to the set of cached block-aligned prefixes, emitted by
/// the [`RadixCache`] when delta tracking is on
/// ([`RadixCache::set_event_tracking`]). The sharded router's shadow
/// index (DESIGN.md S24) replays these to mirror a worker's cache
/// contents tokens-only — no slab rows ride along, so an event costs
/// bytes proportional to the token run, not the cache payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PrefixEvent {
    /// A novel tail was cached. `tokens` is the full block-aligned
    /// root path of the new leaf; its trailing `new_blocks` blocks are
    /// the newly cached ones (the leading blocks were already held by
    /// ancestor nodes and were announced by earlier events).
    Insert {
        /// Full root-path token run of the inserted leaf.
        tokens: Vec<u32>,
        /// How many trailing blocks of `tokens` are newly cached.
        new_blocks: usize,
    },
    /// A leaf was evicted. `tokens` is the removed leaf's full
    /// block-aligned root path; its trailing `removed_blocks` blocks
    /// left the cache (ancestor blocks survive until they become
    /// childless leaves and are evicted by their own event).
    Evict {
        /// Full root-path token run of the removed leaf.
        tokens: Vec<u32>,
        /// How many trailing blocks of `tokens` left the cache.
        removed_blocks: usize,
    },
}

/// One tree node: a block-aligned token run plus its cached slab rows.
#[derive(Debug)]
struct Node {
    parent: usize,
    /// Token run; `tokens.len() == blocks.len() * block_tokens` (the
    /// root's run is empty).
    tokens: Vec<u32>,
    /// Cache-owned references into the block pool, one per full block.
    blocks: Vec<BlockId>,
    /// Stored slab rows, one `[L, run, w]` payload per slab (dtype from
    /// the cache).
    data: Vec<SlabRows>,
    /// Children keyed by the first `block_tokens` tokens of their run
    /// (siblings always differ somewhere within that first block).
    children: HashMap<Vec<u32>, usize>,
    /// LRU clock stamp of the last lookup/insert touching this node.
    last_used: u64,
}

/// Token-keyed radix tree over refcounted block chains.
#[derive(Debug)]
pub struct RadixCache {
    /// Tokens per block (the sharing granularity; matches the pool).
    pub block_tokens: usize,
    layers: usize,
    /// Per-slab row width (cache elements per token per layer).
    widths: Vec<usize>,
    /// Per-slab scale groups per row (`n_groups(w, QUANT_GROUP)`; only
    /// read when `dtype` is int8).
    groups: Vec<usize>,
    /// Element dtype the stored rows carry (must match the engine's
    /// slabs: rows are spliced back verbatim).
    dtype: CacheDtype,
    /// Node arena; index 0 is the (empty, unevictable) root.
    nodes: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    clock: u64,
    stats: PrefixStats,
    /// When true, insert/evict mutations append [`PrefixEvent`]s for
    /// [`RadixCache::take_events`]. Off by default: a single-worker
    /// engine has no delta consumer and the backlog would only grow.
    track_events: bool,
    /// Pending delta events since the last `take_events`.
    events: Vec<PrefixEvent>,
}

impl RadixCache {
    /// Empty cache over blocks of `block_tokens` tokens for a model of
    /// `layers` layers whose slabs have `widths[si]` elements per token
    /// per layer, stored in `dtype` (int8 rows carry their quantization
    /// scales alongside; see [`SlabRows`]).
    pub fn new(
        block_tokens: usize,
        layers: usize,
        widths: Vec<usize>,
        dtype: CacheDtype,
    ) -> RadixCache {
        assert!(block_tokens > 0, "block_tokens must be > 0");
        assert!(layers > 0, "layers must be > 0");
        let q8 = dtype == CacheDtype::Int8;
        let groups: Vec<usize> =
            widths.iter().map(|&w| n_groups(w, QUANT_GROUP)).collect();
        let root = Node {
            parent: 0,
            tokens: Vec::new(),
            blocks: Vec::new(),
            data: widths
                .iter()
                .zip(&groups)
                .map(|(&w, &g)| SlabRows::zeros(q8, layers, 0, w, g))
                .collect(),
            children: HashMap::new(),
            last_used: 0,
        };
        RadixCache {
            block_tokens,
            layers,
            widths,
            groups,
            dtype,
            nodes: vec![Some(root)],
            free_slots: Vec::new(),
            clock: 0,
            stats: PrefixStats::default(),
            track_events: false,
            events: Vec::new(),
        }
    }

    /// Enable or disable delta-event tracking (see [`PrefixEvent`]).
    /// Disabling discards any pending events.
    pub fn set_event_tracking(&mut self, on: bool) {
        self.track_events = on;
        if !on {
            self.events.clear();
        }
    }

    /// Drain the pending delta events (always empty unless
    /// [`RadixCache::set_event_tracking`] turned tracking on). Events
    /// are ordered exactly as the mutations happened, so replaying them
    /// into an empty mirror reproduces the cached-prefix set.
    pub fn take_events(&mut self) -> Vec<PrefixEvent> {
        std::mem::take(&mut self.events)
    }

    /// Full block-aligned root-path token run of node `i` (ancestor
    /// runs concatenated with its own run).
    fn full_path_tokens(&self, i: usize) -> Vec<u32> {
        let mut chain = Vec::new();
        let mut cur = i;
        while cur != 0 {
            chain.push(cur);
            cur = self.node(cur).parent;
        }
        let mut out = Vec::new();
        for &n in chain.iter().rev() {
            out.extend_from_slice(&self.node(n).tokens);
        }
        out
    }

    /// The element dtype stored rows carry.
    pub fn dtype(&self) -> CacheDtype {
        self.dtype
    }

    /// Current counter snapshot.
    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    /// Blocks currently held by the cache.
    pub fn cached_blocks(&self) -> usize {
        self.stats.cached_blocks
    }

    /// Record the prefix outcome of one *successful* admission (hits and
    /// miss counters are admission-scoped, not lookup-scoped, so a
    /// lookup whose admission then fails on pool pressure is not
    /// counted).
    pub fn record_admission(&mut self, cached_tokens: usize) {
        if cached_tokens > 0 {
            self.stats.hits += 1;
            self.stats.hit_tokens += cached_tokens;
        } else {
            self.stats.misses += 1;
        }
    }

    fn node(&self, i: usize) -> &Node {
        // lint: allow(R3) — slab invariant: child edges only ever hold
        // live node indices (removal unlinks the edge first).
        self.nodes[i].as_ref().expect("live node")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node {
        // lint: allow(R3) — same slab invariant as `node` above.
        self.nodes[i].as_mut().expect("live node")
    }

    fn touch(&mut self, i: usize) {
        self.clock += 1;
        let clock = self.clock;
        self.node_mut(i).last_used = clock;
    }

    fn alloc_slot(&mut self, node: Node) -> usize {
        match self.free_slots.pop() {
            Some(i) => {
                self.nodes[i] = Some(node);
                i
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        }
    }

    /// Longest cached prefix of `prompt`, in full blocks, capped at
    /// `max_tokens` (callers pass `prompt.len() - 1` so at least one
    /// prompt token is always left to prefill — the engine needs a
    /// final-position forward pass to produce first logits). The matched
    /// chain is `fork`ed: the caller owns those references.
    pub fn lookup(
        &mut self,
        prompt: &[u32],
        max_tokens: usize,
        alloc: &mut BlockAllocator,
    ) -> Result<PrefixHit> {
        let bt = self.block_tokens;
        let cap_blocks = prompt.len().min(max_tokens) / bt;
        let mut segments: Vec<(usize, usize)> = Vec::new(); // (node, blocks used)
        let mut matched = 0usize; // blocks
        let mut cur = 0usize;
        while matched < cap_blocks {
            let key = &prompt[matched * bt..(matched + 1) * bt];
            let found = self.node(cur).children.get(key).copied();
            let Some(child) = found else { break };
            let nb = self.node(child).blocks.len();
            let mut m = 1usize; // first block matched via the key
            while m < nb && matched + m < cap_blocks {
                let lo = (matched + m) * bt;
                if self.node(child).tokens[m * bt..(m + 1) * bt]
                    != prompt[lo..lo + bt]
                {
                    break;
                }
                m += 1;
            }
            segments.push((child, m));
            matched += m;
            self.touch(child);
            if m < nb {
                break; // partial node match: the run diverges or the cap hit
            }
            cur = child;
        }
        if matched == 0 {
            return Ok(PrefixHit::default());
        }
        // Assemble the forked chain + stored rows in token order.
        let mut chain = Vec::with_capacity(matched);
        for &(node, m) in &segments {
            chain.extend_from_slice(&self.node(node).blocks[..m]);
        }
        let chain = alloc.fork(&chain)?;
        let tokens = matched * bt;
        let q8 = self.dtype == CacheDtype::Int8;
        let mut rows = Vec::with_capacity(self.widths.len());
        for (si, (&w, &g)) in
            self.widths.iter().zip(&self.groups).enumerate()
        {
            let mut out = SlabRows::zeros(q8, self.layers, tokens, w, g);
            let mut p = 0usize; // output token cursor
            for &(node, m) in &segments {
                let node_ref = self.node(node);
                let run = node_ref.tokens.len();
                let seg = m * self.block_tokens;
                out.copy_tokens(
                    tokens,
                    p,
                    &node_ref.data[si],
                    run,
                    0,
                    seg,
                    self.layers,
                    w,
                    g,
                );
                p += seg;
            }
            rows.push(out);
        }
        Ok(PrefixHit { tokens, chain, rows })
    }

    /// Insert the full-block prefix of `tokens` (a finished request's
    /// prompt), aliasing `chain` (the request's block chain, which must
    /// cover it). `rows` produces the slab rows — one `[L, aligned, w]`
    /// buffer per slab, where `aligned = (tokens.len() / block_tokens)
    /// * block_tokens` — and is invoked ONLY when a novel tail is
    /// actually cached, so the steady-state fully-cached completion
    /// copies nothing. Only the novel tail allocates cache references
    /// (via `fork`); an already-cached path is just LRU touched.
    /// Returns the number of newly cached blocks.
    pub fn insert<F>(
        &mut self,
        tokens: &[u32],
        chain: &[BlockId],
        rows: F,
        alloc: &mut BlockAllocator,
    ) -> Result<usize>
    where
        F: FnOnce() -> Result<Vec<SlabRows>>,
    {
        let bt = self.block_tokens;
        let total = tokens.len() / bt; // full blocks to ensure cached
        if total == 0 {
            return Ok(0);
        }
        ensure!(
            chain.len() >= total,
            "insert chain of {} blocks cannot cover {total} prompt blocks",
            chain.len()
        );
        let mut matched = 0usize; // blocks
        let mut cur = 0usize;
        self.touch(cur);
        while matched < total {
            let key = tokens[matched * bt..(matched + 1) * bt].to_vec();
            let found = self.node(cur).children.get(&key[..]).copied();
            let Some(child) = found else {
                // Novel tail: one new leaf holds the whole remainder.
                // Materialize + validate the rows only now.
                let rows = rows()?;
                ensure!(
                    rows.len() == self.widths.len(),
                    "insert got {} row buffers for {} slabs",
                    rows.len(),
                    self.widths.len()
                );
                let q8 = self.dtype == CacheDtype::Int8;
                for (si, (&w, &g)) in
                    self.widths.iter().zip(&self.groups).enumerate()
                {
                    rows[si]
                        .check(q8, self.layers, total * bt, w, g)
                        .map_err(|e| anyhow::anyhow!("slab {si}: {e}"))?;
                }
                let fresh = alloc.fork(&chain[matched..total])?;
                let n_new = fresh.len();
                let leaf = Node {
                    parent: cur,
                    tokens: tokens[matched * bt..total * bt].to_vec(),
                    blocks: fresh,
                    data: self.slice_rows(&rows, total, matched, total),
                    children: HashMap::new(),
                    last_used: 0,
                };
                let slot = self.alloc_slot(leaf);
                self.node_mut(cur).children.insert(key, slot);
                self.touch(slot);
                self.stats.cached_blocks += n_new;
                if self.track_events {
                    self.events.push(PrefixEvent::Insert {
                        tokens: tokens[..total * bt].to_vec(),
                        new_blocks: n_new,
                    });
                }
                return Ok(n_new);
            };
            let nb = self.node(child).blocks.len();
            let mut m = 1usize;
            while m < nb && matched + m < total {
                let lo = (matched + m) * bt;
                if self.node(child).tokens[m * bt..(m + 1) * bt]
                    != tokens[lo..lo + bt]
                {
                    break;
                }
                m += 1;
            }
            self.touch(child);
            matched += m;
            if m == nb {
                cur = child; // fully consumed this node's run
                continue;
            }
            if matched == total {
                return Ok(0); // prefix already present mid-run
            }
            // Divergence inside the run: split `child` at block m, then
            // loop back — the next iteration sees the shortened node and
            // hangs the novel tail off it.
            self.split(child, m);
            cur = child;
        }
        Ok(0) // the whole prefix was already cached
    }

    /// Split node `i`'s run after `at` blocks: `i` keeps the head run,
    /// a new child takes the tail run plus `i`'s former children. Block
    /// references just move between nodes (no refcount change).
    fn split(&mut self, i: usize, at: usize) {
        let bt = self.block_tokens;
        let (tail_tokens, tail_blocks, old_children, last_used, old_data) = {
            // lint: allow(R3) — split is only called on a live interior
            // node found by walk().
            let node = self.nodes[i].as_mut().expect("live node");
            debug_assert!(at > 0 && at < node.blocks.len());
            (
                node.tokens.split_off(at * bt),
                node.blocks.split_off(at),
                std::mem::take(&mut node.children),
                node.last_used,
                std::mem::take(&mut node.data),
            )
        };
        let run = at + tail_blocks.len(); // original run length in blocks
        let mut head_data = Vec::with_capacity(self.widths.len());
        let mut tail_data = Vec::with_capacity(self.widths.len());
        for ((&w, &g), old) in
            self.widths.iter().zip(&self.groups).zip(&old_data)
        {
            let (head_t, run_t) = (at * bt, run * bt);
            head_data.push(old.slice_tokens(
                run_t,
                0,
                head_t,
                self.layers,
                w,
                g,
            ));
            tail_data.push(old.slice_tokens(
                run_t,
                head_t,
                run_t,
                self.layers,
                w,
                g,
            ));
        }
        let key = tail_tokens[..bt].to_vec();
        let tail_node = Node {
            parent: i,
            tokens: tail_tokens,
            blocks: tail_blocks,
            data: tail_data,
            children: old_children,
            last_used,
        };
        let slot = self.alloc_slot(tail_node);
        // Re-parent the moved grandchildren.
        let grand: Vec<usize> =
            self.node(slot).children.values().copied().collect();
        for g in grand {
            self.node_mut(g).parent = slot;
        }
        let node = self.node_mut(i);
        node.data = head_data;
        node.children.insert(key, slot);
    }

    /// Slice `rows` (covering `total` blocks) down to blocks
    /// `[from, to)`, preserving the per-slab `[L, run, w]` layout (and
    /// the per-row scales when quantized).
    fn slice_rows(
        &self,
        rows: &[SlabRows],
        total: usize,
        from: usize,
        to: usize,
    ) -> Vec<SlabRows> {
        let bt = self.block_tokens;
        self.widths
            .iter()
            .zip(&self.groups)
            .enumerate()
            .map(|(si, (&w, &g))| {
                rows[si].slice_tokens(
                    total * bt,
                    from * bt,
                    to * bt,
                    self.layers,
                    w,
                    g,
                )
            })
            .collect()
    }

    /// Evict least-recently-used leaves until the pool has at least
    /// `want_free` free blocks or no evictable leaf remains. Returns the
    /// number of blocks whose cache reference was released (they return
    /// to the free pool unless a live request still forks them).
    ///
    /// Victim selection is a linear scan of the node arena per evicted
    /// leaf — O(leaves × arena) under sustained pressure. Fine at
    /// serving-bench scale (tens of nodes); a heap/intrusive LRU list
    /// over leaves is the known local change if tree sizes grow.
    pub fn evict(&mut self, want_free: usize, alloc: &mut BlockAllocator) -> usize {
        let mut released = 0usize;
        while alloc.free_blocks() < want_free {
            let mut victim: Option<(usize, u64)> = None;
            for (i, slot) in self.nodes.iter().enumerate() {
                let Some(n) = slot else { continue };
                if i == 0 || !n.children.is_empty() {
                    continue;
                }
                if victim.map(|(_, lu)| n.last_used < lu).unwrap_or(true) {
                    victim = Some((i, n.last_used));
                }
            }
            let Some((leaf, _)) = victim else { break };
            released += self.remove_leaf(leaf, alloc);
        }
        released
    }

    /// Release every cached block and reset the tree (shutdown/tests).
    pub fn clear(&mut self, alloc: &mut BlockAllocator) -> usize {
        let mut released = 0usize;
        loop {
            let leaf = self.nodes.iter().enumerate().find_map(|(i, slot)| {
                slot.as_ref()
                    .filter(|n| i != 0 && n.children.is_empty())
                    .map(|_| i)
            });
            let Some(leaf) = leaf else { break };
            released += self.remove_leaf(leaf, alloc);
        }
        released
    }

    /// Drop a leaf: release the cache's block references and unlink it.
    fn remove_leaf(&mut self, leaf: usize, alloc: &mut BlockAllocator) -> usize {
        // Root path must be walked while the node is still in the
        // arena (the parent chain dies with the take() below).
        let path = if self.track_events {
            Some(self.full_path_tokens(leaf))
        } else {
            None
        };
        // lint: allow(R3) — eviction candidates come from the live-leaf
        // scan; the slab entry is Some until this take().
        let node = self.nodes[leaf].take().expect("live leaf");
        debug_assert!(node.children.is_empty() && leaf != 0);
        alloc.release(&node.blocks);
        let released = node.blocks.len();
        let key = &node.tokens[..self.block_tokens];
        self.node_mut(node.parent).children.remove(key);
        self.free_slots.push(leaf);
        self.stats.cached_blocks -= released;
        self.stats.evicted_blocks += released;
        if let Some(tokens) = path {
            self.events.push(PrefixEvent::Evict {
                tokens,
                removed_blocks: released,
            });
        }
        released
    }

    /// Structural audit for tests: runs block-aligned, data sized, child
    /// keys consistent, parents correct, block gauge exact, and every
    /// cached block live in the allocator.
    pub fn check_consistency(&self, alloc: &BlockAllocator) -> Result<()> {
        let bt = self.block_tokens;
        let mut total_blocks = 0usize;
        for (i, slot) in self.nodes.iter().enumerate() {
            let Some(n) = slot else { continue };
            if n.tokens.len() != n.blocks.len() * bt {
                bail!("node {i}: {} tokens vs {} blocks", n.tokens.len(),
                      n.blocks.len());
            }
            if i != 0 && n.blocks.is_empty() {
                bail!("non-root node {i} with empty run");
            }
            let q8 = self.dtype == CacheDtype::Int8;
            for (si, (&w, &g)) in
                self.widths.iter().zip(&self.groups).enumerate()
            {
                if n.data[si]
                    .check(q8, self.layers, n.tokens.len(), w, g)
                    .is_err()
                {
                    bail!("node {i} slab {si}: bad data size/dtype");
                }
            }
            for &b in &n.blocks {
                if alloc.refcount(b) == 0 {
                    bail!("node {i}: cached block {b} is not live");
                }
            }
            total_blocks += n.blocks.len();
            for (key, &c) in &n.children {
                let child = self
                    .nodes
                    .get(c)
                    .and_then(|s| s.as_ref())
                    .ok_or_else(|| anyhow::anyhow!("node {i}: dead child {c}"))?;
                if child.parent != i {
                    bail!("child {c} parent {} != {i}", child.parent);
                }
                if child.tokens[..bt] != key[..] {
                    bail!("child {c}: key mismatch");
                }
            }
        }
        if total_blocks != self.stats.cached_blocks {
            bail!(
                "cached_blocks gauge {} != {} counted",
                self.stats.cached_blocks,
                total_blocks
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Pcg64;

    /// Cache over 2 slabs (widths 3 and 2), 2 layers, 4-token blocks.
    fn cache() -> RadixCache {
        RadixCache::new(4, 2, vec![3, 2], CacheDtype::F32)
    }

    /// Deterministic fake slab rows for `tokens` starting at position 0:
    /// element = (slab, layer, pos, elem) encoded — position-dependent
    /// like real KV rows.
    fn rows_for(c: &RadixCache, toks: &[u32]) -> Vec<SlabRows> {
        c.widths
            .iter()
            .enumerate()
            .map(|(si, &w)| {
                let mut out = vec![0.0f32; c.layers * toks.len() * w];
                for l in 0..c.layers {
                    for (p, &t) in toks.iter().enumerate() {
                        for e in 0..w {
                            out[(l * toks.len() + p) * w + e] = (si * 1000
                                + l * 100
                                + p * 10
                                + e) as f32
                                + t as f32 / 64.0;
                        }
                    }
                }
                SlabRows::F32(out)
            })
            .collect()
    }

    #[test]
    fn insert_then_lookup_roundtrip() {
        let mut a = BlockAllocator::new(16, 4);
        let mut c = cache();
        let toks: Vec<u32> = (0..12).collect(); // 3 full blocks
        let chain = a.alloc(12).unwrap();
        let rows = rows_for(&c, &toks);
        let added =
            c.insert(&toks, &chain, || Ok(rows.clone()), &mut a).unwrap();
        assert_eq!(added, 3);
        a.release(&chain); // request finishes; cache keeps the blocks
        assert_eq!(a.free_blocks(), 13);
        c.check_consistency(&a).unwrap();

        // longest prefix of a longer prompt, capped below the full run
        let prompt: Vec<u32> = (0..16).collect();
        let hit = c.lookup(&prompt, prompt.len() - 1, &mut a).unwrap();
        assert_eq!(hit.tokens, 12);
        assert_eq!(hit.chain.len(), 3);
        assert_eq!(hit.rows, rows);
        a.release(&hit.chain);
        c.check_consistency(&a).unwrap();

        // the cap leaves at least one token to prefill: a prompt equal to
        // the cached run matches only 2 of its 3 blocks
        let hit = c.lookup(&toks, toks.len() - 1, &mut a).unwrap();
        assert_eq!(hit.tokens, 8);
        a.release(&hit.chain);

        // diverging first block: miss
        let other: Vec<u32> = (100..112).collect();
        let miss = c.lookup(&other, 11, &mut a).unwrap();
        assert_eq!(miss.tokens, 0);
        assert!(miss.chain.is_empty());
        c.clear(&mut a);
        assert_eq!(a.free_blocks(), 16);
        a.check_invariants().unwrap();
    }

    #[test]
    fn divergence_splits_at_block_boundary() {
        let mut a = BlockAllocator::new(16, 4);
        let mut c = cache();
        let ab: Vec<u32> = (0..12).collect();
        let chain = a.alloc(12).unwrap();
        let rows_ab = rows_for(&c, &ab);
        c.insert(&ab, &chain, || Ok(rows_ab), &mut a).unwrap();
        a.release(&chain);

        // same first block, diverges inside the second
        let mut ac = ab.clone();
        ac[5] = 99;
        let chain2 = a.alloc(12).unwrap();
        let rows_ac = rows_for(&c, &ac);
        let added =
            c.insert(&ac, &chain2, || Ok(rows_ac), &mut a).unwrap();
        assert_eq!(added, 2, "only the divergent tail is newly cached");
        a.release(&chain2);
        assert_eq!(c.cached_blocks(), 5);
        c.check_consistency(&a).unwrap();

        // both paths now resolve: shared block + own tails
        let hit_ab = c.lookup(&ab, 11, &mut a).unwrap();
        assert_eq!(hit_ab.tokens, 8);
        assert_eq!(hit_ab.rows, c.slice_rows(&rows_for(&c, &ab), 3, 0, 2));
        let hit_ac = c.lookup(&ac, 11, &mut a).unwrap();
        assert_eq!(hit_ac.tokens, 8);
        assert_eq!(hit_ac.rows, c.slice_rows(&rows_for(&c, &ac), 3, 0, 2));
        // the shared first block is the SAME physical block on both paths
        assert_eq!(hit_ab.chain[0], hit_ac.chain[0]);
        assert_ne!(hit_ab.chain[1], hit_ac.chain[1]);
        a.release(&hit_ab.chain);
        a.release(&hit_ac.chain);
        c.check_consistency(&a).unwrap();
        c.clear(&mut a);
        a.check_invariants().unwrap();
        assert_eq!(a.free_blocks(), 16);
    }

    #[test]
    fn insert_is_idempotent() {
        let mut a = BlockAllocator::new(8, 4);
        let mut c = cache();
        let toks: Vec<u32> = (0..8).collect();
        let chain = a.alloc(8).unwrap();
        let rows = rows_for(&c, &toks);
        assert_eq!(
            c.insert(&toks, &chain, || Ok(rows.clone()), &mut a).unwrap(),
            2
        );
        assert_eq!(
            c.insert(&toks, &chain, || Ok(rows.clone()), &mut a).unwrap(),
            0
        );
        a.release(&chain);
        assert_eq!(c.cached_blocks(), 2);
        c.check_consistency(&a).unwrap();
    }

    #[test]
    fn partial_blocks_are_never_cached() {
        let mut a = BlockAllocator::new(8, 4);
        let mut c = cache();
        let toks: Vec<u32> = (0..6).collect(); // 1 full block + 2 tokens
        let chain = a.alloc(6).unwrap();
        let full = &toks[..4];
        let full_rows = rows_for(&c, full);
        let added =
            c.insert(full, &chain, || Ok(full_rows), &mut a).unwrap();
        assert_eq!(added, 1);
        // a 3-token prompt can never hit (no full block to match)
        let hit = c.lookup(&toks[..3], 2, &mut a).unwrap();
        assert_eq!(hit.tokens, 0);
        a.release(&chain);
        c.check_consistency(&a).unwrap();
    }

    #[test]
    fn lru_eviction_frees_least_recent_leaf_first() {
        let mut a = BlockAllocator::new(6, 4);
        let mut c = cache();
        let p1: Vec<u32> = (0..8).collect();
        let p2: Vec<u32> = (100..108).collect();
        for p in [&p1, &p2] {
            let chain = a.alloc(8).unwrap();
            let rows = rows_for(&c, p);
            c.insert(p, &chain, || Ok(rows), &mut a).unwrap();
            a.release(&chain);
        }
        assert_eq!(a.free_blocks(), 2);
        // touch p1 so p2 is the LRU leaf
        let hit = c.lookup(&p1, 7, &mut a).unwrap();
        a.release(&hit.chain);
        // pressure: want 4 free -> p2's 2 blocks are evicted
        let released = c.evict(4, &mut a);
        assert_eq!(released, 2);
        assert_eq!(a.free_blocks(), 4);
        assert_eq!(c.lookup(&p2, 7, &mut a).unwrap().tokens, 0);
        assert_eq!(c.lookup(&p1, 7, &mut a).unwrap().tokens, 4);
        assert_eq!(c.stats().evicted_blocks, 2);
        c.check_consistency(&a).unwrap();
        a.check_invariants().unwrap();
    }

    #[test]
    fn eviction_respects_live_request_forks() {
        let mut a = BlockAllocator::new(4, 4);
        let mut c = cache();
        let p: Vec<u32> = (0..8).collect();
        let chain = a.alloc(8).unwrap();
        let rows = rows_for(&c, &p);
        c.insert(&p, &chain, || Ok(rows), &mut a).unwrap();
        a.release(&chain);
        // a live request forks the cached prefix...
        let hit = c.lookup(&p, 7, &mut a).unwrap();
        assert_eq!(hit.chain.len(), 1);
        // ...then eviction drops the cache's references; the forked
        // block must stay live (not returned to the free pool)
        c.evict(4, &mut a);
        assert_eq!(c.cached_blocks(), 0);
        assert_eq!(a.free_blocks(), 3);
        assert_eq!(a.refcount(hit.chain[0]), 1);
        a.release(&hit.chain);
        assert_eq!(a.free_blocks(), 4);
        a.check_invariants().unwrap();
    }

    /// Quantized rows (ISSUE 5): an int8 cache stores the exact i8
    /// bytes + scales handed to insert, lookups splice them back
    /// verbatim (no f32 round-trip), splits preserve them, and eviction
    /// under pool pressure keeps tree + allocator consistent.
    #[test]
    fn quantized_rows_round_trip_and_survive_split_and_eviction() {
        use crate::kvcache::quant::quantize_row;
        let mut a = BlockAllocator::new(8, 4);
        let mut c = RadixCache::new(4, 2, vec![3, 2], CacheDtype::Int8);
        assert_eq!(c.dtype(), CacheDtype::Int8);
        // quantize the deterministic fake rows per token-layer row
        let q8_rows_for = |c: &RadixCache, toks: &[u32]| -> Vec<SlabRows> {
            c.widths
                .iter()
                .zip(&c.groups)
                .enumerate()
                .map(|(si, (&w, &g))| {
                    let mut data = vec![0i8; c.layers * toks.len() * w];
                    let mut scales = vec![0.0f32; c.layers * toks.len() * g];
                    for r in 0..c.layers * toks.len() {
                        let src: Vec<f32> = (0..w)
                            .map(|e| (si * 100 + r * 10 + e) as f32 / 37.0)
                            .collect();
                        quantize_row(
                            &src,
                            QUANT_GROUP,
                            &mut data[r * w..(r + 1) * w],
                            &mut scales[r * g..(r + 1) * g],
                        );
                    }
                    SlabRows::Q8 { data, scales }
                })
                .collect()
        };
        let ab: Vec<u32> = (0..8).collect();
        let chain = a.alloc(8).unwrap();
        let rows_ab = q8_rows_for(&c, &ab);
        c.insert(&ab, &chain, || Ok(rows_ab.clone()), &mut a).unwrap();
        a.release(&chain);
        c.check_consistency(&a).unwrap();
        // exact-byte lookup (capped at 7 -> first block only)
        let hit = c.lookup(&ab, 7, &mut a).unwrap();
        assert_eq!(hit.tokens, 4);
        assert_eq!(hit.rows, c.slice_rows(&rows_ab, 2, 0, 1));
        a.release(&hit.chain);
        // divergence inside the second block forces a split; the shared
        // first block's quantized bytes survive it
        let mut ac = ab.clone();
        ac[5] ^= 1;
        let chain2 = a.alloc(8).unwrap();
        let rows_ac = q8_rows_for(&c, &ac);
        c.insert(&ac, &chain2, || Ok(rows_ac), &mut a).unwrap();
        a.release(&chain2);
        c.check_consistency(&a).unwrap();
        let hit2 = c.lookup(&ab, 7, &mut a).unwrap();
        assert_eq!(hit2.rows, c.slice_rows(&rows_ab, 2, 0, 1));
        a.release(&hit2.chain);
        // f32 rows into an int8 cache are rejected at insert
        let toks2: Vec<u32> = (100..104).collect();
        let chain3 = a.alloc(4).unwrap();
        let bad: Vec<SlabRows> = vec![
            SlabRows::F32(vec![0.0; 2 * 4 * 3]),
            SlabRows::F32(vec![0.0; 2 * 4 * 2]),
        ];
        assert!(c.insert(&toks2, &chain3, || Ok(bad), &mut a).is_err());
        a.release(&chain3);
        // eviction under pressure releases quantized leaves cleanly
        c.evict(8, &mut a);
        assert_eq!(c.cached_blocks(), 0);
        assert_eq!(a.free_blocks(), 8);
        a.check_invariants().unwrap();
        c.check_consistency(&a).unwrap();
    }

    /// Property: random insert/lookup/evict workloads keep the tree and
    /// the allocator consistent, conserve blocks exactly, and lookups
    /// agree with a naive prefix-set reference model.
    #[test]
    fn prop_random_workload_matches_reference() {
        prop::check(
            "radix-cache-workload",
            32,
            |rng: &mut Pcg64| {
                (0..40)
                    .map(|_| (rng.next_u64(), rng.below(4) as u8))
                    .collect::<Vec<_>>()
            },
            |ops| {
                let mut a = BlockAllocator::new(24, 4);
                let mut c = cache();
                // reference: the set of cached block-aligned prefixes
                let mut reference: Vec<Vec<u32>> = Vec::new();
                let mut live: Vec<Vec<BlockId>> = Vec::new();
                for &(x, kind) in ops {
                    // prompts drawn from a tiny alphabet so prefixes collide
                    let len = 4 + (x % 17) as usize;
                    let toks: Vec<u32> =
                        (0..len).map(|i| ((x >> (i % 8)) & 1) as u32).collect();
                    match kind {
                        0 | 1 => {
                            // simulate a request lifecycle: alloc, insert
                            // prompt prefix, release
                            if !a.can_admit(len) {
                                continue;
                            }
                            let chain =
                                a.alloc(len).map_err(|e| e.to_string())?;
                            let aligned = len / 4 * 4;
                            if aligned > 0 {
                                let full = &toks[..aligned];
                                let rows = rows_for(&c, full);
                                c.insert(full, &chain, || Ok(rows), &mut a)
                                    .map_err(|e| e.to_string())?;
                                for b in 1..=aligned / 4 {
                                    let p = toks[..b * 4].to_vec();
                                    if !reference.contains(&p) {
                                        reference.push(p);
                                    }
                                }
                            }
                            a.release(&chain);
                        }
                        2 => {
                            let cap = len.saturating_sub(1);
                            let hit = c
                                .lookup(&toks, cap, &mut a)
                                .map_err(|e| e.to_string())?;
                            let want = reference
                                .iter()
                                .filter(|p| {
                                    p.len() <= cap
                                        && toks.starts_with(p)
                                })
                                .map(|p| p.len())
                                .max()
                                .unwrap_or(0);
                            if hit.tokens != want {
                                return Err(format!(
                                    "lookup matched {} tokens, reference \
                                     says {want}",
                                    hit.tokens
                                ));
                            }
                            live.push(hit.chain);
                        }
                        _ => {
                            let want = (x % 8) as usize;
                            c.evict(want, &mut a);
                            // mirror: eviction removes whole maximal
                            // prefixes; rebuild the reference from what
                            // still resolves
                            reference.retain(|p| {
                                let mut probe = p.clone();
                                probe.push(7); // one spare token past the cap
                                c.lookup(&probe, p.len(), &mut a)
                                    .map(|h| {
                                        a.release(&h.chain);
                                        h.tokens == p.len()
                                    })
                                    .unwrap_or(false)
                            });
                        }
                    }
                    c.check_consistency(&a).map_err(|e| e.to_string())?;
                    a.check_invariants().map_err(|e| e.to_string())?;
                    let held: usize = live.iter().map(|ch| ch.len()).sum();
                    // exact conservation: free + cache-held + request-held
                    // >= total only via sharing; the strict check is that
                    // used blocks never exceed cache + live references
                    if a.used_blocks() > c.cached_blocks() + held {
                        return Err(format!(
                            "leak: {} used > {} cached + {held} held",
                            a.used_blocks(),
                            c.cached_blocks()
                        ));
                    }
                }
                for ch in live.drain(..) {
                    a.release(&ch);
                }
                let released = c.clear(&mut a);
                if a.free_blocks() != 24 {
                    return Err(format!(
                        "leaked blocks: {} free after clearing {released}",
                        a.free_blocks()
                    ));
                }
                a.check_invariants().map_err(|e| e.to_string())
            },
        );
    }
}
