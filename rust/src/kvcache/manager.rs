//! Slot manager: binds logical sequences to lanes of the fixed-batch
//! decode artifacts and tracks per-slot cache occupancy.
//!
//! The AOT decode artifact has a baked batch dimension B; the coordinator
//! multiplexes live requests onto those B lanes (continuous batching).
//! Idle lanes decode a masked dummy token (length 0 -> attention masked),
//! which is how vLLM-style slot reuse maps onto a static-shape runtime.

use anyhow::{bail, Result};

use crate::kvcache::layout::CacheLayout;

/// State of one decode lane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Slot {
    /// Free for the next admission.
    Idle,
    /// Occupied by a request (id, current cached length).
    Busy {
        /// Owning request id.
        request: u64,
        /// Tokens currently cached on this lane.
        len: usize,
    },
}

/// Lane assignment + occupancy accounting for one model's decode batch.
#[derive(Debug)]
pub struct SlotManager {
    /// Per-variant cache geometry the byte accounting uses.
    pub layout: CacheLayout,
    /// Serving window per lane (positions `0..max_seq`).
    pub max_seq: usize,
    slots: Vec<Slot>,
}

impl SlotManager {
    /// `batch` idle lanes over a `max_seq` serving window.
    pub fn new(layout: CacheLayout, batch: usize, max_seq: usize) -> SlotManager {
        SlotManager { layout, max_seq, slots: vec![Slot::Idle; batch] }
    }

    /// Number of decode lanes.
    pub fn batch(&self) -> usize {
        self.slots.len()
    }

    /// All lane states, indexed by slot.
    pub fn slots(&self) -> &[Slot] {
        &self.slots
    }

    /// Lanes currently idle (admission capacity).
    pub fn idle_count(&self) -> usize {
        self.slots.iter().filter(|s| **s == Slot::Idle).count()
    }

    /// Claim a lane for a request whose prompt has `prompt_len` tokens.
    pub fn claim(&mut self, request: u64, prompt_len: usize) -> Result<usize> {
        if prompt_len >= self.max_seq {
            bail!("prompt of {prompt_len} tokens exceeds max_seq {}",
                  self.max_seq);
        }
        for (i, s) in self.slots.iter_mut().enumerate() {
            if *s == Slot::Idle {
                *s = Slot::Busy { request, len: prompt_len };
                return Ok(i);
            }
        }
        bail!("no idle slot");
    }

    /// Record one decoded token on a lane; errors at the context limit.
    pub fn advance(&mut self, slot: usize) -> Result<usize> {
        match &mut self.slots[slot] {
            Slot::Busy { len, .. } => {
                if *len + 1 >= self.max_seq {
                    bail!("slot {slot} hit max_seq {}", self.max_seq);
                }
                *len += 1;
                Ok(*len)
            }
            Slot::Idle => bail!("advance on idle slot {slot}"),
        }
    }

    /// Cached length of a lane (0 when idle).
    pub fn len_of(&self, slot: usize) -> usize {
        match &self.slots[slot] {
            Slot::Busy { len, .. } => *len,
            Slot::Idle => 0,
        }
    }

    /// Owning request id of a lane, if busy.
    pub fn request_of(&self, slot: usize) -> Option<u64> {
        match &self.slots[slot] {
            Slot::Busy { request, .. } => Some(*request),
            Slot::Idle => None,
        }
    }

    /// Return a lane to the idle pool.
    pub fn free(&mut self, slot: usize) {
        self.slots[slot] = Slot::Idle;
    }

    /// Live cache bytes across all busy lanes (the metric Table-1's cache
    /// column and the serving bench report).
    pub fn live_cache_bytes(&self) -> usize {
        self.slots
            .iter()
            .map(|s| match s {
                Slot::Busy { len, .. } => self.layout.bytes_for_seq(*len),
                Slot::Idle => 0,
            })
            .sum()
    }

    /// Worst-case bytes if every lane filled to max_seq.
    pub fn capacity_bytes(&self) -> usize {
        self.batch() * self.layout.bytes_for_seq(self.max_seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, Variant};

    fn mgr(variant: Variant) -> SlotManager {
        let cfg = ModelConfig::tiny();
        SlotManager::new(CacheLayout::new(&cfg, variant), 4, 64)
    }

    #[test]
    fn claim_advance_free_cycle() {
        let mut m = mgr(Variant::Mha);
        let s = m.claim(7, 10).unwrap();
        assert_eq!(m.idle_count(), 3);
        assert_eq!(m.len_of(s), 10);
        assert_eq!(m.advance(s).unwrap(), 11);
        assert_eq!(m.request_of(s), Some(7));
        m.free(s);
        assert_eq!(m.idle_count(), 4);
    }

    #[test]
    fn rejects_over_capacity() {
        let mut m = mgr(Variant::Mha);
        for i in 0..4 {
            m.claim(i, 1).unwrap();
        }
        assert!(m.claim(99, 1).is_err());
    }

    #[test]
    fn rejects_long_prompt_and_context_overflow() {
        let mut m = mgr(Variant::Mha);
        assert!(m.claim(1, 64).is_err());
        let s = m.claim(1, 62).unwrap();
        m.advance(s).unwrap(); // 63
        assert!(m.advance(s).is_err()); // would hit 64
    }

    #[test]
    fn cache_accounting_tracks_compression() {
        let mut base = mgr(Variant::Mha);
        let mut ekv = mgr(Variant::EliteKv { r: 4, d_ckv: 64 }); // 25 %
        let sb = base.claim(1, 40).unwrap();
        let se = ekv.claim(1, 40).unwrap();
        assert_eq!(base.live_cache_bytes(), 4 * ekv.live_cache_bytes());
        base.advance(sb).unwrap();
        ekv.advance(se).unwrap();
        assert_eq!(base.live_cache_bytes(), 4 * ekv.live_cache_bytes());
        assert_eq!(ekv.capacity_bytes() * 4, base.capacity_bytes());
    }
}
