//! Paged cache-block allocator with ref-counting (vLLM-style substrate).
//!
//! Sequences map to chains of fixed-size token blocks; blocks are
//! ref-counted so shared prefixes can alias the same physical block.
//! The serving coordinator uses this for admission control: a request is
//! only scheduled when its worst-case block need fits the pool, which is
//! exactly where EliteKV's compressed layout buys capacity (the same pool
//! holds ~4x the tokens at cache ratio 25 %).

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Identifier of a physical cache block.
pub type BlockId = u32;

/// Fixed-size paged allocator over an abstract block pool.
#[derive(Debug)]
pub struct BlockAllocator {
    pub block_tokens: usize,
    n_blocks: usize,
    free: Vec<BlockId>,
    refcnt: HashMap<BlockId, u32>,
}

impl BlockAllocator {
    /// Pool sized for `budget_bytes` of cache at `bytes_per_token`.
    pub fn with_budget(
        budget_bytes: usize,
        bytes_per_token: usize,
        block_tokens: usize,
    ) -> BlockAllocator {
        let n_blocks = budget_bytes / (bytes_per_token * block_tokens);
        Self::new(n_blocks, block_tokens)
    }

    pub fn new(n_blocks: usize, block_tokens: usize) -> BlockAllocator {
        BlockAllocator {
            block_tokens,
            n_blocks,
            free: (0..n_blocks as BlockId).rev().collect(),
            refcnt: HashMap::new(),
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks needed for a sequence of `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can a sequence of `tokens` tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Allocate a chain of blocks for `tokens` tokens.
    pub fn alloc(&mut self, tokens: usize) -> Result<Vec<BlockId>> {
        let need = self.blocks_for(tokens);
        if need > self.free.len() {
            bail!("out of cache blocks: need {need}, free {}", self.free.len());
        }
        let mut out = Vec::with_capacity(need);
        for _ in 0..need {
            let b = self.free.pop().unwrap();
            self.refcnt.insert(b, 1);
            out.push(b);
        }
        Ok(out)
    }

    /// Extend a chain by one token; allocates a new block on boundary.
    pub fn extend(&mut self, chain: &mut Vec<BlockId>, new_len: usize) -> Result<()> {
        let need = self.blocks_for(new_len);
        while chain.len() < need {
            let Some(b) = self.free.pop() else {
                bail!("out of cache blocks while extending");
            };
            self.refcnt.insert(b, 1);
            chain.push(b);
        }
        Ok(())
    }

    /// Share an existing chain (prefix reuse): bump refcounts.
    pub fn fork(&mut self, chain: &[BlockId]) -> Vec<BlockId> {
        for b in chain {
            *self.refcnt.get_mut(b).expect("live block") += 1;
        }
        chain.to_vec()
    }

    /// Release a chain; blocks return to the pool at refcount zero.
    pub fn release(&mut self, chain: &[BlockId]) {
        for &b in chain {
            let cnt = self.refcnt.get_mut(&b).expect("live block");
            *cnt -= 1;
            if *cnt == 0 {
                self.refcnt.remove(&b);
                self.free.push(b);
            }
        }
    }

    /// Invariant check: every block is either free or ref-counted, once.
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = std::collections::HashSet::new();
        for &b in &self.free {
            if !seen.insert(b) {
                bail!("block {b} double-free");
            }
            if self.refcnt.contains_key(&b) {
                bail!("block {b} free but ref-counted");
            }
        }
        for (&b, &c) in &self.refcnt {
            if !seen.insert(b) {
                bail!("block {b} both free and live");
            }
            if c == 0 {
                bail!("block {b} live with refcount 0");
            }
        }
        if seen.len() != self.n_blocks {
            bail!("lost blocks: {} of {}", seen.len(), self.n_blocks);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Pcg64;

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = BlockAllocator::new(8, 16);
        assert!(a.can_admit(100)); // 7 blocks
        let chain = a.alloc(100).unwrap();
        assert_eq!(chain.len(), 7);
        assert_eq!(a.free_blocks(), 1);
        a.release(&chain);
        assert_eq!(a.free_blocks(), 8);
        a.check_invariants().unwrap();
    }

    #[test]
    fn admission_denied_when_full() {
        let mut a = BlockAllocator::new(2, 16);
        let _c = a.alloc(32).unwrap();
        assert!(!a.can_admit(1));
        assert!(a.alloc(1).is_err());
    }

    #[test]
    fn extend_allocates_on_boundary() {
        let mut a = BlockAllocator::new(4, 4);
        let mut chain = a.alloc(4).unwrap();
        assert_eq!(chain.len(), 1);
        a.extend(&mut chain, 5).unwrap();
        assert_eq!(chain.len(), 2);
        a.extend(&mut chain, 8).unwrap();
        assert_eq!(chain.len(), 2);
        a.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_until_release() {
        let mut a = BlockAllocator::new(4, 4);
        let chain = a.alloc(16).unwrap();
        assert_eq!(a.free_blocks(), 0);
        let shared = a.fork(&chain);
        a.release(&chain);
        assert_eq!(a.free_blocks(), 0); // still referenced by `shared`
        a.release(&shared);
        assert_eq!(a.free_blocks(), 4);
        a.check_invariants().unwrap();
    }

    #[test]
    fn budget_sizing_reflects_compression() {
        // Same budget, 4x smaller per-token cache -> 4x the blocks.
        let base = BlockAllocator::with_budget(1 << 20, 16384, 16);
        let ekv = BlockAllocator::with_budget(1 << 20, 4096, 16);
        assert_eq!(ekv.n_blocks(), 4 * base.n_blocks());
    }

    /// Property: any interleaving of alloc/extend/fork/release keeps the
    /// pool consistent and never loses blocks.
    #[test]
    fn prop_random_workload_invariants() {
        prop::check(
            "block-allocator-workload",
            48,
            |rng: &mut Pcg64| {
                let ops: Vec<u64> = (0..60).map(|_| rng.next_u64()).collect();
                ops
            },
            |ops| {
                let mut a = BlockAllocator::new(16, 4);
                let mut live: Vec<Vec<BlockId>> = Vec::new();
                for &op in ops {
                    match op % 4 {
                        0 => {
                            let want = (op / 4 % 40) as usize + 1;
                            if a.can_admit(want) {
                                live.push(a.alloc(want).map_err(|e| e.to_string())?);
                            }
                        }
                        1 => {
                            if !live.is_empty() {
                                let i = (op / 4) as usize % live.len();
                                let c = live.swap_remove(i);
                                a.release(&c);
                            }
                        }
                        2 => {
                            if !live.is_empty() {
                                let i = (op / 4) as usize % live.len();
                                let f = a.fork(&live[i].clone());
                                live.push(f);
                            }
                        }
                        _ => {
                            if !live.is_empty() && a.free_blocks() > 0 {
                                let i = (op / 4) as usize % live.len();
                                let cur = live[i].len() * a.block_tokens;
                                let mut c = live.swap_remove(i);
                                let _ = a.extend(&mut c, cur + 1);
                                live.push(c);
                            }
                        }
                    }
                    a.check_invariants().map_err(|e| e.to_string())?;
                }
                for c in live.drain(..) {
                    a.release(&c);
                }
                if a.free_blocks() != 16 {
                    return Err(format!("leaked blocks: {}", a.free_blocks()));
                }
                a.check_invariants().map_err(|e| e.to_string())
            },
        );
    }
}
