//! Paged cache-block allocator with ref-counting (vLLM-style substrate).
//!
//! Sequences map to chains of fixed-size token blocks; blocks are
//! ref-counted so shared prefixes can alias the same physical block.
//! The serving coordinator uses this for admission control: a request is
//! only scheduled when its worst-case block need fits the pool, which is
//! exactly where EliteKV's compressed layout buys capacity (the same pool
//! holds ~4x the tokens at cache ratio 25 %).

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Identifier of a physical cache block.
pub type BlockId = u32;

/// Fixed-size paged allocator over an abstract block pool.
#[derive(Debug)]
pub struct BlockAllocator {
    /// Tokens per block (the paging granularity of admission control).
    pub block_tokens: usize,
    n_blocks: usize,
    free: Vec<BlockId>,
    refcnt: HashMap<BlockId, u32>,
    /// Releases of blocks that were not live (double-release / stale
    /// chain). Never cleared; `check_invariants` reports it so the bug
    /// surfaces at the next audit point instead of corrupting the pool.
    over_released: usize,
}

impl BlockAllocator {
    /// Pool sized for `budget_bytes` of cache at `bytes_per_token`.
    pub fn with_budget(
        budget_bytes: usize,
        bytes_per_token: usize,
        block_tokens: usize,
    ) -> BlockAllocator {
        let n_blocks = budget_bytes / (bytes_per_token * block_tokens);
        Self::new(n_blocks, block_tokens)
    }

    /// Pool of `n_blocks` blocks of `block_tokens` tokens each.
    pub fn new(n_blocks: usize, block_tokens: usize) -> BlockAllocator {
        BlockAllocator {
            block_tokens,
            n_blocks,
            free: (0..n_blocks as BlockId).rev().collect(),
            refcnt: HashMap::new(),
            over_released: 0,
        }
    }

    /// Total pool size in blocks.
    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Blocks currently free.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently held by live chains.
    pub fn used_blocks(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    /// Current reference count of a block (0 when free or unknown).
    pub fn refcount(&self, b: BlockId) -> u32 {
        self.refcnt.get(&b).copied().unwrap_or(0)
    }

    /// Blocks needed for a sequence of `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can a sequence of `tokens` tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Allocate a chain of blocks for `tokens` tokens.
    pub fn alloc(&mut self, tokens: usize) -> Result<Vec<BlockId>> {
        let need = self.blocks_for(tokens);
        if need > self.free.len() {
            bail!("out of cache blocks: need {need}, free {}", self.free.len());
        }
        let mut out = Vec::with_capacity(need);
        for _ in 0..need {
            // lint: allow(R3) — `need <= free.len()` bailed above.
            let b = self.free.pop().unwrap();
            self.refcnt.insert(b, 1);
            out.push(b);
        }
        Ok(out)
    }

    /// Extend a chain by one token; allocates a new block on boundary.
    pub fn extend(&mut self, chain: &mut Vec<BlockId>, new_len: usize) -> Result<()> {
        let need = self.blocks_for(new_len);
        while chain.len() < need {
            let Some(b) = self.free.pop() else {
                bail!("out of cache blocks while extending");
            };
            self.refcnt.insert(b, 1);
            chain.push(b);
        }
        Ok(())
    }

    /// Share an existing chain (prefix reuse): bump per-block refcounts.
    /// Errors if any block of the chain is not live (stale chain) —
    /// forking it would alias memory another sequence may reuse.
    pub fn fork(&mut self, chain: &[BlockId]) -> Result<Vec<BlockId>> {
        for (i, b) in chain.iter().enumerate() {
            if !self.refcnt.contains_key(b) {
                // Roll back the bumps already made so a failed fork
                // leaves refcounts exactly as they were.
                for bb in &chain[..i] {
                    // lint: allow(R3) — every bb in chain[..i] passed
                    // the contains_key check this pass.
                    *self.refcnt.get_mut(bb).unwrap() -= 1;
                }
                bail!("fork of dead block {b} (stale chain)");
            }
            // lint: allow(R3) — contains_key checked directly above.
            *self.refcnt.get_mut(b).unwrap() += 1;
        }
        Ok(chain.to_vec())
    }

    /// Release a chain; each block's refcount decrements and the block
    /// returns to the pool at zero. Releasing a block that is not live
    /// (double-release / stale chain) is recorded instead of panicking;
    /// `check_invariants` reports it.
    pub fn release(&mut self, chain: &[BlockId]) {
        for &b in chain {
            match self.refcnt.get_mut(&b) {
                Some(cnt) => {
                    *cnt -= 1;
                    if *cnt == 0 {
                        self.refcnt.remove(&b);
                        self.free.push(b);
                    }
                }
                None => {
                    log::error!("over-release of block {b} (not live)");
                    self.over_released += 1;
                }
            }
        }
    }

    /// Invariant check: every block is either free or ref-counted, once,
    /// and no release ever hit a non-live block.
    pub fn check_invariants(&self) -> Result<()> {
        if self.over_released > 0 {
            bail!(
                "{} over-release(s) recorded: some chain was released \
                 twice or after its blocks were recycled",
                self.over_released
            );
        }
        let mut seen = std::collections::HashSet::new();
        for &b in &self.free {
            if !seen.insert(b) {
                bail!("block {b} double-free");
            }
            if self.refcnt.contains_key(&b) {
                bail!("block {b} free but ref-counted");
            }
        }
        for (&b, &c) in &self.refcnt {
            if !seen.insert(b) {
                bail!("block {b} both free and live");
            }
            if c == 0 {
                bail!("block {b} live with refcount 0");
            }
        }
        if seen.len() != self.n_blocks {
            bail!("lost blocks: {} of {}", seen.len(), self.n_blocks);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Pcg64;

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = BlockAllocator::new(8, 16);
        assert!(a.can_admit(100)); // 7 blocks
        let chain = a.alloc(100).unwrap();
        assert_eq!(chain.len(), 7);
        assert_eq!(a.free_blocks(), 1);
        a.release(&chain);
        assert_eq!(a.free_blocks(), 8);
        a.check_invariants().unwrap();
    }

    #[test]
    fn admission_denied_when_full() {
        let mut a = BlockAllocator::new(2, 16);
        let _c = a.alloc(32).unwrap();
        assert!(!a.can_admit(1));
        assert!(a.alloc(1).is_err());
    }

    #[test]
    fn extend_allocates_on_boundary() {
        let mut a = BlockAllocator::new(4, 4);
        let mut chain = a.alloc(4).unwrap();
        assert_eq!(chain.len(), 1);
        a.extend(&mut chain, 5).unwrap();
        assert_eq!(chain.len(), 2);
        a.extend(&mut chain, 8).unwrap();
        assert_eq!(chain.len(), 2);
        a.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_until_release() {
        let mut a = BlockAllocator::new(4, 4);
        let chain = a.alloc(16).unwrap();
        assert_eq!(a.free_blocks(), 0);
        let shared = a.fork(&chain).unwrap();
        a.release(&chain);
        assert_eq!(a.free_blocks(), 0); // still referenced by `shared`
        a.release(&shared);
        assert_eq!(a.free_blocks(), 4);
        a.check_invariants().unwrap();
    }

    #[test]
    fn double_release_is_caught_not_corrupting() {
        let mut a = BlockAllocator::new(4, 4);
        let chain = a.alloc(16).unwrap();
        a.release(&chain);
        assert_eq!(a.free_blocks(), 4);
        // the second release must not panic, must not double-free...
        a.release(&chain);
        assert_eq!(a.free_blocks(), 4);
        // ...and must be reported by the invariant check.
        let err = a.check_invariants().unwrap_err().to_string();
        assert!(err.contains("over-release"), "{err}");
    }

    #[test]
    fn release_one_fork_keeps_sibling_blocks_live() {
        // The ISSUE-2 scenario: fork a chain, release one side, and the
        // sibling's blocks must NOT return to the pool (no reuse while
        // still referenced).
        let mut a = BlockAllocator::new(4, 4);
        let original = a.alloc(16).unwrap();
        let forked = a.fork(&original).unwrap();
        a.release(&original);
        // pool still empty: a fresh alloc must fail, proving no block of
        // the surviving fork was recycled
        assert!(a.alloc(1).is_err());
        for &b in &forked {
            assert_eq!(a.refcount(b), 1);
        }
        a.release(&forked);
        assert_eq!(a.free_blocks(), 4);
        a.check_invariants().unwrap();
    }

    #[test]
    fn fork_of_stale_chain_is_error_and_rolls_back() {
        let mut a = BlockAllocator::new(4, 4);
        let chain = a.alloc(8).unwrap(); // 2 blocks
        let keep = a.alloc(4).unwrap(); // 1 block, stays live
        a.release(&chain);
        // chain is stale: forking [live, dead] must fail and leave the
        // live block's refcount untouched
        let mixed = vec![keep[0], chain[0]];
        assert!(a.fork(&mixed).is_err());
        assert_eq!(a.refcount(keep[0]), 1);
        a.release(&keep);
        a.check_invariants().unwrap();
    }

    #[test]
    fn budget_sizing_reflects_compression() {
        // Same budget, 4x smaller per-token cache -> 4x the blocks.
        let base = BlockAllocator::with_budget(1 << 20, 16384, 16);
        let ekv = BlockAllocator::with_budget(1 << 20, 4096, 16);
        assert_eq!(ekv.n_blocks(), 4 * base.n_blocks());
    }

    /// A naive reference allocator driven as an ORACLE CHECKER: it
    /// applies the real allocator's outputs (the concrete chains) to its
    /// own trivial free-set + refcount model and verifies exact
    /// per-block accounting after every operation. Any divergence —
    /// handing out a non-free block, freeing too early/late, a refcount
    /// drifting — is a real bug in one of the two, and the model is
    /// simple enough to trust.
    #[derive(Debug)]
    struct RefAlloc {
        free: std::collections::BTreeSet<BlockId>,
        refs: HashMap<BlockId, u32>,
    }

    impl RefAlloc {
        fn new(n: usize) -> RefAlloc {
            RefAlloc {
                free: (0..n as BlockId).collect(),
                refs: HashMap::new(),
            }
        }

        /// Real allocator handed out `fresh` blocks: each must have been
        /// free here too.
        fn on_fresh(&mut self, fresh: &[BlockId]) -> Result<(), String> {
            for &b in fresh {
                if !self.free.remove(&b) {
                    return Err(format!("block {b} handed out but not free"));
                }
                self.refs.insert(b, 1);
            }
            Ok(())
        }

        fn on_fork(&mut self, chain: &[BlockId]) -> Result<(), String> {
            for &b in chain {
                match self.refs.get_mut(&b) {
                    Some(c) => *c += 1,
                    None => return Err(format!("forked dead block {b}")),
                }
            }
            Ok(())
        }

        fn on_release(&mut self, chain: &[BlockId]) -> Result<(), String> {
            for &b in chain {
                match self.refs.get_mut(&b) {
                    Some(c) if *c > 1 => *c -= 1,
                    Some(_) => {
                        self.refs.remove(&b);
                        self.free.insert(b);
                    }
                    None => return Err(format!("released dead block {b}")),
                }
            }
            Ok(())
        }

        /// Exact agreement: same free count, same live set, same
        /// per-block refcounts.
        fn agrees_with(&self, a: &BlockAllocator) -> Result<(), String> {
            if a.free_blocks() != self.free.len() {
                return Err(format!(
                    "free count: real {} vs reference {}",
                    a.free_blocks(),
                    self.free.len()
                ));
            }
            for (&b, &c) in &self.refs {
                if a.refcount(b) != c {
                    return Err(format!(
                        "block {b}: refcount real {} vs reference {c}",
                        a.refcount(b)
                    ));
                }
            }
            for &b in &self.free {
                if a.refcount(b) != 0 {
                    return Err(format!("block {b} free here, live there"));
                }
            }
            Ok(())
        }
    }

    /// Property (ISSUE 4): random alloc/extend/fork/release sequences
    /// keep the real allocator in EXACT agreement with the naive
    /// reference model — free-block counts and every per-block refcount
    /// — with `check_invariants` green after every op.
    #[test]
    fn prop_allocator_matches_naive_reference() {
        prop::check(
            "block-allocator-vs-reference",
            48,
            |rng: &mut Pcg64| {
                (0..80).map(|_| rng.next_u64()).collect::<Vec<u64>>()
            },
            |ops| {
                let mut a = BlockAllocator::new(12, 4);
                let mut model = RefAlloc::new(12);
                let mut live: Vec<Vec<BlockId>> = Vec::new();
                for &op in ops {
                    match op % 4 {
                        0 => {
                            let want = (op / 4 % 24) as usize + 1;
                            if a.can_admit(want) {
                                let chain =
                                    a.alloc(want).map_err(|e| e.to_string())?;
                                model.on_fresh(&chain)?;
                                live.push(chain);
                            } else if a.alloc(want).is_ok() {
                                return Err(
                                    "alloc succeeded past can_admit".into()
                                );
                            }
                        }
                        1 => {
                            if !live.is_empty() {
                                let i = (op / 4) as usize % live.len();
                                let c = live.swap_remove(i);
                                a.release(&c);
                                model.on_release(&c)?;
                            }
                        }
                        2 => {
                            if !live.is_empty() {
                                let i = (op / 4) as usize % live.len();
                                let f = a
                                    .fork(&live[i].clone())
                                    .map_err(|e| e.to_string())?;
                                model.on_fork(&f)?;
                                live.push(f);
                            }
                        }
                        _ => {
                            if !live.is_empty() {
                                let i = (op / 4) as usize % live.len();
                                let mut c = live.swap_remove(i);
                                let before = c.len();
                                let cur = before * a.block_tokens;
                                if a.extend(&mut c, cur + 1).is_ok() {
                                    model.on_fresh(&c[before..])?;
                                } else if c.len() != before {
                                    return Err(
                                        "failed extend mutated chain".into()
                                    );
                                }
                                live.push(c);
                            }
                        }
                    }
                    a.check_invariants().map_err(|e| e.to_string())?;
                    model.agrees_with(&a)?;
                }
                for c in live.drain(..) {
                    a.release(&c);
                    model.on_release(&c)?;
                }
                model.agrees_with(&a)?;
                if a.free_blocks() != 12 {
                    return Err(format!("leaked: {} free", a.free_blocks()));
                }
                a.check_invariants().map_err(|e| e.to_string())
            },
        );
    }

    /// Property: any interleaving of alloc/extend/fork/release keeps the
    /// pool consistent and never loses blocks.
    #[test]
    fn prop_random_workload_invariants() {
        prop::check(
            "block-allocator-workload",
            48,
            |rng: &mut Pcg64| {
                let ops: Vec<u64> = (0..60).map(|_| rng.next_u64()).collect();
                ops
            },
            |ops| {
                let mut a = BlockAllocator::new(16, 4);
                let mut live: Vec<Vec<BlockId>> = Vec::new();
                for &op in ops {
                    match op % 4 {
                        0 => {
                            let want = (op / 4 % 40) as usize + 1;
                            if a.can_admit(want) {
                                live.push(a.alloc(want).map_err(|e| e.to_string())?);
                            }
                        }
                        1 => {
                            if !live.is_empty() {
                                let i = (op / 4) as usize % live.len();
                                let c = live.swap_remove(i);
                                a.release(&c);
                            }
                        }
                        2 => {
                            if !live.is_empty() {
                                let i = (op / 4) as usize % live.len();
                                let f = a
                                    .fork(&live[i].clone())
                                    .map_err(|e| e.to_string())?;
                                live.push(f);
                            }
                        }
                        _ => {
                            if !live.is_empty() && a.free_blocks() > 0 {
                                let i = (op / 4) as usize % live.len();
                                let cur = live[i].len() * a.block_tokens;
                                let mut c = live.swap_remove(i);
                                let _ = a.extend(&mut c, cur + 1);
                                live.push(c);
                            }
                        }
                    }
                    a.check_invariants().map_err(|e| e.to_string())?;
                }
                for c in live.drain(..) {
                    a.release(&c);
                }
                if a.free_blocks() != 16 {
                    return Err(format!("leaked blocks: {}", a.free_blocks()));
                }
                a.check_invariants().map_err(|e| e.to_string())
            },
        );
    }
}
