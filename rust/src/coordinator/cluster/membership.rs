//! Worker membership for the sharded router (DESIGN.md S24): slot
//! lifecycle (live / draining / dead), liveness sweeps over the worker
//! thread handles, and the live in-flight load gauge the routing
//! policies consume. This is pure bookkeeping — no channel traffic is
//! interpreted here; `cluster/router.rs` drives the transitions.

use std::sync::mpsc;
use std::thread;

use super::router::Cmd;

/// Lifecycle state of one worker slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerState {
    /// Accepting routed requests.
    Live,
    /// A drain barrier is outstanding: the worker is finishing its
    /// in-flight work and no new requests are routed to it until the
    /// barrier marker comes back.
    Draining,
    /// The worker thread exited (graceful leave, engine error, or
    /// panic). Dead slots are never routed to again; slot ids are
    /// stable, so surviving workers keep their identity.
    Dead,
}

/// One worker slot: the command channel into the worker thread, the
/// join handle liveness is swept through, and the routing gauges.
pub(crate) struct WorkerSlot {
    /// Command channel into the worker thread.
    pub(crate) tx: mpsc::Sender<Cmd>,
    /// Join handle; `is_finished()` is the liveness probe, `None` once
    /// joined (leave/shutdown).
    pub(crate) handle: Option<thread::JoinHandle<()>>,
    /// Lifecycle state (see [`WorkerState`]).
    pub(crate) state: WorkerState,
    /// Requests in flight: incremented at route time, decremented as
    /// each response streams back (NOT at drain — that was the PR-10
    /// load-accounting bug this module fixes).
    pub(crate) outstanding: usize,
}

/// Worker-slot table: join/leave, liveness sweeps, load accounting.
/// All index-taking methods are total — an out-of-range slot id reads
/// as dead/unloaded rather than panicking (R3: no panics on the
/// serving path).
#[derive(Default)]
pub struct Membership {
    slots: Vec<WorkerSlot>,
}

impl Membership {
    /// Empty table.
    pub(crate) fn new() -> Membership {
        Membership { slots: Vec::new() }
    }

    /// Register a freshly spawned worker; returns its slot id.
    pub(crate) fn join(
        &mut self,
        tx: mpsc::Sender<Cmd>,
        handle: thread::JoinHandle<()>,
    ) -> usize {
        self.slots.push(WorkerSlot {
            tx,
            handle: Some(handle),
            state: WorkerState::Live,
            outstanding: 0,
        });
        self.slots.len() - 1
    }

    /// Number of slots ever joined (dead slots included — ids are
    /// stable).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when no worker ever joined.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Slot ids currently routable (live, not draining, not dead).
    pub fn live(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.state == WorkerState::Live)
            .map(|(i, _)| i)
            .collect()
    }

    /// Lifecycle state of slot `i` (out-of-range reads as dead).
    pub fn state(&self, i: usize) -> WorkerState {
        self.slots.get(i).map(|s| s.state).unwrap_or(WorkerState::Dead)
    }

    /// In-flight load of slot `i` (0 when out of range).
    pub fn load(&self, i: usize) -> usize {
        self.slots.get(i).map(|s| s.outstanding).unwrap_or(0)
    }

    /// Iterate `(slot id, slot)` pairs.
    pub(crate) fn iter(
        &self,
    ) -> impl Iterator<Item = (usize, &WorkerSlot)> {
        self.slots.iter().enumerate()
    }

    /// Send a command to slot `i`; false when the slot is out of range
    /// or its worker thread hung up the channel.
    pub(crate) fn send(&self, i: usize, cmd: Cmd) -> bool {
        match self.slots.get(i) {
            Some(s) => s.tx.send(cmd).is_ok(),
            None => false,
        }
    }

    /// Bump slot `i`'s in-flight load (route time).
    pub(crate) fn inc_load(&mut self, i: usize) {
        if let Some(s) = self.slots.get_mut(i) {
            s.outstanding += 1;
        }
    }

    /// Drop slot `i`'s in-flight load by one (response streamed back).
    pub(crate) fn dec_load(&mut self, i: usize) {
        if let Some(s) = self.slots.get_mut(i) {
            s.outstanding = s.outstanding.saturating_sub(1);
        }
    }

    /// Zero every slot's load (drain barrier: anything still counted
    /// was lost to an engine error and is reported by the caller).
    pub(crate) fn reset_loads(&mut self) {
        for s in &mut self.slots {
            s.outstanding = 0;
        }
    }

    /// Mark a live slot draining (drain barrier sent).
    pub(crate) fn begin_drain(&mut self, i: usize) {
        if let Some(s) = self.slots.get_mut(i) {
            if s.state == WorkerState::Live {
                s.state = WorkerState::Draining;
            }
        }
    }

    /// Barrier marker received: a draining slot is routable again.
    pub(crate) fn finish_drain(&mut self, i: usize) {
        if let Some(s) = self.slots.get_mut(i) {
            if s.state == WorkerState::Draining {
                s.state = WorkerState::Live;
            }
        }
    }

    /// Mark slot `i` dead and zero its load (its in-flight requests
    /// are lost; the router's drain accounting reports them).
    pub(crate) fn mark_dead(&mut self, i: usize) {
        if let Some(s) = self.slots.get_mut(i) {
            s.state = WorkerState::Dead;
            s.outstanding = 0;
        }
    }

    /// Liveness sweep: any non-dead slot whose thread has exited (or
    /// was already joined) becomes dead. Returns the newly dead ids.
    pub(crate) fn sweep(&mut self) -> Vec<usize> {
        let mut newly_dead = Vec::new();
        for (i, s) in self.slots.iter_mut().enumerate() {
            if s.state == WorkerState::Dead {
                continue;
            }
            let finished = s
                .handle
                .as_ref()
                .map(|h| h.is_finished())
                .unwrap_or(true);
            if finished {
                s.state = WorkerState::Dead;
                s.outstanding = 0;
                newly_dead.push(i);
            }
        }
        newly_dead
    }

    /// Graceful leave: tell slot `i`'s worker to shut down, join its
    /// thread, and mark the slot dead.
    pub(crate) fn leave(&mut self, i: usize) {
        let _ = self.send(i, Cmd::Shutdown);
        if let Some(s) = self.slots.get_mut(i) {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
            s.state = WorkerState::Dead;
            s.outstanding = 0;
        }
    }

    /// Shut every worker down and join all threads (router drop path).
    pub(crate) fn shutdown_all(&mut self) {
        for s in &self.slots {
            let _ = s.tx.send(Cmd::Shutdown);
        }
        for s in &mut self.slots {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle_worker() -> (mpsc::Sender<Cmd>, thread::JoinHandle<()>) {
        let (tx, rx) = mpsc::channel::<Cmd>();
        let handle = thread::spawn(move || {
            while let Ok(cmd) = rx.recv() {
                if matches!(cmd, Cmd::Shutdown) {
                    break;
                }
            }
        });
        (tx, handle)
    }

    #[test]
    fn lifecycle_live_drain_dead() {
        let mut m = Membership::new();
        let (tx, h) = idle_worker();
        let i = m.join(tx, h);
        assert_eq!(m.state(i), WorkerState::Live);
        assert_eq!(m.live(), vec![i]);
        m.begin_drain(i);
        assert_eq!(m.state(i), WorkerState::Draining);
        assert!(m.live().is_empty());
        m.finish_drain(i);
        assert_eq!(m.state(i), WorkerState::Live);
        m.leave(i);
        assert_eq!(m.state(i), WorkerState::Dead);
        assert!(m.live().is_empty());
        // Totality: out-of-range ids read as dead/unloaded.
        assert_eq!(m.state(99), WorkerState::Dead);
        assert_eq!(m.load(99), 0);
    }

    #[test]
    fn sweep_detects_exited_threads() {
        let mut m = Membership::new();
        let (tx, h) = idle_worker();
        let i = m.join(tx, h);
        assert!(m.sweep().is_empty());
        // Ask the worker to exit, then wait for the thread to finish.
        assert!(m.send(i, Cmd::Shutdown));
        for _ in 0..200 {
            if !m.sweep().is_empty() {
                break;
            }
            thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(m.state(i), WorkerState::Dead);
    }

    #[test]
    fn load_accounting_saturates() {
        let mut m = Membership::new();
        let (tx, h) = idle_worker();
        let i = m.join(tx, h);
        m.inc_load(i);
        m.inc_load(i);
        assert_eq!(m.load(i), 2);
        m.dec_load(i);
        assert_eq!(m.load(i), 1);
        m.dec_load(i);
        m.dec_load(i); // saturates at 0, never underflows
        assert_eq!(m.load(i), 0);
        m.leave(i);
    }
}
