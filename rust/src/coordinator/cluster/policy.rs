//! Routing policies for the sharded router (DESIGN.md S24): the
//! [`RoutePolicy`] trait, the blind [`LeastLoaded`] baseline, the
//! shadow-index-driven [`PrefixAffinity`] policy, and the tokens-only
//! [`ShadowIndex`] mirror of a worker's radix-cache contents that
//! affinity routing consults.
//!
//! The shadow is exact, not approximate: the radix cache's delta
//! stream ([`PrefixEvent`]) announces every block-granular change, and
//! each cached block belongs to exactly one node path, so each
//! block-aligned prefix string is inserted exactly once and removed
//! exactly once — a plain set mirrors the cache with no refcounting.

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::kvcache::radix::PrefixEvent;

/// Tokens-only mirror of one worker's radix-cache contents: the set of
/// block-aligned prompt prefixes the worker could serve from cache,
/// with no slab rows attached. Kept current by replaying the worker's
/// [`PrefixEvent`] deltas (piggybacked on its response channel).
#[derive(Clone, Debug)]
pub struct ShadowIndex {
    /// Sharing granularity in tokens (must match the engines'
    /// `SchedulerConfig::block_tokens`, or shadowed prefixes would
    /// never align with real cache contents).
    block_tokens: usize,
    /// Every block-aligned cached prefix, one entry per cached block
    /// (the entry for block `b` of a chain is the prefix of length
    /// `b * block_tokens`).
    prefixes: HashSet<Vec<u32>>,
}

impl ShadowIndex {
    /// Empty shadow at the worker's block granularity.
    pub fn new(block_tokens: usize) -> ShadowIndex {
        ShadowIndex {
            block_tokens: block_tokens.max(1),
            prefixes: HashSet::new(),
        }
    }

    /// Replay one worker delta into the mirror.
    pub fn apply(&mut self, ev: &PrefixEvent) {
        let bt = self.block_tokens;
        match ev {
            PrefixEvent::Insert { tokens, new_blocks } => {
                let total = tokens.len() / bt;
                let first = total.saturating_sub(*new_blocks);
                for b in first + 1..=total {
                    self.prefixes.insert(tokens[..b * bt].to_vec());
                }
            }
            PrefixEvent::Evict { tokens, removed_blocks } => {
                let total = tokens.len() / bt;
                let first = total.saturating_sub(*removed_blocks);
                for b in first + 1..=total {
                    self.prefixes.remove(&tokens[..b * bt]);
                }
            }
        }
    }

    /// Blocks currently mirrored (each block-aligned prefix is exactly
    /// one cached block; equals the worker's `cached_blocks` gauge
    /// once its deltas are applied).
    pub fn blocks(&self) -> usize {
        self.prefixes.len()
    }

    /// True when nothing is mirrored.
    pub fn is_empty(&self) -> bool {
        self.prefixes.is_empty()
    }

    /// True when this exact block-aligned prefix is mirrored (test
    /// surface for the shadow-vs-cache property suite).
    pub fn contains_prefix(&self, tokens: &[u32]) -> bool {
        self.prefixes.contains(tokens)
    }

    /// Longest mirrored prefix of `prompt`, in blocks. Ascends one
    /// block at a time and stops at the first miss — valid because the
    /// radix tree is prefix-closed, so the mirror is too.
    pub fn matched_blocks(&self, prompt: &[u32]) -> usize {
        let bt = self.block_tokens;
        let mut matched = 0usize;
        while (matched + 1) * bt <= prompt.len()
            && self.prefixes.contains(&prompt[..(matched + 1) * bt])
        {
            matched += 1;
        }
        matched
    }
}

/// One routable worker as a policy sees it.
#[derive(Debug)]
pub struct Candidate<'a> {
    /// Worker slot id.
    pub worker: usize,
    /// Requests in flight on this worker right now (incremented at
    /// route time, decremented as responses stream back).
    pub load: usize,
    /// The worker's shadow index.
    pub shadow: &'a ShadowIndex,
}

/// A policy's verdict for one request.
#[derive(Clone, Copy, Debug)]
pub struct RouteDecision {
    /// Chosen worker slot id.
    pub worker: usize,
    /// Shadowed prefix blocks the choice was based on (0 for blind
    /// policies and for affinity's least-loaded fallback).
    pub affinity_blocks: usize,
}

/// A routing policy: pick one live worker for a prompt. Policies may
/// keep state (`&mut self`) — e.g. a rotation counter — but must be
/// deterministic given the same call sequence, so routed runs are
/// reproducible. `candidates` is non-empty (the router bails out
/// before routing when no live worker remains); a defensive
/// implementation still returns worker 0 on an empty slice rather
/// than panicking.
pub trait RoutePolicy: Send {
    /// Stable policy tag reported in stats and bench rows.
    fn name(&self) -> &'static str;
    /// Choose a worker from `candidates` for `prompt`.
    fn route(
        &mut self,
        prompt: &[u32],
        candidates: &[Candidate<'_>],
    ) -> RouteDecision;
}

/// Blind baseline: the least-loaded live worker, with a rotating
/// tie-break. The rotation matters: under closed-loop (serialized)
/// traffic every submit sees all loads at zero, and a lowest-id
/// tie-break would pin the whole trace to worker 0 — rotating spreads
/// ties round-robin so the baseline actually exercises N workers.
#[derive(Debug, Default)]
pub struct LeastLoaded {
    rr: usize,
}

impl RoutePolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(
        &mut self,
        _prompt: &[u32],
        candidates: &[Candidate<'_>],
    ) -> RouteDecision {
        let n = candidates.len();
        let mut best: Option<(usize, usize)> = None; // (load, worker)
        for k in 0..n {
            let c = &candidates[(self.rr + k) % n];
            if best.map(|(l, _)| c.load < l).unwrap_or(true) {
                best = Some((c.load, c.worker));
            }
        }
        self.rr = self.rr.wrapping_add(1);
        let (_, worker) = best.unwrap_or((0, 0));
        RouteDecision { worker, affinity_blocks: 0 }
    }
}

/// Cache-affinity policy: route to the worker whose shadow index holds
/// the longest block-aligned prefix of the prompt, so shared system
/// prompts concentrate on one worker instead of re-missing once per
/// worker. Ties among equally long matches go to the least loaded of
/// the tied workers (lowest id on a full tie — sticky, so an affinity
/// group does not migrate); a no-hit falls back to the
/// [`LeastLoaded`] baseline entirely.
#[derive(Debug, Default)]
pub struct PrefixAffinity {
    fallback: LeastLoaded,
}

impl RoutePolicy for PrefixAffinity {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn route(
        &mut self,
        prompt: &[u32],
        candidates: &[Candidate<'_>],
    ) -> RouteDecision {
        let mut best_blocks = 0usize;
        for c in candidates {
            best_blocks = best_blocks.max(c.shadow.matched_blocks(prompt));
        }
        if best_blocks == 0 {
            return self.fallback.route(prompt, candidates);
        }
        let mut winner: Option<(usize, usize)> = None; // (load, worker)
        for c in candidates {
            if c.shadow.matched_blocks(prompt) != best_blocks {
                continue;
            }
            if winner.map(|(l, _)| c.load < l).unwrap_or(true) {
                winner = Some((c.load, c.worker));
            }
        }
        let (_, worker) = winner.unwrap_or((0, 0));
        RouteDecision { worker, affinity_blocks: best_blocks }
    }
}

/// CLI-facing policy selector (`--route-policy affinity|least-loaded`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicyKind {
    /// Blind least-loaded routing ([`LeastLoaded`]).
    LeastLoaded,
    /// Shadow-index cache-affinity routing ([`PrefixAffinity`]).
    PrefixAffinity,
}

impl RoutePolicyKind {
    /// Parse a `--route-policy` value.
    pub fn parse(tag: &str) -> Result<RoutePolicyKind> {
        match tag {
            "least-loaded" => Ok(RoutePolicyKind::LeastLoaded),
            "affinity" => Ok(RoutePolicyKind::PrefixAffinity),
            other => bail!(
                "unknown route policy `{other}` \
                 (expected affinity or least-loaded)"
            ),
        }
    }

    /// Stable tag (round-trips through [`RoutePolicyKind::parse`]).
    pub fn tag(&self) -> &'static str {
        match self {
            RoutePolicyKind::LeastLoaded => "least-loaded",
            RoutePolicyKind::PrefixAffinity => "affinity",
        }
    }

    /// Instantiate the policy.
    pub fn build(&self) -> Box<dyn RoutePolicy> {
        match self {
            RoutePolicyKind::LeastLoaded => {
                Box::new(LeastLoaded::default())
            }
            RoutePolicyKind::PrefixAffinity => {
                Box::new(PrefixAffinity::default())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev_insert(tokens: Vec<u32>, new_blocks: usize) -> PrefixEvent {
        PrefixEvent::Insert { tokens, new_blocks }
    }

    #[test]
    fn shadow_mirrors_insert_and_evict() {
        let mut s = ShadowIndex::new(2);
        // Leaf [1,2,3,4]: blocks [1,2] and [1,2,3,4], both novel.
        s.apply(&ev_insert(vec![1, 2, 3, 4], 2));
        assert_eq!(s.blocks(), 2);
        assert!(s.contains_prefix(&[1, 2]));
        assert!(s.contains_prefix(&[1, 2, 3, 4]));
        // Sibling tail [1,2,9,9]: only its last block is novel.
        s.apply(&ev_insert(vec![1, 2, 9, 9], 1));
        assert_eq!(s.blocks(), 3);
        // Evicting the [3,4] leaf removes only its own block.
        s.apply(&PrefixEvent::Evict {
            tokens: vec![1, 2, 3, 4],
            removed_blocks: 1,
        });
        assert_eq!(s.blocks(), 2);
        assert!(s.contains_prefix(&[1, 2]));
        assert!(!s.contains_prefix(&[1, 2, 3, 4]));
        assert!(s.contains_prefix(&[1, 2, 9, 9]));
    }

    #[test]
    fn shadow_matched_blocks_ascends_to_first_miss() {
        let mut s = ShadowIndex::new(2);
        s.apply(&ev_insert(vec![1, 2, 3, 4], 2));
        assert_eq!(s.matched_blocks(&[1, 2, 3, 4, 5, 6]), 2);
        assert_eq!(s.matched_blocks(&[1, 2, 7, 8]), 1);
        assert_eq!(s.matched_blocks(&[9, 9, 9, 9]), 0);
        // Partial trailing block never counts.
        assert_eq!(s.matched_blocks(&[1, 2, 3]), 1);
    }

    #[test]
    fn least_loaded_rotates_ties_and_prefers_low_load() {
        let s0 = ShadowIndex::new(2);
        let s1 = ShadowIndex::new(2);
        let mut p = LeastLoaded::default();
        let tied = [
            Candidate { worker: 0, load: 0, shadow: &s0 },
            Candidate { worker: 1, load: 0, shadow: &s1 },
        ];
        // All-zero loads: the rotation alternates the winner.
        assert_eq!(p.route(&[], &tied).worker, 0);
        assert_eq!(p.route(&[], &tied).worker, 1);
        assert_eq!(p.route(&[], &tied).worker, 0);
        // A genuinely lighter worker wins regardless of rotation.
        let skewed = [
            Candidate { worker: 0, load: 5, shadow: &s0 },
            Candidate { worker: 1, load: 1, shadow: &s1 },
        ];
        for _ in 0..4 {
            assert_eq!(p.route(&[], &skewed).worker, 1);
        }
    }

    #[test]
    fn affinity_prefers_longest_prefix_and_falls_back() {
        let mut s0 = ShadowIndex::new(2);
        let mut s1 = ShadowIndex::new(2);
        s0.apply(&ev_insert(vec![1, 2], 1));
        s1.apply(&ev_insert(vec![1, 2, 3, 4], 2));
        let mut p = PrefixAffinity::default();
        let cands = [
            Candidate { worker: 0, load: 0, shadow: &s0 },
            Candidate { worker: 1, load: 9, shadow: &s1 },
        ];
        // Longer shadowed prefix beats lighter load.
        let d = p.route(&[1, 2, 3, 4, 5, 5], &cands);
        assert_eq!(d.worker, 1);
        assert_eq!(d.affinity_blocks, 2);
        // Equal match length: load breaks the tie.
        let d = p.route(&[1, 2, 9, 9], &cands);
        assert_eq!(d.worker, 0);
        assert_eq!(d.affinity_blocks, 1);
        // No hit anywhere: least-loaded fallback, zero affinity.
        let d = p.route(&[7, 7, 7, 7], &cands);
        assert_eq!(d.worker, 0);
        assert_eq!(d.affinity_blocks, 0);
    }

    #[test]
    fn kind_round_trips_and_rejects_unknown() {
        for kind in
            [RoutePolicyKind::LeastLoaded, RoutePolicyKind::PrefixAffinity]
        {
            assert_eq!(RoutePolicyKind::parse(kind.tag()).unwrap(), kind);
            assert_eq!(kind.build().name(), kind.tag());
        }
        assert!(RoutePolicyKind::parse("random").is_err());
    }
}
