//! Command/response plumbing of the sharded router (DESIGN.md S24):
//! fan requests over engine worker threads, stream responses back
//! *live* (so worker load decrements as work completes instead of
//! resetting only at drain), mirror each worker's radix-cache deltas
//! into a per-worker [`ShadowIndex`], and drain with exact
//! missing-response accounting when workers die mid-round.
//!
//! Ordering contract: a worker flushes its cache deltas BEFORE the
//! responses of the engine step that produced them. Per-sender FIFO
//! then guarantees that once the router has seen a request's response,
//! it has already seen that request's cache insertions — which is what
//! makes closed-loop affinity routing deterministic.
//!
//! Routing invariance: workers run identical engine configurations and
//! a request's sampling seed comes from its own params (xor'd with the
//! request id), so per-request outputs are bitwise identical no matter
//! which worker serves them (`rust/tests/sharded_routing.rs`).

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::api::{FinishReason, Request, Response};
use crate::coordinator::server::{InferenceServer, ServerStats};
use crate::kvcache::radix::PrefixEvent;

use super::membership::{Membership, WorkerState};
use super::policy::{Candidate, RoutePolicyKind, ShadowIndex};

/// Router -> worker commands.
pub(crate) enum Cmd {
    /// Run this request on the worker's engine.
    Submit(Request),
    /// Finish all in-flight work, streaming responses, then mark the
    /// drain barrier.
    Drain,
    /// Snapshot the engine's scheduler stats through the one-shot sender.
    Stats(mpsc::Sender<ServerStats>),
    /// Exit the worker loop.
    Shutdown,
}

/// Worker -> router traffic. `DrainDone(i)` is worker `i`'s barrier
/// marker: it lets `Router::drain` terminate even when an engine
/// errored mid-drain and some submitted requests will never produce a
/// response.
enum WorkerMsg {
    /// Radix-cache deltas from one engine step, flushed BEFORE that
    /// step's responses (see the module-level ordering contract).
    Deltas { worker: usize, events: Vec<PrefixEvent> },
    /// One completed (or rejected) request; `worker` keys the live
    /// load decrement.
    Response { worker: usize, response: Response },
    /// Worker `i` finished draining.
    DrainDone(usize),
}

/// A thread-local engine constructor. PJRT client handles are not Send,
/// so each worker builds its own engine *inside* its thread.
pub type EngineFactory =
    Box<dyn FnOnce() -> anyhow::Result<InferenceServer> + Send>;

/// Per-worker routing accounting (the S24 bench columns).
#[derive(Clone, Debug, Default)]
pub struct RouteStats {
    /// Tag of the policy that routed (`"affinity"`/`"least-loaded"`).
    pub policy: &'static str,
    /// Requests routed to each worker slot (cumulative).
    pub routed: Vec<usize>,
    /// Routed requests whose decision matched a nonzero shadowed
    /// prefix, per worker slot.
    pub affinity_hits: Vec<usize>,
    /// Shadowed prefix blocks those matches claimed, summed per slot.
    pub affinity_blocks: Vec<usize>,
    /// Current shadow-index size per worker slot, in blocks (gauge).
    pub shadow_blocks: Vec<usize>,
}

/// Policy-routed request fan-out over N single-engine worker threads,
/// with streaming response collection and per-worker shadow radix
/// indexes (DESIGN.md S24).
pub struct Router {
    members: Membership,
    policy: Box<dyn super::policy::RoutePolicy>,
    policy_kind: RoutePolicyKind,
    shadows: Vec<ShadowIndex>,
    rx: mpsc::Receiver<WorkerMsg>,
    /// Responses streamed in since the last drain returned.
    pending: Vec<Response>,
    submitted: usize,
    collected: usize,
    routed: Vec<usize>,
    affinity_hits: Vec<usize>,
    affinity_blocks: Vec<usize>,
}

/// Flush one engine step's output: cache deltas first, then the
/// responses the same step completed (the module-level ordering
/// contract).
fn flush(
    worker: usize,
    engine: &mut InferenceServer,
    out: &mpsc::Sender<WorkerMsg>,
    responses: Vec<Response>,
) {
    let events = engine.take_prefix_events();
    if !events.is_empty() {
        let _ = out.send(WorkerMsg::Deltas { worker, events });
    }
    for response in responses {
        let _ = out.send(WorkerMsg::Response { worker, response });
    }
}

/// Body of one worker thread: build the engine in-thread, then
/// interleave command handling with engine steps — while the engine is
/// busy, commands are polled between steps so responses stream out
/// live; while idle, the loop blocks on the channel. An engine error
/// is terminal: the loop logs, exits, and the router's liveness sweep
/// reclassifies the slot as dead.
fn worker_loop(
    i: usize,
    factory: EngineFactory,
    cmd_rx: mpsc::Receiver<Cmd>,
    out: mpsc::Sender<WorkerMsg>,
) {
    let mut engine = match factory() {
        Ok(mut e) => {
            e.track_prefix_events(true);
            e
        }
        Err(e) => {
            log::error!("engine {i} init failed: {e:#}");
            return;
        }
    };
    loop {
        let cmd = if engine.busy() {
            match cmd_rx.try_recv() {
                Ok(c) => Some(c),
                Err(mpsc::TryRecvError::Empty) => None,
                Err(mpsc::TryRecvError::Disconnected) => break,
            }
        } else {
            match cmd_rx.recv() {
                Ok(c) => Some(c),
                Err(_) => break,
            }
        };
        match cmd {
            Some(Cmd::Submit(req)) => {
                let id = req.id;
                if let Err(e) = engine.submit(req) {
                    log::error!("engine {i}: request {id} rejected: {e:#}");
                    // Keep the router's response accounting exact: a
                    // rejection still produces one response.
                    let _ = out.send(WorkerMsg::Response {
                        worker: i,
                        response: Response {
                            id,
                            tokens: Vec::new(),
                            ttft: 0.0,
                            tpot: 0.0,
                            latency: 0.0,
                            finish: FinishReason::Rejected,
                        },
                    });
                }
            }
            Some(Cmd::Stats(tx)) => {
                let _ = tx.send(engine.stats.clone());
            }
            Some(Cmd::Drain) => {
                let mut failed = false;
                while engine.busy() {
                    match engine.step() {
                        Ok(responses) => {
                            flush(i, &mut engine, &out, responses);
                        }
                        Err(e) => {
                            log::error!("engine {i}: {e:#}");
                            failed = true;
                            break;
                        }
                    }
                }
                flush(i, &mut engine, &out, Vec::new());
                // Always mark the barrier, even after an engine error —
                // in-flight requests may be lost but drain() must
                // return.
                let _ = out.send(WorkerMsg::DrainDone(i));
                if failed {
                    // The engine is poisoned; exit so the liveness
                    // sweep retires this slot instead of routing more
                    // requests into errors.
                    break;
                }
            }
            Some(Cmd::Shutdown) => break,
            None => match engine.step() {
                Ok(responses) => flush(i, &mut engine, &out, responses),
                Err(e) => {
                    log::error!("engine {i}: {e:#}");
                    break;
                }
            },
        }
    }
}

impl Router {
    /// Least-loaded router at the default 16-token shadow granularity
    /// (the blind policy never reads shadow contents, so the
    /// granularity is irrelevant here; this is the back-compatible
    /// constructor).
    pub fn new(factories: Vec<EngineFactory>) -> Router {
        Router::with_policy(factories, RoutePolicyKind::LeastLoaded, 16)
    }

    /// Build a router with one worker thread per factory, routing with
    /// `policy`. `block_tokens` sets the shadow-index granularity and
    /// must match the engines' `SchedulerConfig::block_tokens` for
    /// affinity routing to see real cache contents.
    pub fn with_policy(
        factories: Vec<EngineFactory>,
        policy: RoutePolicyKind,
        block_tokens: usize,
    ) -> Router {
        let (resp_tx, rx) = mpsc::channel::<WorkerMsg>();
        let mut members = Membership::new();
        let n = factories.len();
        for (i, factory) in factories.into_iter().enumerate() {
            let (tx, cmd_rx) = mpsc::channel::<Cmd>();
            let out = resp_tx.clone();
            let handle = thread::Builder::new()
                .name(format!("elitekv-engine-{i}"))
                .spawn(move || worker_loop(i, factory, cmd_rx, out))
                // lint: allow(R3) — worker-pool construction runs
                // once at router startup, not on the request path.
                .expect("spawn engine worker");
            members.join(tx, handle);
        }
        // `resp_tx` is dropped here: only workers hold senders, so the
        // channel disconnects (and drain/recv errors out) when every
        // worker thread has exited.
        drop(resp_tx);
        Router {
            members,
            policy: policy.build(),
            policy_kind: policy,
            shadows: (0..n).map(|_| ShadowIndex::new(block_tokens)).collect(),
            rx,
            pending: Vec::new(),
            submitted: 0,
            collected: 0,
            routed: vec![0; n],
            affinity_hits: vec![0; n],
            affinity_blocks: vec![0; n],
        }
    }

    /// Number of engine worker slots (dead slots included; ids are
    /// stable).
    pub fn n_workers(&self) -> usize {
        self.members.len()
    }

    /// Live in-flight load per worker slot: incremented at route time,
    /// decremented as each response streams back (dead slots read 0).
    pub fn loads(&self) -> Vec<usize> {
        (0..self.members.len()).map(|i| self.members.load(i)).collect()
    }

    /// Lifecycle state per worker slot.
    pub fn states(&self) -> Vec<WorkerState> {
        (0..self.members.len()).map(|i| self.members.state(i)).collect()
    }

    /// Per-worker routing accounting under the active policy.
    pub fn route_stats(&self) -> RouteStats {
        RouteStats {
            policy: self.policy_kind.tag(),
            routed: self.routed.clone(),
            affinity_hits: self.affinity_hits.clone(),
            affinity_blocks: self.affinity_blocks.clone(),
            shadow_blocks: self.shadows.iter().map(|s| s.blocks()).collect(),
        }
    }

    /// Drain worker traffic without blocking and return how many
    /// responses have streamed in this round so far. This is the live
    /// half of collection: loads decrement and shadow indexes update
    /// here (and inside submit/drain, which pump too), not only at the
    /// drain barrier.
    pub fn poll(&mut self) -> usize {
        self.pump();
        self.collected
    }

    /// Consume every buffered worker message. Stale `DrainDone`
    /// markers (from a worker that died right after barrier-marking a
    /// previous round) are ignored here — barrier masks are per-drain.
    fn pump(&mut self) {
        while let Ok(msg) = self.rx.try_recv() {
            self.apply(msg);
        }
    }

    /// Fold one worker message into router state; returns the worker
    /// id when the message was a drain barrier marker.
    fn apply(&mut self, msg: WorkerMsg) -> Option<usize> {
        match msg {
            WorkerMsg::Deltas { worker, events } => {
                if let Some(shadow) = self.shadows.get_mut(worker) {
                    for ev in &events {
                        shadow.apply(ev);
                    }
                }
                None
            }
            WorkerMsg::Response { worker, response } => {
                self.members.dec_load(worker);
                self.collected += 1;
                self.pending.push(response);
                None
            }
            WorkerMsg::DrainDone(i) => Some(i),
        }
    }

    /// Route one request. Pumps pending worker traffic first (so loads
    /// and shadows are current), asks the policy for a worker, and
    /// reroutes if the chosen worker's channel is gone (marking the
    /// slot dead). Errors only when no live worker remains.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        self.pump();
        self.members.sweep();
        loop {
            let live = self.members.live();
            if live.is_empty() {
                bail!("router has no live workers");
            }
            let candidates: Vec<Candidate<'_>> = live
                .iter()
                .filter_map(|&w| {
                    self.shadows.get(w).map(|shadow| Candidate {
                        worker: w,
                        load: self.members.load(w),
                        shadow,
                    })
                })
                .collect();
            let decision = self.policy.route(&req.prompt, &candidates);
            let w = decision.worker;
            if !self.members.send(w, Cmd::Submit(req.clone())) {
                log::error!(
                    "worker {w} hung up; rerouting request {}",
                    req.id
                );
                self.members.mark_dead(w);
                continue;
            }
            self.members.inc_load(w);
            self.submitted += 1;
            if let Some(r) = self.routed.get_mut(w) {
                *r += 1;
            }
            if decision.affinity_blocks > 0 {
                if let Some(h) = self.affinity_hits.get_mut(w) {
                    *h += 1;
                }
                if let Some(b) = self.affinity_blocks.get_mut(w) {
                    *b += decision.affinity_blocks;
                }
            }
            return Ok(());
        }
    }

    /// Snapshot scheduler stats from every non-dead worker, keyed by
    /// slot id (dead workers are skipped — their engine is gone). Call
    /// after [`Router::drain`] for end-of-run numbers.
    pub fn stats(&self) -> Vec<(usize, ServerStats)> {
        let mut out = Vec::new();
        for (i, slot) in self.members.iter() {
            if slot.state == WorkerState::Dead {
                continue;
            }
            let (tx, rx) = mpsc::channel();
            if !self.members.send(i, Cmd::Stats(tx)) {
                continue;
            }
            match rx.recv() {
                Ok(s) => out.push((i, s)),
                Err(_) => {
                    log::error!("worker {i} exited before reporting stats");
                }
            }
        }
        out
    }

    /// Gracefully remove worker `i` from the cluster: its thread is
    /// told to shut down and joined, and the slot goes dead. Requests
    /// still in flight on it are NOT recovered — the next
    /// [`Router::drain`] reports them as missing — so leave idle
    /// workers, or drain first.
    pub fn leave(&mut self, i: usize) {
        self.pump();
        self.members.leave(i);
        // Sweep up anything it flushed between the pump and its exit.
        self.pump();
    }

    /// Run all workers to completion and return every response routed
    /// since the last drain (both the already-streamed and the ones
    /// collected during the barrier). Returns once every worker has
    /// finished draining (or died); responses lost to engine errors or
    /// worker panics are reported as an error instead of blocking
    /// forever.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        self.members.sweep();
        let n = self.members.len();
        let mut done_mask = vec![false; n];
        for i in 0..n {
            // A dead worker (init failure / engine error / panic) will
            // never send its barrier marker: count it done up front.
            if self.members.state(i) == WorkerState::Dead {
                if let Some(d) = done_mask.get_mut(i) {
                    *d = true;
                }
                continue;
            }
            if self.members.send(i, Cmd::Drain) {
                self.members.begin_drain(i);
            } else {
                self.members.mark_dead(i);
                if let Some(d) = done_mask.get_mut(i) {
                    *d = true;
                }
            }
        }
        // Consume until EVERY live worker has marked its barrier —
        // per-sender FIFO means all of a worker's responses (and
        // deltas) precede its marker, so nothing is left behind for
        // the next round. The timeout arm sweeps for workers that
        // died mid-drain (their thread is finished but no marker ever
        // arrives).
        while done_mask.iter().any(|d| !d) {
            match self.rx.recv_timeout(Duration::from_millis(250)) {
                Ok(msg) => {
                    if let Some(i) = self.apply(msg) {
                        if let Some(d) = done_mask.get_mut(i) {
                            *d = true;
                        }
                        self.members.finish_drain(i);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    for i in self.members.sweep() {
                        log::error!(
                            "worker {i} died during drain; its \
                             in-flight requests are lost"
                        );
                        if let Some(d) = done_mask.get_mut(i) {
                            *d = true;
                        }
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // A worker that died between flushing output and its marker
        // leaves messages buffered: sweep them up now so they are not
        // mis-attributed to the NEXT round's accounting.
        self.pump();
        let out = std::mem::take(&mut self.pending);
        let missing = self.submitted.saturating_sub(self.collected);
        // Full barrier: reset the accounting either way so a later
        // submit/drain round starts clean.
        self.submitted = 0;
        self.collected = 0;
        self.members.reset_loads();
        if missing > 0 {
            bail!(
                "{missing} request(s) lost to engine errors during drain \
                 ({} responses collected; see worker logs)",
                out.len()
            );
        }
        Ok(out)
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.members.shutdown_all();
    }
}

#[cfg(test)]
mod tests {
    use std::time::Instant;

    use super::*;
    use crate::config::{ModelConfig, Variant};
    use crate::coordinator::api::GenParams;
    use crate::coordinator::scheduler::SchedulerConfig;
    use crate::native::{NativeModel, NativeRunner};

    fn tiny_factory() -> EngineFactory {
        Box::new(|| {
            let cfg = ModelConfig::tiny();
            let model = NativeModel::init(&cfg, Variant::Mha, 7, None)?;
            let runner = NativeRunner::new(model, 2, 64)?;
            let scheduler = SchedulerConfig {
                prefix_cache: true,
                ..SchedulerConfig::with_budget(1 << 20)
            };
            InferenceServer::with_config(Box::new(runner), &scheduler)
        })
    }

    fn req(id: u64, prompt: Vec<u32>) -> Request {
        Request::new(
            id,
            prompt,
            GenParams {
                max_new_tokens: 4,
                stop_token: None,
                ..Default::default()
            },
        )
    }

    /// The PR-10 satellite pin: `outstanding` used to be incremented at
    /// submit and only reset at drain, so "least-loaded" was really
    /// "fewest-submitted-this-round". With streaming collection the
    /// load must hit zero as responses arrive, BEFORE any drain.
    #[test]
    fn streaming_collection_decrements_load_before_drain() {
        let cfg = ModelConfig::tiny();
        let mut router = Router::new(vec![tiny_factory(), tiny_factory()]);
        let n_req = 4u64;
        for i in 0..n_req {
            let prompt: Vec<u32> =
                (0..8).map(|t| ((i * 8 + t) % cfg.vocab as u64) as u32).collect();
            router.submit(req(i, prompt)).unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(60);
        while router.poll() < n_req as usize {
            assert!(
                Instant::now() < deadline,
                "responses never streamed back"
            );
            thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(
            router.loads(),
            vec![0, 0],
            "loads must decrement live as responses stream back"
        );
        let responses = router.drain().unwrap();
        assert_eq!(responses.len(), n_req as usize);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n_req).collect::<Vec<_>>());
    }

    #[test]
    fn leave_retires_worker_but_cluster_keeps_serving() {
        let cfg = ModelConfig::tiny();
        let mut router = Router::new(vec![tiny_factory(), tiny_factory()]);
        router.leave(0);
        assert_eq!(router.states()[0], WorkerState::Dead);
        let prompt: Vec<u32> = (0..8).map(|t| t % cfg.vocab as u32).collect();
        for i in 0..3 {
            router.submit(req(i, prompt.clone())).unwrap();
        }
        let responses = router.drain().unwrap();
        assert_eq!(responses.len(), 3);
        assert_eq!(router.route_stats().routed, vec![0, 3]);
    }
}
