//! Sharded multi-worker serving (DESIGN.md S24): the scale-out layer
//! that turns N isolated engine workers into one coordinated cluster.
//!
//! * [`membership`] — worker slots: join/leave lifecycle, liveness
//!   sweeps over the thread handles, draining state, and the live
//!   in-flight load gauge the policies route on.
//! * [`policy`] — the [`RoutePolicy`] trait with the blind
//!   [`LeastLoaded`] baseline and the shadow-index-driven
//!   [`PrefixAffinity`] router, plus [`ShadowIndex`], the tokens-only
//!   mirror of a worker's radix-cache contents.
//! * [`router`] — command/response plumbing: fan requests over the
//!   worker threads, stream responses (and piggybacked radix-cache
//!   deltas) back live, and drain with exact missing-response
//!   accounting when workers die.
//!
//! Routing never changes what a request generates: every worker runs
//! the same engine configuration and sampling is seeded per request,
//! so per-request outputs are bitwise identical no matter which worker
//! serves them (`rust/tests/sharded_routing.rs` pins this).

pub mod membership;
pub mod policy;
pub mod router;

pub use membership::{Membership, WorkerState};
pub use policy::{
    Candidate, LeastLoaded, PrefixAffinity, RouteDecision, RoutePolicy,
    RoutePolicyKind, ShadowIndex,
};
pub use router::{EngineFactory, RouteStats, Router};
