//! Admission queue + continuous-batching policy.
//!
//! Decisions mirror vLLM's scheduler at miniature scale: requests wait in
//! FIFO; a request is admitted when (a) a decode lane is idle and (b) the
//! block allocator can cover its worst-case cache need. Because EliteKV
//! shrinks bytes-per-token, the same block pool admits ~1/ratio times the
//! sequences — the capacity effect the serving bench measures.
//!
//! Admission is deliberately agnostic to HOW the engine prefills: under
//! chunked prefill (DESIGN.md S22) the same FIFO/budget decision admits a
//! request whose prompt will then be computed a chunk per iteration, so
//! new admissions keep landing while earlier lanes are still mid-prefill.

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::api::Request;
use crate::kvcache::block::BlockId;
use crate::kvcache::quant::SlabRows;
use crate::kvcache::radix::{PrefixEvent, PrefixHit, PrefixStats, RadixCache};
use crate::kvcache::{BlockAllocator, SlotManager};

/// One admitted request: the lane it was assigned, the block chain
/// charged for it, and — when the prefix cache hit — how many prompt
/// tokens are already cached (with their stored slab rows), so the
/// engine prefills only the suffix.
pub struct Admission {
    /// The admitted request.
    pub request: Request,
    /// Decode lane assigned by the [`SlotManager`].
    pub slot: usize,
    /// Block chain covering the worst-case footprint; its first
    /// `cached_tokens / block_tokens` blocks alias the radix cache.
    pub chain: Vec<BlockId>,
    /// Prompt tokens served from the prefix cache (0 = none; always a
    /// multiple of `block_tokens` and strictly less than the prompt).
    pub cached_tokens: usize,
    /// Stored slab rows for the cached tokens, one `[L, cached, w]`
    /// payload per cache slab in the engine's cache dtype (empty when
    /// `cached_tokens == 0`).
    pub cached_rows: Vec<SlabRows>,
}

/// FIFO queue with block-budget admission control.
pub struct AdmissionQueue {
    queue: VecDeque<Request>,
    /// The paged block pool admissions are charged against.
    pub allocator: BlockAllocator,
    /// worst-case generation length used for admission (prompt + max_new)
    pub conservative: bool,
    /// Prefix radix cache (`SchedulerConfig::prefix_cache`); `None`
    /// disables sharing entirely.
    pub prefix: Option<RadixCache>,
}

impl AdmissionQueue {
    /// Empty queue over a block pool (conservative admission by default,
    /// prefix cache off).
    pub fn new(allocator: BlockAllocator) -> AdmissionQueue {
        AdmissionQueue {
            queue: VecDeque::new(),
            allocator,
            conservative: true,
            prefix: None,
        }
    }

    /// Enqueue at the FIFO tail (no admissibility check — see
    /// [`AdmissionQueue::admissible`] for the submit-time gate).
    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    /// Requests currently waiting.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when nothing is waiting.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Worst-case token footprint used for admission control.
    pub fn need_tokens(&self, req: &Request) -> usize {
        if self.conservative {
            req.prompt.len() + req.params.max_new_tokens
        } else {
            req.prompt.len()
        }
    }

    /// Can this request EVER be admitted by this queue + slot geometry?
    /// (Prompt must fit the serving window with room to generate, and the
    /// worst-case block need must not exceed the whole pool.) Requests
    /// failing this would park at the head of the FIFO forever.
    pub fn admissible(&self, req: &Request, slots: &SlotManager) -> Result<()> {
        anyhow::ensure!(
            !req.prompt.is_empty(),
            "request {}: empty prompt (nothing to prefill)",
            req.id
        );
        // The engine always samples at least one token per admitted
        // lane, so a zero-token request cannot be honored — reject it
        // instead of returning an unrequested token.
        anyhow::ensure!(
            req.params.max_new_tokens > 0,
            "request {}: max_new_tokens must be at least 1",
            req.id
        );
        // The lane advances once per generated token before the next
        // decode — prompt + max_new must fit the window or the run
        // would die at SlotManager::advance mid-decode.
        let gen = req.params.max_new_tokens;
        anyhow::ensure!(
            req.prompt.len() + gen <= slots.max_seq,
            "request {}: prompt of {} tokens + up to {gen} generated \
             cannot fit the {}-token serving window",
            req.id,
            req.prompt.len(),
            slots.max_seq
        );
        let need = self.allocator.blocks_for(self.need_tokens(req));
        anyhow::ensure!(
            need <= self.allocator.n_blocks(),
            "request {}: worst-case need of {need} blocks exceeds the \
             whole pool ({} blocks); raise --cache-budget-mb or lower \
             max_new_tokens",
            req.id,
            self.allocator.n_blocks()
        );
        Ok(())
    }

    /// Admit as many queued requests as the lanes + block pool allow.
    /// With the prefix cache enabled, the longest cached full-block
    /// prefix of each prompt is reused (forked, not re-allocated) and
    /// only the remaining worst-case footprint draws fresh blocks; when
    /// fresh blocks run short, LRU cache leaves are evicted first.
    /// The engine decides what to DO with an admission — monolithic
    /// prefill in the admission iteration, or parking the lane at a
    /// prefill cursor to be advanced chunk-by-chunk (S22); either way
    /// the admission proceeds while other lanes are mid-chunk-prefill.
    pub fn admit(&mut self, slots: &mut SlotManager) -> Vec<Admission> {
        let mut admitted = Vec::new();
        while slots.idle_count() > 0 {
            let Some(front) = self.queue.front() else { break };
            if front.prompt.is_empty() || front.prompt.len() >= slots.max_seq
            {
                // Defensive: an empty or over-long prompt that slipped
                // past `admissible` must not panic/error the engine loop
                // (prefill requires 1 <= len < window). Drop it.
                // lint: allow(R3) — `front` above proves the queue is
                // non-empty.
                let req = self.queue.pop_front().unwrap();
                log::error!(
                    "dropping request {}: prompt of {} tokens outside \
                     [1, {})",
                    req.id,
                    req.prompt.len(),
                    slots.max_seq
                );
                continue;
            }
            let need = self.need_tokens(front);
            if self.allocator.blocks_for(need) > self.allocator.n_blocks() {
                // Defensive twin of the prompt-bounds drop above: a head
                // request larger than the WHOLE pool would never admit
                // and busy-loop the engine; drop it instead of waiting.
                // lint: allow(R3) — `front` above proves the queue is
                // non-empty.
                let req = self.queue.pop_front().unwrap();
                log::error!(
                    "dropping request {}: worst-case need of {} blocks \
                     exceeds the whole pool ({})",
                    req.id,
                    self.allocator.blocks_for(need),
                    self.allocator.n_blocks()
                );
                continue;
            }
            // Longest cached prefix, capped one token short of the
            // prompt: the engine must prefill at least the final prompt
            // position to produce first logits.
            let hit = match &mut self.prefix {
                Some(pc) => {
                    let cap = front.prompt.len() - 1;
                    match pc.lookup(&front.prompt, cap, &mut self.allocator)
                    {
                        Ok(hit) => hit,
                        Err(e) => {
                            log::error!("prefix lookup failed: {e:#}");
                            PrefixHit::default()
                        }
                    }
                }
                None => PrefixHit::default(),
            };
            let need_blocks = self.allocator.blocks_for(need);
            let fresh_needed = need_blocks - hit.chain.len();
            if self.allocator.free_blocks() < fresh_needed {
                if let Some(pc) = &mut self.prefix {
                    // Pool pressure: shed cold cached prefixes. The hit's
                    // own blocks are safe — the fork above owns separate
                    // references, so an evicted node cannot free them.
                    pc.evict(fresh_needed, &mut self.allocator);
                }
                if self.allocator.free_blocks() < fresh_needed {
                    // strict FIFO: no head-of-line bypass
                    self.allocator.release(&hit.chain);
                    break;
                }
            }
            let mut chain = hit.chain;
            if let Err(e) = self.allocator.extend(&mut chain, need) {
                log::error!("admission extend failed after check: {e:#}");
                self.allocator.release(&chain);
                break;
            }
            // lint: allow(R3) — `front` above proves the queue is
            // non-empty.
            let req = self.queue.pop_front().unwrap();
            let slot = slots
                .claim(req.id, req.prompt.len())
                // lint: allow(R3) — admission checked an idle slot and
                // the prompt bounds before reaching claim.
                .expect("idle slot and prompt length checked");
            if let Some(pc) = &mut self.prefix {
                pc.record_admission(hit.tokens);
            }
            admitted.push(Admission {
                request: req,
                slot,
                chain,
                cached_tokens: hit.tokens,
                cached_rows: hit.rows,
            });
        }
        admitted
    }

    /// Return a finished request's blocks to the pool.
    pub fn release(&mut self, chain: &[BlockId]) {
        self.allocator.release(chain);
    }

    /// True when the prefix radix cache is active.
    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Prefix-cache counter snapshot (None when disabled).
    pub fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.as_ref().map(|pc| pc.stats())
    }

    /// Enable or disable prefix delta-event tracking (no-op when the
    /// radix cache is off). See [`RadixCache::set_event_tracking`].
    pub fn set_prefix_event_tracking(&mut self, on: bool) {
        if let Some(pc) = &mut self.prefix {
            pc.set_event_tracking(on);
        }
    }

    /// Drain pending prefix delta events (empty when the cache is off
    /// or tracking is disabled). See [`PrefixEvent`].
    pub fn take_prefix_events(&mut self) -> Vec<PrefixEvent> {
        match &mut self.prefix {
            Some(pc) => pc.take_events(),
            None => Vec::new(),
        }
    }

    /// Insert a finished request's full-block prompt prefix into the
    /// radix cache (no-op when disabled). `chain` is the request's block
    /// chain — the cached tail forks it — and `rows` produces the lane's
    /// slab rows for the aligned prefix, invoked only when a novel tail
    /// is actually cached. Returns newly cached blocks.
    pub fn prefix_insert<F>(
        &mut self,
        tokens: &[u32],
        chain: &[BlockId],
        rows: F,
    ) -> Result<usize>
    where
        F: FnOnce() -> Result<Vec<SlabRows>>,
    {
        match &mut self.prefix {
            Some(pc) => pc.insert(tokens, chain, rows, &mut self.allocator),
            None => Ok(0),
        }
    }

    /// Grow a live chain to cover `new_len` tokens, evicting LRU cache
    /// leaves first if the pool is dry. Mirrors
    /// [`BlockAllocator::extend`]'s contract otherwise.
    pub fn extend_with_eviction(
        &mut self,
        chain: &mut Vec<BlockId>,
        new_len: usize,
    ) -> Result<()> {
        let need = self.allocator.blocks_for(new_len);
        let missing = need.saturating_sub(chain.len());
        if self.allocator.free_blocks() < missing {
            if let Some(pc) = &mut self.prefix {
                pc.evict(missing, &mut self.allocator);
            }
        }
        self.allocator.extend(chain, new_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, Variant};
    use crate::coordinator::api::{GenParams, Request};
    use crate::kvcache::CacheLayout;

    fn setup(n_blocks: usize) -> (AdmissionQueue, SlotManager) {
        let cfg = ModelConfig::tiny();
        let layout = CacheLayout::new(&cfg, Variant::Mha);
        let q = AdmissionQueue::new(BlockAllocator::new(n_blocks, 16));
        let slots = SlotManager::new(layout, 4, 256);
        (q, slots)
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(
            id,
            vec![1; prompt_len],
            GenParams { max_new_tokens: max_new, ..Default::default() },
        )
    }

    #[test]
    fn admits_up_to_lane_count() {
        let (mut q, mut slots) = setup(100);
        for i in 0..6 {
            q.push(req(i, 8, 8));
        }
        let admitted = q.admit(&mut slots);
        assert_eq!(admitted.len(), 4); // 4 lanes
        assert_eq!(q.len(), 2);
        assert_eq!(slots.idle_count(), 0);
    }

    #[test]
    fn admission_blocked_by_pool() {
        let (mut q, mut slots) = setup(2); // 32 tokens of pool
        q.push(req(0, 16, 16)); // needs 2 blocks
        q.push(req(1, 16, 16)); // pool exhausted
        let admitted = q.admit(&mut slots);
        assert_eq!(admitted.len(), 1);
        assert_eq!(q.len(), 1);
        // releasing lets the second one in
        let adm = &admitted[0];
        slots.free(adm.slot);
        q.release(&adm.chain);
        let second = q.admit(&mut slots);
        assert_eq!(second.len(), 1);
    }

    /// With the radix cache on, a second request sharing a cached prefix
    /// draws fewer fresh blocks and reports its cached token count.
    #[test]
    fn prefix_hit_reuses_cached_blocks() {
        let cfg = ModelConfig::tiny();
        let layout = CacheLayout::new(&cfg, Variant::Mha);
        let mut q = AdmissionQueue::new(BlockAllocator::new(8, 4));
        q.prefix = Some(RadixCache::new(
            4,
            cfg.n_layers,
            vec![2, 2],
            crate::kvcache::CacheDtype::F32,
        ));
        let mut slots = SlotManager::new(layout, 2, 256);

        // request 0: 8-token prompt (2 blocks) + 4 new -> 3 blocks
        q.push(req(0, 8, 4));
        let first = q.admit(&mut slots);
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].cached_tokens, 0);
        let adm = &first[0];
        // finish request 0: insert its prompt prefix, then release
        let l = cfg.n_layers;
        let rows: Vec<SlabRows> = vec![
            SlabRows::F32(vec![1.0; l * 8 * 2]),
            SlabRows::F32(vec![2.0; l * 8 * 2]),
        ];
        let cached = q
            .prefix_insert(&adm.request.prompt, &adm.chain, || Ok(rows))
            .unwrap();
        assert_eq!(cached, 2);
        slots.free(adm.slot);
        q.release(&adm.chain);

        // request 1: same prompt -> both full prompt blocks hit
        q.push(req(1, 8, 4));
        let second = q.admit(&mut slots);
        assert_eq!(second.len(), 1);
        // cap is prompt-1 = 7 tokens -> only 1 of 2 blocks reusable
        assert_eq!(second[0].cached_tokens, 4);
        assert_eq!(second[0].cached_rows.len(), 2);
        let SlabRows::F32(row0) = &second[0].cached_rows[0] else {
            panic!("expected f32 rows")
        };
        assert_eq!(row0.len(), l * 4 * 2);
        let stats = q.prefix_stats().unwrap();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert_eq!(stats.hit_tokens, 4);
        q.allocator.check_invariants().unwrap();
    }

    /// Pool pressure evicts cold cache leaves instead of parking the
    /// FIFO head forever.
    #[test]
    fn admission_evicts_cache_under_pressure() {
        let cfg = ModelConfig::tiny();
        let layout = CacheLayout::new(&cfg, Variant::Mha);
        let mut q = AdmissionQueue::new(BlockAllocator::new(4, 4));
        q.prefix = Some(RadixCache::new(
            4,
            cfg.n_layers,
            vec![1],
            crate::kvcache::CacheDtype::F32,
        ));
        let mut slots = SlotManager::new(layout, 2, 256);
        let l = cfg.n_layers;

        // request 0 fills 3 of 4 pool blocks and leaves its 2-block
        // prompt prefix cached
        q.push(req(0, 8, 4));
        let first = q.admit(&mut slots);
        assert_eq!(first.len(), 1);
        let adm = &first[0];
        let rows = vec![SlabRows::F32(vec![0.5; l * 8])];
        q.prefix_insert(&adm.request.prompt, &adm.chain, || Ok(rows))
            .unwrap();
        slots.free(adm.slot);
        q.release(&adm.chain);
        assert_eq!(q.allocator.free_blocks(), 2);

        // request 1 with a DIFFERENT prompt needs 4 blocks: the 2 cached
        // blocks must be evicted to admit it
        let mut other = req(1, 12, 4);
        other.prompt = vec![9; 12];
        q.push(other);
        let second = q.admit(&mut slots);
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].cached_tokens, 0);
        let stats = q.prefix_stats().unwrap();
        assert_eq!(stats.evicted_blocks, 2);
        assert_eq!(stats.cached_blocks, 0);
        q.allocator.check_invariants().unwrap();
    }

    #[test]
    fn fifo_no_bypass() {
        let (mut q, mut slots) = setup(3);
        q.push(req(0, 40, 8)); // needs 3 blocks
        q.push(req(1, 4, 4));  // would fit, but must wait behind head
        let _ = q.admit(&mut slots); // admits req 0, pool now empty
        let admitted = q.admit(&mut slots);
        assert!(admitted.is_empty());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn compressed_layout_admits_more() {
        // Same byte budget, EliteKV 25 % layout -> 4x the block count.
        let cfg = ModelConfig::tiny();
        let budget = 1024 * 1024;
        let base_layout = CacheLayout::new(&cfg, Variant::Mha);
        let ekv_layout =
            CacheLayout::new(&cfg, Variant::EliteKv { r: 4, d_ckv: 64 });
        let base_alloc = BlockAllocator::with_budget(
            budget, base_layout.bytes_per_token(), 16);
        let ekv_alloc = BlockAllocator::with_budget(
            budget, ekv_layout.bytes_per_token(), 16);
        assert_eq!(ekv_alloc.n_blocks(), 4 * base_alloc.n_blocks());
    }
}
