//! Admission queue + continuous-batching policy.
//!
//! Decisions mirror vLLM's scheduler at miniature scale: requests wait in
//! FIFO; a request is admitted when (a) a decode lane is idle and (b) the
//! block allocator can cover its worst-case cache need. Because EliteKV
//! shrinks bytes-per-token, the same block pool admits ~1/ratio times the
//! sequences — the capacity effect the serving bench measures.

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::api::Request;
use crate::kvcache::{BlockAllocator, SlotManager};

/// FIFO queue with block-budget admission control.
pub struct AdmissionQueue {
    queue: VecDeque<Request>,
    /// The paged block pool admissions are charged against.
    pub allocator: BlockAllocator,
    /// worst-case generation length used for admission (prompt + max_new)
    pub conservative: bool,
}

impl AdmissionQueue {
    /// Empty queue over a block pool (conservative admission by default).
    pub fn new(allocator: BlockAllocator) -> AdmissionQueue {
        AdmissionQueue { queue: VecDeque::new(), allocator, conservative: true }
    }

    /// Enqueue at the FIFO tail (no admissibility check — see
    /// [`AdmissionQueue::admissible`] for the submit-time gate).
    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    /// Requests currently waiting.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when nothing is waiting.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Worst-case token footprint used for admission control.
    pub fn need_tokens(&self, req: &Request) -> usize {
        if self.conservative {
            req.prompt.len() + req.params.max_new_tokens
        } else {
            req.prompt.len()
        }
    }

    /// Can this request EVER be admitted by this queue + slot geometry?
    /// (Prompt must fit the serving window with room to generate, and the
    /// worst-case block need must not exceed the whole pool.) Requests
    /// failing this would park at the head of the FIFO forever.
    pub fn admissible(&self, req: &Request, slots: &SlotManager) -> Result<()> {
        anyhow::ensure!(
            !req.prompt.is_empty(),
            "request {}: empty prompt (nothing to prefill)",
            req.id
        );
        // The engine always samples at least one token per admitted
        // lane, so a zero-token request cannot be honored — reject it
        // instead of returning an unrequested token.
        anyhow::ensure!(
            req.params.max_new_tokens > 0,
            "request {}: max_new_tokens must be at least 1",
            req.id
        );
        // The lane advances once per generated token before the next
        // decode — prompt + max_new must fit the window or the run
        // would die at SlotManager::advance mid-decode.
        let gen = req.params.max_new_tokens;
        anyhow::ensure!(
            req.prompt.len() + gen <= slots.max_seq,
            "request {}: prompt of {} tokens + up to {gen} generated \
             cannot fit the {}-token serving window",
            req.id,
            req.prompt.len(),
            slots.max_seq
        );
        let need = self.allocator.blocks_for(self.need_tokens(req));
        anyhow::ensure!(
            need <= self.allocator.n_blocks(),
            "request {}: worst-case need of {need} blocks exceeds the \
             whole pool ({} blocks); raise --cache-budget-mb or lower \
             max_new_tokens",
            req.id,
            self.allocator.n_blocks()
        );
        Ok(())
    }

    /// Admit as many queued requests as the lanes + block pool allow.
    /// Returns (request, slot, block chain) triples.
    pub fn admit(
        &mut self,
        slots: &mut SlotManager,
    ) -> Vec<(Request, usize, Vec<crate::kvcache::block::BlockId>)> {
        let mut admitted = Vec::new();
        while slots.idle_count() > 0 {
            let Some(front) = self.queue.front() else { break };
            if front.prompt.is_empty() || front.prompt.len() >= slots.max_seq
            {
                // Defensive: an empty or over-long prompt that slipped
                // past `admissible` must not panic/error the engine loop
                // (prefill requires 1 <= len < window). Drop it.
                let req = self.queue.pop_front().unwrap();
                log::error!(
                    "dropping request {}: prompt of {} tokens outside \
                     [1, {})",
                    req.id,
                    req.prompt.len(),
                    slots.max_seq
                );
                continue;
            }
            let need = self.need_tokens(front);
            if self.allocator.blocks_for(need) > self.allocator.n_blocks() {
                // Defensive twin of the prompt-bounds drop above: a head
                // request larger than the WHOLE pool would never admit
                // and busy-loop the engine; drop it instead of waiting.
                let req = self.queue.pop_front().unwrap();
                log::error!(
                    "dropping request {}: worst-case need of {} blocks \
                     exceeds the whole pool ({})",
                    req.id,
                    self.allocator.blocks_for(need),
                    self.allocator.n_blocks()
                );
                continue;
            }
            if !self.allocator.can_admit(need) {
                break; // strict FIFO: no head-of-line bypass
            }
            let req = self.queue.pop_front().unwrap();
            let chain = self.allocator.alloc(need).expect("checked");
            let slot = slots
                .claim(req.id, req.prompt.len())
                .expect("idle slot and prompt length checked");
            admitted.push((req, slot, chain));
        }
        admitted
    }

    /// Return a finished request's blocks to the pool.
    pub fn release(&mut self, chain: &[crate::kvcache::block::BlockId]) {
        self.allocator.release(chain);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, Variant};
    use crate::coordinator::api::{GenParams, Request};
    use crate::kvcache::CacheLayout;

    fn setup(n_blocks: usize) -> (AdmissionQueue, SlotManager) {
        let cfg = ModelConfig::tiny();
        let layout = CacheLayout::new(&cfg, Variant::Mha);
        let q = AdmissionQueue::new(BlockAllocator::new(n_blocks, 16));
        let slots = SlotManager::new(layout, 4, 256);
        (q, slots)
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(
            id,
            vec![1; prompt_len],
            GenParams { max_new_tokens: max_new, ..Default::default() },
        )
    }

    #[test]
    fn admits_up_to_lane_count() {
        let (mut q, mut slots) = setup(100);
        for i in 0..6 {
            q.push(req(i, 8, 8));
        }
        let admitted = q.admit(&mut slots);
        assert_eq!(admitted.len(), 4); // 4 lanes
        assert_eq!(q.len(), 2);
        assert_eq!(slots.idle_count(), 0);
    }

    #[test]
    fn admission_blocked_by_pool() {
        let (mut q, mut slots) = setup(2); // 32 tokens of pool
        q.push(req(0, 16, 16)); // needs 2 blocks
        q.push(req(1, 16, 16)); // pool exhausted
        let admitted = q.admit(&mut slots);
        assert_eq!(admitted.len(), 1);
        assert_eq!(q.len(), 1);
        // releasing lets the second one in
        let (_r, slot, chain) = &admitted[0];
        slots.free(*slot);
        q.release(chain);
        let second = q.admit(&mut slots);
        assert_eq!(second.len(), 1);
    }

    #[test]
    fn fifo_no_bypass() {
        let (mut q, mut slots) = setup(3);
        q.push(req(0, 40, 8)); // needs 3 blocks
        q.push(req(1, 4, 4));  // would fit, but must wait behind head
        let _ = q.admit(&mut slots); // admits req 0, pool now empty
        let admitted = q.admit(&mut slots);
        assert!(admitted.is_empty());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn compressed_layout_admits_more() {
        // Same byte budget, EliteKV 25 % layout -> 4x the block count.
        let cfg = ModelConfig::tiny();
        let budget = 1024 * 1024;
        let base_layout = CacheLayout::new(&cfg, Variant::Mha);
        let ekv_layout =
            CacheLayout::new(&cfg, Variant::EliteKv { r: 4, d_ckv: 64 });
        let base_alloc = BlockAllocator::with_budget(
            budget, base_layout.bytes_per_token(), 16);
        let ekv_alloc = BlockAllocator::with_budget(
            budget, ekv_layout.bytes_per_token(), 16);
        assert_eq!(ekv_alloc.n_blocks(), 4 * base_alloc.n_blocks());
    }
}
