//! Request/response surface of the serving coordinator.

use std::time::Instant;

/// Sampling parameters.
#[derive(Clone, Debug)]
pub struct GenParams {
    /// Maximum tokens to generate (must be at least 1 to be servable).
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling.
    pub temperature: f32,
    /// Nucleus (top-p) truncation applied on top of temperature sampling;
    /// 1.0 disables it. Ignored when `temperature == 0`.
    pub top_p: f32,
    /// Stop token (defaults to the corpus EOS).
    pub stop_token: Option<u32>,
    /// Per-request sampling seed (xor'd with the request id).
    pub seed: u64,
}

impl Default for GenParams {
    fn default() -> GenParams {
        GenParams {
            max_new_tokens: 32,
            temperature: 0.0,
            top_p: 1.0,
            stop_token: Some(crate::data::corpus::EOS),
            seed: 0,
        }
    }
}

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen request id, echoed in the [`Response`].
    pub id: u64,
    /// Prompt token ids (must be non-empty and fit the serving window).
    pub prompt: Vec<u32>,
    /// Sampling/stop parameters.
    pub params: GenParams,
    /// Enqueue timestamp: TTFT/latency/admission waits measure from here.
    pub enqueued: Instant,
}

impl Request {
    /// Build a request stamped with the current time.
    pub fn new(id: u64, prompt: Vec<u32>, params: GenParams) -> Request {
        Request { id, prompt, params, enqueued: Instant::now() }
    }
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    /// The originating request's id.
    pub id: u64,
    /// Generated token ids (empty on rejection).
    pub tokens: Vec<u32>,
    /// Seconds from enqueue to first generated token.
    pub ttft: f64,
    /// Mean inter-token gap in seconds (time per output token over the
    /// decode phase: first token to last token divided by `tokens - 1`;
    /// 0 for single-token generations and rejections).
    pub tpot: f64,
    /// Seconds from enqueue to completion.
    pub latency: f64,
    /// Why generation stopped.
    pub finish: FinishReason,
}

/// Terminal state of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The stop token was generated.
    Stop,
    /// `max_new_tokens` was reached (or the generation was truncated by
    /// mid-decode pool exhaustion under optimistic admission).
    Length,
    /// The scheduler refused the request outright (prompt outside the
    /// serving window, or worst-case cache need larger than the whole
    /// block pool). `tokens` is empty.
    Rejected,
}
