//! Serving coordinator (DESIGN.md S11): the vLLM-style L3 layer.
//!
//! * [`api`]     — request/response types and generation parameters
//!   (greedy / temperature / top-p nucleus sampling).
//! * [`batcher`] — FIFO admission queue + continuous-batching policy over
//!   the fixed decode lanes (static-shape analog of vLLM's scheduler).
//! * [`scheduler`] — scheduler policy (block granularity, cache byte
//!   budget, conservative vs. optimistic admission) and the deterministic
//!   arrival traces the engine is benchmarked with.
//! * [`server`]  — the inference engine: prefill-splice + iterative decode
//!   over the compressed KV cache, sampling, stop handling, per-request
//!   latency metrics. Drives any [`crate::runtime::Backend`] — the native
//!   Rust decode path (no artifacts) or the PJRT executor (feature
//!   `pjrt`).
//! * [`cluster`] — sharded multi-worker scale-out (DESIGN.md S24):
//!   worker membership and liveness, routing policies (blind
//!   least-loaded vs. cache-affinity over a shadow radix index kept
//!   current by worker deltas), and the streaming router that fans
//!   requests over N engine worker threads.

pub mod api;
pub mod batcher;
pub mod cluster;
pub mod scheduler;
pub mod server;

pub use api::{GenParams, Request, Response};
pub use batcher::{Admission, AdmissionQueue};
pub use cluster::{
    EngineFactory, RoutePolicyKind, RouteStats, Router, WorkerState,
};
pub use scheduler::{ArrivalTrace, SchedulerConfig, TraceOpts};
pub use server::{InferenceServer, ServerStats};
