//! Scheduler policy knobs + the deterministic arrival traces the
//! continuous-batching engine is driven and benchmarked with.
//!
//! The engine loop itself lives in [`crate::coordinator::server`]; this
//! module owns the pieces that shape its decisions:
//!
//! * [`SchedulerConfig`] — block granularity, the cache byte budget the
//!   [`crate::kvcache::BlockAllocator`] pool is sized from (per variant:
//!   `CacheLayout::bytes_per_token`, so J-LRD/S-LRD compression directly
//!   raises achievable concurrency), and the admission policy
//!   (conservative = reserve prompt + max_new up front, so a decode can
//!   never die to pool exhaustion mid-sequence).
//! * [`ArrivalTrace`] — a seeded mixed prefill/decode workload: requests
//!   with varied prompt/generation lengths arriving over engine steps,
//!   replayed identically by `elitekv bench` and the scheduler tests.

use crate::coordinator::api::{GenParams, Request};
use crate::data::CorpusGen;
use crate::kvcache::CacheDtype;
use crate::util::Pcg64;

/// Policy + sizing of the continuous-batching scheduler.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Tokens per cache block (paging granularity of admission control).
    pub block_tokens: usize,
    /// Byte budget the block pool is sized from (`--cache-budget-mb`,
    /// CLI-side in MiB); the per-variant
    /// `CacheLayout::bytes_per_token` converts it into a block count.
    pub cache_budget_bytes: usize,
    /// Admit only when prompt + max_new worst-case fits the pool (true,
    /// the default), or on prompt footprint alone, growing chains via
    /// `extend` (false; `--optimistic-admission` clears this).
    pub conservative: bool,
    /// Enable the prefix radix cache (`--prefix-cache`): finished
    /// prompts' full-block prefixes are retained in a
    /// [`crate::kvcache::RadixCache`] and later admissions reuse the
    /// longest cached prefix instead of re-prefilling it (DESIGN.md
    /// S18). Requires a backend that supports mid-sequence prefill
    /// resume (the native runner; not the static PJRT artifacts).
    pub prefix_cache: bool,
    /// Cache element dtype (`--cache-dtype`, DESIGN.md S19). The
    /// budget-to-block-count math divides the byte budget by the
    /// *dtype-aware* `CacheLayout::bytes_per_token`, so the same
    /// `--cache-budget-mb` admits ~4x the tokens at int8 — compression
    /// compounding straight into concurrency. Must match the backend's
    /// slabs; the engine constructor enforces agreement.
    pub cache_dtype: CacheDtype,
    /// Sparse decode row budget (`--sparse-k`, DESIGN.md S20): `Some(k)`
    /// attends only the top-k cache rows per step, `None` is exact dense
    /// attention. Purely a compute/bandwidth knob — admission math is
    /// unchanged (every row is still cached so evicted rows can rejoin
    /// the top-k later). Must match the backend's own `sparse_k`; the
    /// engine constructor enforces agreement.
    pub sparse_k: Option<usize>,
    /// Chunked prefill budget (`--prefill-chunk`, DESIGN.md S22): at
    /// most this many prompt tokens are prefilled per engine iteration,
    /// Sarathi-style, so already-live decode lanes advance every
    /// iteration instead of stalling behind one long monolithic prefill.
    /// `0` (the default) keeps today's behavior: each admission wave is
    /// prefilled whole before its first decode step. Chunking is purely
    /// a scheduling knob — chunked and monolithic prefill are bitwise
    /// identical per request (S17 row-independence makes the chunk
    /// boundaries invisible to the math). Requires a backend that can
    /// resume a prefill mid-sequence (the native runner); the engine
    /// constructor enforces support.
    pub prefill_chunk_tokens: usize,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            block_tokens: 16,
            cache_budget_bytes: 64 << 20,
            conservative: true,
            prefix_cache: false,
            cache_dtype: CacheDtype::F32,
            sparse_k: None,
            prefill_chunk_tokens: 0,
        }
    }
}

impl SchedulerConfig {
    /// Default policy with an explicit byte budget.
    pub fn with_budget(cache_budget_bytes: usize) -> SchedulerConfig {
        SchedulerConfig { cache_budget_bytes, ..Default::default() }
    }
}

/// One request of a replayable workload, tagged with the engine step at
/// which it becomes visible to the scheduler.
#[derive(Clone, Debug)]
pub struct TraceItem {
    /// Engine step at which the request arrives.
    pub arrive_step: usize,
    /// The request itself (re-stamp `enqueued` at replay time).
    pub request: Request,
}

/// Shape of a generated [`ArrivalTrace`].
#[derive(Clone, Debug)]
pub struct TraceOpts {
    /// Total requests in the trace.
    pub n_requests: usize,
    /// Minimum prompt length (inclusive).
    pub prompt_min: usize,
    /// Maximum prompt length (inclusive).
    pub prompt_max: usize,
    /// Minimum generation length (inclusive).
    pub max_new_min: usize,
    /// Maximum generation length (inclusive).
    pub max_new_max: usize,
    /// Mean engine steps between arrivals (0 = all arrive at step 0).
    pub inter_arrival_steps: usize,
    /// Tokens of a shared "system prompt" prepended to EVERY request's
    /// prompt (0 = fully independent prompts). The prefix stream is
    /// drawn once per trace, so all requests share it byte-identically —
    /// the canonical multi-user workload the prefix radix cache
    /// amortizes. `prompt_min`/`prompt_max` bound the per-request tail
    /// AFTER the shared prefix.
    pub shared_prefix_tokens: usize,
}

impl Default for TraceOpts {
    fn default() -> TraceOpts {
        TraceOpts {
            n_requests: 24,
            prompt_min: 4,
            prompt_max: 24,
            max_new_min: 4,
            max_new_max: 16,
            inter_arrival_steps: 2,
            shared_prefix_tokens: 0,
        }
    }
}

/// A deterministic mixed prefill/decode arrival trace: same (vocab,
/// seed, opts) -> byte-identical workload, so dense and compressed
/// variants are benchmarked against exactly the same request stream.
#[derive(Clone, Debug)]
pub struct ArrivalTrace {
    /// Trace items in non-decreasing `arrive_step` order.
    pub items: Vec<TraceItem>,
}

impl ArrivalTrace {
    /// Deterministically generate a trace: same (vocab, seed, opts) →
    /// byte-identical workload.
    pub fn generate(vocab: usize, seed: u64, opts: &TraceOpts) -> ArrivalTrace {
        let mut gen = CorpusGen::new(vocab, seed);
        let mut rng = Pcg64::new(seed, 0x7ace);
        let shared = gen.stream(opts.shared_prefix_tokens);
        let mut step = 0usize;
        let items = (0..opts.n_requests)
            .map(|i| {
                let plen = rng.range(opts.prompt_min, opts.prompt_max + 1);
                let max_new =
                    rng.range(opts.max_new_min, opts.max_new_max + 1);
                if opts.inter_arrival_steps > 0 && i > 0 {
                    step += rng.range(0, 2 * opts.inter_arrival_steps + 1);
                }
                let mut prompt = shared.clone();
                prompt.extend(gen.stream(plen));
                TraceItem {
                    arrive_step: step,
                    request: Request::new(
                        i as u64,
                        prompt,
                        GenParams {
                            max_new_tokens: max_new,
                            stop_token: None, // fixed-length: comparable work
                            temperature: 0.0,
                            top_p: 1.0,
                            seed: i as u64,
                        },
                    ),
                }
            })
            .collect();
        ArrivalTrace { items }
    }

    /// Total tokens the trace will generate (sum of max_new).
    pub fn total_new_tokens(&self) -> usize {
        self.items
            .iter()
            .map(|t| t.request.params.max_new_tokens)
            .sum()
    }

    /// Last arrival step.
    pub fn horizon(&self) -> usize {
        self.items.iter().map(|t| t.arrive_step).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_in_bounds() {
        let opts = TraceOpts::default();
        let a = ArrivalTrace::generate(512, 9, &opts);
        let b = ArrivalTrace::generate(512, 9, &opts);
        assert_eq!(a.items.len(), opts.n_requests);
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.arrive_step, y.arrive_step);
            assert_eq!(x.request.prompt, y.request.prompt);
            assert_eq!(
                x.request.params.max_new_tokens,
                y.request.params.max_new_tokens
            );
        }
        for t in &a.items {
            assert!(t.request.prompt.len() >= opts.prompt_min);
            assert!(t.request.prompt.len() <= opts.prompt_max);
            assert!(t.request.params.max_new_tokens >= opts.max_new_min);
            assert!(t.request.params.max_new_tokens <= opts.max_new_max);
        }
        // arrivals are non-decreasing in step
        for w in a.items.windows(2) {
            assert!(w[0].arrive_step <= w[1].arrive_step);
        }
    }

    #[test]
    fn shared_prefix_trace_shares_byte_identically() {
        let opts = TraceOpts { shared_prefix_tokens: 32, ..Default::default() };
        let t = ArrivalTrace::generate(512, 3, &opts);
        let first = &t.items[0].request.prompt;
        assert!(first.len() >= 32 + opts.prompt_min);
        for item in &t.items {
            let p = &item.request.prompt;
            assert_eq!(&p[..32], &first[..32], "shared prefix diverged");
            let tail = p.len() - 32;
            assert!(tail >= opts.prompt_min && tail <= opts.prompt_max);
        }
        // distinct tails exist (not one degenerate request repeated)
        assert!(t.items.iter().any(|i| i.request.prompt != *first));
    }

    #[test]
    fn zero_inter_arrival_is_a_burst() {
        let opts = TraceOpts { inter_arrival_steps: 0, ..Default::default() };
        let t = ArrivalTrace::generate(512, 1, &opts);
        assert!(t.items.iter().all(|i| i.arrive_step == 0));
        assert_eq!(t.horizon(), 0);
    }
}
