//! The inference engine: continuous batching over fixed decode lanes,
//! prefill splicing, sampling, and metrics — backend-agnostic.
//!
//! One engine iteration:
//!   1. admit queued requests into idle lanes (block-budget permitting);
//!      monolithic mode (`prefill_chunk_tokens == 0`) prefills the whole
//!      admission wave here and splices its cache rows into the live
//!      cache tensors, chunked mode (DESIGN.md S22) only parks the lanes
//!      with a prefill cursor;
//!   2. advance every mid-prefill lane by at most one chunk of prompt
//!      tokens (chunked mode only; lanes reaching their prompt length
//!      go live this same iteration);
//!   3. one decode step across all live lanes (idle and mid-prefill
//!      lanes run a masked dummy);
//!   4. sample per live lane (greedy / temperature / top-p), emit
//!      finished responses, free lanes/blocks.
//!
//! The engine drives any [`Backend`]: the pure-Rust native runner (no
//! artifacts at all) or the PJRT executor (feature `pjrt`). Python is
//! nowhere in this loop either way.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::api::{FinishReason, GenParams, Request, Response};
use crate::coordinator::batcher::{Admission, AdmissionQueue};
use crate::coordinator::scheduler::SchedulerConfig;
use crate::kvcache::block::BlockId;
use crate::kvcache::quant::{n_groups, SlabRows};
use crate::kvcache::radix::RadixCache;
use crate::kvcache::{
    slab_row_widths, BlockAllocator, CacheLayout, SlotManager,
};
use crate::runtime::{Backend, HostTensor};
use crate::util::Pcg64;

struct Lane {
    request: Request,
    blocks: Vec<BlockId>,
    generated: Vec<u32>,
    // Prompt tokens whose cache rows exist (computed or spliced from the
    // prefix cache). The lane decodes only once this reaches the prompt
    // length; monolithic admission sets it there immediately, chunked
    // admission parks it at the cached prefix length.
    cursor: usize,
    first_token_at: Option<Instant>,
    last_token_at: Option<Instant>,
    // Largest wall-clock gap between consecutive sampled tokens — the
    // per-request decode-stall measure chunked prefill bounds.
    max_gap_s: f64,
    rng: Pcg64,
}

/// A lane decodes only once its whole prompt has been prefilled.
fn is_live(lane: &Option<Lane>) -> bool {
    matches!(lane, Some(l) if l.cursor >= l.request.prompt.len())
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// Requests fully served (stop token or length limit).
    pub completed: usize,
    /// Total tokens generated across all completed requests.
    pub generated_tokens: usize,
    /// Engine decode iterations executed.
    pub decode_steps: usize,
    /// Prefill calls issued (one per admission wave, not per request).
    pub prefills: usize,
    /// High-water mark of live cache bytes across busy lanes.
    pub peak_cache_bytes: usize,
    /// Peak number of simultaneously busy lanes (the capacity headline:
    /// under one byte budget, compressed variants admit more).
    pub max_concurrency: usize,
    /// Number of admissions observed (one wait sample each).
    pub admission_waits: usize,
    /// Sum of all enqueue-to-admission waits, in seconds.
    pub admission_wait_sum_s: f64,
    /// Ring of the most recent admission waits (percentile estimates),
    /// bounded by [`ADMISSION_WAIT_WINDOW`] so a long-lived engine's
    /// stats stay O(1) in memory.
    pub admission_wait_recent_s: Vec<f64>,
    /// Peak blocks held by live chains.
    pub peak_blocks_used: usize,
    /// Pool size (blocks), for occupancy ratios.
    pub blocks_total: usize,
    /// Sum of blocks-in-use across occupancy samples (one sample per
    /// engine iteration with busy lanes, taken BEFORE same-step
    /// releases so short generations still register).
    pub blocks_used_sum: usize,
    /// Number of samples accumulated into `blocks_used_sum`.
    pub occupancy_samples: usize,
    /// Prompt tokens actually prefilled (suffix-only under prefix-cache
    /// hits, full prompts otherwise) — the bench's measure of prefill
    /// work saved by prefix sharing.
    pub prefill_tokens: usize,
    /// Admissions that reused a cached prefix (`--prefix-cache` only).
    pub prefix_hits: usize,
    /// Admissions that found no cached prefix (`--prefix-cache` only).
    pub prefix_misses: usize,
    /// Prompt tokens served from the prefix cache instead of prefilled.
    pub prefix_hit_tokens: usize,
    /// Cache blocks released by LRU eviction under pool pressure.
    pub prefix_evicted_blocks: usize,
    /// Blocks currently held by the prefix cache (gauge).
    pub prefix_cached_blocks: usize,
    /// Cache rows actually attended across all decode steps under
    /// sparse decode (`--sparse-k`, DESIGN.md S20): each active lane
    /// contributes `min(k, seq_len)` per step. Zero when dense.
    pub sparse_attended_rows: usize,
    /// Cache rows a dense engine would have attended over the same
    /// steps (each active lane contributes its full `seq_len`). The
    /// ratio `sparse_attended_rows / sparse_dense_rows` is the measured
    /// fraction of attention bandwidth the top-k selection kept. Zero
    /// when dense.
    pub sparse_dense_rows: usize,
    /// Ring of completed requests' time-to-first-token samples in
    /// seconds (enqueue to first sampled token), bounded by
    /// [`LATENCY_WINDOW`] like
    /// [`ServerStats::admission_wait_recent_s`] — the bench derives its
    /// TTFT p50/p95/p99 columns from this.
    pub ttft_recent_s: Vec<f64>,
    /// TTFT samples ever recorded (ring write index for
    /// [`ServerStats::ttft_recent_s`]).
    pub ttft_count: usize,
    /// Ring of completed requests' mean inter-token gaps (TPOT) in
    /// seconds, bounded by [`LATENCY_WINDOW`].
    pub tpot_recent_s: Vec<f64>,
    /// TPOT samples ever recorded (ring write index for
    /// [`ServerStats::tpot_recent_s`]).
    pub tpot_count: usize,
    /// Worst wall-clock gap between two consecutive tokens of any
    /// completed request, in seconds — the decode-stall measure chunked
    /// prefill (`--prefill-chunk`, DESIGN.md S22) exists to bound: a
    /// monolithic long-prompt prefill shows up here as one giant gap on
    /// every lane that was mid-decode while it ran.
    pub max_decode_gap_s: f64,
    /// Kernel ISA the GEMM microkernels dispatched to (`scalar` /
    /// `avx2` / `neon` — DESIGN.md S23), resolved once at server
    /// construction from runtime detection and the `ELITEKV_KERNEL_ISA`
    /// override. Empty only on a default-constructed stats value.
    pub kernel_isa: &'static str,
}

/// Capacity of [`ServerStats::admission_wait_recent_s`].
pub const ADMISSION_WAIT_WINDOW: usize = 4096;

/// Capacity of the per-request latency rings
/// ([`ServerStats::ttft_recent_s`], [`ServerStats::tpot_recent_s`]).
pub const LATENCY_WINDOW: usize = 4096;

impl ServerStats {
    /// Record one enqueue-to-admission wait.
    pub fn record_admission_wait(&mut self, seconds: f64) {
        if self.admission_wait_recent_s.len() < ADMISSION_WAIT_WINDOW {
            self.admission_wait_recent_s.push(seconds);
        } else {
            let i = self.admission_waits % ADMISSION_WAIT_WINDOW;
            self.admission_wait_recent_s[i] = seconds;
        }
        self.admission_waits += 1;
        self.admission_wait_sum_s += seconds;
    }

    /// Record one completed request's time-to-first-token.
    pub fn record_ttft(&mut self, seconds: f64) {
        if self.ttft_recent_s.len() < LATENCY_WINDOW {
            self.ttft_recent_s.push(seconds);
        } else {
            let i = self.ttft_count % LATENCY_WINDOW;
            self.ttft_recent_s[i] = seconds;
        }
        self.ttft_count += 1;
    }

    /// Record one completed request's mean inter-token gap (TPOT).
    pub fn record_tpot(&mut self, seconds: f64) {
        if self.tpot_recent_s.len() < LATENCY_WINDOW {
            self.tpot_recent_s.push(seconds);
        } else {
            let i = self.tpot_count % LATENCY_WINDOW;
            self.tpot_recent_s[i] = seconds;
        }
        self.tpot_count += 1;
    }

    /// Mean admission wait in seconds (0 when nothing was admitted).
    pub fn mean_admission_wait_s(&self) -> f64 {
        if self.admission_waits == 0 {
            0.0
        } else {
            self.admission_wait_sum_s / self.admission_waits as f64
        }
    }

    /// Mean block-pool occupancy in [0, 1] across busy engine iterations.
    pub fn mean_block_occupancy(&self) -> f64 {
        if self.occupancy_samples == 0 || self.blocks_total == 0 {
            0.0
        } else {
            self.blocks_used_sum as f64
                / (self.occupancy_samples * self.blocks_total) as f64
        }
    }

    /// Admission-scoped prefix hit rate `hits / (hits + misses)` in
    /// [0, 1]; 0.0 when the prefix cache is off or nothing was
    /// admitted. The sharded bench reports this per worker and in
    /// aggregate (DESIGN.md S24).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }
}

/// Single-worker inference engine over one [`Backend`].
pub struct InferenceServer {
    /// The serving engine all forward steps run through.
    pub backend: Box<dyn Backend>,
    /// FIFO admission queue + the block pool it charges against.
    pub queue: AdmissionQueue,
    slots: SlotManager,
    lanes: Vec<Option<Lane>>,
    caches: Vec<HostTensor>,
    logits: Option<HostTensor>,
    /// Request the Pallas-lowered decode artifact where the backend has
    /// one (PJRT elitekv variants); other backends ignore it.
    pub use_pallas: bool,
    /// Aggregate serving metrics, updated every engine iteration.
    pub stats: ServerStats,
    batch: usize,
    max_seq: usize,
    // Chunked prefill budget (SchedulerConfig::prefill_chunk_tokens):
    // 0 = monolithic admission-time prefill, today's default.
    prefill_chunk: usize,
}

impl InferenceServer {
    /// `cache_budget_bytes` sizes the block pool (admission control);
    /// everything else takes the [`SchedulerConfig`] defaults.
    pub fn new(
        backend: Box<dyn Backend>,
        cache_budget_bytes: usize,
    ) -> Result<InferenceServer> {
        Self::with_config(
            backend,
            &SchedulerConfig::with_budget(cache_budget_bytes),
        )
    }

    /// Build the engine around an explicit scheduler policy. The lane
    /// count and serving window come from the backend (`serve_shape`);
    /// the block pool is sized from the byte budget divided by this
    /// variant's `CacheLayout::bytes_per_token` — the point where cache
    /// compression becomes admission capacity.
    pub fn with_config(
        backend: Box<dyn Backend>,
        cfg: &SchedulerConfig,
    ) -> Result<InferenceServer> {
        anyhow::ensure!(cfg.block_tokens > 0, "block_tokens must be > 0");
        let (batch, max_seq) = backend.serve_shape()?;
        // The dtype is the backend's: its slabs ARE that storage. The
        // scheduler config must agree or the budget math and the actual
        // bytes would diverge silently.
        let dtype = backend.cache_dtype();
        anyhow::ensure!(
            cfg.cache_dtype == dtype,
            "scheduler cache dtype `{}` != backend cache dtype `{}`; \
             pass the same --cache-dtype to both",
            cfg.cache_dtype.tag(),
            dtype.tag()
        );
        // Same agreement for the sparse row budget: the backend's
        // attention is what actually runs sparse; the config is how the
        // workload was described. Silent divergence would make the
        // mirrored selection stats lie.
        anyhow::ensure!(
            cfg.sparse_k == backend.sparse_k(),
            "scheduler sparse_k {:?} != backend sparse_k {:?}; \
             pass the same --sparse-k to both",
            cfg.sparse_k,
            backend.sparse_k()
        );
        if cfg.prefill_chunk_tokens > 0 {
            anyhow::ensure!(
                backend.supports_chunked_prefill(),
                "--prefill-chunk needs a backend that can resume a \
                 prefill mid-sequence (`{}` cannot; use --backend native \
                 or --prefill-chunk 0)",
                backend.kind()
            );
        }
        let layout = CacheLayout::with_dtype(
            backend.config(),
            backend.variant().clone(),
            dtype,
        );
        let allocator = BlockAllocator::with_budget(
            cfg.cache_budget_bytes,
            layout.bytes_per_token().max(1),
            cfg.block_tokens,
        );
        anyhow::ensure!(
            allocator.n_blocks() > 0,
            "cache budget of {} bytes holds zero {}-token blocks at {} \
             bytes/token; raise --cache-budget-mb or lower --block-tokens",
            cfg.cache_budget_bytes,
            cfg.block_tokens,
            layout.bytes_per_token()
        );
        let slots = SlotManager::new(layout, batch, max_seq);
        let caches = backend.empty_caches()?;
        let mut queue = AdmissionQueue::new(allocator);
        queue.conservative = cfg.conservative;
        if cfg.prefix_cache {
            anyhow::ensure!(
                backend.supports_prefix_prefill(),
                "--prefix-cache needs a backend that can resume a \
                 prefill mid-sequence (`{}` cannot; use --backend native)",
                backend.kind()
            );
            // One radix tree per engine, keyed to this variant's slab
            // geometry: rows are stored per slab at `widths[si]`
            // elements per token per layer, in the engine's cache dtype
            // (quantized rows splice back as stored bytes — no f32
            // round-trip).
            let widths =
                slab_row_widths(backend.config(), backend.variant());
            queue.prefix = Some(RadixCache::new(
                cfg.block_tokens,
                backend.config().n_layers,
                widths,
                dtype,
            ));
        }
        let stats = ServerStats {
            blocks_total: queue.allocator.n_blocks(),
            kernel_isa: crate::native::simd::active().name(),
            ..Default::default()
        };
        Ok(InferenceServer {
            backend,
            queue,
            slots,
            lanes: (0..batch).map(|_| None).collect(),
            caches,
            logits: None,
            use_pallas: false,
            stats,
            batch,
            max_seq,
            prefill_chunk: cfg.prefill_chunk_tokens,
        })
    }

    /// Enqueue a request. Errors if the request can NEVER be served by
    /// this engine (prompt outside the serving window, or a worst-case
    /// block need larger than the whole pool) — accepting it would park
    /// the FIFO head forever and hang `run_to_completion`.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        self.queue.admissible(&req, &self.slots)?;
        self.queue.push(req);
        Ok(())
    }

    /// True while requests are queued or lanes are mid-generation.
    pub fn busy(&self) -> bool {
        !self.queue.is_empty() || self.lanes.iter().any(|l| l.is_some())
    }

    /// Enable prefix delta-event tracking so a sharded router can keep
    /// a shadow index of this engine's radix-cache contents (DESIGN.md
    /// S24). No-op when the prefix cache is off.
    pub fn track_prefix_events(&mut self, on: bool) {
        self.queue.set_prefix_event_tracking(on);
    }

    /// Drain prefix delta events accumulated since the last call
    /// (always empty unless [`InferenceServer::track_prefix_events`]
    /// enabled tracking).
    pub fn take_prefix_events(&mut self) -> Vec<crate::kvcache::radix::PrefixEvent> {
        self.queue.take_prefix_events()
    }

    /// Cache bytes currently held by busy lanes.
    pub fn live_cache_bytes(&self) -> usize {
        self.slots.live_cache_bytes()
    }

    /// The most recent logits tensor `[B, vocab]` (None while idle).
    /// Test/debug surface: the prefix-cache differential suite compares
    /// these bitwise between cache-on and cache-off engines.
    pub fn logits_snapshot(&self) -> Option<&HostTensor> {
        self.logits.as_ref()
    }

    /// The live cache slabs `[L, B, S, ...]` (same test/debug surface).
    pub fn cache_snapshot(&self) -> &[HostTensor] {
        &self.caches
    }

    /// Per-slot occupancy snapshot: `(request id, prefilled prompt
    /// tokens, prompt length, generated tokens)` for busy lanes, `None`
    /// for idle ones. Test/debug surface: the chunked-prefill
    /// differential suite uses it to attribute logits rows to requests
    /// and to check the prefill-cursor state machine against a
    /// reference model.
    pub fn lane_progress(&self) -> Vec<Option<(u64, usize, usize, usize)>> {
        self.lanes
            .iter()
            .map(|l| {
                l.as_ref().map(|lane| {
                    (
                        lane.request.id,
                        lane.cursor,
                        lane.request.prompt.len(),
                        lane.generated.len(),
                    )
                })
            })
            .collect()
    }

    /// Drive the engine until all submitted requests complete.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.busy() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    /// One engine iteration; returns any completed responses.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        self.admit()?;
        self.advance_prefill()?;
        self.decode_once()
    }

    /// Admit queued requests (lane + block budget permitting) and prefill
    /// exactly the newly admitted lanes; running lanes are untouched.
    /// Under the prefix cache, each admission's cached prompt rows are
    /// spliced into the prefill's seed caches and only the suffix is
    /// computed (per-lane start offset).
    fn admit(&mut self) -> Result<()> {
        let admitted = self.queue.admit(&mut self.slots);
        if admitted.is_empty() {
            return Ok(());
        }
        let now = Instant::now();
        if self.prefill_chunk > 0 {
            return self.admit_chunked(admitted, now);
        }
        // One prefill covering the newly admitted lanes. `fresh_mask`
        // tells backends which lanes matter so they can skip the rest
        // (the native runner does; static PJRT artifacts compute all).
        let mut tokens = vec![0i32; self.batch * self.max_seq];
        let mut lens = vec![1i32; self.batch];
        let mut fresh_mask = vec![false; self.batch];
        let mut starts = vec![0i32; self.batch];
        // Seed slabs are only materialized when some admission actually
        // resumes from a cached prefix; the plain path (prefix cache
        // off, or all misses) keeps the single-allocation prefill.
        let mut seed_caches: Option<Vec<HostTensor>> = None;
        for adm in &admitted {
            let (req, slot) = (&adm.request, adm.slot);
            if req.prompt.len() >= self.max_seq {
                bail!("prompt exceeds serving window");
            }
            for (i, &t) in req.prompt.iter().enumerate() {
                tokens[slot * self.max_seq + i] = t as i32;
            }
            lens[slot] = req.prompt.len() as i32;
            fresh_mask[slot] = true;
            starts[slot] = adm.cached_tokens as i32;
            if adm.cached_tokens > 0 {
                if seed_caches.is_none() {
                    seed_caches = Some(self.backend.empty_caches()?);
                }
                // lint: allow(R3) — populated by the is_none() branch
                // directly above; Option dance keeps empty_caches()?
                // fallible.
                let seed = seed_caches.as_mut().unwrap();
                for (dst, rows) in seed.iter_mut().zip(&adm.cached_rows) {
                    splice_prefix_rows(dst, rows, slot, adm.cached_tokens)?;
                }
            }
            self.stats
                .record_admission_wait((now - req.enqueued).as_secs_f64());
            self.stats.prefill_tokens +=
                req.prompt.len() - adm.cached_tokens;
        }
        let (logits, fresh) = match seed_caches {
            Some(seed) => self.backend.prefill_lanes_from(
                &tokens,
                &lens,
                &fresh_mask,
                &starts,
                seed,
            )?,
            None => {
                self.backend.prefill_lanes(&tokens, &lens, &fresh_mask)?
            }
        };
        self.stats.prefills += 1;
        // Splice admitted lanes' cache rows + logits into live state.
        for adm in admitted {
            let slot = adm.slot;
            for (dst, src) in self.caches.iter_mut().zip(&fresh) {
                splice_lane(dst, src, slot)?;
            }
            let lane_logits = self.logits.get_or_insert_with(|| {
                HostTensor::zeros(logits.shape())
            });
            splice_row(lane_logits, &logits, slot)?;
            let req = adm.request;
            let seed = req.params.seed ^ req.id;
            self.lanes[slot] = Some(Lane {
                cursor: req.prompt.len(),
                request: req,
                blocks: adm.chain,
                generated: Vec::new(),
                first_token_at: None,
                last_token_at: None,
                max_gap_s: 0.0,
                rng: Pcg64::seeded(seed),
            });
        }
        let busy = self.lanes.iter().filter(|l| l.is_some()).count();
        self.stats.max_concurrency = self.stats.max_concurrency.max(busy);
        self.sync_prefix_stats();
        Ok(())
    }

    /// Chunked-mode admission (DESIGN.md S22): no prompt math runs here.
    /// Each admitted lane's rows in the LIVE cache slabs are zeroed (a
    /// recycled lane must be bitwise-indistinguishable from the
    /// monolithic path, whose whole-lane splice from freshly zeroed
    /// prefill slabs clears any stale rows), cached prefix rows are
    /// spliced straight into the live slabs, and the lane parks with its
    /// prefill cursor at the cached length. [`InferenceServer::step`]'s
    /// `advance_prefill` then computes at most one chunk per engine
    /// iteration until the cursor reaches the prompt length.
    fn admit_chunked(
        &mut self,
        admitted: Vec<Admission>,
        now: Instant,
    ) -> Result<()> {
        for adm in admitted {
            let slot = adm.slot;
            if adm.request.prompt.len() >= self.max_seq {
                bail!("prompt exceeds serving window");
            }
            for dst in self.caches.iter_mut() {
                zero_lane(dst, slot)?;
            }
            if adm.cached_tokens > 0 {
                for (dst, rows) in
                    self.caches.iter_mut().zip(&adm.cached_rows)
                {
                    splice_prefix_rows(dst, rows, slot, adm.cached_tokens)?;
                }
            }
            self.stats.record_admission_wait(
                (now - adm.request.enqueued).as_secs_f64(),
            );
            self.stats.prefill_tokens +=
                adm.request.prompt.len() - adm.cached_tokens;
            let req = adm.request;
            let seed = req.params.seed ^ req.id;
            self.lanes[slot] = Some(Lane {
                cursor: adm.cached_tokens,
                request: req,
                blocks: adm.chain,
                generated: Vec::new(),
                first_token_at: None,
                last_token_at: None,
                max_gap_s: 0.0,
                rng: Pcg64::seeded(seed),
            });
        }
        let busy = self.lanes.iter().filter(|l| l.is_some()).count();
        self.stats.max_concurrency = self.stats.max_concurrency.max(busy);
        self.sync_prefix_stats();
        Ok(())
    }

    /// Advance every mid-prefill lane by at most one chunk of prompt
    /// tokens (a no-op in monolithic mode or when nothing is pending).
    /// All pending lanes share ONE batched
    /// [`Backend::prefill_lanes_from`] call on the live cache slabs —
    /// the runner computes only the fresh lanes' `start..len` positions
    /// and writes only their rows, so live lanes' rows are untouched
    /// (S17 row-independence). A lane whose cursor reaches its prompt
    /// length has its final-position logits row spliced into the live
    /// logits and decodes THIS same iteration — exactly the iteration a
    /// monolithic admission would first decode it.
    fn advance_prefill(&mut self) -> Result<()> {
        if self.prefill_chunk == 0 {
            return Ok(());
        }
        let mut tokens = vec![0i32; self.batch * self.max_seq];
        let mut lens = vec![1i32; self.batch];
        let mut fresh = vec![false; self.batch];
        let mut starts = vec![0i32; self.batch];
        let mut any = false;
        for slot in 0..self.batch {
            let Some(lane) = &self.lanes[slot] else { continue };
            let plen = lane.request.prompt.len();
            if lane.cursor >= plen {
                continue;
            }
            let end = plen.min(lane.cursor + self.prefill_chunk);
            for i in lane.cursor..end {
                tokens[slot * self.max_seq + i] =
                    lane.request.prompt[i] as i32;
            }
            lens[slot] = end as i32;
            starts[slot] = lane.cursor as i32;
            fresh[slot] = true;
            any = true;
        }
        if !any {
            return Ok(());
        }
        let caches = std::mem::take(&mut self.caches);
        let (logits, caches) = self
            .backend
            .prefill_lanes_from(&tokens, &lens, &fresh, &starts, caches)?;
        self.caches = caches;
        self.stats.prefills += 1;
        for slot in 0..self.batch {
            if !fresh[slot] {
                continue;
            }
            let done = match self.lanes[slot].as_mut() {
                Some(lane) => {
                    lane.cursor = lens[slot] as usize;
                    lane.cursor == lane.request.prompt.len()
                }
                None => false,
            };
            if done {
                let lane_logits = self.logits.get_or_insert_with(|| {
                    HostTensor::zeros(logits.shape())
                });
                splice_row(lane_logits, &logits, slot)?;
            }
        }
        Ok(())
    }

    /// Mirror the radix cache's counters into [`ServerStats`].
    fn sync_prefix_stats(&mut self) {
        if let Some(ps) = self.queue.prefix_stats() {
            self.stats.prefix_hits = ps.hits;
            self.stats.prefix_misses = ps.misses;
            self.stats.prefix_hit_tokens = ps.hit_tokens;
            self.stats.prefix_evicted_blocks = ps.evicted_blocks;
            self.stats.prefix_cached_blocks = ps.cached_blocks;
        }
    }

    /// Retire a lane: account for its generation, build the response,
    /// insert the prompt's full-block prefix into the radix cache
    /// (insert-on-free), and return slot + blocks to their pools.
    fn finish_lane(
        &mut self,
        slot: usize,
        lane: Lane,
        reason: FinishReason,
    ) -> Response {
        let now = Instant::now();
        self.stats.completed += 1;
        let n = lane.generated.len();
        self.stats.generated_tokens += n;
        // TPOT: mean inter-token gap across the decode phase. One-token
        // generations have no gap to average; report 0.
        let tpot = match (lane.first_token_at, lane.last_token_at) {
            (Some(first), Some(last)) if n > 1 => {
                (last - first).as_secs_f64() / (n - 1) as f64
            }
            _ => 0.0,
        };
        let response = Response {
            id: lane.request.id,
            tokens: lane.generated,
            ttft: lane
                .first_token_at
                .map(|t| (t - lane.request.enqueued).as_secs_f64())
                .unwrap_or(0.0),
            tpot,
            latency: (now - lane.request.enqueued).as_secs_f64(),
            finish: reason,
        };
        self.stats.record_ttft(response.ttft);
        self.stats.record_tpot(tpot);
        if lane.max_gap_s > self.stats.max_decode_gap_s {
            self.stats.max_decode_gap_s = lane.max_gap_s;
        }
        if self.queue.prefix_enabled() {
            let bt = self.queue.allocator.block_tokens;
            let aligned = lane.request.prompt.len() / bt * bt;
            if aligned > 0 {
                // Row extraction is lazy: a prompt whose prefix is
                // already fully cached (the steady state under a shared
                // system prompt) walks the tree and copies nothing.
                // Caching must never take the serving loop down: a
                // failed insert only loses a sharing opportunity.
                let caches = &self.caches;
                if let Err(e) = self.queue.prefix_insert(
                    &lane.request.prompt[..aligned],
                    &lane.blocks[..aligned / bt],
                    || extract_prefix_rows(caches, slot, aligned),
                ) {
                    log::error!("prefix insert failed: {e:#}");
                }
            }
            self.sync_prefix_stats();
        }
        self.queue.release(&lane.blocks);
        self.slots.free(slot);
        response
    }

    /// One decode step for every live lane; sample + handle completions.
    /// Mid-prefill lanes (chunked mode) are skipped everywhere: they
    /// have no logits row yet, never sample, and their slot chain does
    /// not advance.
    fn decode_once(&mut self) -> Result<Vec<Response>> {
        if !self.lanes.iter().any(is_live) {
            return Ok(Vec::new());
        }
        // Sample the block high-water mark BEFORE this step's releases,
        // so even a 1-token generation registers its pool footprint.
        let used = self.queue.allocator.used_blocks();
        self.stats.peak_blocks_used = self.stats.peak_blocks_used.max(used);
        self.stats.blocks_used_sum += used;
        self.stats.occupancy_samples += 1;
        // Sample next token per busy lane from the current logits.
        let vocab = self.backend.config().vocab;
        let logits = self
            .logits
            .as_ref()
            // lint: allow(R3) — engine invariant: decode_busy_lanes is
            // only entered after a prefill/decode stored logits.
            .expect("logits present when lanes busy")
            .clone();
        let lvals = logits.as_f32()?;
        let mut next = vec![0i32; self.batch];
        let mut pos = vec![0i32; self.batch];
        for slot in 0..self.batch {
            if let Some(lane) = &mut self.lanes[slot] {
                if lane.cursor < lane.request.prompt.len() {
                    continue; // mid-prefill: nothing to sample yet
                }
                let row = &lvals[slot * vocab..(slot + 1) * vocab];
                let tok = sample(row, &lane.request.params, &mut lane.rng);
                let tnow = Instant::now();
                if let Some(prev) = lane.last_token_at {
                    let gap = (tnow - prev).as_secs_f64();
                    if gap > lane.max_gap_s {
                        lane.max_gap_s = gap;
                    }
                }
                if lane.first_token_at.is_none() {
                    lane.first_token_at = Some(tnow);
                }
                lane.last_token_at = Some(tnow);
                lane.generated.push(tok);
                next[slot] = tok as i32;
                pos[slot] = self.slots.len_of(slot) as i32;
            }
        }
        // Completions BEFORE spending a decode step on finished lanes.
        let mut done = Vec::new();
        for slot in 0..self.batch {
            let finished = match &self.lanes[slot] {
                // Mid-prefill lanes have sampled nothing and cannot
                // finish; the `_` arm covers them and idle slots.
                Some(lane) if lane.cursor >= lane.request.prompt.len() => {
                    // lint: allow(R3) — a live lane has sampled at
                    // least one token (the loop above pushes one every
                    // iteration a lane is live).
                    let last = *lane.generated.last().unwrap();
                    let hit_stop =
                        lane.request.params.stop_token == Some(last);
                    let hit_len = lane.generated.len()
                        >= lane.request.params.max_new_tokens;
                    hit_stop || hit_len
                }
                _ => false,
            };
            if finished {
                // lint: allow(R3) — `finished` is only true in the
                // Some(lane) match arm above.
                let lane = self.lanes[slot].take().unwrap();
                let reason = if lane.request.params.stop_token
                    == lane.generated.last().copied()
                {
                    FinishReason::Stop
                } else {
                    FinishReason::Length
                };
                done.push(self.finish_lane(slot, lane, reason));
            }
        }
        // Decode the sampled tokens for live lanes still running; idle
        // and mid-prefill lanes are flagged inactive so backends that
        // can skip them (native) do.
        if self.lanes.iter().any(is_live) {
            let active: Vec<bool> = self.lanes.iter().map(is_live).collect();
            let caches = std::mem::take(&mut self.caches);
            let (logits, caches) = self.backend.decode_active(
                &next, &pos, &active, caches, self.use_pallas)?;
            self.caches = caches;
            self.logits = Some(logits);
            self.stats.decode_steps += 1;
            // Mirror the sparse selection into the stats: per active
            // lane this step attended min(k, len) of the len rows a
            // dense engine would have read (len = pos + 1).
            if let Some(k) = self.backend.sparse_k() {
                for slot in 0..self.batch {
                    if !active[slot] {
                        continue;
                    }
                    let rows = pos[slot] as usize + 1;
                    self.stats.sparse_attended_rows += k.min(rows);
                    self.stats.sparse_dense_rows += rows;
                }
            }
            for slot in 0..self.batch {
                if !active[slot] {
                    continue; // idle, or mid-prefill (no token decoded)
                }
                self.slots.advance(slot)?;
                let need = self.slots.len_of(slot);
                // lint: allow(R3) — this loop iterates live slots only;
                // active[slot] proved the lane Some above.
                let lane = self.lanes[slot].as_mut().unwrap();
                if self
                    .queue
                    .extend_with_eviction(&mut lane.blocks, need)
                    .is_ok()
                {
                    continue;
                }
                // Pool exhausted mid-growth — reachable only under
                // optimistic admission (conservative reservations cover
                // max_new up front). Truncate THIS lane's generation
                // rather than killing every other in-flight request.
                // lint: allow(R3) — same busy-slot invariant as the
                // as_mut() above; take() ends this lane.
                let lane = self.lanes[slot].take().unwrap();
                log::warn!(
                    "request {}: block pool exhausted at {} tokens; \
                     truncating generation ({} tokens emitted)",
                    lane.request.id,
                    need,
                    lane.generated.len()
                );
                done.push(self.finish_lane(slot, lane, FinishReason::Length));
            }
            self.stats.peak_cache_bytes = self
                .stats
                .peak_cache_bytes
                .max(self.slots.live_cache_bytes());
        } else {
            // No live lane remains (mid-prefill lanes may still exist:
            // their completing chunk re-seeds logits via splice_row).
            self.logits = None;
        }
        Ok(done)
    }
}

/// Splice `rows` (`[L, tokens, w]` from the prefix radix cache, in the
/// engine's cache dtype) into lane `lane`'s positions `0..tokens` of a
/// `[L, B, S, ...]` slab. Quantized rows are copied as stored bytes +
/// scales — the replayed lane is indistinguishable from the lane that
/// originally computed them.
fn splice_prefix_rows(
    dst: &mut HostTensor,
    rows: &SlabRows,
    lane: usize,
    tokens: usize,
) -> Result<()> {
    let shape = dst.shape().to_vec();
    if shape.len() < 4 {
        bail!("prefix splice expects [L, B, S, ...] slabs, got {shape:?}");
    }
    // lint: allow(R3) — len >= 4 bailed on the line above.
    let (l_n, b_n, s_n) = (shape[0], shape[1], shape[2]);
    let w: usize = shape[3..].iter().product();
    if lane >= b_n || tokens > s_n {
        bail!("prefix splice out of range: lane {lane}, {tokens} tokens");
    }
    match (dst, rows) {
        (HostTensor::F32(d, _), SlabRows::F32(r)) => {
            if r.len() != l_n * tokens * w {
                bail!(
                    "prefix splice mismatch: {} row elems into {shape:?}",
                    r.len()
                );
            }
            for l in 0..l_n {
                let src = &r[l * tokens * w..(l + 1) * tokens * w];
                let base = ((l * b_n + lane) * s_n) * w;
                d[base..base + tokens * w].copy_from_slice(src);
            }
        }
        (
            HostTensor::Q8 { data, scales, row, group, .. },
            SlabRows::Q8 { data: rd, scales: rs },
        ) => {
            if *row != w {
                bail!("prefix splice q8 row width {row} != slab width {w}");
            }
            let g = n_groups(w, *group);
            if rd.len() != l_n * tokens * w || rs.len() != l_n * tokens * g {
                bail!(
                    "prefix splice q8 mismatch: {}/{} into {shape:?}",
                    rd.len(),
                    rs.len()
                );
            }
            for l in 0..l_n {
                let base = ((l * b_n + lane) * s_n) * w;
                data[base..base + tokens * w].copy_from_slice(
                    &rd[l * tokens * w..(l + 1) * tokens * w],
                );
                let sbase = ((l * b_n + lane) * s_n) * g;
                scales[sbase..sbase + tokens * g].copy_from_slice(
                    &rs[l * tokens * g..(l + 1) * tokens * g],
                );
            }
        }
        _ => bail!("prefix splice dtype mismatch (slab vs stored rows)"),
    }
    Ok(())
}

/// Extract lane `lane`'s positions `0..tokens` from every slab as
/// `[L, tokens, w]` payloads in the slab's dtype (the radix cache's
/// storage layout; quantized slabs yield their exact bytes + scales).
fn extract_prefix_rows(
    caches: &[HostTensor],
    lane: usize,
    tokens: usize,
) -> Result<Vec<SlabRows>> {
    caches
        .iter()
        .map(|slab| {
            let shape = slab.shape().to_vec();
            if shape.len() < 4 {
                bail!("prefix extract expects [L, B, S, ...] slabs");
            }
            // lint: allow(R3) — len >= 4 bailed on the line above.
            let (l_n, b_n, s_n) = (shape[0], shape[1], shape[2]);
            let w: usize = shape[3..].iter().product();
            if lane >= b_n || tokens > s_n {
                bail!("prefix extract out of range for {shape:?}");
            }
            match slab {
                HostTensor::F32(s, _) => {
                    let mut out = vec![0.0f32; l_n * tokens * w];
                    for l in 0..l_n {
                        let base = ((l * b_n + lane) * s_n) * w;
                        out[l * tokens * w..(l + 1) * tokens * w]
                            .copy_from_slice(&s[base..base + tokens * w]);
                    }
                    Ok(SlabRows::F32(out))
                }
                HostTensor::Q8 { data, scales, row, group, .. } => {
                    if *row != w {
                        bail!("prefix extract q8 row width mismatch");
                    }
                    let g = n_groups(w, *group);
                    let mut out_d = vec![0i8; l_n * tokens * w];
                    let mut out_s = vec![0.0f32; l_n * tokens * g];
                    for l in 0..l_n {
                        let base = ((l * b_n + lane) * s_n) * w;
                        out_d[l * tokens * w..(l + 1) * tokens * w]
                            .copy_from_slice(&data[base..base + tokens * w]);
                        let sbase = ((l * b_n + lane) * s_n) * g;
                        out_s[l * tokens * g..(l + 1) * tokens * g]
                            .copy_from_slice(
                                &scales[sbase..sbase + tokens * g],
                            );
                    }
                    Ok(SlabRows::Q8 { data: out_d, scales: out_s })
                }
                HostTensor::I32(..) => bail!("cache slabs are never i32"),
            }
        })
        .collect()
}

/// Zero lane `lane`'s rows of a stacked `[L, B, ...]` cache tensor
/// (payload AND scales for quantized slabs — `HostTensor::zeros_q8`
/// starts all scales at 0, so this restores exactly that state).
/// Chunked admission uses it so a recycled lane is
/// bitwise-indistinguishable from the monolithic path, whose whole-lane
/// splice from freshly zeroed prefill slabs clears any stale rows
/// beyond the new prompt.
fn zero_lane(dst: &mut HostTensor, lane: usize) -> Result<()> {
    let shape = dst.shape().to_vec();
    if shape.len() < 2 {
        bail!("cache zero shape too small: {shape:?}");
    }
    // lint: allow(R3) — len >= 2 bailed on the line above.
    let (layers, batch) = (shape[0], shape[1]);
    let lane_stride: usize = shape[2..].iter().product();
    let layer_stride = batch * lane_stride;
    if lane >= batch {
        bail!("cache zero lane {lane} outside [0, {batch})");
    }
    match dst {
        HostTensor::F32(d, _) => {
            for l in 0..layers {
                let off = l * layer_stride + lane * lane_stride;
                d[off..off + lane_stride].fill(0.0);
            }
        }
        HostTensor::Q8 { data, scales, row, group, .. } => {
            let g = n_groups(*row, *group);
            let lane_rows = lane_stride / *row;
            let scale_lane = lane_rows * g;
            let scale_layer = batch * scale_lane;
            for l in 0..layers {
                let off = l * layer_stride + lane * lane_stride;
                data[off..off + lane_stride].fill(0);
                let soff = l * scale_layer + lane * scale_lane;
                scales[soff..soff + scale_lane].fill(0.0);
            }
        }
        HostTensor::I32(..) => bail!("cache slabs are never i32"),
    }
    Ok(())
}

/// Copy lane `b`'s rows of a stacked [L, B, ...] cache tensor (payload
/// AND scales for quantized slabs).
fn splice_lane(dst: &mut HostTensor, src: &HostTensor, lane: usize) -> Result<()> {
    let shape = src.shape().to_vec();
    if dst.shape() != shape.as_slice() || shape.len() < 2 {
        bail!("cache splice shape mismatch: {:?} vs {shape:?}", dst.shape());
    }
    // lint: allow(R3) — len >= 2 bailed on the line above.
    let (layers, batch) = (shape[0], shape[1]);
    let lane_stride: usize = shape[2..].iter().product();
    let layer_stride = batch * lane_stride;
    match (dst, src) {
        (HostTensor::F32(d, _), HostTensor::F32(s, _)) => {
            for l in 0..layers {
                let off = l * layer_stride + lane * lane_stride;
                d[off..off + lane_stride]
                    .copy_from_slice(&s[off..off + lane_stride]);
            }
        }
        (
            HostTensor::Q8 { data: dd, scales: ds, row: dr, group: dg, .. },
            HostTensor::Q8 { data: sd, scales: ss, row: sr, group: sg, .. },
        ) => {
            if dr != sr || dg != sg {
                bail!("cache splice q8 geometry mismatch");
            }
            let g = n_groups(*dr, *dg);
            let lane_rows = lane_stride / *dr;
            let scale_lane = lane_rows * g;
            let scale_layer = batch * scale_lane;
            for l in 0..layers {
                let off = l * layer_stride + lane * lane_stride;
                dd[off..off + lane_stride]
                    .copy_from_slice(&sd[off..off + lane_stride]);
                let soff = l * scale_layer + lane * scale_lane;
                ds[soff..soff + scale_lane]
                    .copy_from_slice(&ss[soff..soff + scale_lane]);
            }
        }
        _ => bail!("cache splice dtype mismatch"),
    }
    Ok(())
}

/// Copy row `lane` of a [B, V] tensor.
fn splice_row(dst: &mut HostTensor, src: &HostTensor, lane: usize) -> Result<()> {
    let shape = src.shape().to_vec();
    if dst.shape() != shape.as_slice() || shape.len() != 2 {
        bail!("row splice shape mismatch");
    }
    // lint: allow(R3) — len == 2 bailed on the line above.
    let w = shape[1];
    let (HostTensor::F32(d, _), HostTensor::F32(s, _)) = (dst, src) else {
        bail!("row splice expects f32");
    };
    d[lane * w..(lane + 1) * w].copy_from_slice(&s[lane * w..(lane + 1) * w]);
    Ok(())
}

/// Greedy, temperature, or nucleus (top-p) sampling from one logit row.
fn sample(row: &[f32], params: &GenParams, rng: &mut Pcg64) -> u32 {
    if params.temperature <= 0.0 {
        // total_cmp: NaN-total order, so no panicking float unwrap on
        // the per-token hot path (R3).
        return row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as u32)
            .unwrap_or(0);
    }
    let t = params.temperature;
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut weights: Vec<f64> =
        row.iter().map(|&x| (((x - max) / t) as f64).exp()).collect();
    if params.top_p < 1.0 {
        // Nucleus truncation: keep the smallest prob-sorted prefix whose
        // mass reaches top_p; zero the tail.
        let total: f64 = weights.iter().sum();
        let mut order: Vec<usize> = (0..weights.len()).collect();
        order.sort_by(|&a, &b| weights[b].total_cmp(&weights[a]));
        let target = (params.top_p.max(0.0) as f64) * total;
        let mut mass = 0.0;
        let mut keep = 0;
        for (rank, &i) in order.iter().enumerate() {
            mass += weights[i];
            keep = rank + 1;
            if mass >= target {
                break;
            }
        }
        for &i in &order[keep..] {
            weights[i] = 0.0;
        }
    }
    let total: f64 = weights.iter().sum();
    let mut u = rng.f64() * total;
    for (i, w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 && *w > 0.0 {
            return i as u32;
        }
    }
    // numerical fallback: the largest surviving weight
    weights
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as u32)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_greedy_is_argmax() {
        let row = [0.1f32, 2.0, -1.0, 0.5];
        let mut rng = Pcg64::seeded(1);
        let p = GenParams::default();
        assert_eq!(sample(&row, &p, &mut rng), 1);
    }

    #[test]
    fn sample_temperature_covers_support() {
        let row = [1.0f32, 1.0, 1.0];
        let p = GenParams { temperature: 1.0, ..Default::default() };
        let mut rng = Pcg64::seeded(2);
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[sample(&row, &p, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_top_p_truncates_tail() {
        // One dominant token: tiny top_p must always pick it.
        let row = [8.0f32, 0.0, 0.0, 0.0];
        let p = GenParams {
            temperature: 1.0,
            top_p: 0.5,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(3);
        for _ in 0..100 {
            assert_eq!(sample(&row, &p, &mut rng), 0);
        }
    }

    #[test]
    fn sample_top_p_one_keeps_full_support() {
        let row = [1.0f32, 1.0];
        let p = GenParams {
            temperature: 1.0,
            top_p: 1.0,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(4);
        let mut seen = [false; 2];
        for _ in 0..100 {
            seen[sample(&row, &p, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn zero_lane_clears_only_target() {
        let mut dst = HostTensor::F32(
            (0..24).map(|x| x as f32).collect(),
            vec![2, 3, 4], // L=2, B=3, rest=4
        );
        zero_lane(&mut dst, 1).unwrap();
        let d = dst.as_f32().unwrap();
        // lane 1 of layer 0 = elems 4..8; layer 1 = 16..20
        assert!(d[4..8].iter().all(|&x| x == 0.0));
        assert!(d[16..20].iter().all(|&x| x == 0.0));
        assert_eq!(&d[0..4], &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(&d[8..12], &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(&d[20..24], &[20.0, 21.0, 22.0, 23.0]);
    }

    #[test]
    fn splice_lane_copies_only_target() {
        let src = HostTensor::F32((0..24).map(|x| x as f32).collect(),
                                  vec![2, 3, 4]); // L=2,B=3,rest=4
        let mut dst = HostTensor::zeros(&[2, 3, 4]);
        splice_lane(&mut dst, &src, 1).unwrap();
        let d = dst.as_f32().unwrap();
        // lane 1 of layer 0 = elems 4..8; layer 1 = 16..20
        assert_eq!(&d[4..8], &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(&d[16..20], &[16.0, 17.0, 18.0, 19.0]);
        assert!(d[0..4].iter().all(|&x| x == 0.0));
        assert!(d[8..16].iter().all(|&x| x == 0.0));
    }
}
