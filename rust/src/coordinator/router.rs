//! Leader/worker router: fan requests out to engine worker threads and
//! collect responses (the scale-out shape of vllm-project/router, scaled
//! to threads instead of hosts).

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::api::{FinishReason, Request, Response};
use crate::coordinator::server::{InferenceServer, ServerStats};

enum Cmd {
    Submit(Request),
    Drain,
    /// Snapshot the engine's scheduler stats through the one-shot sender.
    Stats(mpsc::Sender<ServerStats>),
    Shutdown,
}

/// Worker -> router traffic. `DrainDone(i)` is worker `i`'s barrier
/// marker: it lets `Router::drain` terminate even when an engine errored
/// mid-drain and some submitted requests will never produce a response.
enum WorkerMsg {
    Response(Response),
    DrainDone(usize),
}

struct Worker {
    tx: mpsc::Sender<Cmd>,
    outstanding: usize,
    handle: Option<thread::JoinHandle<()>>,
}

/// Least-loaded request router over N single-engine workers.
pub struct Router {
    workers: Vec<Worker>,
    rx: mpsc::Receiver<WorkerMsg>,
    submitted: usize,
    collected: usize,
}

/// A thread-local engine constructor. PJRT client handles are not Send,
/// so each worker builds its own engine *inside* its thread.
pub type EngineFactory =
    Box<dyn FnOnce() -> anyhow::Result<InferenceServer> + Send>;

impl Router {
    /// Build a router with one worker thread per factory.
    pub fn new(factories: Vec<EngineFactory>) -> Router {
        let (resp_tx, rx) = mpsc::channel::<WorkerMsg>();
        let workers = factories
            .into_iter()
            .enumerate()
            .map(|(i, factory)| {
                let (tx, cmd_rx) = mpsc::channel::<Cmd>();
                let out = resp_tx.clone();
                let handle = thread::Builder::new()
                    .name(format!("elitekv-engine-{i}"))
                    .spawn(move || {
                        let mut engine = match factory() {
                            Ok(e) => e,
                            Err(e) => {
                                log::error!("engine {i} init failed: {e:#}");
                                return;
                            }
                        };
                        loop {
                            match cmd_rx.recv() {
                                Ok(Cmd::Submit(req)) => {
                                    let id = req.id;
                                    if let Err(e) = engine.submit(req) {
                                        log::error!(
                                            "engine {i}: request {id} \
                                             rejected: {e:#}"
                                        );
                                        // Keep the router's response
                                        // accounting exact: a rejection
                                        // still produces one response.
                                        let _ = out.send(
                                            WorkerMsg::Response(Response {
                                                id,
                                                tokens: Vec::new(),
                                                ttft: 0.0,
                                                tpot: 0.0,
                                                latency: 0.0,
                                                finish:
                                                    FinishReason::Rejected,
                                            }),
                                        );
                                    }
                                }
                                Ok(Cmd::Stats(tx)) => {
                                    let _ = tx.send(engine.stats.clone());
                                }
                                Ok(Cmd::Drain) => {
                                    match engine.run_to_completion() {
                                        Ok(responses) => {
                                            for r in responses {
                                                let _ = out.send(
                                                    WorkerMsg::Response(r),
                                                );
                                            }
                                        }
                                        Err(e) => {
                                            log::error!("engine {i}: {e:#}");
                                        }
                                    }
                                    // Always mark the barrier, even after
                                    // an engine error — in-flight requests
                                    // may be lost but drain() must return.
                                    let _ = out.send(WorkerMsg::DrainDone(i));
                                }
                                Ok(Cmd::Shutdown) | Err(_) => break,
                            }
                        }
                    })
                    // lint: allow(R3) — worker-pool construction runs
                    // once at router startup, not on the request path.
                    .expect("spawn engine worker");
                Worker { tx, outstanding: 0, handle: Some(handle) }
            })
            .collect();
        // `resp_tx` is dropped here: only workers hold senders, so the
        // channel disconnects (and drain/recv errors out) when every
        // worker thread has exited.
        drop(resp_tx);
        Router { workers, rx, submitted: 0, collected: 0 }
    }

    /// Number of engine worker threads.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Route to the least-loaded worker.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        let Some((idx, _)) = self
            .workers
            .iter()
            .enumerate()
            .min_by_key(|(_, w)| w.outstanding)
        else {
            bail!("router has no workers");
        };
        self.workers[idx]
            .tx
            .send(Cmd::Submit(req))
            .map_err(|_| anyhow::anyhow!("worker {idx} hung up"))?;
        self.workers[idx].outstanding += 1;
        self.submitted += 1;
        Ok(())
    }

    /// Snapshot every worker's scheduler stats (admission waits, peak
    /// concurrency, block occupancy). Call after [`Router::drain`] for
    /// end-of-run numbers.
    pub fn stats(&self) -> Result<Vec<crate::coordinator::ServerStats>> {
        let mut out = Vec::with_capacity(self.workers.len());
        for (i, w) in self.workers.iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            w.tx
                .send(Cmd::Stats(tx))
                .map_err(|_| anyhow::anyhow!("worker {i} hung up"))?;
            out.push(rx.recv().map_err(|_| {
                anyhow::anyhow!("worker {i} exited before reporting stats")
            })?);
        }
        Ok(out)
    }

    /// Run all workers to completion and collect every response. Returns
    /// once every worker has finished draining (or died); if responses
    /// were lost to engine errors or worker panics, that is reported as
    /// an error instead of blocking forever.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        // A worker whose command channel is gone (init failure / panic)
        // will never send its barrier marker: count it done up front.
        let mut done_mask = vec![false; self.workers.len()];
        for (i, w) in self.workers.iter().enumerate() {
            if w.tx.send(Cmd::Drain).is_err() {
                done_mask[i] = true;
            }
        }
        // Consume until EVERY live worker has marked its barrier —
        // per-sender FIFO means all of a worker's responses precede its
        // marker, so nothing is left behind for the next round. The
        // timeout arm sweeps for workers that panicked mid-drain (their
        // thread is finished but no marker ever arrives).
        let mut out = Vec::with_capacity(self.submitted - self.collected);
        while done_mask.iter().any(|d| !d) {
            match self.rx.recv_timeout(Duration::from_millis(250)) {
                Ok(WorkerMsg::Response(r)) => {
                    self.collected += 1;
                    out.push(r);
                }
                Ok(WorkerMsg::DrainDone(i)) => done_mask[i] = true,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    for (i, w) in self.workers.iter().enumerate() {
                        let dead = w
                            .handle
                            .as_ref()
                            .map(|h| h.is_finished())
                            .unwrap_or(true);
                        if !done_mask[i] && dead {
                            log::error!(
                                "worker {i} died during drain; its \
                                 in-flight requests are lost"
                            );
                            done_mask[i] = true;
                        }
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // A worker that died between sending responses and its marker
        // leaves those responses buffered: sweep them up now so they are
        // not mis-attributed to the NEXT round's accounting.
        while let Ok(msg) = self.rx.try_recv() {
            if let WorkerMsg::Response(r) = msg {
                self.collected += 1;
                out.push(r);
            }
        }
        let missing = self.submitted.saturating_sub(self.collected);
        // Full barrier: reset the accounting either way so a later
        // submit/drain round starts clean.
        self.submitted = 0;
        self.collected = 0;
        for w in &mut self.workers {
            w.outstanding = 0;
        }
        if missing > 0 {
            bail!(
                "{missing} request(s) lost to engine errors during drain \
                 ({} responses collected; see worker logs)",
                out.len()
            );
        }
        Ok(out)
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Cmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}
