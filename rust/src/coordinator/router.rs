//! Leader/worker router: fan requests out to engine worker threads and
//! collect responses (the scale-out shape of vllm-project/router, scaled
//! to threads instead of hosts).

use std::sync::mpsc;
use std::thread;

use anyhow::{bail, Result};

use crate::coordinator::api::{Request, Response};
use crate::coordinator::server::InferenceServer;

enum Cmd {
    Submit(Request),
    Drain,
    Shutdown,
}

struct Worker {
    tx: mpsc::Sender<Cmd>,
    outstanding: usize,
    handle: Option<thread::JoinHandle<()>>,
}

/// Least-loaded request router over N single-engine workers.
pub struct Router {
    workers: Vec<Worker>,
    rx: mpsc::Receiver<Response>,
    resp_tx: mpsc::Sender<Response>,
    submitted: usize,
    collected: usize,
}

/// A thread-local engine constructor. PJRT client handles are not Send,
/// so each worker builds its own engine *inside* its thread.
pub type EngineFactory =
    Box<dyn FnOnce() -> anyhow::Result<InferenceServer> + Send>;

impl Router {
    /// Build a router with one worker thread per factory.
    pub fn new(factories: Vec<EngineFactory>) -> Router {
        let (resp_tx, rx) = mpsc::channel::<Response>();
        let workers = factories
            .into_iter()
            .enumerate()
            .map(|(i, factory)| {
                let (tx, cmd_rx) = mpsc::channel::<Cmd>();
                let out = resp_tx.clone();
                let handle = thread::Builder::new()
                    .name(format!("elitekv-engine-{i}"))
                    .spawn(move || {
                        let mut engine = match factory() {
                            Ok(e) => e,
                            Err(e) => {
                                log::error!("engine {i} init failed: {e:#}");
                                return;
                            }
                        };
                        loop {
                            match cmd_rx.recv() {
                                Ok(Cmd::Submit(req)) => engine.submit(req),
                                Ok(Cmd::Drain) => {
                                    match engine.run_to_completion() {
                                        Ok(responses) => {
                                            for r in responses {
                                                let _ = out.send(r);
                                            }
                                        }
                                        Err(e) => {
                                            log::error!("engine {i}: {e:#}");
                                        }
                                    }
                                }
                                Ok(Cmd::Shutdown) | Err(_) => break,
                            }
                        }
                    })
                    .expect("spawn engine worker");
                Worker { tx, outstanding: 0, handle: Some(handle) }
            })
            .collect();
        Router { workers, rx, resp_tx, submitted: 0, collected: 0 }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Route to the least-loaded worker.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        let Some((idx, _)) = self
            .workers
            .iter()
            .enumerate()
            .min_by_key(|(_, w)| w.outstanding)
        else {
            bail!("router has no workers");
        };
        self.workers[idx]
            .tx
            .send(Cmd::Submit(req))
            .map_err(|_| anyhow::anyhow!("worker {idx} hung up"))?;
        self.workers[idx].outstanding += 1;
        self.submitted += 1;
        Ok(())
    }

    /// Run all workers to completion and collect every response.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        for w in &self.workers {
            let _ = w.tx.send(Cmd::Drain);
        }
        let mut out = Vec::with_capacity(self.submitted - self.collected);
        while self.collected < self.submitted {
            let r = self.rx.recv().map_err(|_| {
                anyhow::anyhow!("all workers exited with responses pending")
            })?;
            self.collected += 1;
            out.push(r);
        }
        for w in &mut self.workers {
            w.outstanding = 0;
        }
        Ok(out)
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Cmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
        let _ = &self.resp_tx;
    }
}
