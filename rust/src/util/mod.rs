//! Substrate utilities standing in for crates unavailable offline
//! (rand, serde/serde_json, criterion's stats core, proptest, rayon).

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;

pub use json::Json;
pub use rng::Pcg64;
pub use stats::Summary;
