//! Statistics for the bench harness (criterion-core substitute).

/// Summary statistics of a sample of measurements.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
        }
    }

    /// Human-readable one-liner with the given unit.
    pub fn fmt(&self, unit: &str) -> String {
        format!(
            "mean {:.3}{u} ± {:.3} (p50 {:.3}{u}, p90 {:.3}{u}, p95 {:.3}{u}, \
             p99 {:.3}{u}, n={})",
            self.mean, self.std, self.p50, self.p90, self.p95, self.p99,
            self.n,
            u = unit
        )
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Welford online mean/variance accumulator (training-loss tracking).
#[derive(Clone, Debug, Default)]
pub struct Online {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
}

/// Exponential moving average (loss smoothing in trainer logs).
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v * (1.0 - self.alpha) + x * self.alpha,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p99, 2.0);
    }

    #[test]
    fn summary_percentiles_ordered() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p95);
        assert!(s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p50 - 49.5).abs() < 1e-9);
        assert!((s.p95 - 94.05).abs() < 1e-9);
        assert!((s.mean - 49.5).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "empty sample")]
    fn summary_of_empty_panics() {
        let _ = Summary::of(&[]);
    }

    #[test]
    fn summary_single_sample_is_every_percentile() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.n, 1);
        assert_eq!(s.min, 7.5);
        assert_eq!(s.max, 7.5);
        assert_eq!(s.p50, 7.5);
        assert_eq!(s.p90, 7.5);
        assert_eq!(s.p95, 7.5);
        assert_eq!(s.p99, 7.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_exact_quantile_boundaries() {
        // 5 evenly spaced points: q*(n-1) lands exactly on indices, so
        // the interpolation must return the sample values themselves.
        let sorted = [0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 0.25), 1.0);
        assert_eq!(percentile(&sorted, 0.50), 2.0);
        assert_eq!(percentile(&sorted, 0.75), 3.0);
        assert_eq!(percentile(&sorted, 1.0), 4.0);
        // midpoint between two samples interpolates linearly
        assert!((percentile(&sorted, 0.125) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 4.0, 2.0, 8.0, 5.0];
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((o.mean() - mean).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 4.0;
        assert!((o.var() - var).abs() < 1e-12);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..30 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }
}
