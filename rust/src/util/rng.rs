//! PCG64 PRNG + distribution helpers (offline substitute for `rand`).
//!
//! PCG-XSL-RR 128/64 (O'Neill 2014). Deterministic across platforms, which
//! the experiment harness relies on for reproducible corpora and seeds.

/// Permuted congruential generator, 128-bit state / 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with an arbitrary stream id; two different `seq` values give
    /// independent streams from the same seed.
    pub fn new(seed: u64, seq: u64) -> Self {
        let inc = ((seq as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Single-argument convenience constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniform element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.range(0, i + 1));
        }
    }

    /// Sample from a Zipf(s) distribution over [0, n) (corpus word draw).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on the fly is O(n); fine for small vocabularies —
        // the corpus generator memoizes a table instead for hot use.
        let mut norm = 0.0;
        for k in 1..=n {
            norm += 1.0 / (k as f64).powf(s);
        }
        let target = self.f64() * norm;
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            if acc >= target {
                return k - 1;
            }
        }
        n - 1
    }
}

/// Precomputed Zipf table for repeated draws over a fixed support.
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let norm = acc;
        for c in &mut cdf {
            *c /= norm;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let x = rng.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg64::seeded(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(6);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_table_monotone_mass() {
        let mut rng = Pcg64::seeded(7);
        let t = ZipfTable::new(20, 1.2);
        let mut counts = [0usize; 20];
        for _ in 0..20_000 {
            counts[t.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[5] && counts[5] > counts[15]);
    }
}
