//! Fixed-size thread pool (offline substitute for rayon/tokio workers).
//!
//! The serving coordinator uses this for request handling; compile-time
//! conversion uses `scope` for parallel per-layer SVDs.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple fixed-size thread pool with a shared work queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("elitekv-worker-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx) }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker hung up");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for i in 0..n across up to `par` scoped threads and collect
/// results in order. Panics propagate.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(
    n: usize,
    par: usize,
    f: F,
) -> Vec<T> {
    if n == 0 {
        return Vec::new();
    }
    let par = par.clamp(1, n);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..par {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let v = f(i);
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|x| x.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(50, 8, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }
}
