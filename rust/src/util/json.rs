//! Minimal JSON parser/emitter (offline substitute for `serde_json`).
//!
//! Covers the full JSON grammar; used for artifact manifests, run
//! configuration files, and experiment result records.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required manifest fields.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key `{key}` in {self}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Shape helper: `[1, 2, 3]` -> `vec![1, 2, 3]`.
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // ---------------- constructors ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_num<T: Into<f64> + Copy>(xs: &[T]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x.into())).collect())
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
    }

    // ---------------- parse ----------------

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    self.pos = start + width;
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while self
            .peek()
            .map(|c| {
                c.is_ascii_digit()
                    || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            })
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"config": {"name": "tiny", "d_model": 256},
                      "params": [{"name": "embed", "shape": [512, 256]}],
                      "ratio": 0.344, "ok": true, "none": null}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.req("config").req("name").as_str(), Some("tiny"));
        assert_eq!(j.req("config").req("d_model").as_usize(), Some(256));
        assert_eq!(
            j.req("params").as_arr().unwrap()[0].req("shape").as_shape(),
            Some(vec![512, 256])
        );
        assert!((j.req("ratio").as_f64().unwrap() - 0.344).abs() < 1e-12);
        // reparse of the emitted form is identical
        let emitted = j.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), j);
    }

    #[test]
    fn parses_nested_and_escapes() {
        let j = Json::parse(r#"["a\n\"b\"", [1, -2.5e3, 3]]"#).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr[0].as_str(), Some("a\n\"b\""));
        assert_eq!(arr[1].as_arr().unwrap()[1].as_f64(), Some(-2500.0));
    }

    #[test]
    fn parses_unicode_strings() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::num(128).to_string(), "128");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }
}
