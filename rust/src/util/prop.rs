//! Mini property-testing framework (offline substitute for `proptest`).
//!
//! Deterministic, seeded case generation with failure reporting of the
//! exact seed+case index so any failure replays. Used by the coordinator
//! and kv-cache invariant suites (DESIGN.md S16).
//!
//! The case stream derives from the property name, optionally mixed with
//! the `ELITEKV_PROP_SEED` environment variable (decimal or `0x` hex):
//! CI pins it so failures reproduce verbatim from the logged value, and
//! developers can sweep it to explore fresh cases without code changes.

use crate::util::rng::Pcg64;

/// Number of cases per property (kept modest: single-core CI budget).
pub const DEFAULT_CASES: usize = 64;

/// Environment variable mixed into every property's case stream.
pub const PROP_SEED_ENV: &str = "ELITEKV_PROP_SEED";

/// The `ELITEKV_PROP_SEED` override (0 when unset or unparsable).
fn env_seed() -> u64 {
    let Ok(raw) = std::env::var(PROP_SEED_ENV) else { return 0 };
    let raw = raw.trim();
    let parsed = match raw.strip_prefix("0x").or_else(|| raw.strip_prefix("0X"))
    {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => raw.parse(),
    };
    parsed.unwrap_or_else(|_| {
        eprintln!("warning: ignoring unparsable {PROP_SEED_ENV}=`{raw}`");
        0
    })
}

/// Run `prop` against `cases` generated inputs. On failure, panics with
/// the generating seed, case index, and `ELITEKV_PROP_SEED` value so the
/// exact case replays.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let env = env_seed();
    let base_seed = fnv1a(name) ^ env;
    for case in 0..cases {
        let mut rng = Pcg64::new(base_seed, case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} \
                 (seed {base_seed:#x}, {PROP_SEED_ENV}={env}): \
                 {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// FNV-1a of the property name, so each property gets a stable stream.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("sum-commutes", 32, |rng| {
            (rng.below(1000) as i64, rng.below(1000) as i64)
        }, |(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn check_reports_failure() {
        check("always-fails", 4, |rng| rng.below(10), |_| Err("nope".into()));
    }
}
