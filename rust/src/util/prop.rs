//! Mini property-testing framework (offline substitute for `proptest`).
//!
//! Deterministic, seeded case generation with failure reporting of the
//! exact seed+case index so any failure replays. Used by the coordinator
//! and kv-cache invariant suites (DESIGN.md S16).
//!
//! The case stream derives from the property name, optionally mixed with
//! the `ELITEKV_PROP_SEED` environment variable (decimal or `0x` hex):
//! CI pins it so failures reproduce verbatim from the logged value, and
//! developers can sweep it to explore fresh cases without code changes.
//!
//! The per-property case count can likewise be overridden with the
//! `ELITEKV_PROP_CASES` environment variable (a positive integer): CI's
//! second property shard raises it to widen coverage, and developers can
//! crank it locally for a soak run. Failure messages echo the seed, the
//! effective case count, and both environment values so any failure
//! replays exactly.

use crate::util::rng::Pcg64;

/// Number of cases per property (kept modest: single-core CI budget).
pub const DEFAULT_CASES: usize = 64;

/// Environment variable mixed into every property's case stream.
pub const PROP_SEED_ENV: &str = "ELITEKV_PROP_SEED";

/// Environment variable overriding every property's case count.
pub const PROP_CASES_ENV: &str = "ELITEKV_PROP_CASES";

/// The `ELITEKV_PROP_SEED` override (0 when unset or unparsable).
fn env_seed() -> u64 {
    let Ok(raw) = std::env::var(PROP_SEED_ENV) else { return 0 };
    let raw = raw.trim();
    let parsed = match raw.strip_prefix("0x").or_else(|| raw.strip_prefix("0X"))
    {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => raw.parse(),
    };
    parsed.unwrap_or_else(|_| {
        eprintln!("warning: ignoring unparsable {PROP_SEED_ENV}=`{raw}`");
        0
    })
}

/// The `ELITEKV_PROP_CASES` override (`None` when unset, non-positive,
/// or unparsable — the caller's count then stands).
fn env_cases() -> Option<usize> {
    let raw = std::env::var(PROP_CASES_ENV).ok()?;
    let raw = raw.trim();
    match raw.parse::<usize>() {
        Ok(n) if n > 0 => Some(n),
        _ => {
            eprintln!(
                "warning: ignoring {PROP_CASES_ENV}=`{raw}` \
                 (want a positive integer)"
            );
            None
        }
    }
}

/// Run `prop` against `cases` generated inputs (`ELITEKV_PROP_CASES`
/// overrides the count when set). On failure, panics with the generating
/// seed, case index, effective case count, and both environment values
/// so the exact case replays.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let env = env_seed();
    let cases = env_cases().unwrap_or(cases);
    let base_seed = fnv1a(name) ^ env;
    for case in 0..cases {
        let mut rng = Pcg64::new(base_seed, case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} of {cases} \
                 (seed {base_seed:#x}, {PROP_SEED_ENV}={env}, \
                 {PROP_CASES_ENV}={}): {msg}\ninput: {input:#?}",
                std::env::var(PROP_CASES_ENV)
                    .unwrap_or_else(|_| "unset".into()),
            );
        }
    }
}

/// FNV-1a of the property name, so each property gets a stable stream.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("sum-commutes", 32, |rng| {
            (rng.below(1000) as i64, rng.below(1000) as i64)
        }, |(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn check_reports_failure() {
        check("always-fails", 4, |rng| rng.below(10), |_| Err("nope".into()));
    }

    /// Run `f` with `PROP_CASES_ENV` set to `val` (or removed), restoring
    /// the previous value afterwards so parallel test threads see the
    /// ambient CI configuration again.
    fn with_cases_env<F: FnOnce()>(val: Option<&str>, f: F) {
        let saved = std::env::var(PROP_CASES_ENV).ok();
        match val {
            Some(v) => std::env::set_var(PROP_CASES_ENV, v),
            None => std::env::remove_var(PROP_CASES_ENV),
        }
        f();
        match saved {
            Some(v) => std::env::set_var(PROP_CASES_ENV, v),
            None => std::env::remove_var(PROP_CASES_ENV),
        }
    }

    /// Count how many cases a passing `check` call actually runs.
    fn runs_with(cases: usize) -> usize {
        let mut ran = 0usize;
        check(
            "cases-env-probe",
            cases,
            |rng| rng.below(10),
            |_| {
                ran += 1;
                Ok(())
            },
        );
        ran
    }

    #[test]
    fn cases_env_overrides_caller_count() {
        with_cases_env(Some("7"), || assert_eq!(runs_with(64), 7));
        // Unset: the caller's count stands (even when CI exported an
        // override for the rest of the run).
        with_cases_env(None, || assert_eq!(runs_with(5), 5));
        // Garbage and zero are warned about and ignored.
        with_cases_env(Some("lots"), || assert_eq!(runs_with(3), 3));
        with_cases_env(Some("0"), || assert_eq!(runs_with(3), 3));
    }
}
