//! Model conversion: MHA checkpoints -> GQA / EliteKV / S-LRD checkpoints
//! (paper §3.2 weight surgery), plus the Appendix-C dimension-allocation
//! solver. All offline, built on the in-repo Jacobi SVD — python is never
//! needed to convert a model.

pub mod allocation;
pub mod elitekv;
pub mod gqa;

pub use allocation::{enumerate_configs, AllocationCandidate};
pub use elitekv::{convert_elitekv, convert_slrd, EliteSelection};
pub use gqa::convert_gqa;
