//! Appendix-C dimension allocation: pick (r, d_ckv) for a target KV-cache
//! budget under the paper's three filters —
//!   1. hardware-friendly: d_ckv aligned (multiple of 128 on H100 tensor
//!      cores; scaled to 32/16 at our widths),
//!   2. no additional parameters: storage_cost(variant) <= storage_cost(mha),
//!   3. lower perplexity: the caller evaluates the shortlisted candidates
//!      on a holdout set and keeps the best.

use crate::config::{ModelConfig, Variant};

/// One shortlisted (r, d_ckv) configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct AllocationCandidate {
    pub variant: Variant,
    /// Exact cache elements per token per layer.
    pub cache_per_token: usize,
    /// Deviation from the requested budget (elements).
    pub budget_error: usize,
    /// KV-projection parameter delta vs. the MHA baseline (<= 0 required
    /// by the no-extra-params rule).
    pub param_delta: i64,
}

/// The alignment granularity for d_ckv at this model width (the paper's
/// multiple-of-128 rule scaled down).
pub fn alignment(cfg: &ModelConfig) -> usize {
    if cfg.d_model >= 512 {
        32
    } else {
        16
    }
}

/// Enumerate candidates whose cache/token/layer lands within `tol` of
/// `budget` elements, obeying alignment + no-extra-params. Sorted by
/// |budget error| then by more elite chunks (higher r preserves more
/// rotation capacity at equal cache).
pub fn enumerate_configs(
    cfg: &ModelConfig,
    budget: usize,
    tol: usize,
) -> Vec<AllocationCandidate> {
    let align = alignment(cfg);
    let base_cost = Variant::Mha.storage_cost(cfg) as i64;
    let mut out = Vec::new();
    let nc = cfg.n_chunks();
    for r in 1..=nc {
        let rot = 2 * r * cfg.n_heads;
        if rot >= budget + tol {
            continue;
        }
        let lo = budget.saturating_sub(tol).saturating_sub(rot);
        let hi = budget + tol - rot;
        let mut c = lo.div_ceil(align).max(1) * align;
        while c <= hi {
            let variant = Variant::EliteKv { r, d_ckv: c };
            let cache = variant.cache_per_token(cfg);
            let delta = variant.storage_cost(cfg) as i64 - base_cost;
            if delta <= 0 {
                out.push(AllocationCandidate {
                    cache_per_token: cache,
                    budget_error: cache.abs_diff(budget),
                    param_delta: delta,
                    variant,
                });
            }
            c += align;
        }
    }
    out.sort_by_key(|c| {
        (
            c.budget_error,
            std::cmp::Reverse(c.variant.r().unwrap_or(0)),
        )
    });
    out
}

/// Pick the candidate minimizing a caller-supplied objective (Appendix C's
/// "lower perplexity" filter; the objective usually runs eval_loss).
pub fn best_by<F: FnMut(&AllocationCandidate) -> f64>(
    candidates: &[AllocationCandidate],
    max_evals: usize,
    mut objective: F,
) -> Option<(AllocationCandidate, f64)> {
    candidates
        .iter()
        .take(max_evals)
        .map(|c| (c.clone(), objective(c)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_respect_all_filters() {
        let cfg = ModelConfig::small();
        let budget = cfg.kv_elems_per_token() / 4; // 25 %
        let cands = enumerate_configs(&cfg, budget, 16);
        assert!(!cands.is_empty());
        let base = Variant::Mha.storage_cost(&cfg) as i64;
        for c in &cands {
            let Variant::EliteKv { r, d_ckv } = c.variant else { panic!() };
            assert_eq!(d_ckv % alignment(&cfg), 0, "alignment");
            assert!(c.variant.storage_cost(&cfg) as i64 <= base, "params");
            assert!(c.cache_per_token.abs_diff(budget) <= 16, "budget");
            assert!(r >= 1 && r <= cfg.n_chunks());
        }
    }

    #[test]
    fn sorted_by_budget_error() {
        let cfg = ModelConfig::small();
        let cands = enumerate_configs(&cfg, 256, 32);
        for w in cands.windows(2) {
            assert!(w[0].budget_error <= w[1].budget_error);
        }
    }

    #[test]
    fn table1_points_are_enumerable() {
        // The grid used in Table 1 must appear among candidates.
        let cfg = ModelConfig::small();
        for (budget, r, c) in [(512, 16, 256), (256, 8, 128), (128, 4, 64)] {
            let cands = enumerate_configs(&cfg, budget, 8);
            assert!(
                cands
                    .iter()
                    .any(|x| x.variant == Variant::EliteKv { r, d_ckv: c }),
                "missing r={r} c={c} at budget {budget}"
            );
        }
    }

    #[test]
    fn best_by_picks_minimum() {
        let cfg = ModelConfig::small();
        let cands = enumerate_configs(&cfg, 256, 32);
        let (best, val) =
            best_by(&cands, 10, |c| c.variant.r().unwrap() as f64).unwrap();
        assert_eq!(val, best.variant.r().unwrap() as f64);
        for c in cands.iter().take(10) {
            assert!(c.variant.r().unwrap() as f64 >= val);
        }
    }

    #[test]
    fn tiny_uses_finer_alignment() {
        assert_eq!(alignment(&ModelConfig::tiny()), 16);
        assert_eq!(alignment(&ModelConfig::small()), 32);
    }
}
