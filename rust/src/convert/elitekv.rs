//! EliteKV weight surgery (paper §3.2): permute elite chunks to the front
//! of each head, split the key projection into rotated/non-rotated parts,
//! and factorize [W^k_ne | W^v] jointly (J-LRD) or separately (S-LRD).
//!
//! Layout contract shared with python/compile/lrd.py (the pytest oracle)
//! and model.py's elitekv variant:
//!   wq   — per-head columns reordered: elite chunk dims first (selection
//!          order), then non-elite ascending; chunk c = dims (2c, 2c+1)
//!   wk_e — elite column pairs of wk                  [d, nh*2r]
//!   a_kv — shared down-projection                    [d, d_ckv]
//!   b_k  — non-elite key up-projection               [d_ckv, nh*(dh-2r)]
//!   b_v  — value up-projection                       [d_ckv, nh*dh]
//! and the runtime extra theta_e[l,h,i] = base^(-e_i/nc).

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::io::Checkpoint;
use crate::linalg::svd_truncate;
use crate::tensor::Tensor;

/// Elite chunk selection: per layer, per head, `r` chunk indices in
/// greedy-selection order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EliteSelection {
    pub chunks: Vec<Vec<Vec<usize>>>, // [L][nh][r]
}

impl EliteSelection {
    pub fn r(&self) -> usize {
        self.chunks[0][0].len()
    }

    pub fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        if self.chunks.len() != cfg.n_layers {
            bail!("selection has {} layers, model {}", self.chunks.len(),
                  cfg.n_layers);
        }
        let r = self.r();
        for (l, layer) in self.chunks.iter().enumerate() {
            if layer.len() != cfg.n_heads {
                bail!("layer {l}: {} heads, model {}", layer.len(),
                      cfg.n_heads);
            }
            for (h, head) in layer.iter().enumerate() {
                if head.len() != r {
                    bail!("layer {l} head {h}: ragged r");
                }
                let mut seen = std::collections::HashSet::new();
                for &c in head {
                    if c >= cfg.n_chunks() || !seen.insert(c) {
                        bail!("layer {l} head {h}: bad chunk {c}");
                    }
                }
            }
        }
        Ok(())
    }

    /// Persist into checkpoint-compatible tensors (one [nh, r] per layer).
    pub fn to_checkpoint(&self, cfg: &ModelConfig) -> Checkpoint {
        let mut ckpt = Checkpoint::new();
        ckpt.set_meta("kind", "elite_selection");
        ckpt.set_meta("r", self.r());
        for (l, layer) in self.chunks.iter().enumerate() {
            let mut data = Vec::with_capacity(cfg.n_heads * self.r());
            for head in layer {
                data.extend(head.iter().map(|&c| c as f32));
            }
            ckpt.insert(
                &format!("elite.l{l}"),
                Tensor::new(vec![cfg.n_heads, self.r()], data),
            );
        }
        ckpt
    }

    pub fn from_checkpoint(ckpt: &Checkpoint, cfg: &ModelConfig) -> Result<EliteSelection> {
        let nc = cfg.n_chunks();
        let mut chunks = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let name = format!("elite.l{l}");
            // Missing tensor: `Checkpoint::get` names the tensor (and
            // thereby the layer) in its error.
            let t = ckpt.get(&name)?;
            if t.shape.len() != 2 || t.shape[0] != cfg.n_heads {
                bail!(
                    "selection tensor `{name}` has shape {:?}, expected \
                     [{} heads, r]",
                    t.shape,
                    cfg.n_heads
                );
            }
            let r = t.shape[1];
            if r == 0 || r > nc {
                bail!(
                    "selection tensor `{name}` has r={r}, expected \
                     1..={nc} (head_dim/2)"
                );
            }
            let mut layer = Vec::with_capacity(cfg.n_heads);
            for h in 0..cfg.n_heads {
                let mut head = Vec::with_capacity(r);
                for i in 0..r {
                    let v = t.at2(h, i);
                    // An f32->usize cast saturates (negatives become 0,
                    // huge values clamp), which would silently remap the
                    // selection — reject anything non-integral or out of
                    // the chunk range instead.
                    if v < 0.0 || v.fract() != 0.0 || v >= nc as f32 {
                        bail!(
                            "selection tensor `{name}` head {h} slot {i}: \
                             chunk index {v} outside 0..{nc} (head_dim/2)"
                        );
                    }
                    head.push(v as usize);
                }
                layer.push(head);
            }
            chunks.push(layer);
        }
        let sel = EliteSelection { chunks };
        sel.validate(cfg)?;
        Ok(sel)
    }
}

/// Column permutation for one head: elite chunk dims first (selection
/// order), then remaining chunks ascending.
pub fn head_permutation(elite: &[usize], d_head: usize) -> Vec<usize> {
    let nc = d_head / 2;
    let eset: std::collections::HashSet<usize> = elite.iter().copied().collect();
    let mut order: Vec<usize> = elite.to_vec();
    order.extend((0..nc).filter(|c| !eset.contains(c)));
    let mut cols = Vec::with_capacity(d_head);
    for c in order {
        cols.push(2 * c);
        cols.push(2 * c + 1);
    }
    cols
}

/// Apply per-head column permutations to a [d, nh*dh] projection matrix.
pub fn permute_heads(
    w: &Tensor,
    elite_l: &[Vec<usize>],
    _n_heads: usize,
    d_head: usize,
) -> Tensor {
    let idx: Vec<usize> = elite_l
        .iter()
        .enumerate()
        .flat_map(|(h, e)| {
            head_permutation(e, d_head)
                .into_iter()
                .map(move |c| h * d_head + c)
        })
        .collect();
    w.gather_cols(&idx)
}

fn copied_layers(cfg: &ModelConfig) -> [&'static str; 6] {
    let _ = cfg;
    ["attn_norm", "wo", "ffn_norm", "w1", "w2", "w3"]
}

/// Embed the selection's `elite.l<i>` tensors into a converted (or
/// uptrained) checkpoint, so serving it later can recover the exact chunk
/// order the weight permutation was built with (wrong selection =
/// silently wrong rotations).
pub fn embed_selection(out: &mut Checkpoint, cfg: &ModelConfig, elite: &EliteSelection) {
    out.set_meta("selection_r", elite.r());
    for (name, t) in elite.to_checkpoint(cfg).tensors {
        out.insert(&name, t);
    }
}

/// MHA checkpoint -> EliteKV (J-LRD) checkpoint. The elite selection is
/// embedded alongside the weights (see [`embed_selection`]).
pub fn convert_elitekv(
    cfg: &ModelConfig,
    mha: &Checkpoint,
    elite: &EliteSelection,
    d_ckv: usize,
) -> Result<Checkpoint> {
    elite.validate(cfg)?;
    let (nh, dh) = (cfg.n_heads, cfg.d_head);
    let r2 = 2 * elite.r();
    let mut out = Checkpoint::new();
    out.set_meta("config", &cfg.name);
    out.set_meta("variant", format!("elitekv_r{}_c{}", elite.r(), d_ckv));
    embed_selection(&mut out, cfg, elite);
    out.insert("embed", mha.get("embed")?.clone());
    out.insert("final_norm", mha.get("final_norm")?.clone());
    for l in 0..cfg.n_layers {
        let p = format!("l{l}.");
        let wq = permute_heads(mha.get(&format!("{p}wq"))?, &elite.chunks[l], nh, dh);
        let wk = permute_heads(mha.get(&format!("{p}wk"))?, &elite.chunks[l], nh, dh);
        // split permuted wk into elite (first 2r dims/head) and the rest
        let (e_idx, ne_idx) = split_indices(nh, dh, r2);
        let wk_e = wk.gather_cols(&e_idx);
        let wk_ne = wk.gather_cols(&ne_idx);
        let wv = mha.get(&format!("{p}wv"))?;
        let w_kv = Tensor::hcat(&[&wk_ne, wv]);
        let (a_kv, b) = svd_truncate(&w_kv, d_ckv);
        let split = nh * (dh - r2);
        out.insert(&format!("{p}wq"), wq);
        out.insert(&format!("{p}wk_e"), wk_e);
        out.insert(&format!("{p}a_kv"), a_kv);
        out.insert(&format!("{p}b_k"), b.cols(0, split));
        out.insert(&format!("{p}b_v"), b.cols(split, b.shape[1]));
        for suffix in copied_layers(cfg) {
            let name = format!("{p}{suffix}");
            out.insert(&name, mha.get(&name)?.clone());
        }
    }
    Ok(out)
}

/// MHA checkpoint -> S-LRD ablation checkpoint (separate K / V latents).
pub fn convert_slrd(
    cfg: &ModelConfig,
    mha: &Checkpoint,
    elite: &EliteSelection,
    d_ck: usize,
    d_cv: usize,
) -> Result<Checkpoint> {
    elite.validate(cfg)?;
    let (nh, dh) = (cfg.n_heads, cfg.d_head);
    let r2 = 2 * elite.r();
    let mut out = Checkpoint::new();
    out.set_meta("config", &cfg.name);
    out.set_meta(
        "variant",
        format!("slrd_r{}_ck{}_cv{}", elite.r(), d_ck, d_cv),
    );
    embed_selection(&mut out, cfg, elite);
    out.insert("embed", mha.get("embed")?.clone());
    out.insert("final_norm", mha.get("final_norm")?.clone());
    for l in 0..cfg.n_layers {
        let p = format!("l{l}.");
        let wq = permute_heads(mha.get(&format!("{p}wq"))?, &elite.chunks[l], nh, dh);
        let wk = permute_heads(mha.get(&format!("{p}wk"))?, &elite.chunks[l], nh, dh);
        let (e_idx, ne_idx) = split_indices(nh, dh, r2);
        let wk_e = wk.gather_cols(&e_idx);
        let wk_ne = wk.gather_cols(&ne_idx);
        let (a_k, b_k) = svd_truncate(&wk_ne, d_ck);
        let (a_v, b_v) = svd_truncate(mha.get(&format!("{p}wv"))?, d_cv);
        out.insert(&format!("{p}wq"), wq);
        out.insert(&format!("{p}wk_e"), wk_e);
        out.insert(&format!("{p}a_k"), a_k);
        out.insert(&format!("{p}b_k"), b_k);
        out.insert(&format!("{p}a_v"), a_v);
        out.insert(&format!("{p}b_v"), b_v);
        for suffix in copied_layers(cfg) {
            let name = format!("{p}{suffix}");
            out.insert(&name, mha.get(&name)?.clone());
        }
    }
    Ok(out)
}

/// Column indices of the elite (first 2r dims of each head) and non-elite
/// parts of an already-permuted [d, nh*dh] matrix.
fn split_indices(nh: usize, dh: usize, r2: usize) -> (Vec<usize>, Vec<usize>) {
    let mut e = Vec::with_capacity(nh * r2);
    let mut ne = Vec::with_capacity(nh * (dh - r2));
    for h in 0..nh {
        for c in 0..dh {
            if c < r2 {
                e.push(h * dh + c);
            } else {
                ne.push(h * dh + c);
            }
        }
    }
    (e, ne)
}

/// theta_e extra [L, nh, r] flat, matching the selection order.
pub fn elite_thetas_flat(cfg: &ModelConfig, elite: &EliteSelection) -> Vec<f32> {
    crate::rope::elite_thetas(cfg, &elite.chunks)
}

/// elite_mask extra [L, nh, nc] flat.
pub fn elite_mask_flat(cfg: &ModelConfig, elite: &EliteSelection) -> Vec<f32> {
    crate::rope::elite_mask(cfg, &elite.chunks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn fake_mha(cfg: &ModelConfig, seed: u64) -> Checkpoint {
        let mut rng = Pcg64::seeded(seed);
        let mut ckpt = Checkpoint::new();
        let d = cfg.d_model;
        ckpt.insert("embed", Tensor::randn(vec![cfg.vocab, d], &mut rng));
        ckpt.insert("final_norm", Tensor::randn(vec![d], &mut rng));
        for l in 0..cfg.n_layers {
            let p = format!("l{l}.");
            let w = cfg.n_heads * cfg.d_head;
            ckpt.insert(&format!("{p}attn_norm"), Tensor::randn(vec![d], &mut rng));
            ckpt.insert(&format!("{p}wq"), Tensor::randn(vec![d, w], &mut rng));
            ckpt.insert(&format!("{p}wk"), Tensor::randn(vec![d, w], &mut rng));
            ckpt.insert(&format!("{p}wv"), Tensor::randn(vec![d, w], &mut rng));
            ckpt.insert(&format!("{p}wo"), Tensor::randn(vec![w, d], &mut rng));
            ckpt.insert(&format!("{p}ffn_norm"), Tensor::randn(vec![d], &mut rng));
            ckpt.insert(&format!("{p}w1"), Tensor::randn(vec![d, cfg.d_ffn], &mut rng));
            ckpt.insert(&format!("{p}w2"), Tensor::randn(vec![cfg.d_ffn, d], &mut rng));
            ckpt.insert(&format!("{p}w3"), Tensor::randn(vec![d, cfg.d_ffn], &mut rng));
        }
        ckpt
    }

    fn sel(cfg: &ModelConfig, r: usize, seed: u64) -> EliteSelection {
        let mut rng = Pcg64::seeded(seed);
        let nc = cfg.n_chunks();
        let chunks = (0..cfg.n_layers)
            .map(|_| {
                (0..cfg.n_heads)
                    .map(|_| {
                        let mut all: Vec<usize> = (0..nc).collect();
                        rng.shuffle(&mut all);
                        all.truncate(r);
                        all
                    })
                    .collect()
            })
            .collect();
        EliteSelection { chunks }
    }

    fn tiny() -> ModelConfig {
        ModelConfig::tiny()
    }

    use crate::config::ModelConfig;

    #[test]
    fn head_permutation_is_complete() {
        let perm = head_permutation(&[3, 0, 7], 32);
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..32).collect::<Vec<_>>());
        assert_eq!(&perm[..6], &[6, 7, 0, 1, 14, 15]);
    }

    #[test]
    fn selection_roundtrip_through_checkpoint() {
        let cfg = tiny();
        let s = sel(&cfg, 4, 1);
        let ckpt = s.to_checkpoint(&cfg);
        let back = EliteSelection::from_checkpoint(&ckpt, &cfg).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn missing_selection_tensor_error_names_the_layer() {
        let cfg = tiny();
        let s = sel(&cfg, 4, 21);
        let mut ckpt = s.to_checkpoint(&cfg);
        ckpt.tensors.remove("elite.l2");
        let err = EliteSelection::from_checkpoint(&ckpt, &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("elite.l2"), "{err}");
    }

    #[test]
    fn wrong_arity_selection_tensor_error_names_the_layer() {
        let cfg = tiny();
        let s = sel(&cfg, 4, 22);
        // rank-1 tensor
        let mut ckpt = s.to_checkpoint(&cfg);
        ckpt.insert("elite.l1", Tensor::new(vec![4], vec![0., 1., 2., 3.]));
        let err = EliteSelection::from_checkpoint(&ckpt, &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("elite.l1"), "{err}");
        // wrong head count
        let mut ckpt = s.to_checkpoint(&cfg);
        ckpt.insert(
            "elite.l3",
            Tensor::new(vec![2, 2], vec![0., 1., 2., 3.]),
        );
        let err = EliteSelection::from_checkpoint(&ckpt, &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("elite.l3"), "{err}");
        // r wider than the chunk ladder
        let nc = cfg.n_chunks();
        let mut ckpt = s.to_checkpoint(&cfg);
        let wide: Vec<f32> =
            (0..cfg.n_heads * (nc + 1)).map(|i| (i % nc) as f32).collect();
        ckpt.insert(
            "elite.l0",
            Tensor::new(vec![cfg.n_heads, nc + 1], wide),
        );
        let err = EliteSelection::from_checkpoint(&ckpt, &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("elite.l0"), "{err}");
    }

    #[test]
    fn out_of_range_chunk_index_rejected_not_wrapped() {
        let cfg = tiny();
        let nc = cfg.n_chunks();
        let mut s = sel(&cfg, 4, 23);
        // index == head_dim/2 is one past the last chunk
        s.chunks[1][0][0] = nc;
        let ckpt = s.to_checkpoint(&cfg);
        let err = EliteSelection::from_checkpoint(&ckpt, &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("elite.l1"), "{err}");
        // a negative index must not saturate to chunk 0 silently
        let s2 = sel(&cfg, 4, 24);
        let mut ckpt = s2.to_checkpoint(&cfg);
        let t = ckpt.tensors.get_mut("elite.l0").unwrap();
        t.data[0] = -1.0;
        let err = EliteSelection::from_checkpoint(&ckpt, &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("elite.l0"), "{err}");
    }

    #[test]
    fn selection_validation_rejects_bad() {
        let cfg = tiny();
        let mut s = sel(&cfg, 4, 2);
        s.chunks[0][0][1] = s.chunks[0][0][0]; // duplicate
        assert!(s.validate(&cfg).is_err());
        let mut s2 = sel(&cfg, 4, 3);
        s2.chunks[1][2][0] = cfg.n_chunks(); // out of range
        assert!(s2.validate(&cfg).is_err());
    }

    #[test]
    fn convert_shapes_match_manifest_contract() {
        let cfg = tiny();
        let mha = fake_mha(&cfg, 4);
        let s = sel(&cfg, 4, 5);
        let out = convert_elitekv(&cfg, &mha, &s, 64).unwrap();
        let (nh, dh, d) = (cfg.n_heads, cfg.d_head, cfg.d_model);
        assert_eq!(out.get("l0.wk_e").unwrap().shape, vec![d, nh * 8]);
        assert_eq!(out.get("l0.a_kv").unwrap().shape, vec![d, 64]);
        assert_eq!(out.get("l0.b_k").unwrap().shape, vec![64, nh * (dh - 8)]);
        assert_eq!(out.get("l0.b_v").unwrap().shape, vec![64, nh * dh]);
        assert_eq!(out.get("l0.wq").unwrap().shape, vec![d, nh * dh]);
    }

    #[test]
    fn full_rank_jlrd_reconstructs_wkv_exactly() {
        // At full rank, a_kv @ [b_k | b_v] must equal [wk_ne | wv].
        let cfg = tiny();
        let mha = fake_mha(&cfg, 6);
        let s = sel(&cfg, 4, 7);
        let d_full = cfg.d_model; // d < total cols, so rank d is full
        let out = convert_elitekv(&cfg, &mha, &s, d_full).unwrap();
        for l in 0..cfg.n_layers {
            let p = format!("l{l}.");
            let a = out.get(&format!("{p}a_kv")).unwrap();
            let bk = out.get(&format!("{p}b_k")).unwrap();
            let bv = out.get(&format!("{p}b_v")).unwrap();
            let rec = a.matmul(&Tensor::hcat(&[bk, bv]));
            // reference: permuted wk non-elite part + wv
            let wk = permute_heads(
                mha.get(&format!("{p}wk")).unwrap(),
                &s.chunks[l], cfg.n_heads, cfg.d_head,
            );
            let (_e, ne) = split_indices(cfg.n_heads, cfg.d_head, 8);
            let want = Tensor::hcat(&[
                &wk.gather_cols(&ne),
                mha.get(&format!("{p}wv")).unwrap(),
            ]);
            let diff = rec.max_abs_diff(&want);
            assert!(diff < 2e-3, "layer {l}: {diff}");
        }
    }

    #[test]
    fn truncation_error_decreases_with_rank() {
        let cfg = tiny();
        let mha = fake_mha(&cfg, 8);
        let s = sel(&cfg, 4, 9);
        let mut errs = Vec::new();
        for rank in [16usize, 64, 128, 256] {
            let out = convert_elitekv(&cfg, &mha, &s, rank).unwrap();
            let a = out.get("l0.a_kv").unwrap();
            let bk = out.get("l0.b_k").unwrap();
            let bv = out.get("l0.b_v").unwrap();
            let rec = a.matmul(&Tensor::hcat(&[bk, bv]));
            let wk = permute_heads(mha.get("l0.wk").unwrap(), &s.chunks[0],
                                   cfg.n_heads, cfg.d_head);
            let (_e, ne) = split_indices(cfg.n_heads, cfg.d_head, 8);
            let want =
                Tensor::hcat(&[&wk.gather_cols(&ne), mha.get("l0.wv").unwrap()]);
            errs.push(rec.sub(&want).fro());
        }
        for w in errs.windows(2) {
            assert!(w[0] > w[1] - 1e-4, "{errs:?}");
        }
    }

    #[test]
    fn converted_checkpoints_embed_their_selection() {
        let cfg = tiny();
        let mha = fake_mha(&cfg, 13);
        let s = sel(&cfg, 4, 14);
        let out = convert_elitekv(&cfg, &mha, &s, 32).unwrap();
        let back = EliteSelection::from_checkpoint(&out, &cfg).unwrap();
        assert_eq!(back, s);
        assert_eq!(out.meta["selection_r"], "4");
        let out_s = convert_slrd(&cfg, &mha, &s, 16, 16).unwrap();
        assert_eq!(EliteSelection::from_checkpoint(&out_s, &cfg).unwrap(), s);
    }

    #[test]
    fn slrd_shapes() {
        let cfg = tiny();
        let mha = fake_mha(&cfg, 10);
        let s = sel(&cfg, 4, 11);
        let out = convert_slrd(&cfg, &mha, &s, 32, 48).unwrap();
        assert_eq!(out.get("l0.a_k").unwrap().shape, vec![cfg.d_model, 32]);
        assert_eq!(out.get("l0.a_v").unwrap().shape, vec![cfg.d_model, 48]);
        assert_eq!(out.get("l0.b_k").unwrap().shape,
                   vec![32, cfg.n_heads * (cfg.d_head - 8)]);
        assert_eq!(out.get("l0.b_v").unwrap().shape,
                   vec![48, cfg.n_heads * cfg.d_head]);
    }

    #[test]
    fn thetas_match_selection_order() {
        let cfg = tiny();
        let s = sel(&cfg, 3, 12);
        let t = elite_thetas_flat(&cfg, &s);
        let nc = cfg.n_chunks();
        // spot-check layer 1, head 2, slot 0
        let c = s.chunks[1][2][0];
        let want = cfg.rope_base.powf(-(c as f64) / nc as f64) as f32;
        let idx = (1 * cfg.n_heads + 2) * 3;
        assert!((t[idx] - want).abs() < 1e-7);
    }
}
