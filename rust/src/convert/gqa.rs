//! GQA conversion baseline (Ainslie et al. 2023): mean-pool the K/V
//! projections of each head group of a trained MHA checkpoint.

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::io::Checkpoint;
use crate::tensor::Tensor;

/// MHA checkpoint -> GQA checkpoint with `n_kv_heads` grouped KV heads.
pub fn convert_gqa(
    cfg: &ModelConfig,
    mha: &Checkpoint,
    n_kv_heads: usize,
) -> Result<Checkpoint> {
    if n_kv_heads == 0 || cfg.n_heads % n_kv_heads != 0 {
        bail!("n_kv_heads {n_kv_heads} must divide n_heads {}", cfg.n_heads);
    }
    let mut out = mha.clone();
    out.set_meta("config", &cfg.name);
    out.set_meta("variant", format!("gqa{n_kv_heads}"));
    for l in 0..cfg.n_layers {
        for w in ["wk", "wv"] {
            let name = format!("l{l}.{w}");
            out.insert(&name, pool_heads(mha.get(&name)?, cfg, n_kv_heads));
        }
    }
    Ok(out)
}

/// Mean-pool a [d, nh*dh] projection into [d, g*dh].
fn pool_heads(w: &Tensor, cfg: &ModelConfig, g: usize) -> Tensor {
    let (d, nh, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head);
    let rep = nh / g;
    let mut out = Tensor::zeros(vec![d, g * dh]);
    let scale = 1.0 / rep as f32;
    for i in 0..d {
        for grp in 0..g {
            for c in 0..dh {
                let mut acc = 0.0f32;
                for r in 0..rep {
                    acc += w.at2(i, (grp * rep + r) * dh + c);
                }
                out.set2(i, grp * dh + c, acc * scale);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn cfg() -> ModelConfig {
        ModelConfig::tiny()
    }

    fn fake(c: &ModelConfig) -> Checkpoint {
        let mut rng = Pcg64::seeded(30);
        let mut ckpt = Checkpoint::new();
        let w = c.n_heads * c.d_head;
        ckpt.insert("embed", Tensor::randn(vec![c.vocab, c.d_model], &mut rng));
        ckpt.insert("final_norm", Tensor::randn(vec![c.d_model], &mut rng));
        for l in 0..c.n_layers {
            for (n, shape) in [
                ("attn_norm", vec![c.d_model]),
                ("wq", vec![c.d_model, w]),
                ("wk", vec![c.d_model, w]),
                ("wv", vec![c.d_model, w]),
                ("wo", vec![w, c.d_model]),
                ("ffn_norm", vec![c.d_model]),
                ("w1", vec![c.d_model, c.d_ffn]),
                ("w2", vec![c.d_ffn, c.d_model]),
                ("w3", vec![c.d_model, c.d_ffn]),
            ] {
                ckpt.insert(&format!("l{l}.{n}"), Tensor::randn(shape, &mut rng));
            }
        }
        ckpt
    }

    #[test]
    fn full_groups_is_identity() {
        let c = cfg();
        let mha = fake(&c);
        let out = convert_gqa(&c, &mha, c.n_heads).unwrap();
        assert_eq!(out.get("l0.wk").unwrap().max_abs_diff(
            mha.get("l0.wk").unwrap()), 0.0);
    }

    #[test]
    fn pooled_shapes_and_mean() {
        let c = cfg();
        let mha = fake(&c);
        let g = 2;
        let out = convert_gqa(&c, &mha, g).unwrap();
        let wk = out.get("l1.wk").unwrap();
        assert_eq!(wk.shape, vec![c.d_model, g * c.d_head]);
        // spot-check one pooled element
        let orig = mha.get("l1.wk").unwrap();
        let rep = c.n_heads / g;
        let mut want = 0.0;
        for r in 0..rep {
            want += orig.at2(3, (0 * rep + r) * c.d_head + 5);
        }
        want /= rep as f32;
        assert!((wk.at2(3, 5) - want).abs() < 1e-6);
    }

    #[test]
    fn rejects_nondivisor_groups() {
        let c = cfg();
        let mha = fake(&c);
        assert!(convert_gqa(&c, &mha, 3).is_err());
        assert!(convert_gqa(&c, &mha, 0).is_err());
    }

    #[test]
    fn q_and_ffn_untouched() {
        let c = cfg();
        let mha = fake(&c);
        let out = convert_gqa(&c, &mha, 2).unwrap();
        for n in ["l0.wq", "l0.wo", "l0.w1", "embed"] {
            assert_eq!(out.get(n).unwrap().max_abs_diff(mha.get(n).unwrap()),
                       0.0, "{n}");
        }
    }
}
