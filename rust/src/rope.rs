//! Host-side RoPE math: frequency ladders, elite-theta tables, rotations.
//!
//! The heavy lifting runs inside the HLO artifacts; this module supplies
//! the *tables* those artifacts consume (the `theta_e` extra for the
//! elitekv/slrd variants, the `elite_mask` for ropelite) and a reference
//! rotation used by the kv-cache tests.

use crate::config::ModelConfig;

/// theta_i = base^(-i / nc) for chunk i (paper §2.2 ladder).
pub fn chunk_theta(base: f64, chunk: usize, n_chunks: usize) -> f64 {
    base.powf(-(chunk as f64) / n_chunks as f64)
}

/// Full frequency ladder for a head: [nc].
pub fn ladder(base: f64, n_chunks: usize) -> Vec<f64> {
    (0..n_chunks).map(|i| chunk_theta(base, i, n_chunks)).collect()
}

/// Build the `theta_e` extra [L, nh, r] (row-major flat) from elite chunk
/// indices [L, nh, r].
pub fn elite_thetas(cfg: &ModelConfig, elite: &[Vec<Vec<usize>>]) -> Vec<f32> {
    let nc = cfg.n_chunks();
    let mut out = Vec::new();
    for layer in elite {
        for head in layer {
            for &c in head {
                out.push(chunk_theta(cfg.rope_base, c, nc) as f32);
            }
        }
    }
    out
}

/// Build the `elite_mask` extra [L, nh, nc] (row-major flat) from elite
/// chunk indices.
pub fn elite_mask(cfg: &ModelConfig, elite: &[Vec<Vec<usize>>]) -> Vec<f32> {
    let nc = cfg.n_chunks();
    let mut out = vec![0.0f32; cfg.n_layers * cfg.n_heads * nc];
    for (l, layer) in elite.iter().enumerate() {
        for (h, head) in layer.iter().enumerate() {
            for &c in head {
                debug_assert!(c < nc);
                out[(l * cfg.n_heads + h) * nc + c] = 1.0;
            }
        }
    }
    out
}

/// Rotate one head vector's chunk `c` at position `pos` (reference math
/// for tests): dims (2c, 2c+1).
pub fn rotate_chunk(x: &mut [f32], c: usize, theta: f64, pos: i64) {
    let ang = pos as f64 * theta;
    let (sin, cos) = ang.sin_cos();
    let (x0, x1) = (x[2 * c] as f64, x[2 * c + 1] as f64);
    x[2 * c] = (x0 * cos - x1 * sin) as f32;
    x[2 * c + 1] = (x0 * sin + x1 * cos) as f32;
}

/// The `Uniform` baseline (paper §4.3.1): r chunks evenly spaced over the
/// ladder, identical for every head.
pub fn uniform_chunks(n_chunks: usize, r: usize) -> Vec<usize> {
    assert!(r >= 1 && r <= n_chunks);
    if r == 1 {
        return vec![0];
    }
    (0..r)
        .map(|i| {
            ((i as f64) * (n_chunks - 1) as f64 / (r - 1) as f64).round()
                as usize
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_descends_from_one() {
        let l = ladder(10000.0, 16);
        assert!((l[0] - 1.0).abs() < 1e-12);
        for w in l.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    #[test]
    fn rotation_preserves_norm() {
        let mut x = vec![3.0, 4.0];
        rotate_chunk(&mut x, 0, 0.123, 77);
        let n = (x[0] * x[0] + x[1] * x[1]).sqrt();
        assert!((n - 5.0).abs() < 1e-5);
    }

    #[test]
    fn rotation_relative_position_property() {
        // (R(m t) q) . (R(n t) k) == q . (R((n - m) t) k)
        let theta = 0.37;
        let q0 = [1.2f32, -0.7];
        let k0 = [0.4f32, 2.2];
        let (m, n) = (9i64, 4i64);
        let mut qm = q0;
        let mut kn = k0;
        rotate_chunk(&mut qm, 0, theta, m);
        rotate_chunk(&mut kn, 0, theta, n);
        let lhs = qm[0] * kn[0] + qm[1] * kn[1];
        let mut krel = k0;
        rotate_chunk(&mut krel, 0, theta, n - m);
        let rhs = q0[0] * krel[0] + q0[1] * krel[1];
        assert!((lhs - rhs).abs() < 1e-5, "{lhs} vs {rhs}");
    }

    #[test]
    fn mask_marks_exactly_r_chunks_per_head() {
        let cfg = ModelConfig::tiny();
        let elite = vec![
            vec![vec![0usize, 3, 7]; cfg.n_heads];
            cfg.n_layers
        ];
        let m = elite_mask(&cfg, &elite);
        let nc = cfg.n_chunks();
        for lh in 0..cfg.n_layers * cfg.n_heads {
            let row = &m[lh * nc..(lh + 1) * nc];
            assert_eq!(row.iter().filter(|&&x| x == 1.0).count(), 3);
            assert_eq!(row[0], 1.0);
            assert_eq!(row[3], 1.0);
            assert_eq!(row[7], 1.0);
        }
    }

    #[test]
    fn thetas_follow_ladder() {
        let cfg = ModelConfig::tiny();
        let elite = vec![vec![vec![0usize, 5]; cfg.n_heads]; cfg.n_layers];
        let t = elite_thetas(&cfg, &elite);
        assert!((t[0] as f64 - 1.0).abs() < 1e-9);
        let want = chunk_theta(cfg.rope_base, 5, cfg.n_chunks());
        assert!((t[1] as f64 - want).abs() < 1e-9);
    }

    #[test]
    fn uniform_chunks_span_ladder() {
        assert_eq!(uniform_chunks(16, 4), vec![0, 5, 10, 15]);
        assert_eq!(uniform_chunks(16, 1), vec![0]);
        assert_eq!(uniform_chunks(16, 16),
                   (0..16).collect::<Vec<_>>());
    }
}
