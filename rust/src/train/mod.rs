//! Training engine: pretraining and uptraining loops (paper §4.1) plus
//! the probe-battery scorer that produces the Table-1/2 columns.
//!
//! The scorer is backend-agnostic (native or PJRT); the train loops drive
//! the in-graph AdamW artifact and therefore require `--features pjrt`.

pub mod scorer;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use scorer::{score_probes, ScoreReport};
#[cfg(feature = "pjrt")]
pub use trainer::{TrainLoop, TrainOpts, TrainReport};
