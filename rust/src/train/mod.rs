//! Training engine: pretraining and uptraining loops (paper §4.1) plus
//! the probe-battery scorer that produces the Table-1/2 columns.

pub mod scorer;
pub mod trainer;

pub use scorer::{score_probes, ScoreReport};
pub use trainer::{TrainLoop, TrainOpts, TrainReport};
