//! Probe-battery scorer: greedy decoding through any serving backend,
//! exact-match accuracy per task — the machinery behind every "Avg."
//! column in the reproduced tables. Backend-agnostic: runs on the native
//! decode path with zero artifacts, or through PJRT with `--features
//! pjrt`.

use anyhow::{bail, Result};

use crate::data::probes::{ProbeSet, Scores};
use crate::runtime::{backend, Backend};

/// Scores plus the holdout perplexity measured alongside them.
#[derive(Clone, Debug)]
pub struct ScoreReport {
    pub scores: Scores,
    pub ppl: f64,
    pub n_items: usize,
}

/// Greedy-decode every probe and compute exact-match accuracies.
///
/// Items are multiplexed onto the backend's fixed decode lanes in groups;
/// lanes beyond the last item decode a masked dummy.
pub fn score_probes(
    backend: &dyn Backend,
    probes: &ProbeSet,
) -> Result<Scores> {
    let (b, s) = backend.serve_shape()?;
    let vocab = backend.config().vocab;
    let mut passed = Vec::with_capacity(probes.items.len());
    for group in probes.items.chunks(b) {
        let mut tokens = vec![0i32; b * s];
        let mut lens = vec![1i32; b]; // dummy lanes attend to one pad token
        for (lane, item) in group.iter().enumerate() {
            if item.prompt.len() + item.answer.len() >= s {
                bail!("probe longer than serving window");
            }
            for (i, &t) in item.prompt.iter().enumerate() {
                tokens[lane * s + i] = t as i32;
            }
            lens[lane] = item.prompt.len() as i32;
        }
        let (mut logits, mut caches) = backend.prefill(&tokens, &lens)?;
        let steps = group.iter().map(|i| i.answer.len()).max().unwrap_or(0);
        let mut ok = vec![true; group.len()];
        let mut pos: Vec<i32> = lens.clone();
        for step in 0..steps {
            // greedy pick per lane
            let l = logits.as_f32()?;
            let mut next = vec![0i32; b];
            for lane in 0..b {
                let row = &l[lane * vocab..(lane + 1) * vocab];
                let (arg, _) = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap();
                next[lane] = arg as i32;
            }
            for (lane, item) in group.iter().enumerate() {
                if step < item.answer.len()
                    && next[lane] != item.answer[step] as i32
                {
                    ok[lane] = false;
                }
            }
            if step + 1 < steps {
                let (lg, cs) = backend.decode(&next, &pos, caches, false)?;
                logits = lg;
                caches = cs;
                for p in pos.iter_mut() {
                    *p += 1;
                }
            }
        }
        passed.extend(ok);
    }
    Ok(probes.score(&passed))
}

/// Probes + perplexity in one call (the standard evaluation bundle).
pub fn full_report(
    be: &dyn Backend,
    probes: &ProbeSet,
    ppl_batches: usize,
) -> Result<ScoreReport> {
    let mut gen = crate::data::CorpusGen::new(be.config().vocab, 1);
    gen.reseed(1, 0xe7a1); // the shared holdout stream (see trainer)
    let ppl = backend::perplexity(be, &mut gen, ppl_batches)?;
    let scores = score_probes(be, probes)?;
    Ok(ScoreReport { scores, ppl, n_items: probes.items.len() })
}
