//! Training loop around the in-graph AdamW step (paper §4.1: constant LR,
//! beta = [0.9, 0.95], weight decay 0.1 — all baked into the artifact).

use std::time::Instant;

use anyhow::Result;

use crate::data::CorpusGen;
use crate::runtime::{ModelRunner, TrainState};
use crate::util::stats::Ema;

/// Options for one training run.
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub steps: usize,
    pub lr: f32,
    /// Evaluate perplexity every `eval_every` steps (0 = only at the end).
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Log to stdout every `log_every` steps (0 = silent).
    pub log_every: usize,
    /// Seed for the training stream (eval uses an independent stream).
    pub data_seed: u64,
}

impl Default for TrainOpts {
    fn default() -> TrainOpts {
        TrainOpts {
            steps: 100,
            lr: 1e-3,
            eval_every: 0,
            eval_batches: 4,
            log_every: 20,
            data_seed: 1,
        }
    }
}

/// One loss/ppl observation along the run.
#[derive(Clone, Debug)]
pub struct TrainPoint {
    pub step: usize,
    pub tokens: usize,
    pub loss: f64,
    pub ppl: Option<f64>,
}

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub points: Vec<TrainPoint>,
    pub final_loss: f64,
    pub final_ppl: f64,
    pub tokens_seen: usize,
    pub seconds: f64,
}

/// Owns the corpus streams and drives train_step/eval_loss artifacts.
pub struct TrainLoop<'a> {
    pub runner: &'a ModelRunner,
    train_gen: CorpusGen,
}

impl<'a> TrainLoop<'a> {
    pub fn new(runner: &'a ModelRunner, opts: &TrainOpts) -> TrainLoop<'a> {
        let vocab = runner.manifest.config.vocab;
        // NOTE: world seed is fixed at 1 for every run so that pretraining,
        // uptraining, and evaluation all share one fact table; only the
        // sentence stream varies with data_seed.
        let mut train_gen = CorpusGen::new(vocab, 1);
        train_gen.reseed(opts.data_seed, 0x7261_494e); // train stream
        TrainLoop { runner, train_gen }
    }

    /// Fresh holdout generator (same world, eval stream).
    pub fn holdout(&self) -> CorpusGen {
        let mut g = CorpusGen::new(self.runner.manifest.config.vocab, 1);
        // reuse the eval stream id so every caller sees the same holdout
        g.reseed(1, 0xe7a1);
        g
    }

    /// Run `opts.steps` steps of AdamW, mutating `state`.
    pub fn run(
        &mut self,
        state: &mut TrainState,
        opts: &TrainOpts,
    ) -> Result<TrainReport> {
        let (b, t) = self.runner.train_shape()?;
        let started = Instant::now();
        let mut ema = Ema::new(0.1);
        let mut points = Vec::new();
        let mut tokens = 0usize;
        let mut last_loss = f64::NAN;
        for i in 1..=opts.steps {
            let batch = self.train_gen.next_batch(b, t);
            let (loss, gnorm) = self.runner.train_step(state, &batch, opts.lr)?;
            anyhow::ensure!(
                loss.is_finite() && gnorm.is_finite(),
                "divergence at step {i}: loss={loss} gnorm={gnorm}"
            );
            tokens += b * t;
            last_loss = ema.push(loss as f64);
            let want_eval = opts.eval_every > 0 && i % opts.eval_every == 0;
            if want_eval {
                let ppl = self.eval_ppl(state, opts.eval_batches)?;
                points.push(TrainPoint { step: i, tokens, loss: last_loss,
                                         ppl: Some(ppl) });
            } else if opts.log_every > 0 && i % opts.log_every == 0 {
                points.push(TrainPoint { step: i, tokens, loss: last_loss,
                                         ppl: None });
            }
            if opts.log_every > 0 && i % opts.log_every == 0 {
                log::info!(
                    "step {i}/{} loss {last_loss:.4} gnorm {gnorm:.3} \
                     ({:.2} s/step)",
                    opts.steps,
                    started.elapsed().as_secs_f64() / i as f64
                );
            }
        }
        let final_ppl = self.eval_ppl(state, opts.eval_batches)?;
        Ok(TrainReport {
            points,
            final_loss: last_loss,
            final_ppl,
            tokens_seen: tokens,
            seconds: started.elapsed().as_secs_f64(),
        })
    }

    /// Holdout perplexity for the current parameters (fresh stream each
    /// call, so every evaluation sees the same held-out distribution).
    pub fn eval_ppl(&mut self, state: &TrainState, batches: usize) -> Result<f64> {
        let mut gen = self.holdout();
        self.runner.perplexity(&state.params, &mut gen, batches)
    }
}

impl ModelRunner {
    pub fn train_shape(&self) -> Result<(usize, usize)> {
        self.manifest.train_shape()
    }
}
