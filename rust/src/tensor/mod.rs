//! Minimal CPU f32 tensor — just enough linear algebra for weight surgery
//! (conversion), checkpoint manipulation, and host-side verification.
//!
//! Row-major dense storage. Not a performance path: the model's compute
//! runs inside XLA; this backs the *offline* converter and tests.

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape/data mismatch: {shape:?} vs {}", data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(x: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![x] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// 2-D accessor (matrix view).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.rank(), 2);
        let cols = self.shape[1];
        self.data[i * cols + j] = v;
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Matrix transpose (2-D only).
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(vec![c, r], out)
    }

    /// Matrix multiply (2-D x 2-D), blocked over k for cache friendliness.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dim mismatch");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            let o_row = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    o_row[j] += a * b_row[j];
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    /// Horizontal concat of 2-D matrices (equal rows).
    pub fn hcat(mats: &[&Tensor]) -> Tensor {
        assert!(!mats.is_empty());
        let rows = mats[0].shape[0];
        let total: usize = mats.iter().map(|m| {
            assert_eq!(m.rank(), 2);
            assert_eq!(m.shape[0], rows);
            m.shape[1]
        }).sum();
        let mut out = vec![0.0f32; rows * total];
        for i in 0..rows {
            let mut off = 0;
            for m in mats {
                let c = m.shape[1];
                out[i * total + off..i * total + off + c]
                    .copy_from_slice(&m.data[i * c..(i + 1) * c]);
                off += c;
            }
        }
        Tensor::new(vec![rows, total], out)
    }

    /// Column slice [lo, hi) of a 2-D matrix.
    pub fn cols(&self, lo: usize, hi: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        assert!(lo <= hi && hi <= c);
        let w = hi - lo;
        let mut out = vec![0.0f32; r * w];
        for i in 0..r {
            out[i * w..(i + 1) * w]
                .copy_from_slice(&self.data[i * c + lo..i * c + hi]);
        }
        Tensor::new(vec![r, w], out)
    }

    /// Gather columns of a 2-D matrix by index list.
    pub fn gather_cols(&self, idx: &[usize]) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let w = idx.len();
        let mut out = vec![0.0f32; r * w];
        for i in 0..r {
            for (jj, &j) in idx.iter().enumerate() {
                debug_assert!(j < c);
                out[i * w + jj] = self.data[i * c + j];
            }
        }
        Tensor::new(vec![r, w], out)
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor::new(
            self.shape.clone(),
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        )
    }

    pub fn scale(&self, s: f32) -> Tensor {
        Tensor::new(self.shape.clone(), self.data.iter().map(|x| x * s).collect())
    }

    /// Random normal tensor (testing / synthetic workloads).
    pub fn randn(shape: Vec<usize>, rng: &mut crate::util::Pcg64) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.normal() as f32).collect())
    }

    /// Maximum absolute difference (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::seeded(1);
        let a = Tensor::randn(vec![5, 7], &mut rng);
        let mut eye = Tensor::zeros(vec![7, 7]);
        for i in 0..7 {
            eye.set2(i, i, 1.0);
        }
        let out = a.matmul(&eye);
        assert!(a.max_abs_diff(&out) < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(vec![2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(2);
        let a = Tensor::randn(vec![3, 9], &mut rng);
        assert!(a.max_abs_diff(&a.t().t()) < 1e-9);
    }

    #[test]
    fn hcat_and_cols_roundtrip() {
        let mut rng = Pcg64::seeded(3);
        let a = Tensor::randn(vec![4, 3], &mut rng);
        let b = Tensor::randn(vec![4, 5], &mut rng);
        let cat = Tensor::hcat(&[&a, &b]);
        assert_eq!(cat.shape, vec![4, 8]);
        assert!(cat.cols(0, 3).max_abs_diff(&a) < 1e-9);
        assert!(cat.cols(3, 8).max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn gather_cols_permutation() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let g = a.gather_cols(&[2, 0, 1]);
        assert_eq!(g.data, vec![3., 1., 2., 6., 4., 5.]);
    }

    #[test]
    fn fro_norm() {
        let a = Tensor::new(vec![1, 2], vec![3.0, 4.0]);
        assert!((a.fro() - 5.0).abs() < 1e-9);
    }
}
