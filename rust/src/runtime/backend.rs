//! The `Backend` trait: one serving contract, two engines (DESIGN.md §5).
//!
//! Every consumer above the runtime — the continuous-batching
//! [`crate::coordinator::InferenceServer`], the probe scorer, the bench
//! harness, the CLI — talks to this trait instead of a concrete engine.
//! Two implementations exist:
//!
//! * [`crate::native::NativeRunner`] — the pure-Rust decode path. Always
//!   available; needs no Python, no HLO artifacts, no XLA toolchain.
//! * `PjrtBackend` / `PjrtView` (feature `pjrt`) — the AOT path wrapping
//!   `crate::runtime::ModelRunner`, executing HLO-text artifacts
//!   through the PJRT CPU client.
//!
//! The cache contract is shared: `prefill` returns per-variant cache
//! slabs shaped `[L, B, S, ...]` (see `kvcache::layout::slab_specs`) and
//! `decode` consumes/returns the same slabs, so the coordinator's lane
//! splicing is backend-agnostic.

use anyhow::Result;

use crate::config::{ModelConfig, Variant};
use crate::data::corpus::Batch;
use crate::kvcache::CacheDtype;
use crate::runtime::HostTensor;

/// A serving engine for one (config, variant) model.
pub trait Backend {
    /// Short backend identifier ("native" / "pjrt") for logs and reports.
    fn kind(&self) -> &'static str;

    /// The model geometry this engine serves.
    fn config(&self) -> &ModelConfig;

    /// The architecture variant this engine serves (determines the
    /// cache slab layout and the per-token rotation scheme).
    fn variant(&self) -> &Variant;

    /// Element storage of this engine's cache slabs (DESIGN.md S19).
    /// The scheduler sizes its block pool from this (int8 quarters
    /// `bytes_per_token`, quadrupling blocks under one byte budget) and
    /// the radix cache stores rows in it. Only the native runner
    /// supports [`CacheDtype::Int8`]; the default is f32.
    fn cache_dtype(&self) -> CacheDtype {
        CacheDtype::F32
    }

    /// Sparse-decode row budget (DESIGN.md S20): `Some(k)` when this
    /// engine attends only the top-k cache rows per step, `None` for
    /// exact dense attention. The server mirrors this into its
    /// selection stats and the scheduler config cross-checks it. Only
    /// the native runner implements sparse decode; the default is dense.
    fn sparse_k(&self) -> Option<usize> {
        None
    }

    /// (decode lanes, serving window) of this engine instance.
    fn serve_shape(&self) -> Result<(usize, usize)>;

    /// (batch, seq) this backend evaluates loss over.
    fn eval_shape(&self) -> Result<(usize, usize)>;

    /// Prefill a padded prompt batch `tokens [B*S]` with per-lane true
    /// lengths. Returns (last-position logits [B, vocab], cache slabs).
    fn prefill(
        &self,
        tokens: &[i32],
        true_len: &[i32],
    ) -> Result<(HostTensor, Vec<HostTensor>)>;

    /// [`Backend::prefill`] with per-lane relevance: the scheduler sets
    /// `fresh[i] == true` only for newly admitted lanes; the other lanes'
    /// outputs are never read (their live cache rows are preserved by the
    /// caller's splice). Backends that can skip stale lanes should — the
    /// native runner does; the default recomputes everything, which the
    /// static-shape PJRT artifacts do anyway.
    fn prefill_lanes(
        &self,
        tokens: &[i32],
        true_len: &[i32],
        fresh: &[bool],
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let _ = fresh;
        self.prefill(tokens, true_len)
    }

    /// True when this backend can resume a prefill mid-sequence
    /// ([`Backend::prefill_lanes_from`] with nonzero `start`), which the
    /// prefix radix cache needs. The native runner can; static-shape
    /// AOT artifacts cannot.
    fn supports_prefix_prefill(&self) -> bool {
        false
    }

    /// True when this backend can run chunked prefill (DESIGN.md S22):
    /// the scheduler advances a pending lane's prompt a fixed number of
    /// tokens per engine iteration via [`Backend::prefill_lanes_from`]
    /// with a moving start offset, writing directly into the live cache
    /// slabs. The machinery is exactly the mid-sequence resume the
    /// prefix radix cache needs, so the default mirrors
    /// [`Backend::supports_prefix_prefill`].
    fn supports_chunked_prefill(&self) -> bool {
        self.supports_prefix_prefill()
    }

    /// [`Backend::prefill_lanes`] resuming from cached prefixes: lane
    /// `i`'s positions `0..start[i]` are already present in the passed
    /// `caches` (spliced there by the scheduler from the prefix radix
    /// cache) and only `start[i]..true_len[i]` is computed, attending
    /// over the seeded rows. Returns the final-position logits and the
    /// caches with the computed suffix rows filled in.
    ///
    /// The default implementation only supports `start == 0` everywhere
    /// (it ignores the seeded caches and forwards to
    /// [`Backend::prefill_lanes`]); backends report real support via
    /// [`Backend::supports_prefix_prefill`].
    fn prefill_lanes_from(
        &self,
        tokens: &[i32],
        true_len: &[i32],
        fresh: &[bool],
        start: &[i32],
        caches: Vec<HostTensor>,
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        anyhow::ensure!(
            start.iter().all(|&s| s == 0),
            "this backend cannot resume a prefill mid-sequence \
             (prefix cache requires native serving)"
        );
        drop(caches);
        self.prefill_lanes(tokens, true_len, fresh)
    }

    /// One decode step over explicit caches. `pallas` requests the
    /// Pallas-lowered artifact where the backend has one (PJRT elitekv
    /// variants); other backends ignore it.
    fn decode(
        &self,
        token: &[i32],
        pos: &[i32],
        caches: Vec<HostTensor>,
        pallas: bool,
    ) -> Result<(HostTensor, Vec<HostTensor>)>;

    /// [`Backend::decode`] with per-lane liveness: lanes with
    /// `active[i] == false` carry a masked dummy whose output is never
    /// read, so backends that can skip them cheaply should override this
    /// (the native runner does). The default forwards to `decode` with
    /// dead lanes' token/pos sanitized to 0 — static-shape backends
    /// (PJRT) compute every lane regardless, and stale values must never
    /// index out of the embedding/cache gathers; dead-lane logit rows
    /// may still be garbage.
    fn decode_active(
        &self,
        token: &[i32],
        pos: &[i32],
        active: &[bool],
        caches: Vec<HostTensor>,
        pallas: bool,
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let token: Vec<i32> = token
            .iter()
            .zip(active)
            .map(|(&t, &a)| if a { t } else { 0 })
            .collect();
        let pos: Vec<i32> = pos
            .iter()
            .zip(active)
            .map(|(&p, &a)| if a { p } else { 0 })
            .collect();
        self.decode(&token, &pos, caches, pallas)
    }

    /// Zero-filled cache slabs matching this backend's serve shape.
    fn empty_caches(&self) -> Result<Vec<HostTensor>>;

    /// Summed NLL + token count over one batch (perplexity building block).
    fn eval_loss(&self, batch: &Batch) -> Result<(f64, f64)>;
}

/// Perplexity over `n_batches` freshly drawn eval batches (backend-generic
/// twin of `ModelRunner::perplexity`).
pub fn perplexity(
    backend: &dyn Backend,
    gen: &mut crate::data::CorpusGen,
    n_batches: usize,
) -> Result<f64> {
    let (b, t) = backend.eval_shape()?;
    let mut sum = 0.0;
    let mut count = 0.0;
    for _ in 0..n_batches {
        let batch = gen.next_batch(b, t);
        let (s, c) = backend.eval_loss(&batch)?;
        sum += s;
        count += c;
    }
    Ok((sum / count).exp())
}
