//! Engine: one PJRT CPU client + a compile cache of loaded executables.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::io::manifest::FnSpec;
use crate::runtime::host::HostTensor;

/// PJRT plumbing for [`HostTensor`] (defined backend-agnostically in
/// `runtime::host`; these methods only exist in `pjrt` builds).
impl HostTensor {
    /// Upload to a device buffer we own (freed on drop — unlike the
    /// crate's `execute(&[Literal])` path, which leaks its uploads).
    fn to_device(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        match self {
            HostTensor::F32(d, s) => client
                .buffer_from_host_buffer::<f32>(d, s, None)
                .map_err(|e| anyhow::anyhow!("upload f32: {e:?}")),
            HostTensor::I32(d, s) => client
                .buffer_from_host_buffer::<i32>(d, s, None)
                .map_err(|e| anyhow::anyhow!("upload i32: {e:?}")),
            HostTensor::Q8 { .. } => bail!(
                "quantized cache slabs never cross the PJRT boundary \
                 (--cache-dtype int8 is native-only)"
            ),
        }
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32(
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?,
                dims,
            )),
            xla::ElementType::S32 => Ok(HostTensor::I32(
                lit.to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?,
                dims,
            )),
            ty => bail!("unsupported output element type {ty:?}"),
        }
    }
}

/// A compiled artifact bound to its manifest signature.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Client handle used to create input buffers. NOTE: we deliberately
    /// route execution through `execute_b` with buffers we own — the
    /// crate's `execute(&[Literal])` path leaks every input device buffer
    /// (`buffer.release()` in xla_rs.cc:900 without a matching free),
    /// which at ~27 MB of inputs per train step exhausts memory in
    /// minutes. See EXPERIMENTS.md §Perf for the before/after.
    client: xla::PjRtClient,
    /// Signature from the manifest; `None` for ad-hoc loads.
    pub spec: Option<FnSpec>,
    pub name: String,
}

impl Executable {
    /// Execute with host tensors; returns the decomposed output tuple.
    /// Inputs are borrowed — uploads go straight from the caller's memory
    /// to device buffers without an intermediate host copy.
    pub fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        if let Some(spec) = &self.spec {
            if inputs.len() != spec.inputs.len() {
                bail!(
                    "{}: expected {} inputs, got {}",
                    self.name, spec.inputs.len(), inputs.len()
                );
            }
            for (i, (&t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
                if t.shape() != s.shape.as_slice() || t.dtype() != s.dtype {
                    bail!(
                        "{}: input {i} (`{}`) expects {:?} {:?}, got {:?} {:?}",
                        self.name, s.name, s.dtype, s.shape, t.dtype(),
                        t.shape()
                    );
                }
            }
        }
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| t.to_device(&self.client))
            .collect::<Result<_>>()?;
        let outs = self
            .exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow::anyhow!("{}: execute: {e:?}", self.name))?;
        drop(buffers); // inputs freed eagerly (outputs alias nothing)
        // aot.py lowers with return_tuple=True: one tuple output per replica.
        let tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{}: to_literal: {e:?}", self.name))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("{}: untuple: {e:?}", self.name))?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

/// PJRT CPU client + executable cache keyed by artifact path.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
}

impl Engine {
    pub fn new() -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Load and compile an HLO-text artifact (cached by path).
    pub fn load(
        &self,
        path: impl AsRef<Path>,
        spec: Option<FnSpec>,
    ) -> Result<Arc<Executable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(hit) = self.cache.lock().unwrap().get(&path) {
            return Ok(Arc::clone(hit));
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))?;
        log::debug!("compiled {path:?} in {:?}", t0.elapsed());
        let name = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        let out = Arc::new(Executable {
            exe,
            client: self.client.clone(),
            spec,
            name,
        });
        self.cache.lock().unwrap().insert(path, Arc::clone(&out));
        Ok(out)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
