//! Runtime layer: the [`Backend`] serving contract plus its engines.
//!
//! * [`backend`] — the trait every higher layer (coordinator, scorer,
//!   bench, CLI) programs against; see DESIGN.md §5.
//! * [`host`]    — `HostTensor`, the host-side exchange tensor.
//! * `engine` / `session` (feature `pjrt`) — the AOT path: load HLO
//!   *text* artifacts (DESIGN.md §3), compile once through the PJRT CPU
//!   client, execute many. aot.py lowers jax to stablehlo, converts to an
//!   XlaComputation and dumps `as_hlo_text()`; we parse with
//!   `HloModuleProto::from_text_file`, which reassigns instruction ids
//!   and sidesteps the 64-bit-id proto incompatibility between jax >= 0.5
//!   and xla_extension 0.5.1.
//!
//! The artifact-free native engine lives in [`crate::native`].

pub mod backend;
pub mod host;

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod session;

pub use backend::Backend;
pub use host::HostTensor;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, Executable};
#[cfg(feature = "pjrt")]
pub use session::{ModelRunner, PjrtBackend, PjrtView, TrainState};
