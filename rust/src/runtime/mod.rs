//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! The interchange format is HLO *text* (see DESIGN.md §3): aot.py lowers
//! jax to stablehlo, converts to an XlaComputation and dumps
//! `as_hlo_text()`; we parse with `HloModuleProto::from_text_file`, which
//! reassigns instruction ids and sidesteps the 64-bit-id proto
//! incompatibility between jax >= 0.5 and xla_extension 0.5.1.

pub mod engine;
pub mod session;

pub use engine::{Engine, Executable, HostTensor};
pub use session::{ModelRunner, TrainState};
