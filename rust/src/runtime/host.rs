//! Host-side tensors shared by every backend.
//!
//! `HostTensor` is the runtime's exchange type: the PJRT backend uploads
//! it to device buffers, the native backend computes on it directly, and
//! the serving coordinator splices cache rows through it either way.

use anyhow::{bail, Result};

use crate::io::manifest::Dtype;

/// A host-side tensor crossing the backend boundary.
#[derive(Clone, Debug)]
pub enum HostTensor {
    /// Dense f32 payload + shape (row-major).
    F32(Vec<f32>, Vec<usize>),
    /// Dense i32 payload + shape (row-major; token/position inputs).
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    /// Rank-0 f32 tensor.
    pub fn scalar_f32(x: f32) -> HostTensor {
        HostTensor::F32(vec![x], vec![])
    }

    /// Rank-0 i32 tensor.
    pub fn scalar_i32(x: i32) -> HostTensor {
        HostTensor::I32(vec![x], vec![])
    }

    /// Zero-filled f32 tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor::F32(vec![0.0; shape.iter().product()], shape.to_vec())
    }

    /// The tensor's shape (row-major dims).
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    /// Element dtype tag (manifest interchange).
    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(..) => Dtype::F32,
            HostTensor::I32(..) => Dtype::I32,
        }
    }

    /// Borrow the f32 payload; errors on an i32 tensor.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("expected f32 tensor, got i32"),
        }
    }

    /// Mutable f32 payload (native backend cache writes).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("expected f32 tensor, got i32"),
        }
    }

    /// Borrow the i32 payload; errors on an f32 tensor.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            _ => bail!("expected i32 tensor, got f32"),
        }
    }

    /// Scalar f32 value (accepts rank-0 or single-element tensors).
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, shape {:?}", self.shape());
        }
        Ok(d[0])
    }

    /// Convert from the offline `tensor::Tensor` (f32 only).
    pub fn from_tensor(t: &crate::tensor::Tensor) -> HostTensor {
        HostTensor::F32(t.data.clone(), t.shape.clone())
    }

    /// Convert into the offline `tensor::Tensor` (f32 only).
    pub fn to_tensor(&self) -> Result<crate::tensor::Tensor> {
        Ok(crate::tensor::Tensor::new(
            self.shape().to_vec(),
            self.as_f32()?.to_vec(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = HostTensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.numel(), 6);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn dtype_mismatch_is_error() {
        let t = HostTensor::scalar_i32(4);
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[4]);
    }

    #[test]
    fn mutable_access_round_trip() {
        let mut t = HostTensor::zeros(&[4]);
        t.as_f32_mut().unwrap()[2] = 7.0;
        assert_eq!(t.as_f32().unwrap()[2], 7.0);
    }
}
