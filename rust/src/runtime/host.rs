//! Host-side tensors shared by every backend.
//!
//! `HostTensor` is the runtime's exchange type: the PJRT backend uploads
//! it to device buffers, the native backend computes on it directly, and
//! the serving coordinator splices cache rows through it either way.

use anyhow::{bail, Result};

use crate::io::manifest::Dtype;

/// A host-side tensor crossing the backend boundary.
#[derive(Clone, Debug)]
pub enum HostTensor {
    /// Dense f32 payload + shape (row-major).
    F32(Vec<f32>, Vec<usize>),
    /// Dense i32 payload + shape (row-major; token/position inputs).
    I32(Vec<i32>, Vec<usize>),
    /// Group-quantized int8 tensor (DESIGN.md S19): the native backend's
    /// `--cache-dtype int8` slab storage. `shape` is the logical f32
    /// shape; `data` holds one i8 per logical element; `row` is the
    /// quantization row width (the contiguous span one token writes —
    /// `shape[3..].product()` for `[L,B,S,...]` cache slabs); `scales`
    /// holds `ceil(row/group)` f32 scales per row, row-major. Never
    /// produced by the PJRT path.
    Q8 {
        /// i8 payload, one element per logical f32 element.
        data: Vec<i8>,
        /// Per-row-group scales `[n_rows, ceil(row/group)]` flat.
        scales: Vec<f32>,
        /// Logical (f32-equivalent) shape.
        shape: Vec<usize>,
        /// Elements per quantization row.
        row: usize,
        /// Elements per scale group within a row.
        group: usize,
    },
}

impl HostTensor {
    /// Rank-0 f32 tensor.
    pub fn scalar_f32(x: f32) -> HostTensor {
        HostTensor::F32(vec![x], vec![])
    }

    /// Rank-0 i32 tensor.
    pub fn scalar_i32(x: i32) -> HostTensor {
        HostTensor::I32(vec![x], vec![])
    }

    /// Zero-filled f32 tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor::F32(vec![0.0; shape.iter().product()], shape.to_vec())
    }

    /// Zero-filled group-quantized int8 tensor: `row` elements per
    /// quantization row (must divide the total element count), `group`
    /// elements per scale group. All scales start at 0 (an all-zero
    /// row dequantizes to exact zeros).
    pub fn zeros_q8(shape: &[usize], row: usize, group: usize) -> HostTensor {
        let numel: usize = shape.iter().product();
        assert!(row > 0 && group > 0, "row/group must be positive");
        assert_eq!(numel % row, 0, "row {row} must tile shape {shape:?}");
        let n_rows = numel / row;
        HostTensor::Q8 {
            data: vec![0i8; numel],
            scales: vec![0.0f32; n_rows * row.div_ceil(group)],
            shape: shape.to_vec(),
            row,
            group,
        }
    }

    /// The tensor's shape (row-major dims; logical f32 shape for Q8).
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
            HostTensor::Q8 { shape, .. } => shape,
        }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    /// Element dtype tag (manifest interchange).
    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(..) => Dtype::F32,
            HostTensor::I32(..) => Dtype::I32,
            HostTensor::Q8 { .. } => Dtype::I8,
        }
    }

    /// Borrow the f32 payload; errors on an i32/q8 tensor.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("expected f32 tensor, got {:?}", self.dtype()),
        }
    }

    /// Mutable f32 payload (native backend cache writes).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("expected f32 tensor, got {:?}", self.dtype()),
        }
    }

    /// Borrow the i32 payload; errors otherwise.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            _ => bail!("expected i32 tensor, got {:?}", self.dtype()),
        }
    }

    /// Borrow the quantized payload: `(data, scales, row, group)`.
    /// Errors on dense tensors.
    pub fn as_q8(&self) -> Result<(&[i8], &[f32], usize, usize)> {
        match self {
            HostTensor::Q8 { data, scales, row, group, .. } => {
                Ok((data, scales, *row, *group))
            }
            _ => bail!("expected q8 tensor, got {:?}", self.dtype()),
        }
    }

    /// Mutable quantized payload: `(data, scales, row, group)`.
    pub fn as_q8_mut(
        &mut self,
    ) -> Result<(&mut [i8], &mut [f32], usize, usize)> {
        match self {
            HostTensor::Q8 { data, scales, row, group, .. } => {
                Ok((data, scales, *row, *group))
            }
            _ => bail!("expected q8 tensor, got {:?}", self.dtype()),
        }
    }

    /// True for the group-quantized int8 arm.
    pub fn is_q8(&self) -> bool {
        matches!(self, HostTensor::Q8 { .. })
    }

    /// Scalar f32 value (accepts rank-0 or single-element tensors).
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, shape {:?}", self.shape());
        }
        Ok(d[0])
    }

    /// Convert from the offline `tensor::Tensor` (f32 only).
    pub fn from_tensor(t: &crate::tensor::Tensor) -> HostTensor {
        HostTensor::F32(t.data.clone(), t.shape.clone())
    }

    /// Convert into the offline `tensor::Tensor` (f32 only).
    pub fn to_tensor(&self) -> Result<crate::tensor::Tensor> {
        Ok(crate::tensor::Tensor::new(
            self.shape().to_vec(),
            self.as_f32()?.to_vec(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = HostTensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.numel(), 6);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn dtype_mismatch_is_error() {
        let t = HostTensor::scalar_i32(4);
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[4]);
    }

    #[test]
    fn mutable_access_round_trip() {
        let mut t = HostTensor::zeros(&[4]);
        t.as_f32_mut().unwrap()[2] = 7.0;
        assert_eq!(t.as_f32().unwrap()[2], 7.0);
    }

    #[test]
    fn q8_geometry_and_access() {
        // [2, 1, 3, 8] slab, rows of 8 elements, groups of 4 -> 6 rows,
        // 2 scales each.
        let t = HostTensor::zeros_q8(&[2, 1, 3, 8], 8, 4);
        assert_eq!(t.shape(), &[2, 1, 3, 8]);
        assert_eq!(t.numel(), 48);
        assert!(t.is_q8());
        let (d, s, row, group) = t.as_q8().unwrap();
        assert_eq!((d.len(), s.len(), row, group), (48, 12, 8, 4));
        assert!(t.as_f32().is_err());
        assert_eq!(t.dtype(), Dtype::I8);
        let mut t = t;
        let (d, s, ..) = t.as_q8_mut().unwrap();
        d[9] = -3;
        s[2] = 0.5;
        let (d, s, ..) = t.as_q8().unwrap();
        assert_eq!((d[9], s[2]), (-3, 0.5));
    }
}
