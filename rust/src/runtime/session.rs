//! ModelRunner: a (config, variant) artifact family bound to the engine —
//! the typed façade every higher layer (trainer, search, converter,
//! serving coordinator, benches) talks to.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::data::corpus::Batch;
use crate::io::{Checkpoint, Manifest};
use crate::runtime::backend::Backend;
use crate::runtime::engine::{Engine, Executable};
use crate::runtime::host::HostTensor;

/// Parameters + AdamW state in manifest order.
pub struct TrainState {
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: i32,
}

impl TrainState {
    /// Fresh optimizer state around existing parameters.
    pub fn fresh(params: Vec<HostTensor>) -> TrainState {
        let zeros: Vec<HostTensor> =
            params.iter().map(|p| HostTensor::zeros(p.shape())).collect();
        TrainState { m: zeros.clone(), v: zeros, params, step: 0 }
    }
}

/// Typed access to one (config, variant) artifact family.
pub struct ModelRunner {
    pub engine: Arc<Engine>,
    pub manifest: Manifest,
    /// Variant extras (elite_mask / theta_e) in manifest order; must be
    /// set before running any model function when the variant has extras.
    extras: Vec<HostTensor>,
}

impl ModelRunner {
    pub fn new(
        engine: Arc<Engine>,
        artifacts: impl AsRef<Path>,
        config: &str,
        tag: &str,
    ) -> Result<ModelRunner> {
        let manifest = Manifest::load(artifacts, config, tag)?;
        Ok(ModelRunner { engine, manifest, extras: Vec::new() })
    }

    /// Install the variant extras (validated against the manifest).
    pub fn set_extras(&mut self, extras: Vec<HostTensor>) -> Result<()> {
        if extras.len() != self.manifest.extras.len() {
            bail!(
                "variant `{}` expects {} extras, got {}",
                self.manifest.variant.tag(),
                self.manifest.extras.len(),
                extras.len()
            );
        }
        for (t, (name, shape)) in extras.iter().zip(&self.manifest.extras) {
            if t.shape() != shape.as_slice() {
                bail!("extra `{name}` expects shape {shape:?}, got {:?}",
                      t.shape());
            }
        }
        self.extras = extras;
        Ok(())
    }

    fn need_extras(&self) -> Result<&[HostTensor]> {
        if self.extras.len() != self.manifest.extras.len() {
            bail!(
                "variant `{}` requires extras ({:?}) — call set_extras first",
                self.manifest.variant.tag(),
                self.manifest.extras.iter().map(|(n, _)| n).collect::<Vec<_>>()
            );
        }
        Ok(&self.extras)
    }

    pub fn exec(&self, name: &str) -> Result<Arc<Executable>> {
        let spec = self.manifest.function(name)?.clone();
        self.engine.load(self.manifest.hlo_path(name)?, Some(spec))
    }

    // ------------------------------------------------------------------
    // Parameter plumbing
    // ------------------------------------------------------------------

    /// Initialize parameters from the AOT init artifact.
    pub fn init(&self, seed: i32) -> Result<Vec<HostTensor>> {
        let seed_t = HostTensor::scalar_i32(seed);
        let outs = self.exec("init")?.run(&[&seed_t])?;
        Ok(outs)
    }

    /// Flatten a checkpoint into manifest parameter order.
    pub fn params_from_ckpt(&self, ckpt: &Checkpoint) -> Result<Vec<HostTensor>> {
        self.manifest
            .params
            .iter()
            .map(|(name, shape)| {
                let t = ckpt.get(name)?;
                if &t.shape != shape {
                    bail!("param `{name}`: checkpoint {:?} vs manifest {shape:?}",
                          t.shape);
                }
                Ok(HostTensor::from_tensor(t))
            })
            .collect()
    }

    /// Pack manifest-ordered params into a named checkpoint.
    pub fn ckpt_from_params(&self, params: &[HostTensor]) -> Result<Checkpoint> {
        let mut ckpt = Checkpoint::new();
        ckpt.set_meta("config", &self.manifest.config.name);
        ckpt.set_meta("variant", self.manifest.variant.tag());
        for ((name, _), t) in self.manifest.params.iter().zip(params) {
            ckpt.insert(name, t.to_tensor()?);
        }
        Ok(ckpt)
    }

    /// Extract one named parameter tensor from a manifest-ordered list.
    pub fn param<'a>(
        &self,
        params: &'a [HostTensor],
        name: &str,
    ) -> Result<&'a HostTensor> {
        let idx = self
            .manifest
            .params
            .iter()
            .position(|(n, _)| n == name)
            .with_context(|| format!("no param `{name}`"))?;
        Ok(&params[idx])
    }

    // ------------------------------------------------------------------
    // Training / evaluation
    // ------------------------------------------------------------------

    /// One AdamW step in-graph. Updates `state` in place; returns
    /// (loss, grad_norm).
    pub fn train_step(
        &self,
        state: &mut TrainState,
        batch: &Batch,
        lr: f32,
    ) -> Result<(f32, f32)> {
        let exe = self.exec("train_step")?;
        let extras = self.need_extras()?;
        let np = state.params.len();
        let step_t = HostTensor::scalar_i32(state.step);
        let lr_t = HostTensor::scalar_f32(lr);
        let tokens_t = HostTensor::I32(batch.tokens.clone(),
                                       vec![batch.batch, batch.seq]);
        let targets_t = HostTensor::I32(batch.targets.clone(),
                                        vec![batch.batch, batch.seq]);
        let mask_t = HostTensor::F32(batch.mask.clone(),
                                     vec![batch.batch, batch.seq]);
        let mut inputs: Vec<&HostTensor> =
            Vec::with_capacity(3 * np + 2 + extras.len() + 3);
        inputs.extend(state.params.iter());
        inputs.extend(state.m.iter());
        inputs.extend(state.v.iter());
        inputs.push(&step_t);
        inputs.push(&lr_t);
        inputs.extend(extras.iter());
        inputs.push(&tokens_t);
        inputs.push(&targets_t);
        inputs.push(&mask_t);
        let mut outs = exe.run(&inputs)?;
        // outputs: params*np, m*np, v*np, step, loss, gnorm
        let gnorm = outs.pop().context("gnorm")?.scalar()?;
        let loss = outs.pop().context("loss")?.scalar()?;
        let step = outs.pop().context("step")?;
        state.step = step.as_i32()?[0];
        state.v = outs.split_off(2 * np);
        state.m = outs.split_off(np);
        state.params = outs;
        Ok((loss, gnorm))
    }

    /// Summed NLL + token count over one batch.
    pub fn eval_loss(&self, params: &[HostTensor], batch: &Batch) -> Result<(f64, f64)> {
        let exe = self.exec("eval_loss")?;
        let extras = self.need_extras()?;
        let tokens_t = HostTensor::I32(batch.tokens.clone(),
                                       vec![batch.batch, batch.seq]);
        let targets_t = HostTensor::I32(batch.targets.clone(),
                                        vec![batch.batch, batch.seq]);
        let mask_t = HostTensor::F32(batch.mask.clone(),
                                     vec![batch.batch, batch.seq]);
        let mut inputs: Vec<&HostTensor> =
            Vec::with_capacity(params.len() + extras.len() + 3);
        inputs.extend(params.iter());
        inputs.extend(extras.iter());
        inputs.push(&tokens_t);
        inputs.push(&targets_t);
        inputs.push(&mask_t);
        let outs = exe.run(&inputs)?;
        Ok((outs[0].scalar()? as f64, outs[1].scalar()? as f64))
    }

    /// Perplexity over `n_batches` freshly drawn eval batches.
    pub fn perplexity(
        &self,
        params: &[HostTensor],
        gen: &mut crate::data::CorpusGen,
        n_batches: usize,
    ) -> Result<f64> {
        let (b, t) = self.eval_shape()?;
        let mut sum = 0.0;
        let mut count = 0.0;
        for _ in 0..n_batches {
            let batch = gen.next_batch(b, t);
            let (s, c) = self.eval_loss(params, &batch)?;
            sum += s;
            count += c;
        }
        Ok((sum / count).exp())
    }

    pub fn eval_shape(&self) -> Result<(usize, usize)> {
        let f = self.manifest.function("eval_loss")?;
        let tok = &f.inputs[f.input_index("tokens").context("tokens")?];
        Ok((tok.shape[0], tok.shape[1]))
    }

    // ------------------------------------------------------------------
    // Serving
    // ------------------------------------------------------------------

    /// Prefill a padded prompt batch. Returns (last-position logits
    /// [B, vocab], cache tensors).
    pub fn prefill(
        &self,
        params: &[HostTensor],
        tokens: &[i32],
        true_len: &[i32],
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let exe = self.exec("prefill")?;
        let (b, s) = self.manifest.serve_shape()?;
        if tokens.len() != b * s || true_len.len() != b {
            bail!("prefill expects tokens [{b},{s}] and true_len [{b}]");
        }
        let extras = self.need_extras()?;
        let tokens_t = HostTensor::I32(tokens.to_vec(), vec![b, s]);
        let len_t = HostTensor::I32(true_len.to_vec(), vec![b]);
        let mut inputs: Vec<&HostTensor> =
            Vec::with_capacity(params.len() + extras.len() + 2);
        inputs.extend(params.iter());
        inputs.extend(extras.iter());
        inputs.push(&tokens_t);
        inputs.push(&len_t);
        let mut outs = exe.run(&inputs)?;
        let caches = outs.split_off(1);
        Ok((outs.pop().unwrap(), caches))
    }

    /// One decode step over explicit caches. `pallas` selects the
    /// Pallas-lowered artifact where available (elitekv variants).
    pub fn decode(
        &self,
        params: &[HostTensor],
        token: &[i32],
        pos: &[i32],
        caches: Vec<HostTensor>,
        pallas: bool,
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let name = if pallas && self.manifest.functions.contains_key("decode_pallas")
        {
            "decode_pallas"
        } else {
            "decode"
        };
        let exe = self.exec(name)?;
        let (b, _s) = self.manifest.serve_shape()?;
        if token.len() != b || pos.len() != b {
            bail!("decode expects token/pos of length {b}");
        }
        let extras = self.need_extras()?;
        let token_t = HostTensor::I32(token.to_vec(), vec![b]);
        let pos_t = HostTensor::I32(pos.to_vec(), vec![b]);
        let mut inputs: Vec<&HostTensor> =
            Vec::with_capacity(params.len() + extras.len() + 2 + caches.len());
        inputs.extend(params.iter());
        inputs.extend(extras.iter());
        inputs.push(&token_t);
        inputs.push(&pos_t);
        inputs.extend(caches.iter());
        let mut outs = exe.run(&inputs)?;
        let caches = outs.split_off(1);
        Ok((outs.pop().unwrap(), caches))
    }

    /// Zero-filled cache tensors for the serving artifacts.
    pub fn empty_caches(&self) -> Result<Vec<HostTensor>> {
        let f = self.manifest.function("decode")?;
        Ok(f.inputs
            .iter()
            .filter(|t| t.name.starts_with("cache:"))
            .map(|t| HostTensor::zeros(&t.shape))
            .collect())
    }

    // ------------------------------------------------------------------
    // RoPElite search support (baseline mha artifacts only)
    // ------------------------------------------------------------------

    /// Per-layer pre-RoPE q/k on a calibration batch:
    /// returns (q [L,B,T,nh,dh], k [L,B,T,nh,dh]).
    pub fn capture_qk(
        &self,
        params: &[HostTensor],
        tokens: &[i32],
    ) -> Result<(HostTensor, HostTensor)> {
        let exe = self.exec("capture_qk")?;
        let f = self.manifest.function("capture_qk")?;
        let tok = &f.inputs[f.input_index("tokens").context("tokens")?];
        let (b, t) = (tok.shape[0], tok.shape[1]);
        if tokens.len() != b * t {
            bail!("capture_qk expects tokens [{b},{t}]");
        }
        let tokens_t = HostTensor::I32(tokens.to_vec(), vec![b, t]);
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.push(&tokens_t);
        let mut outs = exe.run(&inputs)?;
        let k = outs.pop().context("k_pre")?;
        let q = outs.pop().context("q_pre")?;
        Ok((q, k))
    }

    /// Algorithm-1 inner step for one layer: distances [nh, nc].
    pub fn ropelite_delta(
        &self,
        q_layer: &HostTensor,
        k_layer: &HostTensor,
        mask: &HostTensor,
    ) -> Result<HostTensor> {
        let exe = self.exec("ropelite_delta")?;
        let mut outs = exe.run(&[q_layer, k_layer, mask])?;
        Ok(outs.pop().context("distance")?)
    }

    /// Contribution baseline scores [L, nh, nc].
    pub fn contribution(
        &self,
        q: &HostTensor,
        k: &HostTensor,
    ) -> Result<HostTensor> {
        let exe = self.exec("contribution")?;
        let mut outs = exe.run(&[q, k])?;
        Ok(outs.pop().context("scores")?)
    }

    /// Borrowed [`Backend`] view over this runner + a parameter set
    /// (evaluation call sites that keep using the runner afterwards).
    pub fn as_backend<'a>(&'a self, params: &'a [HostTensor]) -> PjrtView<'a> {
        PjrtView { runner: self, params }
    }
}

// ---------------------------------------------------------------------------
// Backend adapters (DESIGN.md §5): the PJRT side of the serving contract.
// ---------------------------------------------------------------------------

/// Owned PJRT backend: a runner bound to one parameter set. This is what
/// the serving coordinator boxes when `--backend pjrt` is selected.
pub struct PjrtBackend {
    pub runner: ModelRunner,
    pub params: Vec<HostTensor>,
}

impl PjrtBackend {
    pub fn new(runner: ModelRunner, params: Vec<HostTensor>) -> PjrtBackend {
        PjrtBackend { runner, params }
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn config(&self) -> &crate::config::ModelConfig {
        &self.runner.manifest.config
    }

    fn variant(&self) -> &crate::config::Variant {
        &self.runner.manifest.variant
    }

    fn serve_shape(&self) -> Result<(usize, usize)> {
        self.runner.manifest.serve_shape()
    }

    fn eval_shape(&self) -> Result<(usize, usize)> {
        self.runner.eval_shape()
    }

    fn prefill(
        &self,
        tokens: &[i32],
        true_len: &[i32],
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        self.runner.prefill(&self.params, tokens, true_len)
    }

    fn decode(
        &self,
        token: &[i32],
        pos: &[i32],
        caches: Vec<HostTensor>,
        pallas: bool,
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        self.runner.decode(&self.params, token, pos, caches, pallas)
    }

    fn empty_caches(&self) -> Result<Vec<HostTensor>> {
        self.runner.empty_caches()
    }

    fn eval_loss(&self, batch: &Batch) -> Result<(f64, f64)> {
        self.runner.eval_loss(&self.params, batch)
    }
}

/// Borrowed PJRT backend view (see [`ModelRunner::as_backend`]).
pub struct PjrtView<'a> {
    pub runner: &'a ModelRunner,
    pub params: &'a [HostTensor],
}

impl Backend for PjrtView<'_> {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn config(&self) -> &crate::config::ModelConfig {
        &self.runner.manifest.config
    }

    fn variant(&self) -> &crate::config::Variant {
        &self.runner.manifest.variant
    }

    fn serve_shape(&self) -> Result<(usize, usize)> {
        self.runner.manifest.serve_shape()
    }

    fn eval_shape(&self) -> Result<(usize, usize)> {
        self.runner.eval_shape()
    }

    fn prefill(
        &self,
        tokens: &[i32],
        true_len: &[i32],
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        self.runner.prefill(self.params, tokens, true_len)
    }

    fn decode(
        &self,
        token: &[i32],
        pos: &[i32],
        caches: Vec<HostTensor>,
        pallas: bool,
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        self.runner.decode(self.params, token, pos, caches, pallas)
    }

    fn empty_caches(&self) -> Result<Vec<HostTensor>> {
        self.runner.empty_caches()
    }

    fn eval_loss(&self, batch: &Batch) -> Result<(f64, f64)> {
        self.runner.eval_loss(self.params, batch)
    }
}

