//! [`NativeRunner`]: the native [`Backend`] — batched prefill/decode over
//! the latent cache slabs, artifact-free.
//!
//! Prefill runs lanes in parallel on the in-repo thread pool (each lane
//! builds a private `[L,1,S,...]` slab set, spliced into the batch slabs
//! afterwards); decode steps the lanes sequentially in one pass. Both are
//! exact incremental attention, so `decode(prefill(n)) == prefill(n+1)`
//! holds to f32 noise (pinned in rust/tests/native_e2e.rs).

use anyhow::{bail, ensure, Result};

use crate::config::{ModelConfig, Variant};
use crate::data::corpus::Batch;
use crate::native::model::NativeModel;
use crate::runtime::{Backend, HostTensor};
use crate::util::threadpool::parallel_map;

/// Native serving engine: a model bound to a fixed lane/window geometry.
pub struct NativeRunner {
    pub model: NativeModel,
    batch: usize,
    max_seq: usize,
}

impl NativeRunner {
    /// `batch` decode lanes over a `max_seq` serving window.
    pub fn new(model: NativeModel, batch: usize, max_seq: usize) -> Result<NativeRunner> {
        ensure!(batch > 0, "need at least one decode lane");
        ensure!(
            max_seq > 1 && max_seq <= model.cfg.max_seq,
            "max_seq {max_seq} outside (1, {}]",
            model.cfg.max_seq
        );
        Ok(NativeRunner { model, batch, max_seq })
    }

    /// Default serving geometry mirroring the AOT artifacts (4 lanes,
    /// config window capped at 256).
    pub fn with_defaults(model: NativeModel) -> Result<NativeRunner> {
        let window = model.cfg.max_seq.min(256);
        NativeRunner::new(model, 4, window)
    }

    fn threads(&self) -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(self.batch)
    }
}

impl Backend for NativeRunner {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn config(&self) -> &ModelConfig {
        &self.model.cfg
    }

    fn variant(&self) -> &Variant {
        &self.model.variant
    }

    fn serve_shape(&self) -> Result<(usize, usize)> {
        Ok((self.batch, self.max_seq))
    }

    fn eval_shape(&self) -> Result<(usize, usize)> {
        Ok((2, self.model.cfg.max_seq.min(128)))
    }

    fn prefill(
        &self,
        tokens: &[i32],
        true_len: &[i32],
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let fresh = vec![true; self.batch];
        self.prefill_lanes(tokens, true_len, &fresh)
    }

    /// Native prefill computes ONLY the lanes the scheduler marked fresh:
    /// one full forward per admitted request, zero work for lanes that
    /// are idle or mid-decode (their slab rows stay zero and the caller's
    /// splice never reads them).
    fn prefill_lanes(
        &self,
        tokens: &[i32],
        true_len: &[i32],
        fresh: &[bool],
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let (b, s) = (self.batch, self.max_seq);
        if tokens.len() != b * s || true_len.len() != b || fresh.len() != b {
            bail!(
                "prefill expects tokens [{b},{s}], true_len [{b}], \
                 fresh [{b}]"
            );
        }
        for (lane, &len) in true_len.iter().enumerate() {
            if fresh[lane] && (len < 1 || len as usize > s) {
                bail!("lane {lane}: true_len {len} outside [1, {s}]");
            }
        }
        // Per-lane prefill in parallel: each fresh lane fills a
        // [L,1,S,...] slab set and reports its last-position logits.
        let lane_results: Vec<Result<Option<(Vec<f32>, Vec<HostTensor>)>>> =
            parallel_map(b, self.threads(), |lane| {
                if !fresh[lane] {
                    return Ok(None);
                }
                let len = true_len[lane] as usize;
                let mut caches = self.model.empty_caches(1, s);
                let mut sc = self.model.scratch();
                let mut last = None;
                for i in 0..len {
                    let tok = tokens[lane * s + i];
                    if tok < 0 {
                        bail!("lane {lane}: negative token at {i}");
                    }
                    last = self.model.decode_token_with(
                        &mut sc,
                        &mut caches,
                        0,
                        i,
                        tok as u32,
                        i + 1 == len,
                    )?;
                }
                let logits =
                    last.ok_or_else(|| anyhow::anyhow!("empty prompt"))?;
                Ok(Some((logits, caches)))
            });

        let mut logits = vec![0.0f32; b * self.model.cfg.vocab];
        let mut batch_caches = self.empty_caches()?;
        for (lane, res) in lane_results.into_iter().enumerate() {
            let Some((row, lane_caches)) = res? else { continue };
            let vocab = self.model.cfg.vocab;
            logits[lane * vocab..(lane + 1) * vocab].copy_from_slice(&row);
            for (dst, src) in batch_caches.iter_mut().zip(&lane_caches) {
                splice_lane_from_single(dst, src, lane)?;
            }
        }
        Ok((
            HostTensor::F32(logits, vec![b, self.model.cfg.vocab]),
            batch_caches,
        ))
    }

    fn decode(
        &self,
        token: &[i32],
        pos: &[i32],
        caches: Vec<HostTensor>,
        pallas: bool,
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let active = vec![true; self.batch];
        self.decode_active(token, pos, &active, caches, pallas)
    }

    /// Native decode skips dead lanes entirely — one full forward per
    /// *live* request per step (their logit rows stay zero, never read).
    fn decode_active(
        &self,
        token: &[i32],
        pos: &[i32],
        active: &[bool],
        caches: Vec<HostTensor>,
        _pallas: bool,
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let b = self.batch;
        if token.len() != b || pos.len() != b || active.len() != b {
            bail!("decode expects token/pos/active of length {b}");
        }
        let mut caches = caches;
        let vocab = self.model.cfg.vocab;
        let mut logits = vec![0.0f32; b * vocab];
        let mut sc = self.model.scratch();
        for lane in 0..b {
            if !active[lane] {
                continue;
            }
            ensure!(pos[lane] >= 0, "negative position on lane {lane}");
            ensure!(token[lane] >= 0, "negative token on lane {lane}");
            let row = self
                .model
                .decode_token_with(
                    &mut sc,
                    &mut caches,
                    lane,
                    pos[lane] as usize,
                    token[lane] as u32,
                    true,
                )?
                .expect("logits requested");
            logits[lane * vocab..(lane + 1) * vocab].copy_from_slice(&row);
        }
        Ok((HostTensor::F32(logits, vec![b, vocab]), caches))
    }

    fn empty_caches(&self) -> Result<Vec<HostTensor>> {
        Ok(self.model.empty_caches(self.batch, self.max_seq))
    }

    fn eval_loss(&self, batch: &Batch) -> Result<(f64, f64)> {
        ensure!(batch.tokens.len() == batch.batch * batch.seq,
                "ragged batch");
        let rows: Vec<Result<(f64, f64)>> = parallel_map(
            batch.batch,
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(batch.batch),
            |row| {
                let t = batch.seq;
                let mut caches = self.model.empty_caches(1, t);
                let mut sc = self.model.scratch();
                let mut sum = 0.0f64;
                let mut count = 0.0f64;
                for i in 0..t {
                    let tok = batch.tokens[row * t + i];
                    ensure!(tok >= 0, "negative token");
                    // The cache write must happen even for masked
                    // positions; the vocab-wide logits only when scored.
                    let m = batch.mask[row * t + i] as f64;
                    let logits = self.model.decode_token_with(
                        &mut sc, &mut caches, 0, i, tok as u32, m != 0.0)?;
                    if m == 0.0 {
                        continue;
                    }
                    let logits = logits.expect("logits requested");
                    let target = batch.targets[row * t + i] as usize;
                    ensure!(target < logits.len(), "target out of vocab");
                    let max = logits
                        .iter()
                        .cloned()
                        .fold(f32::NEG_INFINITY, f32::max)
                        as f64;
                    let logz: f64 = max
                        + logits
                            .iter()
                            .map(|&x| ((x as f64) - max).exp())
                            .sum::<f64>()
                            .ln();
                    sum += (logz - logits[target] as f64) * m;
                    count += m;
                }
                Ok((sum, count))
            },
        );
        let mut sum = 0.0;
        let mut count = 0.0;
        for r in rows {
            let (s, c) = r?;
            sum += s;
            count += c;
        }
        Ok((sum, count))
    }
}

/// Copy layer rows from a single-lane slab `[L,1,S,...]` into lane `lane`
/// of a batch slab `[L,B,S,...]`.
fn splice_lane_from_single(
    dst: &mut HostTensor,
    src: &HostTensor,
    lane: usize,
) -> Result<()> {
    let dshape = dst.shape().to_vec();
    let sshape = src.shape().to_vec();
    ensure!(
        dshape.len() == sshape.len()
            && dshape[0] == sshape[0]
            && sshape[1] == 1
            && dshape[2..] == sshape[2..],
        "slab splice mismatch: {dshape:?} vs {sshape:?}"
    );
    let (layers, b) = (dshape[0], dshape[1]);
    ensure!(lane < b, "lane {lane} out of {b}");
    let row: usize = dshape[2..].iter().product();
    let d = dst.as_f32_mut()?;
    let s = src.as_f32()?;
    for l in 0..layers {
        let doff = (l * b + lane) * row;
        let soff = l * row;
        d[doff..doff + row].copy_from_slice(&s[soff..soff + row]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::uniform_selection;

    fn native_tiny(var: Variant, r: Option<usize>) -> NativeRunner {
        let cfg = ModelConfig::tiny();
        let sel = r.map(|r| uniform_selection(&cfg, r));
        let model = NativeModel::init(&cfg, var, 11, sel.as_ref()).unwrap();
        NativeRunner::new(model, 2, 32).unwrap()
    }

    #[test]
    fn prefill_shapes_and_decode_round() {
        let runner = native_tiny(Variant::EliteKv { r: 4, d_ckv: 64 }, Some(4));
        let (b, s) = runner.serve_shape().unwrap();
        let mut tokens = vec![0i32; b * s];
        for lane in 0..b {
            for i in 0..6 {
                tokens[lane * s + i] = (3 + lane + i) as i32;
            }
        }
        let lens = vec![6i32; b];
        let (logits, caches) = runner.prefill(&tokens, &lens).unwrap();
        assert_eq!(logits.shape(), &[b, 512]);
        let (l2, _caches) = runner
            .decode(&vec![5i32; b], &vec![6i32; b], caches, false)
            .unwrap();
        assert_eq!(l2.shape(), &[b, 512]);
        assert!(l2.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn eval_loss_near_uniform_at_init() {
        let runner = native_tiny(Variant::Mha, None);
        let mut gen = crate::data::CorpusGen::new(512, 1);
        let (b, t) = (2, 32);
        let batch = gen.next_batch(b, t);
        let (sum, count) = runner.eval_loss(&batch).unwrap();
        let nll = sum / count;
        assert!((nll - (512f64).ln()).abs() < 0.5, "init nll {nll}");
    }

    #[test]
    fn prefill_lanes_skips_stale_lanes() {
        let runner = native_tiny(Variant::EliteKv { r: 4, d_ckv: 64 }, Some(4));
        let (b, s) = runner.serve_shape().unwrap();
        assert_eq!(b, 2);
        let mut tokens = vec![0i32; b * s];
        for lane in 0..b {
            for i in 0..5 {
                tokens[lane * s + i] = (2 + lane + 2 * i) as i32;
            }
        }
        let lens = vec![5i32; b];
        let (full, _) = runner.prefill(&tokens, &lens).unwrap();
        let (masked, caches) = runner
            .prefill_lanes(&tokens, &lens, &[true, false])
            .unwrap();
        let vocab = runner.config().vocab;
        // fresh lane identical to the full prefill...
        assert_eq!(
            &masked.as_f32().unwrap()[..vocab],
            &full.as_f32().unwrap()[..vocab]
        );
        // ...skipped lane untouched: zero logits and zero cache rows
        assert!(masked.as_f32().unwrap()[vocab..].iter().all(|&x| x == 0.0));
        for slab in &caches {
            let d = slab.as_f32().unwrap();
            let shape = slab.shape();
            let row: usize = shape[2..].iter().product();
            for l in 0..shape[0] {
                let off = (l * shape[1] + 1) * row;
                assert!(d[off..off + row].iter().all(|&x| x == 0.0));
            }
        }
        // stale-lane lengths are not validated (they may be stale too)
        let (bad_len_ok, _) = runner
            .prefill_lanes(&tokens, &[5, 0], &[true, false])
            .unwrap();
        assert_eq!(bad_len_ok.shape(), &[b, vocab]);
    }

    #[test]
    fn prefill_validates_lengths() {
        let runner = native_tiny(Variant::Mha, None);
        let (b, s) = runner.serve_shape().unwrap();
        let tokens = vec![0i32; b * s];
        assert!(runner.prefill(&tokens, &vec![0i32; b]).is_err());
        assert!(runner.prefill(&tokens, &vec![(s + 1) as i32; b]).is_err());
        assert!(runner.prefill(&tokens[1..], &vec![1i32; b]).is_err());
    }
}
