//! [`NativeRunner`]: the native [`Backend`] — batched prefill/decode over
//! the latent cache slabs, artifact-free.
//!
//! Both prefill and decode run through the batched kernel path
//! ([`NativeModel::decode_batch`]): all fresh/active lanes' hidden
//! states stack into one activation matrix, so every projection (and
//! the J-LRD absorbed latent attention) is a single panel-parallel GEMM
//! per layer instead of `lanes × matvec` (DESIGN.md S17). Prefill walks
//! positions step-synchronized across the fresh lanes; lanes whose
//! prompt has ended simply drop out of later steps. Dead/stale lanes
//! are never touched — their logit rows and cache rows stay zero.
//!
//! Both paths are exact incremental attention, so
//! `decode(prefill(n)) == prefill(n+1)` holds to f32 noise (pinned in
//! rust/tests/native_e2e.rs), and every lane's output is independent of
//! which other lanes share the batch (pinned in
//! rust/tests/batched_decode.rs and rust/tests/scheduler.rs).

use std::sync::Mutex;

use anyhow::{bail, ensure, Result};

use crate::config::{ModelConfig, Variant};
use crate::data::corpus::Batch;
use crate::kvcache::CacheDtype;
use crate::native::model::{BatchScratch, LaneStep, NativeModel};
use crate::runtime::{Backend, HostTensor};
use crate::util::threadpool::parallel_map;

/// Native serving engine: a model bound to a fixed lane/window geometry.
pub struct NativeRunner {
    /// The underlying weights + batched/scalar forward steps.
    pub model: NativeModel,
    batch: usize,
    max_seq: usize,
    /// Reusable batched-activation buffers shared by prefill and decode
    /// (the [`Backend`] API is `&self`, so interior mutability; the lock
    /// is held for one batched step at a time, which only serializes
    /// concurrent forward calls on the *same* runner instance).
    scratch: Mutex<BatchScratch>,
}

impl NativeRunner {
    /// `batch` decode lanes over a `max_seq` serving window.
    pub fn new(model: NativeModel, batch: usize, max_seq: usize) -> Result<NativeRunner> {
        ensure!(batch > 0, "need at least one decode lane");
        ensure!(
            max_seq > 1 && max_seq <= model.cfg.max_seq,
            "max_seq {max_seq} outside (1, {}]",
            model.cfg.max_seq
        );
        let scratch = Mutex::new(model.batch_scratch(batch));
        Ok(NativeRunner { model, batch, max_seq, scratch })
    }

    /// Default serving geometry mirroring the AOT artifacts (4 lanes,
    /// config window capped at 256).
    pub fn with_defaults(model: NativeModel) -> Result<NativeRunner> {
        let window = model.cfg.max_seq.min(256);
        NativeRunner::new(model, 4, window)
    }

    /// Name of the kernel ISA this runner's GEMMs dispatch to
    /// (`scalar` / `avx2` / `neon` — DESIGN.md S23): runtime detection
    /// combined with the `ELITEKV_KERNEL_ISA` override, resolved once
    /// per process by [`crate::native::simd::active`]. Surfaced so
    /// serving stats and bench rows can report which inner loops
    /// actually ran.
    pub fn kernel_isa(&self) -> &'static str {
        crate::native::simd::active().name()
    }

    /// Worker-thread cap handed to the kernel layer; the kernels
    /// themselves scale workers down to the FLOP volume of each GEMM
    /// ([`crate::native::kernels::gemm_threads`]), so this is an upper
    /// bound, not a demand.
    fn threads(&self) -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

impl Backend for NativeRunner {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn config(&self) -> &ModelConfig {
        &self.model.cfg
    }

    fn variant(&self) -> &Variant {
        &self.model.variant
    }

    fn cache_dtype(&self) -> CacheDtype {
        self.model.cache_dtype
    }

    fn sparse_k(&self) -> Option<usize> {
        self.model.sparse_k
    }

    fn serve_shape(&self) -> Result<(usize, usize)> {
        Ok((self.batch, self.max_seq))
    }

    fn eval_shape(&self) -> Result<(usize, usize)> {
        Ok((2, self.model.cfg.max_seq.min(128)))
    }

    fn prefill(
        &self,
        tokens: &[i32],
        true_len: &[i32],
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let fresh = vec![true; self.batch];
        self.prefill_lanes(tokens, true_len, &fresh)
    }

    /// Native prefill computes ONLY the lanes the scheduler marked fresh,
    /// and computes them *together*: at every prompt position the live
    /// lanes' rows stack into one batched step, so the projections run
    /// as GEMMs across the whole admission wave instead of lane-by-lane.
    /// Non-fresh lanes cost zero work — their slab rows and logit rows
    /// stay zero and the caller's splice never reads them.
    fn prefill_lanes(
        &self,
        tokens: &[i32],
        true_len: &[i32],
        fresh: &[bool],
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let caches = self.empty_caches()?;
        self.prefill_lanes_from(
            tokens,
            true_len,
            fresh,
            &vec![0i32; self.batch],
            caches,
        )
    }

    fn supports_prefix_prefill(&self) -> bool {
        true
    }

    /// [`NativeRunner::prefill_lanes`] with per-lane start offsets: lane
    /// `i` skips its first `start[i]` prompt positions — those rows were
    /// spliced into `caches` from the prefix radix cache by the caller —
    /// and computes only `start[i]..true_len[i]`, attending over the
    /// seeded prefix rows exactly as a from-scratch prefill would. The
    /// kernel determinism contract (row `i` of a batched step depends
    /// only on row `i`; DESIGN.md S17) makes a resumed prefill
    /// bitwise-identical to a full one given identical prefix rows.
    fn prefill_lanes_from(
        &self,
        tokens: &[i32],
        true_len: &[i32],
        fresh: &[bool],
        start: &[i32],
        caches: Vec<HostTensor>,
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let (b, s) = (self.batch, self.max_seq);
        if tokens.len() != b * s
            || true_len.len() != b
            || fresh.len() != b
            || start.len() != b
        {
            bail!(
                "prefill expects tokens [{b},{s}], true_len [{b}], \
                 fresh [{b}], start [{b}]"
            );
        }
        let mut max_len = 0usize;
        let mut n_fresh = 0usize;
        for (lane, &len) in true_len.iter().enumerate() {
            if !fresh[lane] {
                continue;
            }
            if len < 1 || len as usize > s {
                bail!("lane {lane}: true_len {len} outside [1, {s}]");
            }
            let st = start[lane];
            if st < 0 || st >= len {
                bail!(
                    "lane {lane}: start {st} outside [0, {len}) — at \
                     least the final prompt position must be computed"
                );
            }
            for i in st as usize..len as usize {
                if tokens[lane * s + i] < 0 {
                    bail!("lane {lane}: negative token at {i}");
                }
            }
            max_len = max_len.max(len as usize);
            n_fresh += 1;
        }
        let vocab = self.model.cfg.vocab;
        let mut logits = vec![0.0f32; b * vocab];
        let mut caches = caches;
        if n_fresh == 0 {
            return Ok((HostTensor::F32(logits, vec![b, vocab]), caches));
        }
        let threads = self.threads();
        let mut sc = self.scratch.lock().unwrap();
        let mut steps = Vec::with_capacity(n_fresh);
        for i in 0..max_len {
            steps.clear();
            for lane in 0..b {
                let len = true_len[lane] as usize;
                if !fresh[lane] || i >= len || i < start[lane] as usize {
                    continue;
                }
                steps.push(LaneStep {
                    lane,
                    pos: i,
                    token: tokens[lane * s + i] as u32,
                    want_logits: i + 1 == len,
                });
            }
            let rows = self
                .model
                .decode_batch(&mut sc, &mut caches, &steps, threads)?;
            for (st, row) in steps.iter().zip(rows) {
                if let Some(r) = row {
                    logits[st.lane * vocab..(st.lane + 1) * vocab]
                        .copy_from_slice(&r);
                }
            }
        }
        Ok((HostTensor::F32(logits, vec![b, vocab]), caches))
    }

    fn decode(
        &self,
        token: &[i32],
        pos: &[i32],
        caches: Vec<HostTensor>,
        pallas: bool,
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let active = vec![true; self.batch];
        self.decode_active(token, pos, &active, caches, pallas)
    }

    /// Native decode skips dead lanes entirely and advances the live
    /// lanes as ONE batched kernel step: their hidden states stack into
    /// a single activation matrix, so QKV / attention-output / MLP
    /// projections and the absorbed latent attention run as one GEMM per
    /// layer instead of `lanes × matvec`. Dead lanes' logit rows stay
    /// zero (never read); zero live lanes is a cheap no-op.
    fn decode_active(
        &self,
        token: &[i32],
        pos: &[i32],
        active: &[bool],
        caches: Vec<HostTensor>,
        _pallas: bool,
    ) -> Result<(HostTensor, Vec<HostTensor>)> {
        let b = self.batch;
        if token.len() != b || pos.len() != b || active.len() != b {
            bail!("decode expects token/pos/active of length {b}");
        }
        let mut caches = caches;
        let vocab = self.model.cfg.vocab;
        let mut logits = vec![0.0f32; b * vocab];
        let mut steps = Vec::with_capacity(b);
        for lane in 0..b {
            if !active[lane] {
                continue;
            }
            ensure!(pos[lane] >= 0, "negative position on lane {lane}");
            ensure!(token[lane] >= 0, "negative token on lane {lane}");
            steps.push(LaneStep {
                lane,
                pos: pos[lane] as usize,
                token: token[lane] as u32,
                want_logits: true,
            });
        }
        if !steps.is_empty() {
            let mut sc = self.scratch.lock().unwrap();
            let rows = self.model.decode_batch(
                &mut sc,
                &mut caches,
                &steps,
                self.threads(),
            )?;
            for (st, row) in steps.iter().zip(rows) {
                let row = row.expect("logits requested");
                logits[st.lane * vocab..(st.lane + 1) * vocab]
                    .copy_from_slice(&row);
            }
        }
        Ok((HostTensor::F32(logits, vec![b, vocab]), caches))
    }

    fn empty_caches(&self) -> Result<Vec<HostTensor>> {
        Ok(self.model.empty_caches(self.batch, self.max_seq))
    }

    fn eval_loss(&self, batch: &Batch) -> Result<(f64, f64)> {
        ensure!(batch.tokens.len() == batch.batch * batch.seq,
                "ragged batch");
        let rows: Vec<Result<(f64, f64)>> = parallel_map(
            batch.batch,
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(batch.batch),
            |row| {
                let t = batch.seq;
                let mut caches = self.model.empty_caches(1, t);
                let mut sc = self.model.scratch();
                let mut sum = 0.0f64;
                let mut count = 0.0f64;
                for i in 0..t {
                    let tok = batch.tokens[row * t + i];
                    ensure!(tok >= 0, "negative token");
                    // The cache write must happen even for masked
                    // positions; the vocab-wide logits only when scored.
                    let m = batch.mask[row * t + i] as f64;
                    let logits = self.model.decode_token_with(
                        &mut sc, &mut caches, 0, i, tok as u32, m != 0.0)?;
                    if m == 0.0 {
                        continue;
                    }
                    let logits = logits.expect("logits requested");
                    let target = batch.targets[row * t + i] as usize;
                    ensure!(target < logits.len(), "target out of vocab");
                    let max = logits
                        .iter()
                        .cloned()
                        .fold(f32::NEG_INFINITY, f32::max)
                        as f64;
                    let logz: f64 = max
                        + logits
                            .iter()
                            .map(|&x| ((x as f64) - max).exp())
                            .sum::<f64>()
                            .ln();
                    sum += (logz - logits[target] as f64) * m;
                    count += m;
                }
                Ok((sum, count))
            },
        );
        let mut sum = 0.0;
        let mut count = 0.0;
        for r in rows {
            let (s, c) = r?;
            sum += s;
            count += c;
        }
        Ok((sum, count))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::uniform_selection;

    fn native_tiny(var: Variant, r: Option<usize>) -> NativeRunner {
        let cfg = ModelConfig::tiny();
        let sel = r.map(|r| uniform_selection(&cfg, r));
        let model = NativeModel::init(&cfg, var, 11, sel.as_ref()).unwrap();
        NativeRunner::new(model, 2, 32).unwrap()
    }

    #[test]
    fn prefill_shapes_and_decode_round() {
        let runner = native_tiny(Variant::EliteKv { r: 4, d_ckv: 64 }, Some(4));
        let (b, s) = runner.serve_shape().unwrap();
        let mut tokens = vec![0i32; b * s];
        for lane in 0..b {
            for i in 0..6 {
                tokens[lane * s + i] = (3 + lane + i) as i32;
            }
        }
        let lens = vec![6i32; b];
        let (logits, caches) = runner.prefill(&tokens, &lens).unwrap();
        assert_eq!(logits.shape(), &[b, 512]);
        let (l2, _caches) = runner
            .decode(&vec![5i32; b], &vec![6i32; b], caches, false)
            .unwrap();
        assert_eq!(l2.shape(), &[b, 512]);
        assert!(l2.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn eval_loss_near_uniform_at_init() {
        let runner = native_tiny(Variant::Mha, None);
        let mut gen = crate::data::CorpusGen::new(512, 1);
        let (b, t) = (2, 32);
        let batch = gen.next_batch(b, t);
        let (sum, count) = runner.eval_loss(&batch).unwrap();
        let nll = sum / count;
        assert!((nll - (512f64).ln()).abs() < 0.5, "init nll {nll}");
    }

    #[test]
    fn prefill_lanes_skips_stale_lanes() {
        let runner = native_tiny(Variant::EliteKv { r: 4, d_ckv: 64 }, Some(4));
        let (b, s) = runner.serve_shape().unwrap();
        assert_eq!(b, 2);
        let mut tokens = vec![0i32; b * s];
        for lane in 0..b {
            for i in 0..5 {
                tokens[lane * s + i] = (2 + lane + 2 * i) as i32;
            }
        }
        let lens = vec![5i32; b];
        let (full, _) = runner.prefill(&tokens, &lens).unwrap();
        let (masked, caches) = runner
            .prefill_lanes(&tokens, &lens, &[true, false])
            .unwrap();
        let vocab = runner.config().vocab;
        // fresh lane identical to the full prefill...
        assert_eq!(
            &masked.as_f32().unwrap()[..vocab],
            &full.as_f32().unwrap()[..vocab]
        );
        // ...skipped lane untouched: zero logits and zero cache rows
        assert!(masked.as_f32().unwrap()[vocab..].iter().all(|&x| x == 0.0));
        for slab in &caches {
            let d = slab.as_f32().unwrap();
            let shape = slab.shape();
            let row: usize = shape[2..].iter().product();
            for l in 0..shape[0] {
                let off = (l * shape[1] + 1) * row;
                assert!(d[off..off + row].iter().all(|&x| x == 0.0));
            }
        }
        // stale-lane lengths are not validated (they may be stale too)
        let (bad_len_ok, _) = runner
            .prefill_lanes(&tokens, &[5, 0], &[true, false])
            .unwrap();
        assert_eq!(bad_len_ok.shape(), &[b, vocab]);
    }

    /// Seeding a lane's prefix rows and resuming the prefill mid-prompt
    /// must reproduce the from-scratch prefill bitwise (the contract the
    /// prefix radix cache's differential suite rides on).
    #[test]
    fn resumed_prefill_matches_full_prefill_bitwise() {
        let runner = native_tiny(Variant::EliteKv { r: 4, d_ckv: 64 }, Some(4));
        let (b, s) = runner.serve_shape().unwrap();
        let mut tokens = vec![0i32; b * s];
        for lane in 0..b {
            for i in 0..9 {
                tokens[lane * s + i] = (2 + 3 * lane + i) as i32;
            }
        }
        let lens = vec![9i32; b];
        let (full_logits, full_caches) =
            runner.prefill(&tokens, &lens).unwrap();
        // Seed fresh caches with the first 4 positions of each lane from
        // the full run, then resume at start = 4.
        let mut seeded = runner.empty_caches().unwrap();
        for (dst, src) in seeded.iter_mut().zip(&full_caches) {
            let shape = src.shape().to_vec();
            let (l_n, b_n, s_n) = (shape[0], shape[1], shape[2]);
            let w: usize = shape[3..].iter().product();
            let d = dst.as_f32_mut().unwrap();
            let sr = src.as_f32().unwrap();
            for l in 0..l_n {
                for lane in 0..b_n {
                    for p in 0..4 {
                        let off = ((l * b_n + lane) * s_n + p) * w;
                        d[off..off + w].copy_from_slice(&sr[off..off + w]);
                    }
                }
            }
        }
        let (res_logits, res_caches) = runner
            .prefill_lanes_from(
                &tokens,
                &lens,
                &vec![true; b],
                &vec![4i32; b],
                seeded,
            )
            .unwrap();
        assert_eq!(
            full_logits.as_f32().unwrap(),
            res_logits.as_f32().unwrap(),
            "resumed prefill logits diverge from full prefill"
        );
        for (f, r) in full_caches.iter().zip(&res_caches) {
            assert_eq!(f.as_f32().unwrap(), r.as_f32().unwrap());
        }
        // start == len is rejected (nothing left to compute)
        let err = runner
            .prefill_lanes_from(
                &tokens,
                &lens,
                &vec![true; b],
                &vec![9i32; b],
                runner.empty_caches().unwrap(),
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("final prompt position"), "{err}");
    }

    #[test]
    fn prefill_validates_lengths() {
        let runner = native_tiny(Variant::Mha, None);
        let (b, s) = runner.serve_shape().unwrap();
        let tokens = vec![0i32; b * s];
        assert!(runner.prefill(&tokens, &vec![0i32; b]).is_err());
        assert!(runner.prefill(&tokens, &vec![(s + 1) as i32; b]).is_err());
        assert!(runner.prefill(&tokens[1..], &vec![1i32; b]).is_err());
    }
}
