//! SIMD GEMM microkernels with runtime ISA dispatch (DESIGN.md S23).
//!
//! The S17 panel kernels accumulate every output element in a fixed
//! `k`-ascending order; this module supplies the *inner* loops of that
//! scheme — the contiguous AXPY, its fused-dequant twin, and the
//! contiguous dot product — in three interchangeable implementations:
//!
//! * [`Isa::Scalar`] — the portable reference, line-for-line the loops
//!   the S17 kernels shipped with. Selecting it reproduces the
//!   pre-SIMD results **bitwise**.
//! * [`Isa::Avx2`] — AVX2 + FMA on `x86_64`, 8 lanes per op.
//! * [`Isa::Neon`] — NEON on `aarch64`, 4 lanes per op.
//!
//! # Dispatch
//!
//! [`detect`] probes the host once ([`std::arch`] feature detection) and
//! [`active`] caches the winner in an atomic, so the per-call cost of
//! dispatch is one relaxed load. The `ELITEKV_KERNEL_ISA` environment
//! variable ([`KERNEL_ISA_ENV`]) overrides detection; invalid or
//! host-unsupported values warn on stderr and fall back to detection,
//! matching the `ELITEKV_PROP_CASES` convention. [`resolve`] is the
//! pure core of that policy so the override is unit-testable without
//! touching process state; [`force`] pins the ISA directly for
//! differential tests and benches.
//!
//! # Determinism contract (S23)
//!
//! Within one ISA, every microkernel is a pure function of its operand
//! values with a fixed internal operation order — no
//! data-dependent shortcuts, no lane-count changes at runtime — so the
//! S17 guarantees survive unchanged per ISA: `1 thread ≡ N threads`
//! bitwise, row independence, call-to-call identical results, and the
//! fused-dequant kernels bitwise-equal to dequantize-then-f32. *Across*
//! ISAs, FMA contraction and horizontal-sum reassociation make results
//! differ in the last bits; SIMD ≡ scalar is pinned within the S23
//! tolerance by `rust/tests/simd_kernels.rs`, never assumed bitwise.

use crate::kvcache::quant::dequant;
use std::sync::atomic::{AtomicU8, Ordering};

mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;

#[cfg(target_arch = "aarch64")]
mod neon;

/// Environment variable overriding ISA dispatch
/// (`scalar` | `avx2` | `neon`).
pub const KERNEL_ISA_ENV: &str = "ELITEKV_KERNEL_ISA";

/// An instruction-set choice for the GEMM inner microkernels.
///
/// All variants exist on every build target so tests and the env
/// override can *name* any ISA anywhere; whether the host can *run* one
/// is a separate, runtime question answered by [`supported`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Isa {
    /// Portable scalar reference — the pre-SIMD S17 inner loops, verbatim.
    Scalar = 0,
    /// AVX2 + FMA (`x86_64`), 8 f32 lanes.
    Avx2 = 1,
    /// NEON (`aarch64`), 4 f32 lanes.
    Neon = 2,
}

impl Isa {
    /// Every ISA this build knows how to *name* (not necessarily run).
    pub const ALL: [Isa; 3] = [Isa::Scalar, Isa::Avx2, Isa::Neon];

    /// The lowercase name used by `ELITEKV_KERNEL_ISA`, stats, and
    /// bench rows.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse a (case-insensitive) ISA name; `None` if unrecognized.
    pub fn from_name(raw: &str) -> Option<Isa> {
        Isa::ALL
            .into_iter()
            .find(|isa| isa.name().eq_ignore_ascii_case(raw))
    }

    fn from_u8(raw: u8) -> Isa {
        match raw {
            1 => Isa::Avx2,
            2 => Isa::Neon,
            _ => Isa::Scalar,
        }
    }
}

/// Whether this host can execute `isa`'s microkernels. [`Isa::Scalar`]
/// is always supported; the vector ISAs require both the matching build
/// target and the runtime CPU features.
pub fn supported(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        Isa::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        Isa::Neon => {
            #[cfg(target_arch = "aarch64")]
            {
                std::arch::is_aarch64_feature_detected!("neon")
            }
            #[cfg(not(target_arch = "aarch64"))]
            {
                false
            }
        }
    }
}

/// The widest ISA this host supports (probed fresh on every call;
/// [`active`] caches it).
pub fn detect() -> Isa {
    if supported(Isa::Avx2) {
        Isa::Avx2
    } else if supported(Isa::Neon) {
        Isa::Neon
    } else {
        Isa::Scalar
    }
}

/// Pure dispatch policy: combine the raw `ELITEKV_KERNEL_ISA` value
/// (`None` when unset) with the detected ISA. Returns the ISA to use
/// plus the warning to print when the override is unparsable or names
/// an ISA this host cannot run — in both cases detection stands, the
/// same warn-and-fall-back convention as `ELITEKV_PROP_CASES`.
pub fn resolve(raw: Option<&str>, detected: Isa) -> (Isa, Option<String>) {
    let Some(raw) = raw else { return (detected, None) };
    let trimmed = raw.trim();
    match Isa::from_name(trimmed) {
        Some(isa) if supported(isa) => (isa, None),
        Some(isa) => (
            detected,
            Some(format!(
                "warning: ignoring {KERNEL_ISA_ENV}=`{trimmed}` \
                 ({} not supported on this host); using {}",
                isa.name(),
                detected.name(),
            )),
        ),
        None => (
            detected,
            Some(format!(
                "warning: ignoring unparsable {KERNEL_ISA_ENV}=`{trimmed}` \
                 (want scalar|avx2|neon); using {}",
                detected.name(),
            )),
        ),
    }
}

/// Sentinel meaning "not resolved yet" in [`ACTIVE`].
const ISA_UNSET: u8 = u8::MAX;

/// The resolved ISA, cached after the first [`active`] call.
static ACTIVE: AtomicU8 = AtomicU8::new(ISA_UNSET);

/// The ISA the dispatched microkernels run on: detection combined with
/// the `ELITEKV_KERNEL_ISA` override via [`resolve`], computed once and
/// cached (so the env var is read once per process and the steady-state
/// cost is one relaxed atomic load).
pub fn active() -> Isa {
    let raw = ACTIVE.load(Ordering::Relaxed);
    if raw != ISA_UNSET {
        return Isa::from_u8(raw);
    }
    let env = std::env::var(KERNEL_ISA_ENV).ok();
    let (isa, warning) = resolve(env.as_deref(), detect());
    if let Some(msg) = warning {
        eprintln!("{msg}");
    }
    // Racing first calls compute the same value, so a plain store is a
    // benign last-writer-wins.
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    isa
}

/// Pin [`active`] to `isa` for the rest of the process (differential
/// tests and scalar-vs-SIMD bench twins). Returns `false` — leaving the
/// current choice untouched — when this host cannot run `isa`.
pub fn force(isa: Isa) -> bool {
    if !supported(isa) {
        return false;
    }
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    true
}

/// `dst[j] += av * src[j]` — the panel AXPY of `sgemm`/`sgemm_raw`,
/// dispatched on `isa` (callers hoist [`active`] once per GEMM call).
/// Per-element accumulation order is independent of how callers split
/// `dst`, provided splits land on [`AXPY_BLOCK`]-multiples.
pub fn axpy(isa: Isa, dst: &mut [f32], src: &[f32], av: f32) {
    match isa {
        Isa::Scalar => scalar::axpy(dst, src, av),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: dispatch only carries Avx2 past `supported()` — via
        // `detect`/`resolve`/`force` — so avx2+fma are present.
        Isa::Avx2 => unsafe { avx2::axpy(dst, src, av) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: dispatch only carries Neon past `supported()`.
        Isa::Neon => unsafe { neon::axpy(dst, src, av) },
        #[allow(unreachable_patterns)] // arms the cfg'd ISAs leave behind
        _ => scalar::axpy(dst, src, av),
    }
}

/// `c[i] = Σ_j a[j]·b[j]` — the contiguous dot of `sgemm_nt`,
/// dispatched on `isa`. The vector paths keep per-lane partial sums and
/// reduce them in a fixed lane order, so the result is deterministic
/// per ISA but *reassociated* relative to scalar (S23: toleranced, not
/// bitwise, across ISAs).
pub fn dot(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    match isa {
        Isa::Scalar => scalar::dot(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: dispatch only carries Avx2 past `supported()`.
        Isa::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: dispatch only carries Neon past `supported()`.
        Isa::Neon => unsafe { neon::dot(a, b) },
        #[allow(unreachable_patterns)]
        _ => scalar::dot(a, b),
    }
}

/// Dequant staging width for [`axpy_q8`]: a multiple of every ISA's
/// lane count, so splitting an AXPY at block boundaries preserves each
/// element's operation sequence exactly.
const AXPY_BLOCK: usize = 64;

/// `dst[jj] += av * dequant(q_row[jj], s_row[(j0 + jj) / group])` — the
/// fused-dequant panel AXPY of `sgemm_q8`. Weights are dequantized into
/// an `AXPY_BLOCK` stack buffer and consumed by [`axpy`] on the same
/// ISA: dequantization is a single correctly-rounded multiply per
/// element (identical scalar or vector), so the result stays **bitwise
/// identical** to dequantize-the-window-then-f32-AXPY *within every
/// ISA* — the S19 contract survives dispatch for any `group`/alignment.
pub fn axpy_q8(
    isa: Isa,
    dst: &mut [f32],
    q_row: &[i8],
    s_row: &[f32],
    group: usize,
    j0: usize,
    av: f32,
) {
    debug_assert_eq!(dst.len(), q_row.len());
    let mut tmp = [0.0f32; AXPY_BLOCK];
    let mut off = 0;
    while off < dst.len() {
        let bw = (dst.len() - off).min(AXPY_BLOCK);
        for jj in 0..bw {
            tmp[jj] = dequant(q_row[off + jj], s_row[(j0 + off + jj) / group]);
        }
        axpy(isa, &mut dst[off..off + bw], &tmp[..bw], av);
        off += bw;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn names_round_trip_and_parse_case_insensitively() {
        for isa in Isa::ALL {
            assert_eq!(Isa::from_name(isa.name()), Some(isa));
            assert_eq!(Isa::from_name(&isa.name().to_uppercase()), Some(isa));
        }
        assert_eq!(Isa::from_name("sse9"), None);
        assert_eq!(Isa::from_name(""), None);
    }

    #[test]
    fn resolve_unset_uses_detection() {
        for isa in Isa::ALL {
            assert_eq!(resolve(None, isa), (isa, None));
        }
    }

    #[test]
    fn resolve_accepts_supported_override() {
        // Scalar is supported everywhere, so forcing it must always work
        // regardless of what detection picked.
        let (isa, warn) = resolve(Some("scalar"), detect());
        assert_eq!(isa, Isa::Scalar);
        assert!(warn.is_none());
        let (isa, warn) = resolve(Some("  SCALAR  "), detect());
        assert_eq!(isa, Isa::Scalar);
        assert!(warn.is_none());
    }

    #[test]
    fn resolve_warns_and_falls_back_on_garbage() {
        let detected = detect();
        let (isa, warn) = resolve(Some("sse9"), detected);
        assert_eq!(isa, detected);
        let msg = warn.expect("garbage override must warn");
        assert!(msg.contains(KERNEL_ISA_ENV), "warning names the env var");
        assert!(msg.contains("sse9"), "warning echoes the raw value");
    }

    #[test]
    fn resolve_warns_and_falls_back_on_unsupported_isa() {
        let detected = detect();
        let foreign = Isa::ALL
            .into_iter()
            .find(|&isa| !supported(isa))
            .expect("no build target supports every ISA at once");
        let (isa, warn) = resolve(Some(foreign.name()), detected);
        assert_eq!(isa, detected);
        let msg = warn.expect("unsupported override must warn");
        assert!(msg.contains(foreign.name()));
        assert!(msg.contains("not supported"));
    }

    #[test]
    fn detect_is_supported_and_force_rejects_foreign_isas() {
        assert!(supported(detect()), "detect() must pick a runnable ISA");
        assert!(supported(Isa::Scalar), "scalar is always runnable");
        for isa in Isa::ALL {
            if !supported(isa) {
                assert!(!force(isa), "force must reject {isa:?}");
            }
        }
    }

    #[test]
    fn scalar_axpy_matches_reference_loop_bitwise() {
        let (src, mut dst) = (randv(37, 1), randv(37, 2));
        let mut want = dst.clone();
        let av = 0.37f32;
        for (cv, &wv) in want.iter_mut().zip(&src) {
            *cv += av * wv; // the S17 inner loop, verbatim
        }
        axpy(Isa::Scalar, &mut dst, &src, av);
        assert_eq!(dst, want);
    }

    #[test]
    fn scalar_dot_matches_forward_dot_bitwise() {
        let (a, b) = (randv(129, 3), randv(129, 4));
        assert_eq!(dot(Isa::Scalar, &a, &b), crate::native::forward::dot(&a, &b));
    }

    #[test]
    fn dispatched_axpy_and_dot_stay_close_to_scalar() {
        // The real SIMD ≡ scalar pin lives in rust/tests/simd_kernels.rs;
        // this is the in-module smoke version on the detected ISA.
        let isa = detect();
        let (a, b) = (randv(1000, 5), randv(1000, 6));
        let scalar = dot(Isa::Scalar, &a, &b);
        let vector = dot(isa, &a, &b);
        assert!(
            (scalar - vector).abs() <= 1e-6 * 1001.0,
            "dot diverged: {scalar} vs {vector} on {isa:?}"
        );
        let mut d_s = randv(100, 7);
        let mut d_v = d_s.clone();
        axpy(Isa::Scalar, &mut d_s, &a[..100], 0.5);
        axpy(isa, &mut d_v, &a[..100], 0.5);
        for (s, v) in d_s.iter().zip(&d_v) {
            assert!((s - v).abs() <= 1e-6, "axpy diverged: {s} vs {v}");
        }
    }

    #[test]
    fn axpy_q8_equals_dequantize_then_axpy_on_every_supported_isa() {
        let group = 32usize;
        // 70 columns: ragged tail group AND a ragged vector tail.
        let n = 70usize;
        let w = randv(n, 8);
        let g = crate::kvcache::quant::n_groups(n, group);
        let mut q = vec![0i8; n];
        let mut s = vec![0.0f32; g];
        crate::kvcache::quant::quantize_row(&w, group, &mut q, &mut s);
        let mut deq = vec![0.0f32; n];
        crate::kvcache::quant::dequantize_row(&q, &s, group, &mut deq);
        for isa in Isa::ALL.into_iter().filter(|&isa| supported(isa)) {
            let mut got = randv(n, 9);
            let mut want = got.clone();
            axpy_q8(isa, &mut got, &q, &s, group, 0, 1.25);
            axpy(isa, &mut want, &deq, 1.25);
            assert_eq!(got, want, "fused dequant diverged on {isa:?}");
        }
    }

    #[test]
    fn axpy_q8_honors_group_offset() {
        // j0 = 64 with group 32: the scale index starts at group 2, the
        // panel case sgemm_q8 actually exercises.
        let group = 32usize;
        let (n, j0) = (8usize, 64usize);
        let q: Vec<i8> = (0..n as i8).collect();
        let s = [1.0f32, 1.0, 0.5];
        let mut dst = vec![0.0f32; n];
        axpy_q8(Isa::Scalar, &mut dst, &q, &s, group, j0, 1.0);
        for (jj, &d) in dst.iter().enumerate() {
            assert_eq!(d, (jj as f32) * 0.5);
        }
    }
}
