//! NEON microkernels (`aarch64`, 4 f32 lanes).
//!
//! Same structure and S23 determinism posture as the AVX2 file: fused
//! multiply-add per element in the AXPY, 4 running lane sums reduced in
//! ascending lane order in the dot, scalar tails — deterministic per
//! ISA, toleranced (not bitwise) against scalar.
//!
//! Every entry is `unsafe fn`: callers must guarantee the `neon` CPU
//! feature, which the dispatch front does by routing only
//! `supported()`-checked ISAs here.

#![deny(unsafe_op_in_unsafe_fn)]

use core::arch::aarch64::{
    vdupq_n_f32, vfmaq_f32, vgetq_lane_f32, vld1q_f32, vst1q_f32,
};

/// f32 lanes per NEON vector op.
pub const LANES: usize = 4;

/// `dst[j] += av * src[j]` over 4-lane FMA chunks, scalar mul-add tail.
///
// SAFETY: the caller must guarantee the CPU supports neon
// (the dispatch front only routes `supported()` ISAs here).
#[target_feature(enable = "neon")]
pub unsafe fn axpy(dst: &mut [f32], src: &[f32], av: f32) {
    let n = dst.len().min(src.len());
    // SAFETY: splat has no memory operand; neon is up per the fn contract.
    let va = unsafe { vdupq_n_f32(av) };
    let mut j = 0;
    while j + LANES <= n {
        // SAFETY: `j + LANES <= n` bounds every lane inside both slices;
        // vld1q/vst1q accept unaligned pointers.
        unsafe {
            let w = vld1q_f32(src.as_ptr().add(j));
            let d = vld1q_f32(dst.as_ptr().add(j));
            vst1q_f32(dst.as_mut_ptr().add(j), vfmaq_f32(d, va, w));
        }
        j += LANES;
    }
    for (cv, &wv) in dst[j..n].iter_mut().zip(&src[j..n]) {
        *cv += av * wv;
    }
}

/// Dot product: 4 running lane sums via FMA, reduced in ascending lane
/// order, then the scalar tail folded in sequentially.
///
// SAFETY: same as `axpy` — neon must be available.
#[target_feature(enable = "neon")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    // SAFETY: register-only zero; neon is up per the fn contract.
    let mut acc = unsafe { vdupq_n_f32(0.0) };
    let mut j = 0;
    while j + LANES <= n {
        // SAFETY: `j + LANES <= n` bounds every lane inside both slices.
        unsafe {
            let x = vld1q_f32(a.as_ptr().add(j));
            let y = vld1q_f32(b.as_ptr().add(j));
            acc = vfmaq_f32(acc, x, y);
        }
        j += LANES;
    }
    // SAFETY: constant lane indices 0..4 are in range for a float32x4_t.
    let mut s = unsafe {
        let mut t = vgetq_lane_f32::<0>(acc);
        t += vgetq_lane_f32::<1>(acc);
        t += vgetq_lane_f32::<2>(acc);
        t += vgetq_lane_f32::<3>(acc);
        t
    };
    for (&x, &y) in a[j..n].iter().zip(&b[j..n]) {
        s += x * y;
    }
    s
}
