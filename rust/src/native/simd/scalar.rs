//! Portable scalar microkernels — the S17 inner loops, kept verbatim.
//!
//! These are the *reference* implementations: [`super::Isa::Scalar`]
//! must reproduce the pre-SIMD kernel layer bitwise, so each loop here
//! is the exact expression the panel kernels inlined before dispatch
//! existed (`sgemm_raw`'s AXPY and `forward::dot`'s mul-then-add fold).
//! Every other ISA is pinned against these within the S23 tolerance.

/// `dst[j] += av * src[j]`, one multiply and one add per element in
/// ascending `j` — the original `sgemm_raw` panel AXPY.
pub fn axpy(dst: &mut [f32], src: &[f32], av: f32) {
    for (cv, &wv) in dst.iter_mut().zip(src) {
        *cv += av * wv;
    }
}

/// Sequential mul-then-add dot fold from index 0 — the original
/// [`crate::native::forward::dot`], reproduced so the scalar ISA is
/// self-contained.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}
