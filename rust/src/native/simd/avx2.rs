//! AVX2 + FMA microkernels (`x86_64`, 8 f32 lanes).
//!
//! Operation order is fixed: AXPY fuses multiply-add per element (one
//! rounding where scalar takes two), and the dot keeps 8 running lane
//! sums reduced in ascending lane order before the scalar tail. Both
//! are deterministic for given inputs — the S23 contract — but neither
//! matches scalar bitwise (FMA contraction / sum reassociation).
//!
//! Every entry is `unsafe fn`: callers must guarantee the `avx2` and
//! `fma` CPU features, which the dispatch front does by routing only
//! `supported()`-checked ISAs here.

#![deny(unsafe_op_in_unsafe_fn)]

use core::arch::x86_64::{
    _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps,
    _mm256_storeu_ps,
};

/// f32 lanes per AVX2 vector op.
pub const LANES: usize = 8;

/// `dst[j] += av * src[j]` over 8-lane FMA chunks, scalar mul-add tail.
///
// SAFETY: the caller must guarantee the CPU supports avx2 and
// fma (the dispatch front only routes `supported()` ISAs here).
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn axpy(dst: &mut [f32], src: &[f32], av: f32) {
    let n = dst.len().min(src.len());
    // SAFETY: splat has no memory operand; avx2 is up per the fn contract.
    let va = unsafe { _mm256_set1_ps(av) };
    let mut j = 0;
    while j + LANES <= n {
        // SAFETY: `j + LANES <= n` bounds every lane inside both slices;
        // loadu/storeu accept unaligned pointers.
        unsafe {
            let w = _mm256_loadu_ps(src.as_ptr().add(j));
            let d = _mm256_loadu_ps(dst.as_ptr().add(j));
            _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_fmadd_ps(va, w, d));
        }
        j += LANES;
    }
    for (cv, &wv) in dst[j..n].iter_mut().zip(&src[j..n]) {
        *cv += av * wv;
    }
}

/// Dot product: 8 running lane sums via FMA, reduced in ascending lane
/// order, then the scalar tail folded in sequentially.
///
// SAFETY: same as `axpy` — avx2+fma must be available.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    // SAFETY: register-only zero; avx2 is up per the fn contract.
    let mut acc = unsafe { _mm256_setzero_ps() };
    let mut j = 0;
    while j + LANES <= n {
        // SAFETY: `j + LANES <= n` bounds every lane inside both slices.
        unsafe {
            let x = _mm256_loadu_ps(a.as_ptr().add(j));
            let y = _mm256_loadu_ps(b.as_ptr().add(j));
            acc = _mm256_fmadd_ps(x, y, acc);
        }
        j += LANES;
    }
    let mut lanes = [0.0f32; LANES];
    // SAFETY: `lanes` is exactly LANES f32s; storeu takes unaligned ptrs.
    unsafe { _mm256_storeu_ps(lanes.as_mut_ptr(), acc) };
    let mut s = 0.0f32;
    for &l in &lanes {
        s += l;
    }
    for (&x, &y) in a[j..n].iter().zip(&b[j..n]) {
        s += x * y;
    }
    s
}
